"""L2: zap-lm — the JAX model whose KV cache KVzap prunes.

A byte-level GQA transformer (RoPE + RMSNorm + SwiGLU — the Qwen3/Llama-3
architectural family, scaled for single-core CPU pretraining, DESIGN.md §2).
All attention goes through the L1 Pallas kernels; the same code path is

  * trained at build time (train.py),
  * probed for KVzip+ oracle scores (kvzip_plus_scores → surrogate targets),
  * AOT-lowered to the HLO artifacts the rust coordinator executes
    (prefill / decode / kvzip_score, see aot.py).

Prefill returns, besides the KV cache, every per-position statistic the rust
pruning policies consume; decode consumes a dense masked cache and returns
the updated cache plus the per-step statistics (surrogate scores, vnorm,
attention row). Python never runs on the request path.
"""

import jax
import jax.numpy as jnp

from .config import MODEL, OBS_WINDOW, ModelConfig
from .kernels import (
    attention_with_stats,
    decode_attention,
    surrogate_linear,
    surrogate_mlp,
)

# ---------------------------------------------------------------------------
# Parameters


def init_params(key, cfg: ModelConfig = MODEL):
    """Initialize zap-lm + surrogate parameters (layer-stacked for lax.scan)."""
    L, Dh, Di = cfg.n_layers, cfg.d_model, cfg.d_int
    Hq, Hkv, D, Dm = cfg.n_q_heads, cfg.n_kv_heads, cfg.d_head, cfg.d_surrogate
    V = cfg.vocab
    ks = jax.random.split(key, 12)

    def norm_init(k, shape, fan_in):
        return (jax.random.normal(k, shape) / jnp.sqrt(fan_in)).astype(jnp.float32)

    return {
        "embed": 0.02 * jax.random.normal(ks[0], (V, Dh)).astype(jnp.float32),
        "layers": {
            "ln1": jnp.ones((L, Dh), jnp.float32),
            "ln2": jnp.ones((L, Dh), jnp.float32),
            "wq": norm_init(ks[1], (L, Dh, Hq * D), Dh),
            "wk": norm_init(ks[2], (L, Dh, Hkv * D), Dh),
            "wv": norm_init(ks[3], (L, Dh, Hkv * D), Dh),
            "wo": norm_init(ks[4], (L, Hq * D, Dh), Hq * D),
            "wg": norm_init(ks[5], (L, Dh, Di), Dh),
            "wu": norm_init(ks[6], (L, Dh, Di), Dh),
            "wd": norm_init(ks[7], (L, Di, Dh), Di),
        },
        "ln_f": jnp.ones((Dh,), jnp.float32),
        "w_out": norm_init(ks[8], (Dh, V), Dh),
        "surrogate": {
            "lin_w": jnp.zeros((L, Dh, Hkv), jnp.float32),
            "lin_b": jnp.zeros((L, Hkv), jnp.float32),
            "mlp_w1": norm_init(ks[9], (L, Dh, Dm), Dh),
            "mlp_b1": jnp.zeros((L, Dm), jnp.float32),
            "mlp_w2": jnp.zeros((L, Dm, Hkv), jnp.float32),
            "mlp_b2": jnp.zeros((L, Hkv), jnp.float32),
        },
    }


def model_param_count(params) -> int:
    lm = {k: v for k, v in params.items() if k != "surrogate"}
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(lm))


def surrogate_param_count(params, kind: str) -> int:
    s = params["surrogate"]
    if kind == "linear":
        return int(s["lin_w"].size + s["lin_b"].size)
    return int(s["mlp_w1"].size + s["mlp_b1"].size
               + s["mlp_w2"].size + s["mlp_b2"].size)


# ---------------------------------------------------------------------------
# Building blocks


def rmsnorm(x, g, eps=MODEL.rms_eps):
    return x * g * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)


def rope_tables(positions, cfg: ModelConfig = MODEL):
    """cos/sin tables [T, D/2] for absolute integer positions."""
    half = cfg.d_head // 2
    freqs = cfg.rope_theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x [..., T, D] split-half rotation; cos/sin [T, D/2]."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def swiglu(h, wg, wu, wd):
    return (jax.nn.silu(h @ wg) * (h @ wu)) @ wd


def head_vnorm(v_heads, wo, cfg: ModelConfig = MODEL):
    """||W_O v_i|| per (kv-head, group-head, position) — the Eq. 3 factor.

    v_heads: [Hkv, T, D]; wo: [Hq*D, Dh]. Returns [Hkv, G, T]: the norm of
    each query-head's W_O slice applied to the kv-head's value.
    """
    G, D, Dh = cfg.group, cfg.d_head, cfg.d_model
    wo_h = wo.reshape(cfg.n_kv_heads, G, D, Dh)       # query-head slices
    contrib = jnp.einsum("htd,hgde->hgte", v_heads, wo_h)
    return jnp.linalg.norm(contrib, axis=-1)          # [Hkv, G, T]


# ---------------------------------------------------------------------------
# Prefill


def _layer_prefill(h, layer, cos, sin, true_len, stats_from, win_from,
                   cfg: ModelConfig, want_stats: bool = True):
    """One transformer layer over [T, Dh]; returns (h_out, per-layer stats)."""
    Hq, Hkv, D, G = cfg.n_q_heads, cfg.n_kv_heads, cfg.d_head, cfg.group
    T = h.shape[0]

    # KVzap surrogate scores are predicted from the layer *input* hidden
    # states (paper §3.3) — one or two matmuls, the whole of Criterion 1.
    if want_stats:
        s_lin = surrogate_linear(h, layer["slin_w"], layer["slin_b"])    # [T,Hkv]
        s_mlp = surrogate_mlp(h, layer["smlp_w1"], layer["smlp_b1"],
                              layer["smlp_w2"], layer["smlp_b2"])
    hnorm = jnp.linalg.norm(h, axis=-1)                                  # [T]
    hnorm_inv = 1.0 / jnp.maximum(hnorm, 1e-6)

    x = rmsnorm(h, layer["ln1"])
    q = (x @ layer["wq"]).reshape(T, Hq, D).transpose(1, 0, 2)           # [Hq,T,D]
    k = (x @ layer["wk"]).reshape(T, Hkv, D).transpose(1, 0, 2)          # [Hkv,T,D]
    v = (x @ layer["wv"]).reshape(T, Hkv, D).transpose(1, 0, 2)
    q = apply_rope(q, cos, sin) / jnp.sqrt(D).astype(jnp.float32)
    k = apply_rope(k, cos, sin)

    qg = q.reshape(Hkv, G, T, D)
    out, max_attn, maxn_attn, cum_attn, win_attn = jax.vmap(
        lambda qh, kh, vh: attention_with_stats(
            qh, kh, vh, hnorm_inv, true_len, stats_from, win_from)
    )(qg, k, v)
    # out: [Hkv, G, T, D] -> [T, Hq*D]
    out = out.reshape(Hq, T, D).transpose(1, 0, 2).reshape(T, Hq * D)
    h = h + out @ layer["wo"]
    h = h + swiglu(rmsnorm(h, layer["ln2"]),
                   layer["wg"], layer["wu"], layer["wd"])

    if not want_stats:
        return h, None
    vnorm_g = head_vnorm(v, layer["wo"], cfg)                            # [Hkv,G,T]
    stats = {
        "k": k, "v": v,                                                  # [Hkv,T,D]
        "score_lin": s_lin.T, "score_mlp": s_mlp.T,                      # [Hkv,T]
        # KVzip Eq. 1 (max over queries and over the GQA group):
        "max_attn": jnp.max(max_attn, axis=1),                           # [Hkv,T]
        # KVzip+ Eq. 3: max over group of (max_j a_ji/||h_j||) * ||W_O v_i||
        "plus_attn": jnp.max(maxn_attn * vnorm_g, axis=1),               # [Hkv,T]
        "cum_attn": cum_attn,                                            # [Hkv,T]
        "win_attn": win_attn,                                            # [Hkv,T]
        "vnorm": jnp.max(vnorm_g, axis=1),                               # [Hkv,T]
        "knorm": jnp.linalg.norm(k, axis=-1),                            # [Hkv,T]
        "hidden": h,                                                     # next layer's input
    }
    return h, stats


def _scan_layers(params, cfg: ModelConfig):
    """Merge model-layer and surrogate weights into one scan-able pytree."""
    lay = dict(params["layers"])
    s = params["surrogate"]
    lay.update({
        "slin_w": s["lin_w"], "slin_b": s["lin_b"],
        "smlp_w1": s["mlp_w1"], "smlp_b1": s["mlp_b1"],
        "smlp_w2": s["mlp_w2"], "smlp_b2": s["mlp_b2"],
    })
    return lay


def prefill_single(params, tokens, true_len, stats_from=0,
                   cfg: ModelConfig = MODEL, t_out=None, collect_hidden=False):
    """Prefill one sequence. tokens: [T] int32, true_len: scalar.

    Returns (last-position logits [V], dict of stacked per-layer stats
    [L, ...] with the token axis padded to t_out slots — default cfg.t_max,
    so prefill KV output buffers plug directly into the decode cache).
    stats_from > 0 restricts max/maxn statistics to queries >= stats_from
    (the KVzip repeated-prompt oracle pass).
    """
    T = tokens.shape[0]
    t_out = t_out or cfg.t_max
    h = params["embed"][tokens]                                          # [T, Dh]
    cos, sin = rope_tables(jnp.arange(T), cfg)
    win_from = jnp.maximum(true_len - OBS_WINDOW, 0)
    layers = _scan_layers(params, cfg)

    def step(h, layer):
        h2, stats = _layer_prefill(h, layer, cos, sin, true_len,
                                   stats_from, win_from, cfg)
        if not collect_hidden:
            stats = {k: v for k, v in stats.items() if k != "hidden"}
        return h2, stats

    h0 = h
    h, stats = jax.lax.scan(step, h, layers)
    if collect_hidden:
        # Surrogate input = layer *input* hidden states: h0 for layer 0,
        # layer l-1's output for layer l.
        stats["hidden"] = jnp.concatenate(
            [h0[None], stats["hidden"][:-1]], axis=0)                    # [L,T,Dh]

    hf = rmsnorm(h, params["ln_f"])
    last = jnp.take(hf, jnp.maximum(true_len - 1, 0), axis=0)
    logits = last @ params["w_out"]                                      # [V]

    pad = t_out - T
    if pad > 0:
        stats = {
            k: (jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0))) if v.ndim == 4
                else jnp.pad(v, ((0, 0), (0, 0), (0, pad))) if v.ndim == 3
                else jnp.pad(v, ((0, 0), (0, pad), (0, 0))))
            for k, v in stats.items()
        }
    return logits, stats


def prefill_batch(params, tokens, true_len, cfg: ModelConfig = MODEL):
    """AOT prefill entrypoint. tokens [B, T] int32, true_len [B] int32.

    Output order (the rust runtime indexes the HLO tuple by this order):
      logits      [B, V]
      kcache      [L, B, Hkv, t_max, D]
      vcache      [L, B, Hkv, t_max, D]
      score_lin   [L, B, Hkv, t_max]   KVzap-Linear log-score predictions
      score_mlp   [L, B, Hkv, t_max]   KVzap-MLP  log-score predictions
      max_attn    [L, B, Hkv, t_max]   observed KVzip-style statistic
      plus_attn   [L, B, Hkv, t_max]   observed KVzip+-style statistic
      cum_attn    [L, B, Hkv, t_max]   H2O accumulated attention
      win_attn    [L, B, Hkv, t_max]   SnapKV observed-window attention
      vnorm       [L, B, Hkv, t_max]   ||W_O v_i||
      knorm       [L, B, Hkv, t_max]   ||k_i||
    """
    logits, stats = jax.vmap(
        lambda t, n: prefill_single(params, t, n, 0, cfg))(tokens, true_len)
    # vmap puts B in front of the scanned L axis -> [B, L, ...]; move B inside.
    stats = {k: jnp.moveaxis(v, 0, 1) for k, v in stats.items()}
    return (
        logits,
        stats["k"], stats["v"],
        stats["score_lin"], stats["score_mlp"],
        stats["max_attn"], stats["plus_attn"],
        stats["cum_attn"], stats["win_attn"],
        stats["vnorm"], stats["knorm"],
    )


PREFILL_OUTPUTS = [
    "logits", "kcache", "vcache", "score_lin", "score_mlp",
    "max_attn", "plus_attn", "cum_attn", "win_attn", "vnorm", "knorm",
]


# ---------------------------------------------------------------------------
# KVzip oracle (repeated-prompt double pass, Eq. 1 / Eq. 3)


def kvzip_scores(params, tokens, true_len, cfg: ModelConfig = MODEL):
    """Oracle scoring pass: forward over [prompt; prompt] of static length 2T.

    tokens: [T]; the repeat is placed at dynamic offset true_len, so valid
    content occupies [0, 2*true_len). Only queries j >= true_len contribute
    to the max statistics — exactly "how much does the model attend to
    position i when repeating the prompt" (paper §3.1).

    Returns (s [L, Hkv, T], s_plus [L, Hkv, T]) for the original prompt.
    """
    T = tokens.shape[0]
    tok2 = jnp.zeros((2 * T,), tokens.dtype)
    tok2 = jax.lax.dynamic_update_slice(tok2, tokens, (0,))
    tok2 = jax.lax.dynamic_update_slice(tok2, tokens, (true_len,))
    _, stats = prefill_single(params, tok2, 2 * true_len, stats_from=true_len,
                              cfg=cfg, t_out=2 * T)
    return stats["max_attn"][:, :, :T], stats["plus_attn"][:, :, :T]


def kvzip_batch(params, tokens, true_len, cfg: ModelConfig = MODEL):
    """AOT oracle entrypoint (B=1). tokens [1, T], true_len [1].

    Output order: s [L, 1, Hkv, T], s_plus [L, 1, Hkv, T].
    """
    s, sp = jax.vmap(lambda t, n: kvzip_scores(params, t, n, cfg))(
        tokens, true_len)
    return jnp.moveaxis(s, 0, 1), jnp.moveaxis(sp, 0, 1)


KVZIP_OUTPUTS = ["s", "s_plus"]


# ---------------------------------------------------------------------------
# Surrogate-target collection (build-time only; used by train_surrogate.py)


def collect_pairs(params, tokens, true_len, cfg: ModelConfig = MODEL):
    """Return (hidden [L, T, Dh], log-target s+ [L, Hkv, T]) for training."""
    T = tokens.shape[0]
    _, pre = prefill_single(params, tokens, true_len, 0, cfg, t_out=T,
                            collect_hidden=True)
    _, s_plus = kvzip_scores(params, tokens, true_len, cfg)
    return pre["hidden"], s_plus


# ---------------------------------------------------------------------------
# Decode


def decode_single(params, token, pos, kcache, vcache, mask,
                  cfg: ModelConfig = MODEL):
    """Decode one step for one sequence.

    token: scalar int32; pos: scalar int32 (absolute position of this token);
    kcache/vcache: [L, Hkv, t_max, D]; mask: [L, Hkv, t_max] (1 = attendable).

    Returns (logits [V], kcache', vcache' (new KV written at slot `pos`),
    score_lin/score_mlp/vnorm [L, Hkv], attn_row [L, Hkv, t_max+1] — the
    last column is the new token's self-attention).
    """
    Hq, Hkv, D, G = cfg.n_q_heads, cfg.n_kv_heads, cfg.d_head, cfg.group
    h = params["embed"][token]                                           # [Dh]
    cos, sin = rope_tables(pos[None] if pos.ndim == 0 else pos, cfg)     # [1,D/2]
    layers = _scan_layers(params, cfg)

    def step(h, xs):
        layer, kc, vc, msk = xs
        s_lin = surrogate_linear(h[None], layer["slin_w"], layer["slin_b"],
                                 block_t=1)[0]
        s_mlp = surrogate_mlp(h[None], layer["smlp_w1"], layer["smlp_b1"],
                              layer["smlp_w2"], layer["smlp_b2"], block_t=1)[0]
        x = rmsnorm(h, layer["ln1"])
        q = (x @ layer["wq"]).reshape(Hq, 1, D)
        kn = (x @ layer["wk"]).reshape(Hkv, 1, D)
        vn = (x @ layer["wv"]).reshape(Hkv, 1, D)
        q = apply_rope(q, cos, sin)[:, 0] / jnp.sqrt(D).astype(jnp.float32)
        kn = apply_rope(kn, cos, sin)[:, 0]                              # [Hkv, D]
        vn = vn[:, 0]

        # Cache + the new KV appended as row t_max (static shape t_max+1).
        kx = jnp.concatenate([kc, kn[:, None, :]], axis=1)               # [Hkv,S,D]
        vx = jnp.concatenate([vc, vn[:, None, :]], axis=1)
        mx = jnp.concatenate([msk, jnp.ones((Hkv, 1), msk.dtype)], axis=1)

        qg = q.reshape(Hkv, G, D)
        out, row = jax.vmap(decode_attention)(qg, kx, vx, mx)
        out = out.reshape(Hq * D)
        h2 = h + out @ layer["wo"]
        h2 = h2 + swiglu(rmsnorm(h2, layer["ln2"]),
                         layer["wg"], layer["wu"], layer["wd"])

        vnorm = jnp.max(head_vnorm(vn[:, None, :], layer["wo"], cfg)[:, :, 0],
                        axis=1)                                          # [Hkv]
        # Write the new KV into its true cache slot.
        kc2 = jax.vmap(lambda c, n: jax.lax.dynamic_update_slice(
            c, n[None], (pos, 0)))(kc, kn)
        vc2 = jax.vmap(lambda c, n: jax.lax.dynamic_update_slice(
            c, n[None], (pos, 0)))(vc, vn)
        return h2, (kc2, vc2, s_lin, s_mlp, vnorm, row)

    h, ys = jax.lax.scan(step, h, (layers, kcache, vcache, mask))
    kcache2, vcache2, s_lin, s_mlp, vnorm, rows = ys
    logits = rmsnorm(h, params["ln_f"]) @ params["w_out"]
    return logits, kcache2, vcache2, s_lin, s_mlp, vnorm, rows


def decode_batch(params, tokens, pos, kcache, vcache, mask,
                 cfg: ModelConfig = MODEL):
    """AOT decode entrypoint. tokens [B], pos [B],
    kcache/vcache [L, B, Hkv, t_max, D], mask [L, B, Hkv, t_max].

    Output order:
      logits [B, V]; kcache'/vcache' [L, B, Hkv, t_max, D];
      score_lin/score_mlp/vnorm [L, B, Hkv]; attn_row [L, B, Hkv, t_max+1].
    """
    kc = jnp.moveaxis(kcache, 1, 0)
    vc = jnp.moveaxis(vcache, 1, 0)
    mk = jnp.moveaxis(mask, 1, 0)
    outs = jax.vmap(
        lambda t, p, k, v, m: decode_single(params, t, p, k, v, m, cfg)
    )(tokens, pos, kc, vc, mk)
    logits, kc2, vc2, s_lin, s_mlp, vnorm, rows = outs
    return (
        logits,
        jnp.moveaxis(kc2, 0, 1), jnp.moveaxis(vc2, 0, 1),
        jnp.moveaxis(s_lin, 0, 1), jnp.moveaxis(s_mlp, 0, 1),
        jnp.moveaxis(vnorm, 0, 1), jnp.moveaxis(rows, 0, 1),
    )


DECODE_OUTPUTS = [
    "logits", "kcache", "vcache", "score_lin", "score_mlp", "vnorm", "attn_row",
]


# ---------------------------------------------------------------------------
# Training loss (build-time pretraining)


def _layer_train(h, layer, cos, sin, cfg: ModelConfig):
    """Training-path layer forward: pure-jnp attention (pallas_call has no
    VJP rule, so jax.grad cannot flow through the L1 kernels; the math is
    identical and is cross-checked in python/tests/test_model.py)."""
    Hq, Hkv, D, G = cfg.n_q_heads, cfg.n_kv_heads, cfg.d_head, cfg.group
    T = h.shape[0]
    x = rmsnorm(h, layer["ln1"])
    q = (x @ layer["wq"]).reshape(T, Hq, D).transpose(1, 0, 2)
    k = (x @ layer["wk"]).reshape(T, Hkv, D).transpose(1, 0, 2)
    v = (x @ layer["wv"]).reshape(T, Hkv, D).transpose(1, 0, 2)
    q = apply_rope(q, cos, sin) / jnp.sqrt(D).astype(jnp.float32)
    k = apply_rope(k, cos, sin)
    qg = q.reshape(Hkv, G, T, D)
    scores = jnp.einsum("hgtd,hsd->hgts", qg, k)
    pos = jnp.arange(T)
    causal = pos[:, None] >= pos[None, :]
    scores = jnp.where(causal[None, None], scores, -1e30)
    a = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("hgts,hsd->hgtd", a, v)
    out = out.reshape(Hq, T, D).transpose(1, 0, 2).reshape(T, Hq * D)
    h = h + out @ layer["wo"]
    h = h + swiglu(rmsnorm(h, layer["ln2"]),
                   layer["wg"], layer["wu"], layer["wd"])
    return h


def lm_loss(params, tokens, answer_mask=None, answer_weight=1.0,
            cfg: ModelConfig = MODEL):
    """Next-token cross-entropy over a [B, T] batch (PAD=0 positions masked).

    answer_mask [B, T] upweights answer/chain-of-thought bytes by
    `answer_weight`: retrieval answers are ~3% of the byte stream, so
    without upweighting the induction behaviour the benchmarks test is
    underrepresented in the gradient signal."""

    def fwd(tok):
        T = tok.shape[0]
        h = params["embed"][tok]
        cos, sin = rope_tables(jnp.arange(T), cfg)
        layers = _scan_layers(params, cfg)

        def step(h, layer):
            return _layer_train(h, layer, cos, sin, cfg), None

        h, _ = jax.lax.scan(step, h, layers)
        return rmsnorm(h, params["ln_f"]) @ params["w_out"]

    logits = jax.vmap(fwd)(tokens)                                       # [B,T,V]
    targets = tokens[:, 1:]
    lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    nll = -jnp.take_along_axis(lp, targets[..., None], axis=-1)[..., 0]
    weight = (targets != 0).astype(jnp.float32)
    if answer_mask is not None:
        weight = weight * (1.0 + (answer_weight - 1.0) * answer_mask[:, 1:])
    return jnp.sum(nll * weight) / jnp.maximum(jnp.sum(weight), 1.0)
