"""Weights interchange: params pytree <-> flat binary blob + JSON manifest.

The HLO artifacts take every parameter tensor as a runtime input (keeping the
HLO text small and checkpoint-independent). The rust runtime reads
artifacts/weights.bin once, uploads each tensor as a device buffer in the
order recorded here, and appends those buffers to every execute call.

Blob layout: little-endian f32, tensors concatenated in jax tree-flatten
order (dict keys sorted — deterministic). The manifest records, per tensor:
name (path), shape, byte offset/length; plus the model/bucket metadata the
rust side needs (see aot.py for the artifact-level input/output specs).
"""

import json

import jax
import numpy as np


def flatten_params(params):
    """Deterministic (name, array) list in jax tree-flatten order."""
    leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    out = []
    for path, leaf in leaves:
        name = "/".join(str(getattr(p, "key", p)) for p in path)
        out.append((name, np.asarray(leaf, np.float32)))
    return out


def save_weights(params, blob_path: str):
    entries = []
    offset = 0
    with open(blob_path, "wb") as f:
        for name, arr in flatten_params(params):
            data = arr.astype("<f4").tobytes()
            entries.append({
                "name": name,
                "shape": list(arr.shape),
                "offset": offset,
                "bytes": len(data),
            })
            f.write(data)
            offset += len(data)
    return entries


def load_weights(blob_path: str, entries, template):
    """Rebuild a params pytree (used by tests for round-trip checks)."""
    with open(blob_path, "rb") as f:
        blob = f.read()
    flat = []
    for e in entries:
        arr = np.frombuffer(blob[e["offset"]: e["offset"] + e["bytes"]],
                            "<f4").reshape(e["shape"])
        flat.append(arr)
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, flat)


def save_manifest(path: str, manifest: dict):
    with open(path, "w") as f:
        json.dump(manifest, f, indent=1)
