"""L1 Pallas kernel: fused causal GQA attention + pruning statistics.

This is the compute hot-spot of the paper: one attention pass that also
produces, per KV position, every statistic the pruning policies need
(KVzip Eq. 1 max-attention, KVzip+ Eq. 3 normalized max, H2O cumulative
attention, SnapKV observed-window attention). Fusing the statistics into the
attention kernel is what makes oracle-grade scoring affordable — the paper's
"double forward pass" cost lives entirely in re-running this kernel on the
repeated prompt, never in a separate scoring pass.

Hardware adaptation (DESIGN.md §4): the FlashAttention threadblock tiling of
the GPU original becomes a BlockSpec schedule — queries are tiled in blocks
of `block_q` rows held in VMEM, keys/values stream as full [T, D] panels
(T ≤ 512 → K/V panel ≤ 512·24·4 B ≈ 49 KiB, far under the ~16 MiB VMEM
budget; see EXPERIMENTS.md §Perf for the footprint table). Statistic outputs
are accumulated *across* sequential grid steps into shared output blocks —
the TPU idiom replacing the GPU's atomic reductions.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU lowering is treated as a compile-only target.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(lens_ref, q_ref, k_ref, v_ref, hinv_ref,
                 out_ref, max_ref, maxn_ref, cum_ref, win_ref,
                 *, block_q: int):
    g = pl.program_id(0)
    qi = pl.program_id(1)
    true_len = lens_ref[0]
    stats_from = lens_ref[1]
    win_from = lens_ref[2]

    q = q_ref[0]                       # [Bq, D]
    k = k_ref[...]                     # [T, D]
    v = v_ref[...]                     # [T, D]
    t = k.shape[0]

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)
    kpos = jax.lax.broadcasted_iota(jnp.int32, (1, t), 1)

    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32)   # [Bq, T]
    mask = (qpos >= kpos) & (kpos < true_len)
    scores = jnp.where(mask, scores, NEG_INF)
    # Row softmax: the full key panel is resident, so no online rescale is
    # needed; the flash-style streaming shows up as the query-block grid.
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    a = e / jnp.sum(e, axis=-1, keepdims=True)

    valid_q = (qpos < true_len).astype(a.dtype)                    # [Bq, 1]
    a = a * valid_q
    out_ref[0] = jnp.dot(a, v, preferred_element_type=jnp.float32)

    stats_q = valid_q * (qpos >= stats_from).astype(a.dtype)
    a_st = a * stats_q
    hinv = hinv_ref[pl.ds(qi * block_q, block_q)][:, None]         # [Bq, 1]

    blk_max = jnp.max(a_st, axis=0)                                # [T]
    blk_maxn = jnp.max(a_st * hinv, axis=0)
    blk_cum = jnp.sum(a_st, axis=0)
    win_q = valid_q * (qpos >= win_from).astype(a.dtype)
    blk_win = jnp.sum(a * win_q, axis=0)

    # Per-group stats: accumulate over query blocks (grid dim 1 is fastest).
    @pl.when(qi == 0)
    def _init_g():
        max_ref[0] = blk_max
        maxn_ref[0] = blk_maxn

    @pl.when(qi != 0)
    def _acc_g():
        max_ref[0] = jnp.maximum(max_ref[0], blk_max)
        maxn_ref[0] = jnp.maximum(maxn_ref[0], blk_maxn)

    # Group-summed stats: accumulate over (g, qi).
    @pl.when((g == 0) & (qi == 0))
    def _init():
        cum_ref[...] = blk_cum
        win_ref[...] = blk_win

    @pl.when((g != 0) | (qi != 0))
    def _acc():
        cum_ref[...] = cum_ref[...] + blk_cum
        win_ref[...] = win_ref[...] + blk_win


@functools.partial(jax.jit, static_argnames=("block_q", "interpret"))
def attention_with_stats(q, k, v, hnorm_inv, true_len, stats_from, win_from,
                         block_q: int = 128, interpret: bool = True):
    """Pallas version of ref.attention_with_stats_ref (same signature/returns).

    q: [G, T, D] (scaled + RoPE'd), k/v: [T, D], hnorm_inv: [T];
    true_len/stats_from/win_from: scalar int32.
    T is padded up to a multiple of block_q internally.
    """
    G, T, D = q.shape
    bq = min(block_q, T) if T % min(block_q, T) == 0 else block_q
    tp = ((T + bq - 1) // bq) * bq
    if tp != T:
        pad = tp - T
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, pad), (0, 0)))
        v = jnp.pad(v, ((0, pad), (0, 0)))
        hnorm_inv = jnp.pad(hnorm_inv, (0, pad))

    lens = jnp.stack([jnp.asarray(true_len, jnp.int32),
                      jnp.asarray(stats_from, jnp.int32),
                      jnp.asarray(win_from, jnp.int32)])
    grid = (G, tp // bq)
    out, mx, mxn, cum, win = pl.pallas_call(
        functools.partial(_attn_kernel, block_q=bq),
        grid=grid,
        in_specs=[
            pl.BlockSpec((3,), lambda g, qi: (0,)),            # lens
            pl.BlockSpec((1, bq, D), lambda g, qi: (g, qi, 0)),  # q
            pl.BlockSpec((tp, D), lambda g, qi: (0, 0)),       # k panel
            pl.BlockSpec((tp, D), lambda g, qi: (0, 0)),       # v panel
            pl.BlockSpec((tp,), lambda g, qi: (0,)),           # hnorm_inv
        ],
        out_specs=[
            pl.BlockSpec((1, bq, D), lambda g, qi: (g, qi, 0)),  # out
            pl.BlockSpec((1, tp), lambda g, qi: (g, 0)),       # max_attn
            pl.BlockSpec((1, tp), lambda g, qi: (g, 0)),       # maxn_attn
            pl.BlockSpec((tp,), lambda g, qi: (0,)),           # cum_attn
            pl.BlockSpec((tp,), lambda g, qi: (0,)),           # win_attn
        ],
        out_shape=[
            jax.ShapeDtypeStruct((G, tp, D), jnp.float32),
            jax.ShapeDtypeStruct((G, tp), jnp.float32),
            jax.ShapeDtypeStruct((G, tp), jnp.float32),
            jax.ShapeDtypeStruct((tp,), jnp.float32),
            jax.ShapeDtypeStruct((tp,), jnp.float32),
        ],
        interpret=interpret,
    )(lens, q, k, v, hnorm_inv)
    return (out[:, :T], mx[:, :T], mxn[:, :T], cum[:T], win[:T])
