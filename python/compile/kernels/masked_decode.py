"""L1 Pallas kernel: single-step masked decode attention.

During decoding the cache is a dense [S, D] buffer (S = t_max + 1; the last
row holds the KV pair produced this step) with a per-head keep-mask — the
XLA-side view of the rust paged cache manager (DESIGN.md §4): eviction flips
mask bits, the block tables that account for the freed memory live in rust.

The query is a single row per head, so the whole K/V panel fits in VMEM
(S·D·4 B ≈ 49 KiB per head at zap-lm scale, ~256 KiB at paper scale) and the
kernel is one grid step per group-head; a real-TPU deployment would tile S
only beyond ~32k cache slots. The kernel also emits the attention row summed
over the GQA group — the decode-time statistic update for H2O-style
baselines (KVzap itself never needs it: its scores come from hidden states).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, m_ref, o_ref, row_ref):
    q = q_ref[...]                     # [G, D]
    k = k_ref[...]                     # [S, D]
    v = v_ref[...]
    mask = m_ref[...] > 0.0            # [S]
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32)   # [G, S]
    scores = jnp.where(mask[None, :], scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    a = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[...] = jnp.dot(a, v, preferred_element_type=jnp.float32)
    row_ref[...] = jnp.sum(a, axis=0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def decode_attention(q, k, v, mask, interpret: bool = True):
    """Pallas version of ref.decode_attention_ref.

    q: [G, D] (scaled + RoPE'd); k, v: [S, D]; mask: [S] (1 = attendable).
    Returns (out [G, D], attn_row [S]).
    """
    G, D = q.shape
    S = k.shape[0]
    return pl.pallas_call(
        _decode_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((G, D), lambda i: (0, 0)),
            pl.BlockSpec((S, D), lambda i: (0, 0)),
            pl.BlockSpec((S, D), lambda i: (0, 0)),
            pl.BlockSpec((S,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((G, D), lambda i: (0, 0)),
            pl.BlockSpec((S,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((G, D), jnp.float32),
            jax.ShapeDtypeStruct((S,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, mask)
