"""L1 Pallas kernels: the KVzap surrogate scorers.

The paper's central efficiency claim (Criterion 1, Appendix B) is that KV
importance can be predicted from the residual stream with one or two small
matmuls per layer: KVzap-Linear (h @ W) and KVzap-MLP (GELU(h @ W1) @ W2,
hidden width D_h/8). These kernels tile the token axis in blocks of
`block_t` rows; the weight panels ([Dh, H] / [Dh, Dm] + [Dm, H]) stay
resident in VMEM across grid steps — at paper scale (Dh=4096, Dm=512) that
is ~8.4 MiB in bf16, which fits; at zap-lm scale it is trivial.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _linear_kernel(h_ref, w_ref, b_ref, o_ref):
    o_ref[...] = (
        jnp.dot(h_ref[...], w_ref[...], preferred_element_type=jnp.float32)
        + b_ref[...][None, :]
    )


def _mlp_kernel(h_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    z = jnp.dot(h_ref[...], w1_ref[...], preferred_element_type=jnp.float32)
    z = jax.nn.gelu(z + b1_ref[...][None, :])
    o_ref[...] = (
        jnp.dot(z, w2_ref[...], preferred_element_type=jnp.float32)
        + b2_ref[...][None, :]
    )


def _pad_rows(h, block_t):
    T = h.shape[0]
    tp = ((T + block_t - 1) // block_t) * block_t
    if tp != T:
        h = jnp.pad(h, ((0, tp - T), (0, 0)))
    return h, tp


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def surrogate_linear(h, w, b, block_t: int = 128, interpret: bool = True):
    """KVzap-Linear: h [T, Dh] -> log-score predictions [T, H]."""
    T, Dh = h.shape
    H = w.shape[1]
    bt = min(block_t, T)
    hp, tp = _pad_rows(h, bt)
    out = pl.pallas_call(
        _linear_kernel,
        grid=(tp // bt,),
        in_specs=[
            pl.BlockSpec((bt, Dh), lambda i: (i, 0)),
            pl.BlockSpec((Dh, H), lambda i: (0, 0)),
            pl.BlockSpec((H,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bt, H), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((tp, H), jnp.float32),
        interpret=interpret,
    )(hp, w, b)
    return out[:T]


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def surrogate_mlp(h, w1, b1, w2, b2, block_t: int = 128, interpret: bool = True):
    """KVzap-MLP: h [T, Dh] -> GELU(h@W1+b1)@W2+b2, predictions [T, H]."""
    T, Dh = h.shape
    Dm = w1.shape[1]
    H = w2.shape[1]
    bt = min(block_t, T)
    hp, tp = _pad_rows(h, bt)
    out = pl.pallas_call(
        _mlp_kernel,
        grid=(tp // bt,),
        in_specs=[
            pl.BlockSpec((bt, Dh), lambda i: (i, 0)),
            pl.BlockSpec((Dh, Dm), lambda i: (0, 0)),
            pl.BlockSpec((Dm,), lambda i: (0,)),
            pl.BlockSpec((Dm, H), lambda i: (0, 0)),
            pl.BlockSpec((H,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bt, H), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((tp, H), jnp.float32),
        interpret=interpret,
    )(hp, w1, b1, w2, b2)
    return out[:T]
