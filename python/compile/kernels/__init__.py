"""L1 Pallas kernels for the KVzap reproduction (all interpret=True on CPU)."""

from .attention import attention_with_stats
from .masked_decode import decode_attention
from .surrogate import surrogate_linear, surrogate_mlp

__all__ = [
    "attention_with_stats",
    "decode_attention",
    "surrogate_linear",
    "surrogate_mlp",
]
