"""Pure-jnp correctness oracles for the Pallas kernels.

These are the semantic ground truth: every Pallas kernel in this package is
tested against these functions (pytest + hypothesis sweeps over shapes,
lengths and dtypes in python/tests/test_kernels.py).

Shapes follow the per-(batch, kv-head) kernel view:
    q:      [G, T, D]   G = GQA group size (query heads per KV head)
    k, v:   [T, D]
and the statistics are the raw material for every pruning policy
(KVzip / KVzip+ / H2O / SnapKV / StreamingLLM / ...), see DESIGN.md §3.
"""

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_with_stats_ref(q, k, v, hnorm_inv, true_len, stats_from, win_from):
    """Causal GQA attention + per-KV-position score statistics.

    Args:
        q: [G, T, D] query vectors, already scaled by 1/sqrt(D) and RoPE'd.
        k: [T, D] keys (RoPE'd), v: [T, D] values.
        hnorm_inv: [T] reciprocal norms 1/||h_j|| of the *query* residual
            stream (the KVzip+ normalization of Eq. 3).
        true_len: scalar int — positions >= true_len are padding.
        stats_from: scalar int — only queries j >= stats_from contribute to
            max/maxn statistics. 0 for plain prefill; = true_len for the
            KVzip repeated-prompt oracle (queries from the repeat only).
        win_from: scalar int — queries j >= win_from contribute to win_attn
            (SnapKV-style observed window).

    Returns:
        out:       [G, T, D] attention output.
        max_attn:  [G, T]  max_j a_ji              (KVzip, Eq. 1)
        maxn_attn: [G, T]  max_j a_ji / ||h_j||    (KVzip+ before vnorm, Eq. 3)
        cum_attn:  [T]     sum_{g,j} a_ji          (H2O heavy-hitter score)
        win_attn:  [T]     sum_{g, j>=win_from} a_ji  (SnapKV observed window)
    """
    G, T, D = q.shape
    pos = jnp.arange(T)
    causal = pos[:, None] >= pos[None, :]                 # [Tq, Tk]
    valid_k = pos < true_len
    mask = causal & valid_k[None, :]
    scores = jnp.einsum("gtd,sd->gts", q, k)
    scores = jnp.where(mask[None], scores, NEG_INF)
    a = jax.nn.softmax(scores, axis=-1)                   # [G, Tq, Tk]
    valid_q = (pos < true_len).astype(a.dtype)
    a = a * valid_q[None, :, None]                        # zero pad-query rows
    out = jnp.einsum("gts,sd->gtd", a, v)

    stats_q = valid_q * (pos >= stats_from).astype(a.dtype)
    a_st = a * stats_q[None, :, None]
    max_attn = jnp.max(a_st, axis=1)
    maxn_attn = jnp.max(a_st * hnorm_inv[None, :, None], axis=1)
    cum_attn = jnp.sum(a_st, axis=(0, 1))
    win_q = valid_q * (pos >= win_from).astype(a.dtype)
    win_attn = jnp.sum(a * win_q[None, :, None], axis=(0, 1))
    return out, max_attn, maxn_attn, cum_attn, win_attn


def decode_attention_ref(q, k, v, mask):
    """Single-step masked decode attention over a dense padded cache.

    Args:
        q: [G, D] the new query (scaled, RoPE'd).
        k, v: [S, D] cache (S = t_max + 1; row t_max holds this step's KV).
        mask: [S] 1.0 = attendable, 0.0 = evicted / not-yet-filled.

    Returns:
        out: [G, D], attn_row: [S] (sum of attention over the group —
        the decode-time H2O / oracle statistic update).
    """
    scores = jnp.einsum("gd,sd->gs", q, k)
    scores = jnp.where(mask[None, :] > 0, scores, NEG_INF)
    a = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("gs,sd->gd", a, v)
    return out, jnp.sum(a, axis=0)


def surrogate_linear_ref(h, w, b):
    """KVzap-Linear scorer: h [T, Dh] @ w [Dh, H] + b [H] -> log-scores [T, H]."""
    return h @ w + b


def surrogate_mlp_ref(h, w1, b1, w2, b2):
    """KVzap-MLP scorer (paper §4.1): GELU MLP with hidden width Dh/8."""
    return jax.nn.gelu(h @ w1 + b1) @ w2 + b2
