"""Build-time pretraining of zap-lm (single CPU core, minutes).

Two phases: bulk steps at short sequences, then a long-sequence phase so
RoPE generalizes to the evaluation contexts (128–512). Adam + cosine decay
and gradient clipping are implemented inline (optax is not available in this
image). Only the LM parameters train; the surrogate heads stay frozen here
and are fit afterwards by train_surrogate.py against KVzip+ targets.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus, model
from .config import MODEL, TrainConfig, train_config

# Answer/chain-of-thought bytes are ~3% of the stream; upweighting them
# concentrates gradient signal on the retrieval/induction behaviour the
# benchmarks measure (see corpus.training_text spans).
ANSWER_WEIGHT = 10.0


def adam_init(params):
    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr, wd, clip, b1=0.9, b2=0.95, eps=1e-8):
    gnorm = jnp.sqrt(sum(jnp.sum(g * g)
                         for g in jax.tree_util.tree_leaves(grads)))
    scale = jnp.minimum(1.0, clip / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g,
                               state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g,
                               state["v"], grads)
    mh = jax.tree_util.tree_map(lambda x: x / (1 - b1 ** t), m)
    vh = jax.tree_util.tree_map(lambda x: x / (1 - b2 ** t), v)
    params = jax.tree_util.tree_map(
        lambda p, mm, vv: p - lr * (mm / (jnp.sqrt(vv) + eps) + wd * p),
        params, mh, vh)
    return params, {"m": m, "v": v, "t": t}


def _freeze_surrogate(grads):
    """Surrogate heads are trained separately against KVzip+ targets."""
    grads = dict(grads)
    grads["surrogate"] = jax.tree_util.tree_map(
        jnp.zeros_like, grads["surrogate"])
    return grads


def train(cfg: TrainConfig = None, log=print):
    cfg = cfg or train_config()
    r = corpus.rng_for(cfg.seed)
    params = model.init_params(jax.random.PRNGKey(cfg.seed))
    opt = adam_init(params)
    total = cfg.steps1 + cfg.steps2

    @jax.jit
    def step_fn(params, opt, batch, ans, lr):
        loss, grads = jax.value_and_grad(model.lm_loss)(
            params, batch, ans, ANSWER_WEIGHT)
        grads = _freeze_surrogate(grads)
        params, opt = adam_update(params, grads, opt, lr,
                                  cfg.weight_decay, cfg.clip)
        return params, opt, loss

    losses = []
    t0 = time.time()
    for step in range(total):
        if step < cfg.steps1:
            batch, ans = corpus.training_batch(r, cfg.batch1, cfg.seq1)
        else:
            batch, ans = corpus.training_batch(r, cfg.batch2, cfg.seq2)
        frac = step / max(total - 1, 1)
        warm = min((step + 1) / cfg.warmup, 1.0)
        lr = cfg.lr * warm * 0.5 * (1 + np.cos(np.pi * frac))
        params, opt, loss = step_fn(params, opt, jnp.asarray(batch),
                                    jnp.asarray(ans),
                                    jnp.asarray(lr, jnp.float32))
        losses.append(float(loss))
        if step % 25 == 0 or step == total - 1:
            log(f"  train step {step:4d}/{total} loss {float(loss):.4f} "
                f"lr {lr:.2e} ({time.time()-t0:.0f}s)")
    return params, losses
