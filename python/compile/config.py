"""Model + pipeline configuration for the KVzap reproduction.

zap-lm is the build-time substitute for Qwen3-8B / Llama-3.1-8B (see
DESIGN.md §2): a byte-level GQA transformer with RoPE, RMSNorm and SwiGLU —
the same architectural family the paper evaluates — scaled so that it can be
pretrained on a single CPU core in minutes.

Everything the rust layer needs to know (dims, buckets, special tokens) is
emitted into artifacts/manifest.json by aot.py, so this file is the single
source of truth.
"""

from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    vocab: int = 256          # byte-level
    d_model: int = 192        # D_h
    n_layers: int = 4         # L
    n_q_heads: int = 8        # H_Q
    n_kv_heads: int = 2       # H   (GQA 4x, same ratio as Llama-3.1-8B)
    d_head: int = 24          # D
    d_int: int = 384          # SwiGLU intermediate
    d_surrogate: int = 24     # MLP surrogate hidden width = D_h/8 (paper §4.1)
    rope_theta: float = 10_000.0
    rms_eps: float = 1e-5
    t_max: int = 512          # decode cache capacity

    @property
    def group(self) -> int:
        """Query heads per KV head (GQA group size)."""
        return self.n_q_heads // self.n_kv_heads


@dataclass(frozen=True)
class BucketConfig:
    """Static-shape buckets AOT-compiled into artifacts."""

    prefill_t: tuple = (128, 256, 384, 512)
    prefill_b: tuple = (1, 4)
    decode_b: tuple = (1, 4, 8)
    kvzip_t: tuple = (256, 384, 512)  # oracle double-pass buckets (run at 2T)


@dataclass(frozen=True)
class TrainConfig:
    """Build-time pretraining of zap-lm (single CPU core)."""

    seed: int = 0
    # phase 1: short sequences, bulk of the steps
    steps1: int = 700
    batch1: int = 8
    seq1: int = 224
    # phase 2: long sequences so RoPE generalizes to eval contexts
    steps2: int = 160
    batch2: int = 3
    seq2: int = 512
    lr: float = 3e-3
    warmup: int = 40
    weight_decay: float = 0.01
    clip: float = 1.0


@dataclass(frozen=True)
class SurrogateTrainConfig:
    seed: int = 1
    n_prompts: int = 220          # prompts scored with the KVzip+ oracle
    prompt_len: int = 256         # scored at 2T = 512
    positions_per_prompt: int = 192
    holdout_frac: float = 0.15
    ridge_lambda: float = 1e-2    # KVzap-Linear closed form
    mlp_steps: int = 1200         # KVzap-MLP Adam steps
    mlp_batch: int = 512
    mlp_lr: float = 2e-3
    log_floor: float = -14.0      # clip log(s+) from below


# Special byte tokens (the corpus generators never emit bytes < 16).
PAD, BOS, EOS, SEP = 0, 1, 2, 3

# Sliding window w (paper: 128 @ 4k context; scaled 8x like the contexts).
WINDOW = 16
# Observed-attention window for SnapKV-style stats (last-w queries).
OBS_WINDOW = 32

MODEL = ModelConfig()
BUCKETS = BucketConfig()
TRAIN = TrainConfig()
SURROGATE = SurrogateTrainConfig()


def fast_mode() -> bool:
    """KVZAP_FAST=1 shrinks the pipeline for CI-style smoke runs."""
    import os

    return os.environ.get("KVZAP_FAST", "0") == "1"


def train_config() -> TrainConfig:
    if fast_mode():
        return TrainConfig(steps1=30, steps2=8, warmup=5)
    return TRAIN


def surrogate_config() -> SurrogateTrainConfig:
    if fast_mode():
        return SurrogateTrainConfig(n_prompts=24, mlp_steps=150)
    return SURROGATE
