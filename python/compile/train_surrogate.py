"""Fit the KVzap surrogates against KVzip+ oracle scores (paper §4.1).

Pipeline:
  1. Sample diverse prompts from the corpus mixture; run the KVzip+ oracle
     (repeated-prompt double pass) to obtain log(s+) targets per (layer,
     kv-head, position); pair them with the layer-input hidden states.
  2. KVzap-Linear: per-layer ridge regression, closed form.
  3. KVzap-MLP: per-layer 2-layer GELU MLP (hidden width D_h/8), Adam on MSE.
  4. Report per-head R² on a holdout split (Table 1 / Figs 6–8 data) and
     write the fitted weights back into the params pytree so aot.py bakes
     them into the artifacts' weight manifest.

sklearn/skorch (the paper's tooling) are unavailable in this image; the
ridge solve and the Adam loop are implemented inline in jax.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus, model
from .config import MODEL, SurrogateTrainConfig, surrogate_config


def collect_dataset(params, cfg: SurrogateTrainConfig, log=print):
    """Returns X [N, L, Dh] hidden states and Y [N, L, Hkv] log(s+) targets."""
    r = corpus.rng_for(cfg.seed)
    T = cfg.prompt_len
    collect = jax.jit(lambda t, n: model.collect_pairs(params, t, n))
    xs, ys = [], []
    t0 = time.time()
    for i in range(cfg.n_prompts):
        tok, true_len = corpus.surrogate_prompt(r, T)
        hidden, s_plus = collect(jnp.asarray(tok), jnp.asarray(true_len))
        hidden = np.asarray(hidden)           # [L, T, Dh]
        target = np.log(np.maximum(np.asarray(s_plus), 1e-9))  # [L, Hkv, T]
        target = np.maximum(target, cfg.log_floor)
        n_pos = min(cfg.positions_per_prompt, true_len - 2)
        pos = r.choice(np.arange(1, true_len - 1), size=n_pos, replace=False)
        xs.append(hidden[:, pos].transpose(1, 0, 2))      # [n, L, Dh]
        ys.append(target[:, :, pos].transpose(2, 0, 1))   # [n, L, Hkv]
        if i % 50 == 0:
            log(f"  oracle scoring prompt {i}/{cfg.n_prompts} "
                f"({time.time()-t0:.0f}s)")
    return np.concatenate(xs), np.concatenate(ys)


def fit_linear(X, Y, lam):
    """Closed-form ridge per layer. X [N, Dh], Y [N, Hkv] -> (w, b)."""
    mu = X.mean(0)
    Xc = X - mu
    A = Xc.T @ Xc + lam * len(X) * np.eye(X.shape[1], dtype=np.float64)
    w = np.linalg.solve(A.astype(np.float64), (Xc.T @ (Y - Y.mean(0))).astype(np.float64))
    w = w.astype(np.float32)
    b = Y.mean(0) - mu @ w
    return w, b


def fit_mlp(X, Y, dm, cfg: SurrogateTrainConfig, seed):
    """Per-layer MLP on MSE with Adam. X [N, Dh], Y [N, Hkv]."""
    N, Dh = X.shape
    H = Y.shape[1]
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    p = {
        "w1": (jax.random.normal(k1, (Dh, dm)) / np.sqrt(Dh)).astype(jnp.float32),
        "b1": jnp.zeros((dm,), jnp.float32),
        "w2": (jax.random.normal(k2, (dm, H)) / np.sqrt(dm)).astype(jnp.float32),
        "b2": jnp.asarray(np.tile(Y.mean(0, keepdims=True), (1, 1))[0],
                          jnp.float32),
    }
    m = jax.tree_util.tree_map(jnp.zeros_like, p)
    v = jax.tree_util.tree_map(jnp.zeros_like, p)

    def loss_fn(p, x, y):
        pred = jax.nn.gelu(x @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]
        return jnp.mean((pred - y) ** 2)

    @jax.jit
    def step(p, m, v, x, y, t):
        loss, g = jax.value_and_grad(loss_fn)(p, x, y)
        m = jax.tree_util.tree_map(lambda a, b: 0.9 * a + 0.1 * b, m, g)
        v = jax.tree_util.tree_map(lambda a, b: 0.999 * a + 0.001 * b * b, v, g)
        mh = jax.tree_util.tree_map(lambda a: a / (1 - 0.9 ** t), m)
        vh = jax.tree_util.tree_map(lambda a: a / (1 - 0.999 ** t), v)
        p = jax.tree_util.tree_map(
            lambda pp, a, b: pp - cfg.mlp_lr * a / (jnp.sqrt(b) + 1e-8),
            p, mh, vh)
        return p, m, v, loss

    rs = np.random.default_rng(seed)
    Xj, Yj = jnp.asarray(X), jnp.asarray(Y)
    for t in range(1, cfg.mlp_steps + 1):
        idx = rs.integers(0, N, size=min(cfg.mlp_batch, N))
        p, m, v, loss = step(p, m, v, Xj[idx], Yj[idx],
                             jnp.asarray(t, jnp.float32))
    return {k: np.asarray(x) for k, x in p.items()}


def r2_score(pred, y):
    ss_res = np.sum((pred - y) ** 2, axis=0)
    ss_tot = np.sum((y - y.mean(0)) ** 2, axis=0) + 1e-9
    return 1.0 - ss_res / ss_tot


def train_surrogates(params, cfg: SurrogateTrainConfig = None, log=print):
    """Fit both surrogates; returns (params', metrics dict)."""
    cfg = cfg or surrogate_config()
    L, Hkv, Dm = MODEL.n_layers, MODEL.n_kv_heads, MODEL.d_surrogate
    log(f"collecting surrogate dataset ({cfg.n_prompts} prompts)...")
    X, Y = collect_dataset(params, cfg, log)
    N = len(X)
    n_hold = max(int(N * cfg.holdout_frac), 1)
    perm = np.random.default_rng(cfg.seed).permutation(N)
    tr, ho = perm[n_hold:], perm[:n_hold]
    log(f"  {N} pairs ({len(tr)} train / {len(ho)} holdout) per layer")

    s = {k: np.array(v) for k, v in params["surrogate"].items()}  # writable copies
    r2_lin = np.zeros((L, Hkv))
    r2_mlp = np.zeros((L, Hkv))
    for l in range(L):
        Xl, Yl = X[:, l], Y[:, l]
        w, b = fit_linear(Xl[tr], Yl[tr], cfg.ridge_lambda)
        s["lin_w"][l], s["lin_b"][l] = w, b
        r2_lin[l] = r2_score(Xl[ho] @ w + b, Yl[ho])

        mp = fit_mlp(Xl[tr], Yl[tr], Dm, cfg, cfg.seed + l)
        s["mlp_w1"][l], s["mlp_b1"][l] = mp["w1"], mp["b1"]
        s["mlp_w2"][l], s["mlp_b2"][l] = mp["w2"], mp["b2"]
        pred = np.asarray(
            jax.nn.gelu(Xl[ho] @ mp["w1"] + mp["b1"]) @ mp["w2"] + mp["b2"])
        r2_mlp[l] = r2_score(pred, Yl[ho])
        log(f"  layer {l}: R2 linear {r2_lin[l].mean():.3f} "
            f"mlp {r2_mlp[l].mean():.3f}")

    params = dict(params)
    params["surrogate"] = {k: jnp.asarray(v) for k, v in s.items()}

    # Score-distribution summary for threshold selection + Figs 6-8.
    flatY = Y.reshape(-1)
    qs = [0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9]
    metrics = {
        "n_pairs": int(N),
        "r2_linear": r2_lin.tolist(),
        "r2_mlp": r2_mlp.tolist(),
        "r2_linear_mean": float(r2_lin.mean()),
        "r2_mlp_mean": float(r2_mlp.mean()),
        "target_quantiles": {str(q): float(np.quantile(flatY, q)) for q in qs},
        "target_hist": np.histogram(flatY, bins=40)[0].tolist(),
        "target_hist_edges": np.histogram(flatY, bins=40)[1].tolist(),
        "below_median_frac": float((flatY < np.median(flatY)).mean()),
    }
    return params, metrics
