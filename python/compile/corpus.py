"""Synthetic corpus + task grammar for zap-lm.

This file defines the *shared task grammar*: the rust workload generators
(rust/src/workload/) emit evaluation instances with exactly the same byte
formats, so the build-time-trained model transfers to the rust-served
benchmarks. Any change here must be mirrored there (and vice versa) — the
template lists below are the contract.

The grammar scales the paper's benchmark suites down to zap-lm's context:

  ruler-mini   : niah_single_{1,2,3}, niah_multikey_{1,2,3}, niah_multiquery,
                 niah_multivalue, vt, cwe, fwe, qa_1, qa_2      (13 subsets)
  longbench-mini: sdqa, mdqa, summ, trec, fewshot_math, count,
                 passage_ret, lcc, repobench, kvret             (10 subsets)
  aime-mini    : multi-step integer arithmetic with chain-of-thought decoding

Prompts end with "A " (or "-> " for trec); the answer is the byte string the
model must generate, terminated by "\n". Training texts are prompt+answer+"\n"
followed by EOS.
"""

import numpy as np

from .config import BOS, EOS

# --------------------------------------------------------------------------
# Shared template lists — mirrored verbatim in rust/src/workload/templates.rs

FILLERS = [
    "the sky was clear and the wind moved over the hills. ",
    "a river runs past the old mill near the stone bridge. ",
    "people walked slowly through the quiet market square. ",
    "the train left the station two minutes after noon. ",
    "rain fell softly on the roof of the wooden cabin. ",
    "the library keeps its oldest maps in the north wing. ",
    "a grey cat slept on the warm step by the door. ",
    "the garden path was lined with small white stones. ",
]

NAMES = ["amir", "bella", "chen", "dara", "elif", "farid", "gita", "hana"]
CITIES = ["oslo", "lima", "kyoto", "accra", "quito", "perth", "turin", "hanoi"]
JOBS = ["baker", "pilot", "nurse", "coder", "judge", "miner", "actor", "clerk"]
WORDS = ["apple", "stone", "cloud", "tiger", "brick", "olive", "comet", "fern",
         "maple", "ridge", "pearl", "wolf", "cedar", "lark", "moss", "dune"]

TREC_LABELS = ["loc", "num", "person", "desc", "entity", "abbr"]
TREC_PATTERNS = {
    "loc": ["where is {w}", "where can one find {w}", "what country is {w} in"],
    "num": ["how many {w} are there", "what is the count of {w}",
            "how much {w} is needed"],
    "person": ["who made {w}", "who leads {w}", "who found {w}"],
    "desc": ["what is {w}", "what does {w} mean", "how does {w} work"],
    "entity": ["what kind of {w} is it", "which {w} is best",
               "name a type of {w}"],
    "abbr": ["what does {w} stand for", "expand the term {w}",
             "what is short for {w}"],
}

AIME_OPS = ["+", "-", "*"]


def rng_for(seed: int) -> np.random.Generator:
    return np.random.default_rng(seed)


# --------------------------------------------------------------------------
# Low-level helpers


def _key(r) -> str:
    return "".join(chr(ord("A") + r.integers(0, 26)) for _ in range(4))


def _val(r) -> str:
    return "".join(chr(ord("0") + r.integers(0, 10)) for _ in range(5))


def _filler_block(r, n_bytes: int) -> str:
    out = []
    size = 0
    while size < n_bytes:
        s = FILLERS[int(r.integers(0, len(FILLERS)))]
        out.append(s)
        size += len(s)
    return "".join(out)


def _haystack(r, items, target_len: int) -> str:
    """Scatter `items` (lines) at random depths in filler up to target_len."""
    budget = max(target_len - sum(len(i) + 1 for i in items) - 16, 32)
    cuts = sorted(r.integers(0, budget + 1, size=len(items)))
    segs = []
    prev = 0
    fill = _filler_block(r, budget)
    for c, item in zip(cuts, items):
        segs.append(fill[prev:c])
        segs.append(item + "\n")
        prev = c
    segs.append(fill[prev:budget])
    return "".join(segs)


# --------------------------------------------------------------------------
# ruler-mini subsets — each returns (prompt, answer)


def niah_single(r, target_len, variant=1):
    k, v = _key(r), _val(r)
    line = {1: f"{k} = {v}.", 2: f"note {k} holds {v}.",
            3: f"remember that {k} maps to {v}."}[variant]
    hay = _haystack(r, [line], target_len)
    return f"{hay}Q {k}\nA ", v


def niah_multikey(r, target_len, n_keys=4, variant=1):
    pairs = [(_key(r), _val(r)) for _ in range(n_keys)]
    lines = [f"{k} = {v}." for k, v in pairs]
    hay = _haystack(r, lines, target_len)
    k, v = pairs[int(r.integers(0, n_keys))]
    return f"{hay}Q {k}\nA ", v


def niah_multiquery(r, target_len):
    pairs = [(_key(r), _val(r)) for _ in range(3)]
    lines = [f"{k} = {v}." for k, v in pairs]
    hay = _haystack(r, lines, target_len)
    (k1, v1), (k2, v2) = pairs[0], pairs[2]
    return f"{hay}Q {k1} {k2}\nA ", f"{v1} {v2}"


def niah_multivalue(r, target_len):
    k, v1, v2 = _key(r), _val(r), _val(r)
    hay = _haystack(r, [f"{k} = {v1} {v2}."], target_len)
    return f"{hay}Q {k}\nA ", f"{v1} {v2}"


def vt(r, target_len, hops=3):
    root = _val(r)
    names = [f"V{int(r.integers(10, 99))}" for _ in range(hops + 2)]
    lines = [f"{names[0]} = {root}."]
    for i in range(1, hops):
        lines.append(f"{names[i]} = {names[i-1]}.")
    # distractor chain
    lines.append(f"{names[hops]} = {_val(r)}.")
    lines.append(f"{names[hops+1]} = {names[hops]}.")
    order = r.permutation(len(lines))
    hay = _haystack(r, [lines[i] for i in order], target_len)
    return f"{hay}Q {names[hops-1]}\nA ", root


def cwe(r, target_len):
    common = WORDS[int(r.integers(0, len(WORDS)))]
    others = [w for w in WORDS if w != common]
    seq = [common] * 6
    for _ in range(10):
        seq.append(others[int(r.integers(0, len(others)))])
    r.shuffle(seq)
    lst = "list: " + " ".join(seq) + "."
    hay = _haystack(r, [lst], target_len)
    return f"{hay}Q most\nA ", common


def fwe(r, target_len):
    picks = r.permutation(len(WORDS))[:3]
    a, b, c = (WORDS[int(i)] for i in picks)
    seq = [a] * 5 + [b] * 3 + [c] * 2
    r.shuffle(seq)
    lst = "list: " + " ".join(seq) + "."
    hay = _haystack(r, [lst], target_len)
    return f"{hay}Q most\nA ", a


def qa1(r, target_len):
    n = NAMES[int(r.integers(0, len(NAMES)))]
    c = CITIES[int(r.integers(0, len(CITIES)))]
    d1 = NAMES[int(r.integers(0, len(NAMES)))]
    j = JOBS[int(r.integers(0, len(JOBS)))]
    lines = [f"{n} lives in {c}.", f"{d1} works as a {j}."]
    hay = _haystack(r, lines, target_len)
    return f"{hay}Q where {n}\nA ", c


def qa2(r, target_len):
    n1, n2 = (NAMES[int(i)] for i in r.permutation(len(NAMES))[:2])
    c = CITIES[int(r.integers(0, len(CITIES)))]
    j = JOBS[int(r.integers(0, len(JOBS)))]
    lines = [f"doc1: {n1} lives in {c}.", f"doc2: {n2} works as a {j}."]
    hay = _haystack(r, lines, target_len)
    return f"{hay}Q job {n2}\nA ", j


RULER_SUBSETS = {
    "niah_single_1": lambda r, t: niah_single(r, t, 1),
    "niah_single_2": lambda r, t: niah_single(r, t, 2),
    "niah_single_3": lambda r, t: niah_single(r, t, 3),
    "niah_multikey_1": lambda r, t: niah_multikey(r, t, 3),
    "niah_multikey_2": lambda r, t: niah_multikey(r, t, 4),
    "niah_multikey_3": lambda r, t: niah_multikey(r, t, 5),
    "niah_multiquery": niah_multiquery,
    "niah_multivalue": niah_multivalue,
    "vt": vt,
    "cwe": cwe,
    "fwe": fwe,
    "qa_1": qa1,
    "qa_2": qa2,
}


# --------------------------------------------------------------------------
# longbench-mini subsets


def sdqa(r, target_len):
    return qa1(r, target_len)


def mdqa(r, target_len):
    return qa2(r, target_len)


def summ(r, target_len):
    w = WORDS[int(r.integers(0, len(WORDS)))]
    hay = _haystack(r, [f"!! topic {w}."], target_len)
    return f"{hay}Q topic\nA ", w


def trec(r, target_len, n_shots=None):
    """Few-shot question-type classification (the TREC-outlier proxy)."""
    lines = []
    budget = target_len - 40
    used = 0
    shots = 0
    while n_shots is None or shots < n_shots:
        lbl = TREC_LABELS[int(r.integers(0, len(TREC_LABELS)))]
        pat = TREC_PATTERNS[lbl][int(r.integers(0, len(TREC_PATTERNS[lbl])))]
        w = WORDS[int(r.integers(0, len(WORDS)))]
        line = f"{pat.format(w=w)} -> {lbl}"
        if used + len(line) + 1 > budget:
            break
        lines.append(line)
        used += len(line) + 1
        shots += 1
    lbl = TREC_LABELS[int(r.integers(0, len(TREC_LABELS)))]
    pat = TREC_PATTERNS[lbl][int(r.integers(0, len(TREC_PATTERNS[lbl])))]
    w = WORDS[int(r.integers(0, len(WORDS)))]
    prompt = "\n".join(lines) + f"\n{pat.format(w=w)} -> "
    return prompt, lbl


def fewshot_math(r, target_len):
    lines = []
    used = 0
    while used < target_len - 30:
        a, b = int(r.integers(10, 90)), int(r.integers(10, 90))
        line = f"{a} plus {b} is {a+b}."
        lines.append(line)
        used += len(line) + 1
    a, b = int(r.integers(10, 90)), int(r.integers(10, 90))
    return "\n".join(lines) + f"\n{a} plus {b} is ", str(a + b)


def count_task(r, target_len):
    n = int(r.integers(2, 8))
    marks = ["## section"] * n
    hay = _haystack(r, marks, target_len)
    return f"{hay}Q sections\nA ", str(n)


def passage_ret(r, target_len):
    n_docs = 4
    w = WORDS[int(r.integers(0, len(WORDS)))]
    target = int(r.integers(1, n_docs + 1))
    segs = []
    per = max((target_len - 40) // n_docs, 24)
    for i in range(1, n_docs + 1):
        segs.append(f"doc{i}: " + _filler_block(r, per - 20))
        if i == target:
            segs.append(f"the word {w} is here. ")
    return "".join(segs) + f"Q doc {w}\nA ", str(target)


def lcc(r, target_len):
    lines = []
    used = 0
    vals = {}
    i = 0
    while used < target_len - 30:
        i += 1
        v = int(r.integers(100, 999))
        vals[i] = v
        line = f"let a{i} = {v};"
        lines.append(line)
        used += len(line) + 1
    k = int(r.integers(1, i + 1))
    return "\n".join(lines) + f"\na{k} == ", str(vals[k])


def repobench(r, target_len):
    lines = []
    used = 0
    vals = {}
    i = 0
    while used < target_len - 40:
        i += 1
        v = int(r.integers(100, 999))
        vals[i] = v
        line = f"file{(i % 3) + 1}.rs: let b{i} = {v};"
        lines.append(line)
        used += len(line) + 1
    k = int(r.integers(1, i + 1))
    return "\n".join(lines) + f"\nb{k} == ", str(vals[k])


def kvret(r, target_len):
    return niah_multikey(r, target_len, 5)


LONGBENCH_SUBSETS = {
    "sdqa": sdqa,
    "mdqa": mdqa,
    "summ": summ,
    "trec": trec,
    "fewshot_math": fewshot_math,
    "count": count_task,
    "passage_ret": passage_ret,
    "lcc": lcc,
    "repobench": repobench,
    "kvret": kvret,
}


# --------------------------------------------------------------------------
# aime-mini: chain-of-thought integer arithmetic (decode-phase workload)


def aime(r, n_steps=None):
    """Returns (prompt, full_cot, answer). The model is trained to emit the
    whole chain; evaluation parses the final 'ANSWER n' line."""
    n_steps = n_steps or int(r.integers(6, 11))
    x = int(r.integers(10, 90))
    ops = []
    cur = x
    for _ in range(n_steps):
        while True:
            op = AIME_OPS[int(r.integers(0, len(AIME_OPS)))]
            n = int(r.integers(2, 9)) if op == "*" else int(r.integers(2, 99))
            nxt = cur * n if op == "*" else (cur + n if op == "+" else cur - n)
            if 0 < nxt < 9000:
                break
        ops.append((op, n))
        cur = nxt
    prompt = f"start {x}\nops " + " ".join(f"{o}{n}" for o, n in ops) + "\nA "
    steps = []
    v = x
    for o, n in ops:
        v = v * n if o == "*" else (v + n if o == "+" else v - n)
        steps.append(f"{o}{n} -> {v}")
    cot = "\n".join(steps) + f"\nANSWER {cur}"
    return prompt, cot, str(cur)


# --------------------------------------------------------------------------
# Training mixture


def _multilingual_block(r, n_bytes):
    """Accented-latin filler — the multilingual subset proxy."""
    toks = ["søren går", "el río es", "die straße", "põhja tuul", "çok güzel",
            "länge väg", "außer dem", "ça marche"]
    out, size = [], 0
    while size < n_bytes:
        s = toks[int(r.integers(0, len(toks)))] + " "
        out.append(s)
        size += len(s)
    return "".join(out)


def training_text(r, seq_len: int):
    """One training document: a task instance (with its answer) or filler.

    Returns (doc_bytes, answer_spans): spans are byte ranges (in doc
    coordinates, after the BOS) covering answer/chain-of-thought tokens —
    the LM loss upweights them, since retrieval answers are a tiny fraction
    of the byte stream (train.py, ANSWER_WEIGHT)."""
    kind = int(r.integers(0, 10))
    target = seq_len - 24
    spans = []
    if kind <= 5:   # ruler-style retrieval tasks — the core capability
        name = list(RULER_SUBSETS)[int(r.integers(0, len(RULER_SUBSETS)))]
        # vary prompt lengths so retrieval generalizes across contexts
        tgt = int(r.integers(max(target // 2, 48), target + 1))
        p, a = RULER_SUBSETS[name](r, tgt)
        text = p + a + "\n"
        spans.append((len(p), len(text)))
        # pack a second instance when budget remains (more retrieval
        # signal per document)
        if len(text) + 72 < target:
            p2, a2 = RULER_SUBSETS[name](r, target - len(text))
            spans.append((len(text) + len(p2), len(text) + len(p2) + len(a2) + 1))
            text += p2 + a2 + "\n"
    elif kind <= 7:  # longbench-style tasks
        name = list(LONGBENCH_SUBSETS)[int(r.integers(0, len(LONGBENCH_SUBSETS)))]
        p, a = LONGBENCH_SUBSETS[name](r, target)
        text = p + a + "\n"
        spans.append((len(p), len(text)))
    elif kind == 8:  # reasoning chains (decode-phase capability)
        p, cot, _ = aime(r)
        text = p + cot + "\n"
        spans.append((len(p), len(text)))
        if len(text) < target:
            p2, cot2, _ = aime(r)
            spans.append((len(text) + len(p2), len(text) + len(p2) + len(cot2) + 1))
            text += p2 + cot2 + "\n"
    else:            # multilingual / plain filler (common-crawl proxy)
        text = (_multilingual_block(r, target) if int(r.integers(0, 2)) == 0
                else _filler_block(r, target))
    enc = text.encode("utf-8", errors="replace")[: seq_len - 2]
    doc = bytes([BOS]) + enc + bytes([EOS])
    # shift spans by 1 for BOS and clip to the doc
    spans = [(s + 1, min(e + 1, len(doc))) for s, e in spans if s + 1 < len(doc)]
    return doc, spans


def training_batch(r, batch: int, seq_len: int):
    """Returns (tokens [B, S] int32, answer_mask [B, S] f32)."""
    out = np.zeros((batch, seq_len), np.int32)
    ans = np.zeros((batch, seq_len), np.float32)
    for b in range(batch):
        doc, spans = training_text(r, seq_len)
        out[b, : len(doc)] = np.frombuffer(doc, np.uint8)
        for s, e in spans:
            ans[b, s:e] = 1.0
    return out, ans


def surrogate_prompt(r, seq_len: int):
    """A prompt (no answer) for KVzip+ oracle scoring — mixed subsets, like
    the paper's Nemotron-Pretraining sample."""
    doc, _spans = training_text(r, seq_len)
    arr = np.zeros((seq_len,), np.int32)
    arr[: len(doc)] = np.frombuffer(doc, np.uint8)
    return arr, len(doc)
