"""AOT lowering: jax model -> HLO text artifacts for the rust runtime.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids that the crate's xla_extension
0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md and gen_hlo.py).

Each artifact is lowered per static (batch, length) bucket. Every parameter
tensor is a runtime input — the weights travel separately in weights.bin
(see weights_io.py) — so the rust side feeds [data inputs..., weight
buffers...] in the order recorded in artifacts/manifest.json.
"""

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .config import BUCKETS, MODEL, OBS_WINDOW, PAD, BOS, EOS, SEP, WINDOW
from .weights_io import flatten_params


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _io_entry(name, aval):
    return {"name": name, "shape": [int(d) for d in aval.shape],
            "dtype": {"int32": "i32", "float32": "f32"}[str(aval.dtype)]}


def _lower(fn, data_specs, params, out_names):
    """Lower fn(*data, params); return (hlo_text, inputs_meta, outputs_meta).

    keep_unused=True: the rust runtime feeds the SAME weight-buffer list to
    every artifact; without it jax DCEs unused parameters (e.g. w_out in
    the kvzip oracle, which never computes logits) and the compiled
    program's input arity would no longer match the manifest contract."""
    lowered = jax.jit(fn, keep_unused=True).lower(*[s for _, s in data_specs], params)
    hlo = to_hlo_text(lowered)
    inputs = [_io_entry(n, s) for n, s in data_specs]
    outs = lowered.out_info
    out_meta = [
        {"name": n, "shape": [int(d) for d in o.shape],
         "dtype": {"int32": "i32", "float32": "f32"}[str(o.dtype)]}
        for n, o in zip(out_names, jax.tree_util.tree_leaves(outs))
    ]
    return hlo, inputs, out_meta


def export_artifacts(params, out_dir: str, log=print):
    """Lower all buckets; returns the manifest dict (without weights section)."""
    cfg = MODEL
    L, Hkv, D, Tm, V = (cfg.n_layers, cfg.n_kv_heads, cfg.d_head,
                        cfg.t_max, cfg.vocab)
    arts = {}

    def emit(name, fn, data_specs, out_names, extra):
        hlo, inputs, outputs = _lower(fn, data_specs, params, out_names)
        path = f"{name}.hlo.txt"
        with open(f"{out_dir}/{path}", "w") as f:
            f.write(hlo)
        arts[name] = {"file": path, "inputs": inputs, "outputs": outputs,
                      **extra}
        log(f"  wrote {path} ({len(hlo)//1024} KiB)")

    for b in BUCKETS.prefill_b:
        for t in BUCKETS.prefill_t:
            emit(
                f"prefill_b{b}_t{t}",
                lambda tok, n, p: model.prefill_batch(p, tok, n),
                [("tokens", _spec((b, t), jnp.int32)),
                 ("true_len", _spec((b,), jnp.int32))],
                model.PREFILL_OUTPUTS,
                {"kind": "prefill", "batch": b, "t": t},
            )

    for b in BUCKETS.decode_b:
        emit(
            f"decode_b{b}",
            lambda tok, pos, kc, vc, m, p: model.decode_batch(
                p, tok, pos, kc, vc, m),
            [("tokens", _spec((b,), jnp.int32)),
             ("pos", _spec((b,), jnp.int32)),
             ("kcache", _spec((L, b, Hkv, Tm, D))),
             ("vcache", _spec((L, b, Hkv, Tm, D))),
             ("mask", _spec((L, b, Hkv, Tm)))],
            model.DECODE_OUTPUTS,
            {"kind": "decode", "batch": b, "t": Tm},
        )

    for t in BUCKETS.kvzip_t:
        emit(
            f"kvzip_score_t{t}",
            lambda tok, n, p: model.kvzip_batch(p, tok, n),
            [("tokens", _spec((1, t), jnp.int32)),
             ("true_len", _spec((1,), jnp.int32))],
            model.KVZIP_OUTPUTS,
            {"kind": "kvzip_score", "batch": 1, "t": t},
        )

    manifest = {
        "model": {
            "vocab": V, "d_model": cfg.d_model, "n_layers": L,
            "n_q_heads": cfg.n_q_heads, "n_kv_heads": Hkv, "d_head": D,
            "d_int": cfg.d_int, "d_surrogate": cfg.d_surrogate,
            "t_max": Tm, "rope_theta": cfg.rope_theta,
        },
        "special_tokens": {"pad": PAD, "bos": BOS, "eos": EOS, "sep": SEP},
        "window": WINDOW,
        "obs_window": OBS_WINDOW,
        "buckets": {
            "prefill_t": list(BUCKETS.prefill_t),
            "prefill_b": list(BUCKETS.prefill_b),
            "decode_b": list(BUCKETS.decode_b),
            "kvzip_t": list(BUCKETS.kvzip_t),
        },
        "param_order": [n for n, _ in flatten_params(params)],
        "artifacts": arts,
    }
    return manifest
