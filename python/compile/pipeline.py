"""The `make artifacts` entrypoint: train -> fit surrogates -> AOT export.

Runs ONCE at build time; the rust binary is self-contained afterwards.
Produces in artifacts/:
    weights.bin             all parameter tensors (LE f32, manifest order)
    manifest.json           model dims, buckets, IO specs, thresholds
    surrogate_metrics.json  Table 1 / Figs 6-8 data (R², score histograms)
    *.hlo.txt               prefill / decode / kvzip_score artifacts
and in results/: fig6_8 CSVs (score distribution + R² heatmaps).

KVZAP_FAST=1 shrinks training for smoke runs (CI); the default budget is
sized for a single CPU core (~10 min).
"""

import argparse
import json
import os
import time

import numpy as np

from . import aot, train, train_surrogate, weights_io
from .config import MODEL, fast_mode


def _write_fig6_8_csvs(metrics, results_dir):
    os.makedirs(results_dir, exist_ok=True)
    # Fig 6-8 left: KVzip+ log-score distribution.
    with open(f"{results_dir}/fig6_8_score_hist.csv", "w") as f:
        f.write("bin_left,bin_right,count\n")
        edges = metrics["target_hist_edges"]
        for i, c in enumerate(metrics["target_hist"]):
            f.write(f"{edges[i]:.4f},{edges[i+1]:.4f},{c}\n")
    # Fig 6-8 right: per-(layer, head) R² heatmap + linear-vs-mlp scatter.
    with open(f"{results_dir}/fig6_8_r2_heads.csv", "w") as f:
        f.write("layer,head,r2_linear,r2_mlp\n")
        lin = metrics["r2_linear"]
        mlp = metrics["r2_mlp"]
        for l in range(len(lin)):
            for h in range(len(lin[l])):
                f.write(f"{l},{h},{lin[l][h]:.4f},{mlp[l][h]:.4f}\n")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--results", default="../results")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    t0 = time.time()
    mode = "FAST (smoke)" if fast_mode() else "full"
    print(f"[pipeline] {mode} build starting")

    # Checkpoint-resume: pretraining is the longest phase; keep its output
    # so a failure later in the pipeline never re-pays it.
    ckpt_blob = f"{args.out}/checkpoint.bin"
    ckpt_meta = f"{args.out}/checkpoint.json"
    if (os.path.exists(ckpt_blob) and os.path.exists(ckpt_meta)
            and os.environ.get("KVZAP_RETRAIN", "0") != "1"):
        print("[pipeline] 1/4 reusing pretrained checkpoint "
              "(KVZAP_RETRAIN=1 to retrain)")
        import jax
        import jax.numpy as jnp
        from . import model
        entries = json.load(open(ckpt_meta))
        template = model.init_params(jax.random.PRNGKey(0))
        params = weights_io.load_weights(ckpt_blob, entries["weights"], template)
        params = jax.tree_util.tree_map(jnp.asarray, params)
        losses = entries["losses"]
    else:
        print("[pipeline] 1/4 pretraining zap-lm ...")
        params, losses = train.train()
        entries = weights_io.save_weights(params, ckpt_blob)
        with open(ckpt_meta, "w") as f:
            json.dump({"weights": entries, "losses": losses}, f)
    print(f"[pipeline] final loss {losses[-1]:.4f} "
          f"({time.time()-t0:.0f}s)")

    print("[pipeline] 2/4 fitting KVzap surrogates against KVzip+ oracle ...")
    params, metrics = train_surrogate.train_surrogates(params)
    print(f"[pipeline] Table 1  |  R2 linear {metrics['r2_linear_mean']:.3f}"
          f"  R2 mlp {metrics['r2_mlp_mean']:.3f}")
    metrics["train_losses"] = losses
    with open(f"{args.out}/surrogate_metrics.json", "w") as f:
        json.dump(metrics, f, indent=1)
    _write_fig6_8_csvs(metrics, args.results)

    print("[pipeline] 3/4 writing weights blob ...")
    entries = weights_io.save_weights(params, f"{args.out}/weights.bin")

    print("[pipeline] 4/4 AOT-lowering HLO artifacts ...")
    manifest = aot.export_artifacts(params, args.out)
    manifest["weights"] = entries
    # Default threshold sweep for the benches: quantiles of the oracle
    # log-score distribution (the paper sweeps tau per model the same way).
    manifest["threshold_quantiles"] = metrics["target_quantiles"]
    weights_io.save_manifest(f"{args.out}/manifest.json", manifest)

    print(f"[pipeline] done in {time.time()-t0:.0f}s -> {args.out}")


if __name__ == "__main__":
    main()
