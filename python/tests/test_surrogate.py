"""Surrogate-fitting machinery: ridge solve, MLP trainer, R² accounting."""

import numpy as np
import pytest

from compile import train_surrogate as ts
from compile.config import SurrogateTrainConfig


def test_ridge_recovers_linear_map():
    r = np.random.default_rng(0)
    n, d, h = 2000, 32, 4
    X = r.normal(size=(n, d)).astype(np.float32)
    W = r.normal(size=(d, h)).astype(np.float32)
    b = r.normal(size=(h,)).astype(np.float32)
    Y = X @ W + b + 0.01 * r.normal(size=(n, h)).astype(np.float32)
    w_hat, b_hat = ts.fit_linear(X, Y, lam=1e-4)
    pred = X @ w_hat + b_hat
    r2 = ts.r2_score(pred, Y)
    assert (r2 > 0.99).all(), r2


def test_mlp_fits_nonlinear_map():
    r = np.random.default_rng(1)
    n, d, h = 3000, 16, 2
    X = r.normal(size=(n, d)).astype(np.float32)
    Y = np.stack([np.tanh(X[:, 0] * 2), np.abs(X[:, 1])], 1).astype(np.float32)
    cfg = SurrogateTrainConfig(mlp_steps=600, mlp_batch=256, mlp_lr=5e-3)
    p = ts.fit_mlp(X, Y, dm=16, cfg=cfg, seed=0)
    import jax
    pred = np.asarray(jax.nn.gelu(X @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"])
    r2 = ts.r2_score(pred, Y)
    assert (r2 > 0.7).all(), r2
    # MLP must beat the best linear fit on this nonlinear target
    w_hat, b_hat = ts.fit_linear(X, Y, lam=1e-4)
    r2_lin = ts.r2_score(X @ w_hat + b_hat, Y)
    assert r2.mean() > r2_lin.mean() + 0.2


def test_r2_score_properties():
    y = np.random.default_rng(2).normal(size=(100, 3)).astype(np.float32)
    assert np.allclose(ts.r2_score(y, y), 1.0)
    assert (ts.r2_score(np.zeros_like(y) + y.mean(0), y) <= 1e-6).all()
