"""AOT artifact integrity: the HLO text round-trips through the XLA client
and reproduces the jax-side numerics; the manifest matches the model.

These tests use the real artifacts/ when present (after `make artifacts`)
and otherwise a throwaway tiny export, so the suite passes in both states.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model, weights_io
from compile.config import BUCKETS, MODEL

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _params():
    return model.init_params(jax.random.PRNGKey(3))


def test_hlo_text_roundtrip_executes():
    """Lower a prefill bucket to HLO text, re-parse and execute through the
    XLA client, and compare against the jax execution — the exact path the
    rust runtime uses."""
    params = _params()
    b, t = 1, 128
    fn = lambda tok, n, p: model.prefill_batch(p, tok, n)
    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((b, t), jnp.int32),
        jax.ShapeDtypeStruct((b,), jnp.int32),
        params,
    )
    hlo_text = aot.to_hlo_text(lowered)
    assert "ENTRY" in hlo_text

    from jax._src import xla_bridge
    backend = xla_bridge.get_backend()
    # The same XlaComputation whose as_hlo_text() the rust runtime parses:
    # round-trip it back to MLIR and execute through the XLA client.
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(lowered.compiler_ir("stablehlo")), use_tuple_args=False,
        return_tuple=True)
    exe = backend.compile_and_load(
        xc._xla.mlir.xla_computation_to_mlir_module(comp), backend.devices())

    r = np.random.default_rng(0)
    tok = r.integers(16, 255, size=(b, t)).astype(np.int32)
    n = np.asarray([100], np.int32)
    flat = [tok, n] + [np.asarray(x) for _, x in
                       weights_io.flatten_params(params)]
    outs = exe.execute([backend.buffer_from_pyval(x) for x in flat])
    got_logits = np.asarray(outs[0])
    assert "ENTRY" in hlo_text  # text form is what ships to rust

    want = jax.jit(fn)(jnp.asarray(tok), jnp.asarray(n), params)
    np.testing.assert_allclose(got_logits, np.asarray(want[0]),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.skipif(not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
                    reason="artifacts not built")
def test_manifest_consistency():
    m = json.load(open(os.path.join(ARTIFACTS, "manifest.json")))
    assert m["model"]["d_model"] == MODEL.d_model
    assert m["model"]["t_max"] == MODEL.t_max
    # every artifact file exists and every weight is inside the blob
    blob = os.path.getsize(os.path.join(ARTIFACTS, "weights.bin"))
    for name, a in m["artifacts"].items():
        assert os.path.exists(os.path.join(ARTIFACTS, a["file"])), name
        assert a["outputs"][0]["name"] in ("logits", "s"), name
    for w in m["weights"]:
        assert w["offset"] + w["bytes"] <= blob
        n_elem = int(np.prod(w["shape"])) if w["shape"] else 1
        assert n_elem * 4 == w["bytes"], w["name"]
    # weight order matches param_order (the rust runtime's contract)
    assert [w["name"] for w in m["weights"]] == m["param_order"]


@pytest.mark.skipif(not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
                    reason="artifacts not built")
def test_all_buckets_exported():
    m = json.load(open(os.path.join(ARTIFACTS, "manifest.json")))
    for b in BUCKETS.prefill_b:
        for t in BUCKETS.prefill_t:
            assert f"prefill_b{b}_t{t}" in m["artifacts"]
    for b in BUCKETS.decode_b:
        assert f"decode_b{b}" in m["artifacts"]
    for t in BUCKETS.kvzip_t:
        assert f"kvzip_score_t{t}" in m["artifacts"]


@pytest.mark.skipif(not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
                    reason="artifacts not built")
def test_surrogate_metrics_table1():
    sm = json.load(open(os.path.join(ARTIFACTS, "surrogate_metrics.json")))
    # Table 1 data present and sane
    assert 0.0 < sm["r2_mlp_mean"] <= 1.0
    assert 0.0 < sm["r2_linear_mean"] <= 1.0
    L, H = MODEL.n_layers, MODEL.n_kv_heads
    assert len(sm["r2_linear"]) == L and len(sm["r2_linear"][0]) == H
    qs = sm["target_quantiles"]
    vals = [qs[k] for k in sorted(qs, key=float)]
    assert vals == sorted(vals), "quantiles monotone"


def test_weights_roundtrip(tmp_path):
    params = _params()
    path = str(tmp_path / "w.bin")
    entries = weights_io.save_weights(params, path)
    back = weights_io.load_weights(path, entries, params)
    for (n1, a), (n2, b) in zip(weights_io.flatten_params(params),
                                weights_io.flatten_params(back)):
        assert n1 == n2
        np.testing.assert_array_equal(a, b)
