"""L1 kernel correctness: Pallas (interpret) vs the pure-jnp oracle.

hypothesis sweeps shapes, lengths and mask parameters — the CORE
correctness signal for the compute hot path (deliverable c).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    attention_with_stats,
    decode_attention,
    surrogate_linear,
    surrogate_mlp,
)
from compile.kernels import ref

RTOL, ATOL = 1e-5, 1e-5


def _rand(r, *shape, scale=1.0):
    return jnp.asarray(r.normal(size=shape) * scale, jnp.float32)


def assert_close(a, b, name):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=RTOL,
                               atol=ATOL, err_msg=name)


@settings(max_examples=12, deadline=None)
@given(
    g=st.sampled_from([1, 2, 4]),
    t=st.sampled_from([16, 33, 64, 96, 128]),
    d=st.sampled_from([8, 24]),
    seed=st.integers(0, 2**16),
    data=st.data(),
)
def test_attention_matches_ref(g, t, d, seed, data):
    r = np.random.default_rng(seed)
    true_len = data.draw(st.integers(1, t))
    stats_from = data.draw(st.integers(0, true_len))
    win_from = data.draw(st.integers(0, true_len))
    q = _rand(r, g, t, d, scale=0.3)
    k = _rand(r, t, d, scale=0.3)
    v = _rand(r, t, d)
    hinv = jnp.asarray(r.uniform(0.5, 2.0, size=(t,)), jnp.float32)
    block_q = data.draw(st.sampled_from([16, 32, 128]))

    got = attention_with_stats(q, k, v, hinv, true_len, stats_from, win_from,
                               block_q=block_q)
    want = ref.attention_with_stats_ref(q, k, v, hinv, true_len, stats_from,
                                        win_from)
    for a, b, name in zip(got, want, ["out", "max", "maxn", "cum", "win"]):
        assert_close(a, b, name)


def test_attention_pad_queries_do_not_pollute_stats():
    r = np.random.default_rng(0)
    g, t, d = 2, 64, 8
    q = _rand(r, g, t, d, scale=0.3)
    k = _rand(r, t, d, scale=0.3)
    v = _rand(r, t, d)
    hinv = jnp.ones((t,), jnp.float32)
    # stats must be identical whether pad region contains garbage or zeros
    out1 = attention_with_stats(q, k, v, hinv, 40, 0, 30)
    q2 = q.at[:, 40:].set(99.0)
    k2 = k.at[40:].set(-99.0)
    out2 = attention_with_stats(q2, k2, v, hinv, 40, 0, 30)
    for a, b, name in zip(out1[1:], out2[1:], ["max", "maxn", "cum", "win"]):
        assert_close(a, b, f"pad pollution in {name}")


def test_attention_causality():
    """Key i > query j never receives attention: perturbing future keys
    must not change earlier outputs."""
    r = np.random.default_rng(1)
    g, t, d = 2, 32, 8
    q = _rand(r, g, t, d, scale=0.3)
    k = _rand(r, t, d, scale=0.3)
    v = _rand(r, t, d)
    hinv = jnp.ones((t,), jnp.float32)
    out1 = attention_with_stats(q, k, v, hinv, t, 0, 0)[0]
    k2 = k.at[20:].add(5.0)
    v2 = v.at[20:].add(5.0)
    out2 = attention_with_stats(q, k2, v2, hinv, t, 0, 0)[0]
    assert_close(out1[:, :20], out2[:, :20], "causality")


@settings(max_examples=12, deadline=None)
@given(
    g=st.sampled_from([1, 4]),
    s=st.sampled_from([17, 64, 129, 513]),
    d=st.sampled_from([8, 24]),
    seed=st.integers(0, 2**16),
)
def test_decode_matches_ref(g, s, d, seed):
    r = np.random.default_rng(seed)
    q = _rand(r, g, d, scale=0.3)
    k = _rand(r, s, d, scale=0.3)
    v = _rand(r, s, d)
    mask = jnp.asarray(r.integers(0, 2, size=(s,)), jnp.float32).at[-1].set(1.0)
    o1, r1 = decode_attention(q, k, v, mask)
    o2, r2 = ref.decode_attention_ref(q, k, v, mask)
    assert_close(o1, o2, "decode out")
    assert_close(r1, r2, "decode row")


def test_decode_masked_positions_get_zero_attention():
    r = np.random.default_rng(2)
    g, s, d = 2, 64, 8
    q = _rand(r, g, d)
    k = _rand(r, s, d)
    v = _rand(r, s, d)
    mask = jnp.ones((s,), jnp.float32).at[10].set(0.0)
    _, row = decode_attention(q, k, v, mask)
    assert float(row[10]) == 0.0


@settings(max_examples=10, deadline=None)
@given(
    t=st.sampled_from([1, 7, 64, 130]),
    dh=st.sampled_from([32, 192]),
    h=st.sampled_from([2, 8]),
    seed=st.integers(0, 2**16),
)
def test_surrogates_match_ref(t, dh, h, seed):
    r = np.random.default_rng(seed)
    dm = dh // 8
    hs = _rand(r, t, dh, scale=0.5)
    w = _rand(r, dh, h, scale=0.1)
    b = _rand(r, h)
    assert_close(surrogate_linear(hs, w, b),
                 ref.surrogate_linear_ref(hs, w, b), "linear")
    w1, b1 = _rand(r, dh, dm, scale=0.1), _rand(r, dm)
    w2, b2 = _rand(r, dm, h, scale=0.1), _rand(r, h)
    assert_close(surrogate_mlp(hs, w1, b1, w2, b2),
                 ref.surrogate_mlp_ref(hs, w1, b1, w2, b2), "mlp")


def test_attention_probabilities_sum_to_one():
    """cum_attn summed over keys equals (#group heads x #stat queries)."""
    r = np.random.default_rng(3)
    g, t, d = 4, 64, 8
    q = _rand(r, g, t, d, scale=0.3)
    k = _rand(r, t, d, scale=0.3)
    v = _rand(r, t, d)
    hinv = jnp.ones((t,), jnp.float32)
    true_len = 50
    _, _, _, cum, _ = attention_with_stats(q, k, v, hinv, true_len, 0, true_len)
    np.testing.assert_allclose(float(jnp.sum(cum)), g * true_len, rtol=1e-4)
