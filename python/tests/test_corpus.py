"""Corpus / task-grammar tests: formats, determinism, answer validity."""

import numpy as np
import pytest

from compile import corpus
from compile.config import BOS, EOS


def test_ruler_subsets_all_generate():
    r = corpus.rng_for(0)
    for name, fn in corpus.RULER_SUBSETS.items():
        p, a = fn(r, 200)
        assert p.endswith("A "), name
        assert len(a) >= 1, name
        assert len(p) <= 240, f"{name}: {len(p)}"


def test_longbench_subsets_all_generate():
    r = corpus.rng_for(1)
    for name, fn in corpus.LONGBENCH_SUBSETS.items():
        p, a = fn(r, 200)
        assert len(a) >= 1, name
        assert len(p) <= 260, f"{name}: {len(p)}"


def test_needle_answer_is_in_prompt():
    r = corpus.rng_for(2)
    for _ in range(20):
        p, a = corpus.niah_single(r, 200)
        assert a in p, "needle value must appear in the haystack"


def test_aime_chain_consistent():
    r = corpus.rng_for(3)
    for _ in range(20):
        prompt, cot, answer = corpus.aime(r)
        # replay ops
        lines = prompt.split("\n")
        start = int(lines[0].split(" ")[1])
        cur = start
        for op in lines[1].split(" ")[1:]:
            sym, n = op[0], int(op[1:])
            cur = cur * n if sym == "*" else (cur + n if sym == "+" else cur - n)
            assert 0 < cur < 9000
        assert str(cur) == answer
        assert cot.endswith(f"ANSWER {answer}")


def test_training_text_framing():
    r = corpus.rng_for(4)
    for _ in range(30):
        doc, spans = corpus.training_text(r, 192)
        assert doc[0] == BOS
        assert doc[-1] == EOS
        assert len(doc) <= 192
        assert all(b == 0 or b >= 9 for b in doc[1:-1]), "no stray specials"
        for s, e in spans:
            assert 0 < s <= e <= len(doc)


def test_training_batch_shape_and_padding():
    r = corpus.rng_for(5)
    b, ans = corpus.training_batch(r, 4, 128)
    assert b.shape == (4, 128)
    assert ans.shape == (4, 128)
    assert b.dtype == np.int32
    assert (b >= 0).all() and (b < 256).all()
    assert set(np.unique(ans)) <= {0.0, 1.0}
    # answer masks only cover non-pad tokens
    assert (b[ans > 0] != 0).all()


def test_trec_over_prompting_shots_monotone():
    """More shots -> longer prompt (the over-prompting ablation knob)."""
    r1, r2 = corpus.rng_for(6), corpus.rng_for(6)
    p1, _ = corpus.trec(r1, 400, n_shots=3)
    p2, _ = corpus.trec(r2, 400, n_shots=10)
    assert len(p2) > len(p1)


def test_generators_deterministic_per_seed():
    a, sa = corpus.training_text(corpus.rng_for(42), 160)
    b, sb = corpus.training_text(corpus.rng_for(42), 160)
    assert a == b and sa == sb
