"""L2 model semantics: prefill/decode consistency, KVzip oracle properties,
training-path vs kernel-path equivalence, GQA/RoPE invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.config import MODEL


@pytest.fixture(scope="module")
def params():
    return M.init_params(jax.random.PRNGKey(7))


@pytest.fixture(scope="module")
def tokens():
    r = np.random.default_rng(0)
    return jnp.asarray(r.integers(16, 255, size=(64,)), jnp.int32)


def test_train_path_matches_kernel_path(params, tokens):
    """The pure-jnp training forward and the Pallas prefill forward must
    produce identical hidden states (same math, different kernels)."""
    T = tokens.shape[0]
    h = params["embed"][tokens]
    cos, sin = M.rope_tables(jnp.arange(T))
    layers = M._scan_layers(params, MODEL)

    def train_fwd(h):
        def step(h, layer):
            return M._layer_train(h, layer, cos, sin, MODEL), None
        return jax.lax.scan(step, h, layers)[0]

    def kernel_fwd(h):
        def step(h, layer):
            h2, _ = M._layer_prefill(h, layer, cos, sin, T, 0, T, MODEL,
                                     want_stats=False)
            return h2, None
        return jax.lax.scan(step, h, layers)[0]

    np.testing.assert_allclose(np.asarray(train_fwd(h)),
                               np.asarray(kernel_fwd(h)),
                               rtol=2e-4, atol=2e-4)


def test_prefill_decode_consistency(params, tokens):
    """Decoding token t+1 with the prefill-produced cache must give the same
    logits as prefilling t+1 tokens directly (the KV cache is faithful)."""
    T = 48
    toks = tokens[:T]
    logits_full, _ = M.prefill_single(params, toks, T)

    # prefill T-1 then decode the last token through the cache path
    _, pre = M.prefill_single(params, toks[:-1], T - 1)
    L, Hkv, Tm, D = (MODEL.n_layers, MODEL.n_kv_heads, MODEL.t_max,
                     MODEL.d_head)
    mask = jnp.zeros((L, Hkv, Tm))
    mask = mask.at[:, :, : T - 1].set(1.0)
    logits_dec, _, _, _, _, _, _ = M.decode_single(
        params, toks[-1], jnp.asarray(T - 1), pre["k"], pre["v"], mask)
    np.testing.assert_allclose(np.asarray(logits_full), np.asarray(logits_dec),
                               rtol=2e-4, atol=2e-4)


def test_decode_writes_kv_at_position(params, tokens):
    L, Hkv, Tm, D = (MODEL.n_layers, MODEL.n_kv_heads, MODEL.t_max,
                     MODEL.d_head)
    kc = jnp.zeros((L, Hkv, Tm, D))
    vc = jnp.zeros((L, Hkv, Tm, D))
    mask = jnp.zeros((L, Hkv, Tm))
    pos = jnp.asarray(17)
    _, kc2, vc2, _, _, _, _ = M.decode_single(
        params, tokens[0], pos, kc, vc, mask)
    kc2 = np.array(kc2)  # writable host copy
    assert np.abs(kc2[:, :, 17]).sum() > 0, "new KV written at pos"
    kc2[:, :, 17] = 0
    assert np.abs(kc2).sum() == 0, "only pos slot written"


def test_masked_kv_does_not_affect_decode(params, tokens):
    """Evicting (masking) a KV pair changes nothing except removing that
    pair's contribution — a fully-masked dummy row must be inert."""
    T = 32
    toks = tokens[:T]
    _, pre = M.prefill_single(params, toks, T)
    L, Hkv, Tm = MODEL.n_layers, MODEL.n_kv_heads, MODEL.t_max
    mask = jnp.zeros((L, Hkv, Tm)).at[:, :, :T].set(1.0)
    logits1, *_ = M.decode_single(params, tokens[0], jnp.asarray(T),
                                  pre["k"], pre["v"], mask)
    # poison the cache rows that are masked out (beyond T)
    k2 = pre["k"].at[:, :, T + 1 :].set(99.0)
    v2 = pre["v"].at[:, :, T + 1 :].set(-99.0)
    logits2, *_ = M.decode_single(params, tokens[0], jnp.asarray(T), k2, v2,
                                  mask)
    np.testing.assert_allclose(np.asarray(logits1), np.asarray(logits2),
                               rtol=1e-5, atol=1e-5)


def test_kvzip_scores_shape_and_range(params, tokens):
    T = 64
    s, sp = M.kvzip_scores(params, tokens[:T], jnp.asarray(50))
    assert s.shape == (MODEL.n_layers, MODEL.n_kv_heads, T)
    s = np.asarray(s)
    # Only the original-prompt region [0, true_len) is meaningful (the
    # repeat is placed at offset true_len; rust never reads beyond it).
    assert (s[:, :, :50] >= 0).all() and (s[:, :, :50] <= 1.0 + 1e-5).all(), \
        "Eq.1 scores are attention probabilities"
    assert np.asarray(sp)[:, :, :50].min() >= 0.0
    # every head must attend somewhere in the prompt while repeating it
    assert (s[:, :, :50].max(axis=2) > 0).all()


def test_kvzip_scores_padding_invariant(params, tokens):
    """Oracle scores for a prompt must not depend on the padding bucket."""
    n = 40
    s1, sp1 = M.kvzip_scores(params, tokens[:48], jnp.asarray(n))
    s2, sp2 = M.kvzip_scores(params, tokens[:64], jnp.asarray(n))
    np.testing.assert_allclose(np.asarray(s1)[:, :, :n],
                               np.asarray(s2)[:, :, :n], rtol=2e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(sp1)[:, :, :n],
                               np.asarray(sp2)[:, :, :n], rtol=2e-4, atol=1e-5)


def test_surrogate_scores_independent_of_future(params, tokens):
    """KVzap scores depend only on the hidden state at each position —
    changing later tokens must not change earlier scores (criterion for
    decode-time applicability)."""
    T = 48
    _, pre1 = M.prefill_single(params, tokens[:T], T, t_out=T)
    toks2 = tokens[:T].at[40:].set(77)
    _, pre2 = M.prefill_single(params, toks2, T, t_out=T)
    np.testing.assert_allclose(np.asarray(pre1["score_mlp"])[:, :, :40],
                               np.asarray(pre2["score_mlp"])[:, :, :40],
                               rtol=2e-4, atol=2e-5)


def test_param_counts_match_appendix(params):
    # zap-lm surrogates follow the paper's architecture: Dm = Dh/8
    assert MODEL.d_surrogate == MODEL.d_model // 8
    lin = M.surrogate_param_count(params, "linear")
    mlp = M.surrogate_param_count(params, "mlp")
    L, Dh, Hkv, Dm = (MODEL.n_layers, MODEL.d_model, MODEL.n_kv_heads,
                      MODEL.d_surrogate)
    assert lin == L * (Dh * Hkv + Hkv)
    assert mlp == L * (Dh * Dm + Dm + Dm * Hkv + Hkv)
    assert mlp > lin


def test_lm_loss_decreases_with_teacher_forcing(params):
    """Sanity: loss on a repeated-token sequence is far below uniform."""
    toks = jnp.full((1, 64), 65, jnp.int32)
    loss_uniform = float(jnp.log(jnp.asarray(MODEL.vocab, jnp.float32)))
    # an untrained model should be near uniform
    loss = float(M.lm_loss(params, toks))
    assert abs(loss - loss_uniform) < 1.5
