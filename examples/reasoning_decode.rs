//! Decode-phase pruning on reasoning chains (paper §4.6 / Criterion 2).
//!
//! KVzip cannot prune during decoding; KVzap can, because its scores come
//! from hidden states. This example runs aime-mini chains and shows the
//! sliding-window score buffer evicting KV pairs *while the chain is being
//! generated*, with pass@1 preserved.
//!
//!     cargo run --release --example reasoning_decode

use std::sync::Arc;

use kvzap::coordinator::{Engine, SamplingParams};
use kvzap::policies;
use kvzap::runtime::Runtime;
use kvzap::util::rng::Rng;
use kvzap::workload::{self, generators::parse_aime_answer};

fn main() -> anyhow::Result<()> {
    let rt = Runtime::auto()?;
    let engine = Engine::new(Arc::new(rt));
    let mut rng = Rng::new(5);

    println!("aime-mini reasoning with decode-time pruning (kvzap_mlp, τ=-4)\n");
    for spec in ["full", "kvzap_mlp:-4"] {
        let policy = policies::by_name(spec, engine.window()).unwrap();
        let mut pass = 0;
        let mut comp = 0.0;
        let mut evictions = 0;
        let n = 6;
        for i in 0..n {
            let a = workload::aime_instance(&mut rng.fork(i));
            let sp = SamplingParams::greedy(a.task.max_new);
            let r = engine.generate(&a.task.prompt, policy.as_ref(), &sp)?;
            let ok = parse_aime_answer(&r.text).as_deref() == Some(a.task.answer.as_str());
            pass += ok as usize;
            comp += r.compression;
            evictions += r.decode_evictions;
            if i == 0 {
                println!("  sample chain ({spec}):");
                for line in r.text.lines().take(4) {
                    println!("    {line}");
                }
                println!("    ... answer expected {}\n", a.task.answer);
            }
        }
        println!(
            "{spec:<14} pass@1 {:.2}  compression {:.3}  decode-evictions {}\n",
            pass as f64 / n as f64,
            comp / n as f64,
            evictions
        );
    }
    println!(
        "KVzip-style oracles cannot produce the decode-eviction column at\n\
         all — scoring mid-generation is exactly what the surrogate enables."
    );
    Ok(())
}
