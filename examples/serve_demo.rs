//! End-to-end serving driver (deliverable: E2E validation).
//!
//! Boots the full stack — runtime, engine, continuous batcher, TCP
//! JSON-lines server — then drives it with concurrent clients running a
//! real ruler-mini workload, and reports answer accuracy, latency
//! percentiles, throughput and KV cache compression. Ends with a v2
//! streaming request (token events + done line).
//!
//!     cargo run --release --example serve_demo [-- <n_requests>]

use std::io::Write as _;
use std::sync::Arc;

use kvzap::coordinator::Engine;
use kvzap::runtime::Runtime;
use kvzap::server::{Client, Server, ServerConfig};
use kvzap::util::histogram::Histogram;
use kvzap::util::json::Json;
use kvzap::util::rng::Rng;
use kvzap::workload;

fn main() -> anyhow::Result<()> {
    let n_requests: usize = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with('-'))
        .and_then(|a| a.parse().ok())
        .unwrap_or(16);

    let rt = Runtime::auto()?;
    let engine = Arc::new(Engine::new(Arc::new(rt)));
    // Pre-compile the buckets the workload will hit so latency numbers
    // measure serving, not JIT compilation.
    engine.rt.artifact("prefill_b1_t256")?;
    engine.rt.artifact("prefill_b4_t256")?;
    engine.rt.artifact("decode_b1")?;
    engine.rt.artifact("decode_b4")?;

    let cfg = ServerConfig {
        addr: "127.0.0.1:7713".into(),
        default_policy: "kvzap_mlp:-4".into(),
        max_batch: 4,
        max_wait_us: 3_000,
    };
    let addr = cfg.addr.clone();
    let server = Arc::new(Server::new(engine.clone(), cfg));
    let srv = server.clone();
    let handle = std::thread::spawn(move || srv.serve());
    std::thread::sleep(std::time::Duration::from_millis(200));

    println!("driving {n_requests} requests from 4 concurrent clients ...");
    let t0 = std::time::Instant::now();
    let mut client_handles = vec![];
    for c in 0..4 {
        let addr = addr.clone();
        client_handles.push(std::thread::spawn(move || -> anyhow::Result<(usize, usize, f64, Vec<u64>)> {
            let mut client = Client::connect(&addr)?;
            let mut rng = Rng::new(100 + c as u64);
            let (mut ok, mut total, mut comp) = (0usize, 0usize, 0.0f64);
            let mut lats = vec![];
            for i in 0..n_requests / 4 {
                let task = workload::ruler_instance(
                    "niah_multikey_1", 240, &mut rng.fork(i as u64));
                let req = Json::obj(vec![
                    ("prompt", Json::str(task.prompt.clone())),
                    ("max_new", Json::num(task.max_new as f64)),
                ]);
                let t = std::time::Instant::now();
                let resp = client.request(&req)?;
                lats.push(t.elapsed().as_micros() as u64);
                let text = resp.get("text").and_then(|t| t.as_str()).unwrap_or("");
                ok += task.score(text) as usize;
                comp += resp.get("compression").and_then(|c| c.as_f64()).unwrap_or(0.0);
                total += 1;
            }
            Ok((ok, total, comp, lats))
        }));
    }

    let mut hist = Histogram::new();
    let (mut ok, mut total, mut comp) = (0, 0, 0.0);
    for h in client_handles {
        let (o, t, c, lats) = h.join().unwrap()?;
        ok += o;
        total += t;
        comp += c;
        for l in lats {
            hist.record(l);
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    println!("\n== serve_demo results (policy kvzap_mlp:-4)");
    println!("requests        : {total}");
    println!("accuracy        : {:.1}%", 100.0 * ok as f64 / total as f64);
    println!("mean compression: {:.3} ({:.2}x)", comp / total as f64,
             1.0 / (1.0 - comp / total as f64).max(1e-9));
    println!("throughput      : {:.2} req/s", total as f64 / wall);
    println!("latency         : {}", hist.summary("us"));
    println!("\nengine metrics:\n{}", engine.metrics.report());

    // v2 streaming: tokens arrive as they are decoded, keyed by request id
    let mut sc = Client::connect(&addr)?;
    let task = workload::ruler_instance("niah_single_1", 240, &mut Rng::new(999));
    let req = Json::obj(vec![
        ("id", Json::str("stream-demo")),
        ("prompt", Json::str(task.prompt.clone())),
        ("max_new", Json::num(task.max_new as f64)),
        ("stream", Json::Bool(true)),
    ]);
    print!("\nstreaming demo  : ");
    let done = sc.stream(&req, |t| {
        print!("{t}");
        let _ = std::io::stdout().flush();
    })?;
    println!(
        "  <- done reason={} tokens={} compression={:.3}",
        done.get("reason").and_then(|r| r.as_str()).unwrap_or("?"),
        done.get("tokens_out").and_then(|t| t.as_usize()).unwrap_or(0),
        done.get("compression").and_then(|c| c.as_f64()).unwrap_or(0.0),
    );

    // clean shutdown
    let mut c = Client::connect(&addr)?;
    c.shutdown()?;
    let _ = handle.join();
    Ok(())
}
