//! Input-adaptive compression (paper §4.7 / Fig. 5 left, as a demo).
//!
//! The same KVzap threshold τ yields different compression ratios on
//! different inputs: repetitive synthetic haystacks (ruler-mini) compress
//! harder than information-dense few-shot prompts (longbench-mini trec).
//!
//!     cargo run --release --example adaptive_compression

use std::sync::Arc;

use kvzap::coordinator::{Engine, SamplingParams};
use kvzap::policies;
use kvzap::runtime::Runtime;
use kvzap::util::rng::Rng;
use kvzap::workload;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::auto()?;
    let engine = Engine::new(Arc::new(rt));
    let policy = policies::by_name("kvzap_mlp:-4", engine.window()).unwrap();
    let mut rng = Rng::new(11);

    let mut groups: Vec<(&str, Vec<f64>)> = vec![];
    for (label, suite, subset) in [
        ("ruler niah (repetitive)", "ruler", "niah_single_1"),
        ("ruler vt   (tracing)", "ruler", "vt"),
        ("longbench trec (dense)", "longbench", "trec"),
        ("longbench lcc  (code)", "longbench", "lcc"),
    ] {
        let mut comps = vec![];
        for i in 0..6 {
            let mut r = rng.fork(i);
            let task = if suite == "ruler" {
                workload::ruler_instance(subset, 240, &mut r)
            } else {
                workload::longbench_instance(subset, 240, &mut r)
            };
            let res = engine.generate(
                &task.prompt,
                policy.as_ref(),
                &SamplingParams::greedy(task.max_new),
            )?;
            comps.push(res.compression);
        }
        groups.push((label, comps));
    }

    println!("same threshold τ=-4, per-prompt compression ratios:\n");
    for (label, comps) in &groups {
        let mean = comps.iter().sum::<f64>() / comps.len() as f64;
        let lo = comps.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = comps.iter().cloned().fold(0.0f64, f64::max);
        let bar = "#".repeat((mean * 40.0) as usize);
        println!("{label:<26} mean {mean:.3}  range [{lo:.3}, {hi:.3}]  {bar}");
    }
    println!(
        "\nThresholding adapts the rate to prompt information density\n\
         (paper §4.7): no fixed budget gets both the repetitive and the\n\
         dense prompts right simultaneously."
    );
    Ok(())
}
