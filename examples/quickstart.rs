//! Quickstart: load the best available backend (reference or PJRT
//! artifacts), serve one prompt with and without
//! KVzap pruning, and inspect the accuracy/compression trade-off.
//!
//! Runs hermetically from a fresh checkout (no artifacts needed):
//!     cargo run --release --example quickstart

use std::sync::Arc;

use kvzap::coordinator::{Engine, SamplingParams};
use kvzap::policies;
use kvzap::runtime::Runtime;
use kvzap::util::rng::Rng;
use kvzap::workload;

fn main() -> anyhow::Result<()> {
    // 1. Load the runtime: reference backend, or PJRT artifacts when built.
    let rt = Runtime::auto()?;
    let engine = Engine::new(Arc::new(rt));

    // 2. A needle-in-a-haystack task from the ruler-mini workload.
    let mut rng = Rng::new(7);
    let task = workload::ruler_instance("niah_single_1", 240, &mut rng);
    println!("prompt tail: ...{:?}", &task.prompt[task.prompt.len() - 24..]);
    println!("expected answer: {:?}\n", task.answer);

    // 3. Generate with the full cache, then with KVzap-MLP thresholding.
    let sp = SamplingParams::greedy(task.max_new);
    for spec in ["full", "kvzap_mlp:-4", "kvzap_mlp:-2"] {
        let policy = policies::by_name(spec, engine.window()).unwrap();
        let r = engine.generate(&task.prompt, policy.as_ref(), &sp)?;
        println!(
            "{spec:<14} -> {:?}  correct={}  compression={:.2} ({:.1}x)  \
             prefill={}ms decode={}ms",
            r.text,
            task.score(&r.text),
            r.compression,
            1.0 / (1.0 - r.compression).max(1e-9),
            r.prefill_us / 1000,
            r.decode_us / 1000,
        );
    }

    // 4. Engine metrics (what the serving frontend exports).
    println!("\n{}", engine.metrics.report());
    Ok(())
}
