//! §Perf microbenchmarks (criterion substitute): the numbers behind
//! EXPERIMENTS.md §Perf before/after table.
//!
//! Measures, per layer of the stack:
//!   L3 host path : policy decision, mask rebuild, cache ops, JSON codec
//!   runtime      : prefill per bucket, decode step (B=1/B=4), oracle pass
//!   serving      : batched vs sequential throughput
//!
//!     cargo bench --bench bench_perf -- --iters 5

use std::sync::Arc;

use kvzap::bench_support::{load_engine, results_dir, time_us, write_csv, BenchArgs};
use kvzap::coordinator::SamplingParams;
use kvzap::kvcache::PagedKvCache;
use kvzap::policies::{self, PrunePolicy};
use kvzap::runtime::{Arg, Tensor};
use kvzap::util::json::Json;
use kvzap::util::rng::Rng;
use kvzap::workload;

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::parse();
    let iters = args.usize("iters", 5);
    let engine = load_engine()?;
    let man = engine.rt.manifest.clone();
    let (l, h, tm) = (man.model.n_layers, man.model.n_kv_heads, man.model.t_max);
    let mut csv = vec![];
    let mut emit = |name: &str, us: f64| {
        println!("  {name:<36} {us:>10.1} us");
        csv.push(format!("{name},{us:.1}"));
    };

    println!("== L3 host-path microbenchmarks");
    // policy decision over realistic stat tensors
    let mut rng = Rng::new(1);
    let n = l * h * tm;
    let data: Vec<f32> = (0..n).map(|_| rng.f64() as f32).collect();
    let t = Tensor::new(data, vec![l, 1, h, tm]).unwrap();
    let view = kvzap::policies::PrefillView {
        b: 0, score_lin: &t, score_mlp: &t, max_attn: &t, plus_attn: &t,
        cum_attn: &t, win_attn: &t, vnorm: &t, knorm: &t,
        oracle_s: Some(&t), oracle_s_plus: Some(&t),
    };
    for spec in ["kvzap_mlp:-4", "h2o:0.5", "kvzip:0.5", "adakv:0.5"] {
        let pol = policies::by_name(spec, man.window).unwrap();
        let us = time_us(3, iters.max(20), || {
            let mut cache = PagedKvCache::new(l, h, tm);
            cache.fill(tm - 16);
            pol.prefill_prune(&view, tm - 16, &mut cache);
        });
        emit(&format!("policy_decision[{spec}]"), us);
    }
    // mask rebuild + cache ops
    let us = time_us(3, 50, || {
        let mut cache = PagedKvCache::new(l, h, tm);
        cache.fill(tm);
        let _ = cache.mask_f32();
    });
    emit("cache_fill_plus_mask", us);
    // JSON codec on a serving-size payload
    let payload = Json::obj(vec![
        ("prompt", Json::str("x".repeat(512))),
        ("max_new", Json::num(32.0)),
    ]).dump();
    let us = time_us(3, 200, || {
        let _ = Json::parse(&payload).unwrap();
    });
    emit("json_parse_request", us);

    println!("== runtime: artifact execution");
    for bucket in ["prefill_b1_t128", "prefill_b1_t256", "prefill_b1_t512", "prefill_b4_t256"] {
        let art = engine.rt.artifact(bucket)?;
        let (b, t_) = (art.meta.batch, art.meta.t);
        let toks = vec![65i32; b * t_];
        let lens = vec![t_ as i32; b];
        let us = time_us(1, iters, || {
            engine.rt.exec(&art, &[Arg::I32(&toks, &[b, t_]), Arg::I32(&lens, &[b])]).unwrap();
        });
        emit(&format!("exec[{bucket}]"), us);
    }
    for bucket in ["decode_b1", "decode_b4"] {
        let art = engine.rt.artifact(bucket)?;
        let b = art.meta.batch;
        // bootstrap a cache with a prefill
        let pf = engine.rt.artifact(&format!("prefill_b{b}_t128", b = b))?;
        let toks = vec![65i32; b * 128];
        let lens = vec![128i32; b];
        let outs = engine
            .rt
            .exec(&pf, &[Arg::I32(&toks, &[b, 128]), Arg::I32(&lens, &[b])])?;
        let ki = pf.meta.output_index("kcache")?;
        let vi = pf.meta.output_index("vcache")?;
        let tok = vec![66i32; b];
        let pos = vec![128i32; b];
        let mask = vec![1.0f32; l * b * h * tm];
        let mask_buf = engine.rt.upload_f32(&mask, &[l, b, h, tm])?;
        let us = time_us(1, iters.max(10), || {
            engine
                .rt
                .exec(
                    &art,
                    &[
                        Arg::I32(&tok, &[b]),
                        Arg::I32(&pos, &[b]),
                        Arg::Buf(&outs[ki]),
                        Arg::Buf(&outs[vi]),
                        Arg::Buf(&mask_buf),
                    ],
                )
                .unwrap();
        });
        emit(&format!("exec[{bucket}] (per step)"), us);
    }
    {
        let art = engine.rt.artifact("kvzip_score_t256")?;
        let toks = vec![65i32; 256];
        let lens = vec![200i32];
        let us = time_us(1, iters, || {
            engine.rt.exec(&art, &[Arg::I32(&toks, &[1, 256]), Arg::I32(&lens, &[1])]).unwrap();
        });
        emit("exec[kvzip oracle t256] (2x pass)", us);
    }

    println!("== serving: batched vs sequential (4 requests)");
    let mut rng = Rng::new(4);
    let tasks: Vec<_> = (0..4)
        .map(|i| workload::ruler_instance("niah_single_1", 240, &mut rng.fork(i)))
        .collect();
    let policy = policies::by_name("kvzap_mlp:-4", man.window).unwrap();
    let sp = SamplingParams::greedy(8);
    let us_seq = time_us(1, iters, || {
        for t in &tasks {
            engine.generate(&t.prompt, policy.as_ref(), &sp).unwrap();
        }
    });
    emit("4 requests sequential (b=1)", us_seq);
    let prompts: Vec<&str> = tasks.iter().map(|t| t.prompt.as_str()).collect();
    let us_bat = time_us(1, iters, || {
        engine.generate_batch(&prompts, policy.as_ref(), &sp).unwrap();
    });
    emit("4 requests batched    (b=4)", us_bat);
    println!("  batching speedup: {:.2}x", us_seq / us_bat);

    write_csv(&results_dir().join("perf_microbench.csv"), "name,median_us", &csv)?;
    Ok(())
}
