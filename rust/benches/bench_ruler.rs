//! Figure 1 / Figure 2 / Figures 9-11: RULER accuracy-vs-compression.
//!
//! Sweeps every pruning method over the 13 ruler-mini subsets at the
//! "4k"-scaled context (and "16k"-scaled with --long), writing
//! results/fig1_frontier.csv (+ fig2 zoom series and per-subset CSVs for
//! Figs 9-11) and printing the leaderboard-style frontier table.
//!
//!     cargo bench --bench bench_ruler -- --samples 4 [--per-subset] [--long]

use kvzap::bench_support::{
    aggregate, default_taus, eval_policy, load_engine, print_frontier, results_dir, write_csv,
    BenchArgs, KEEP_FRACS,
};
use kvzap::workload::RULER_SUBSETS;

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::parse();
    let samples = args.usize("samples", 2);
    let seed = args.usize("seed", 42) as u64;
    let ctx = if args.flag("long") { 368 } else { 248 }; // "16k" / "4k" scaled
    let engine = load_engine()?;
    let taus = default_taus(&engine);

    // The Fig. 1 method zoo: KVzap variants + oracles + baselines. The
    // default run (single CPU core) sweeps a reduced grid; --full restores
    // the complete 17-method x 4-point sweep of the paper's figure.
    let (fracs, baselines): (&[f64], &[&str]) = if args.flag("full") {
        (KEEP_FRACS, &[
            "kvzip", "kvzip_plus", "h2o", "snapkv", "adakv", "tova",
            "observed_attn", "expected_attn", "knorm", "streaming_llm", "random",
        ])
    } else {
        (&[0.6, 0.35], &[
            "kvzip", "kvzip_plus", "h2o", "snapkv", "expected_attn",
            "streaming_llm", "random",
        ])
    };
    let mut specs: Vec<String> = vec![];
    for t in &taus {
        specs.push(format!("kvzap_mlp:{t:.2}"));
        specs.push(format!("kvzap_linear:{t:.2}"));
    }
    for f in fracs {
        for name in baselines {
            specs.push(format!("{name}:{f}"));
        }
    }
    specs.push("full".into());

    let mut frontier: Vec<(String, f64, f64, f64)> = vec![];
    let mut csv = vec![];
    let mut per_subset_csv = vec![];
    for spec in &specs {
        let rows = eval_policy(&engine, "ruler", RULER_SUBSETS, spec, samples, ctx, seed)?;
        let (acc, comp, nll) = aggregate(&rows);
        eprintln!("  {spec:<28} acc {:>5.1}%  nll {nll:.3}  comp {comp:.3}", acc * 100.0);
        frontier.push((spec.clone(), comp, acc, nll));
        csv.push(format!("{spec},{comp:.4},{acc:.4},{nll:.4},{samples}"));
        for r in rows {
            per_subset_csv.push(format!(
                "{spec},{},{:.4},{:.4},{:.4}",
                r.subset, r.compression, r.accuracy, r.nll
            ));
        }
    }

    let tag = if args.flag("long") { "16k" } else { "4k" };
    write_csv(
        &results_dir().join(format!("fig1_frontier_{tag}.csv")),
        "policy,compression,accuracy,nll,n",
        &csv,
    )?;
    if args.flag("per-subset") {
        // Figures 9-11: per-subset curves.
        write_csv(
            &results_dir().join(format!("fig9_11_per_subset_{tag}.csv")),
            "policy,subset,compression,accuracy,nll",
            &per_subset_csv,
        )?;
    }
    print_frontier(&format!("Figure 1 | ruler-mini {tag} frontier"), &frontier);

    // Figure 2: zoomed comparison, KVzap vs the oracles it approximates.
    let zoom: Vec<(String, f64, f64, f64)> = frontier
        .iter()
        .filter(|(s, _, _, _)| {
            s.starts_with("kvzap_") || s.starts_with("kvzip") || s.starts_with("expected_attn")
                || s == "full"
        })
        .cloned()
        .collect();
    print_frontier("Figure 2 | zoom: KVzap vs KVzip/KVzip+ oracles", &zoom);
    let zoom_csv: Vec<String> = zoom
        .iter()
        .map(|(s, c, a, n)| format!("{s},{c:.4},{a:.4},{n:.4}"))
        .collect();
    write_csv(
        &results_dir().join(format!("fig2_zoom_{tag}.csv")),
        "policy,compression,accuracy,nll",
        &zoom_csv,
    )?;
    Ok(())
}
