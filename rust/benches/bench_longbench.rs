//! Figure 3 / Figures 12-15: LongBench accuracy-vs-compression.
//!
//! Sweeps the method zoo over the 10 longbench-mini subsets, reports the
//! average with and without the TREC-proxy subset (the paper's Fig. 12
//! outlier analysis) and the TREC over-prompting probe (§4.5).
//!
//!     cargo bench --bench bench_longbench -- --samples 4 [--per-subset]

use kvzap::bench_support::{
    aggregate, default_taus, eval_policy, load_engine, print_frontier, results_dir, write_csv,
    BenchArgs, KEEP_FRACS,
};
use kvzap::workload::LONGBENCH_SUBSETS;

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::parse();
    let samples = args.usize("samples", 2);
    let seed = args.usize("seed", 43) as u64;
    let ctx = args.usize("ctx", 248);
    let engine = load_engine()?;
    let taus = default_taus(&engine);

    let fracs: &[f64] = if args.flag("full") { KEEP_FRACS } else { &[0.6, 0.35] };
    let mut specs: Vec<String> = vec!["full".into()];
    for t in &taus {
        specs.push(format!("kvzap_mlp:{t:.2}"));
        specs.push(format!("kvzap_linear:{t:.2}"));
    }
    for f in fracs {
        for name in ["kvzip", "kvzip_plus", "expected_attn", "snapkv", "streaming_llm"] {
            specs.push(format!("{name}:{f}"));
        }
    }

    let mut frontier = vec![];
    let mut frontier_no_trec = vec![];
    let mut csv = vec![];
    let mut per_subset = vec![];
    for spec in &specs {
        let rows =
            eval_policy(&engine, "longbench", LONGBENCH_SUBSETS, spec, samples, ctx, seed)?;
        let (acc, comp, nll) = aggregate(&rows);
        let no_trec: Vec<_> =
            rows.iter().filter(|r| r.subset != "trec").cloned().collect();
        let (acc_nt, comp_nt, _) = aggregate(&no_trec);
        eprintln!(
            "  {spec:<28} acc {:>5.1}% (excl. trec {:>5.1}%)  comp {comp:.3}",
            acc * 100.0,
            acc_nt * 100.0
        );
        frontier.push((spec.clone(), comp, acc, nll));
        frontier_no_trec.push((spec.clone(), comp_nt, acc_nt, nll));
        csv.push(format!("{spec},{comp:.4},{acc:.4},{nll:.4},{comp_nt:.4},{acc_nt:.4}"));
        for r in rows {
            per_subset.push(format!("{spec},{},{:.4},{:.4},{:.4}",
                r.subset, r.compression, r.accuracy, r.nll));
        }
    }
    write_csv(
        &results_dir().join("fig3_longbench_frontier.csv"),
        "policy,compression,accuracy,nll,compression_excl_trec,accuracy_excl_trec",
        &csv,
    )?;
    if args.flag("per-subset") {
        write_csv(
            &results_dir().join("fig13_15_per_subset.csv"),
            "policy,subset,compression,accuracy,nll",
            &per_subset,
        )?;
    }
    print_frontier("Figure 3 | longbench-mini frontier", &frontier);
    print_frontier("Figure 12 | longbench-mini frontier EXCLUDING trec", &frontier_no_trec);
    Ok(())
}
