//! Prefill throughput benchmark: the blocked + worker-pool reference
//! compute path vs the scalar path, swept over context length × thread
//! count × attention block size × SIMD mode.
//!
//! `threads = 1` is the scalar reference path (naive kernels, inline);
//! `threads = 0` means auto (`std::thread::available_parallelism`). On
//! blocked (threads > 1) sweeps each point runs twice: `simd=scalar` (the
//! blocked scalar oracle) and `simd=auto` (AVX2/NEON microkernels when the
//! host has them). All paths are bitwise identical (enforced by the
//! integration suite), so every speedup reported here is pure compute-path
//! win, not a numerics trade. Emits `BENCH_prefill.json` at the repo root
//! (same shape as `BENCH_decode.json`); each row carries `tok_s`,
//! `speedup` relative to the naive threads=1 run at the same (context,
//! block size), and `simd_speedup` relative to the simd=scalar leg at the
//! same thread budget.
//!
//!     cargo bench --bench bench_prefill            # full sweep
//!     cargo bench --bench bench_prefill -- --quick # CI smoke subset
//!     cargo bench --bench bench_prefill -- --ctx 2048 --threads 8
//!     cargo bench --bench bench_prefill -- --quick --assert-speedup 2
//!
//! `--assert-speedup <factor>` turns the SIMD bar into a hard failure:
//! the largest-context simd=auto leg must clear `<factor>`x over the
//! blocked scalar leg at the same thread budget. On hosts where auto
//! resolves to scalar (no AVX2/NEON) the gate logs loudly and passes —
//! never a red build on plain hardware.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use kvzap::bench_support::{write_bench_json, BenchArgs};
use kvzap::runtime::kernels::SimdMode;
use kvzap::runtime::{Arg, ParallelConfig, Runtime};

struct Row {
    t: usize,
    threads: usize,
    block_rows: usize,
    simd: &'static str,
    tok_s: f64,
    speedup: f64,
    simd_speedup: f64,
}

/// Deterministic prompt with the workload mix the reference model cares
/// about (salient needles in filler): exercises realistic mask/stat paths.
fn prompt_tokens(t: usize) -> (Vec<i32>, usize) {
    let mut toks = vec![0i32; t];
    toks[0] = 1; // BOS
    let body = "KEY7 = 90210. the sky was clear over the bay. ";
    for (i, tok) in toks.iter_mut().enumerate().skip(1) {
        *tok = body.as_bytes()[(i - 1) % body.len()] as i32;
    }
    (toks, t)
}

fn time_prefill(rt: &Runtime, want_t: usize, warmup: usize, iters: usize) -> anyhow::Result<f64> {
    // resolve through the bucket grid so arbitrary --ctx values round up
    let bucket = rt
        .manifest
        .prefill_bucket(want_t, 1)
        .ok_or_else(|| anyhow::anyhow!("no prefill bucket for context {want_t}"))?;
    let pf = rt.artifact(&bucket)?;
    let t = pf.meta.t;
    let (toks, n) = prompt_tokens(t);
    let lens = [n as i32];
    let args = [Arg::I32(&toks, &[1, t]), Arg::I32(&lens, &[1])];
    for _ in 0..warmup {
        let _ = rt.exec(&pf, &args)?;
    }
    let mut best = f64::INFINITY;
    for _ in 0..iters {
        let t0 = Instant::now();
        let outs = rt.exec(&pf, &args)?;
        let dt = t0.elapsed().as_secs_f64();
        drop(outs);
        if dt < best {
            best = dt;
        }
    }
    Ok(n as f64 / best)
}

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::parse();
    let quick = args.flag("quick");
    let auto = ParallelConfig::auto().threads;
    let ctxs: Vec<usize> = match args.usize("ctx", 0) {
        0 if quick => vec![512, 2048],
        0 => vec![512, 1024, 2048],
        // custom contexts round up to the bucket grid (powers of two
        // above the 512 seed bucket)
        c => vec![c.max(512).next_power_of_two()],
    };
    let mut threads: Vec<usize> = match args.usize("threads", 0) {
        0 if quick => vec![1, auto],
        0 => {
            let mut ts = vec![1, 2, auto];
            ts.sort_unstable();
            ts.dedup();
            ts
        }
        t => vec![1, t],
    };
    threads.dedup();
    let blocks: Vec<usize> = if quick { vec![64] } else { vec![32, 64, 128] };
    let iters = args.usize("iters", if quick { 2 } else { 3 });

    let mut rows: Vec<Row> = vec![];
    // naive threads=1 tok/s per (ctx, block) — the speedup denominator
    let mut base: HashMap<(usize, usize), f64> = HashMap::new();
    // blocked-scalar tok/s per (ctx, threads, block) — the simd denominator
    let mut simd_base: HashMap<(usize, usize, usize), f64> = HashMap::new();
    println!(
        "{:>6} {:>8} {:>11} {:>7} {:>14} {:>9} {:>9}",
        "t", "threads", "block_rows", "simd", "prefill tok/s", "speedup", "simd x"
    );
    for &t in &ctxs {
        for &br in &blocks {
            // block sweep only matters off the scalar path; keep the grid
            // small by sweeping blocks at the max context only
            if br != 64 && t != *ctxs.iter().max().unwrap() {
                continue;
            }
            for &th in &threads {
                // threads=1 runs the naive inline path (SIMD never applies
                // there); blocked sweeps run a scalar and an auto leg so the
                // SIMD win is measured at an equal thread budget.
                let legs: &[SimdMode] = if th == 1 {
                    &[SimdMode::Scalar]
                } else {
                    &[SimdMode::Scalar, SimdMode::Auto]
                };
                for &simd in legs {
                    let mut cfg = ParallelConfig::with_threads(th).with_simd(simd);
                    cfg.block_rows = br;
                    let rt = Arc::new(Runtime::reference_with_options(t.max(512), cfg));
                    let tok_s = time_prefill(&rt, t, 1, iters)?;
                    if th == 1 {
                        base.insert((t, br), tok_s);
                    }
                    if simd == SimdMode::Scalar {
                        simd_base.insert((t, th, br), tok_s);
                    }
                    let speedup = tok_s / base.get(&(t, br)).copied().unwrap_or(tok_s);
                    let simd_speedup =
                        tok_s / simd_base.get(&(t, th, br)).copied().unwrap_or(tok_s);
                    let label = simd.name();
                    println!(
                        "{t:>6} {th:>8} {br:>11} {label:>7} {tok_s:>14.1} {speedup:>8.2}x {simd_speedup:>8.2}x"
                    );
                    rows.push(Row {
                        t,
                        threads: th,
                        block_rows: br,
                        simd: label,
                        tok_s,
                        speedup,
                        simd_speedup,
                    });
                }
            }
        }
    }

    let items: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "{{\"t\": {}, \"threads\": {}, \"block_rows\": {}, \"simd\": \"{}\", \"tok_s\": {:.2}, \"speedup\": {:.3}, \"simd_speedup\": {:.3}}}",
                r.t, r.threads, r.block_rows, r.simd, r.tok_s, r.speedup, r.simd_speedup
            )
        })
        .collect();
    write_bench_json("prefill", "reference", quick, &items)?;

    // headline: largest context, auto threads, default block
    if let Some(head) = rows
        .iter()
        .filter(|r| r.threads > 1 && r.block_rows == 64)
        .max_by(|a, b| (a.t, a.threads, a.tok_s.to_bits()).cmp(&(b.t, b.threads, b.tok_s.to_bits())))
    {
        println!(
            "\nheadline: t={} threads={} simd={} -> {:.2}x over scalar (target >= 2x at t=2048)",
            head.t, head.threads, head.simd, head.speedup
        );
    }

    // SIMD acceptance gate: simd=auto vs the blocked scalar leg at the same
    // (largest) context and thread budget. `--assert-speedup <factor>` makes
    // the bar a hard failure; on hosts where auto resolves to scalar the
    // gate logs loudly and passes — never a red build on plain hardware.
    if let Ok(bar) = args.str("assert-speedup", "").parse::<f64>() {
        let gate = rows
            .iter()
            .filter(|r| r.simd == "auto" && r.block_rows == 64)
            .max_by(|a, b| (a.t, a.threads).cmp(&(b.t, b.threads)));
        match gate {
            None => eprintln!(
                "[bench_prefill] SIMD GATE SKIPPED: no simd=auto row measured \
                 (single-thread sweep) — --assert-speedup {bar} is a no-op"
            ),
            Some(g) if !SimdMode::Auto.resolve().is_vector() => eprintln!(
                "[bench_prefill] SIMD GATE SKIPPED: KVZAP_SIMD=auto resolves to scalar \
                 on this host (no AVX2/NEON) — --assert-speedup {bar} is a no-op \
                 (measured {:.2}x at t={} threads={})",
                g.simd_speedup, g.t, g.threads
            ),
            Some(g) => {
                println!(
                    "simd gate [{}]: t={} threads={} auto/scalar {:.2}x (bar {bar}x)",
                    SimdMode::Auto.resolve().tag(),
                    g.t,
                    g.threads,
                    g.simd_speedup
                );
                if g.simd_speedup < bar {
                    anyhow::bail!(
                        "simd=auto speedup {:.2}x at t={} threads={} below the asserted {bar}x bar",
                        g.simd_speedup,
                        g.t,
                        g.threads
                    );
                }
            }
        }
    }
    Ok(())
}
