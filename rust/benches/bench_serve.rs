//! Serving saturation bench: latency and throughput vs offered load
//! through the router/shard layer, at 1/2/4 engine workers.
//!
//! Each cell submits a burst of shared-prefix requests (prompt families
//! from the workload generators — the prefix cache's reuse unit) to a
//! [`ShardPool`] and drives `pool.step()` until every request finishes:
//! consistent-hash placement, fair-share tenant queues, continuous
//! batching and cross-request prefix reuse all on the hot path. Reported
//! per cell: wall time, token throughput, mean and p95 request latency,
//! and the prefix hit/miss counters. Emits `BENCH_serve.json` at the repo
//! root to seed the perf trajectory.
//!
//!     cargo bench --bench bench_serve            # full sweep
//!     cargo bench --bench bench_serve -- --quick # CI smoke subset
//!
//! The prefix cache runs *bounded*: each cell sizes a bytes budget so
//! every family's snapshot fits at once (an a-priori bound from the
//! model dims — pass `--prefix-budget BYTES` to override it, 0 for
//! unbounded), and the eviction/reject counters land in the table and
//! in `BENCH_serve.json`.
//!
//! The `--quick` lane is also a functional gate: the shared-prefix burst
//! must record a nonzero prefix-hit count (a zero-hit run means the reuse
//! path silently stopped engaging), and a run whose budget churned the
//! families out of the cache (evictions with no surviving hits) fails
//! loudly instead of shipping a silently reuse-free number.

use std::sync::mpsc::{self, Receiver, TryRecvError};
use std::sync::Arc;
use std::time::Instant;

use kvzap::bench_support::{write_bench_json, BenchArgs};
use kvzap::coordinator::{
    BatcherConfig, Engine, Request, RouterConfig, SamplingParams, SeqEvent, ShardPool,
};
use kvzap::policies::PolicySpec;
use kvzap::runtime::Runtime;
use kvzap::util::rng::Rng;
use kvzap::workload;

struct Row {
    shards: usize,
    offered: usize,
    tokens: usize,
    wall_s: f64,
    tok_s: f64,
    mean_ms: f64,
    p95_ms: f64,
    prefix_hits: u64,
    prefix_misses: u64,
    prefix_evictions: u64,
    prefix_rejects: u64,
    prefix_bytes: usize,
}

/// Run one saturation cell: a burst of `offered` shared-prefix requests
/// against a fresh pool of `shards` workers, stepped to completion.
/// `prefix_budget` of None sizes a bytes budget every family fits inside
/// (Some(0) = unbounded, Some(n) = exactly n).
fn run_cell(
    shards: usize,
    offered: usize,
    t_max: usize,
    prefix_budget: Option<usize>,
) -> anyhow::Result<Row> {
    let engines: Vec<Arc<Engine>> = (0..shards)
        .map(|_| Arc::new(Engine::new(Arc::new(Runtime::reference_with_t_max(t_max)))))
        .collect();
    let n_families = (offered / 2).max(1);
    // a-priori per-snapshot bound: the full fp32 KV host copy plus slack
    // for the stored logits row — so the default budget admits every
    // family at once and evictions signal a real problem, not sizing
    let m = &engines[0].rt.manifest.model;
    let snap_bound = 2 * m.n_layers * m.n_kv_heads * m.t_max * m.d_head * 4 + (64 << 10);
    let budget = match prefix_budget {
        None => Some(n_families * snap_bound),
        Some(0) => None,
        Some(b) => Some(b),
    };
    let mut pool = ShardPool::new(
        engines,
        BatcherConfig { max_batch: 4, max_wait_us: 0 },
        RouterConfig {
            shards,
            prefix_reuse: true,
            prefix_budget: budget,
            ..RouterConfig::default()
        },
    );

    // duplicated prompt families: every family's members share one byte-
    // identical prompt, so the second member of a family is a prefix hit
    let mut rng = Rng::new(17);
    let families = workload::prefix_families(&mut rng, n_families, 1, 200);
    let policy = PolicySpec::parse("kvzap_mlp:-4").unwrap();
    let mut sp = SamplingParams::greedy(8);
    sp.stop_at_newline = false;

    let t0 = Instant::now();
    let mut rxs: Vec<Option<Receiver<SeqEvent>>> = vec![];
    for i in 0..offered {
        let (tx, rx) = mpsc::channel();
        pool.submit(
            (i + 1) as u64,
            &format!("tenant-{}", i % 3),
            Request {
                prompt: families[i % n_families][0].prompt.clone(),
                policy: policy.clone(),
                sp: sp.clone(),
                stream: false,
                events: tx,
            },
        );
        rxs.push(Some(rx));
    }

    let mut latencies_ms: Vec<f64> = vec![];
    let mut tokens = 0usize;
    let mut iters = 0usize;
    while rxs.iter().any(|r| r.is_some()) {
        pool.step();
        iters += 1;
        anyhow::ensure!(iters < 100_000, "pool failed to drain {offered} requests");
        for slot in rxs.iter_mut() {
            let Some(rx) = slot else { continue };
            loop {
                match rx.try_recv() {
                    Ok(SeqEvent::Done(r)) => {
                        anyhow::ensure!(r.error.is_none(), "request failed: {:?}", r.error);
                        latencies_ms.push(t0.elapsed().as_secs_f64() * 1e3);
                        tokens += r.tokens_out;
                        *slot = None;
                        break;
                    }
                    Ok(SeqEvent::Token { .. }) => {}
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        anyhow::bail!("a request's channel closed without a Done")
                    }
                }
            }
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    latencies_ms.sort_by(|a, b| a.total_cmp(b));
    let mean_ms = latencies_ms.iter().sum::<f64>() / latencies_ms.len() as f64;
    let p95_ms = latencies_ms[(latencies_ms.len() * 95 / 100).min(latencies_ms.len() - 1)];
    let (mut hits, mut misses) = (0u64, 0u64);
    for s in 0..shards {
        let m = &pool.core(s).engine().metrics;
        hits += m.prefix_hits.load(std::sync::atomic::Ordering::Relaxed);
        misses += m.prefix_misses.load(std::sync::atomic::Ordering::Relaxed);
    }
    let (evictions, rejects, bytes) = pool
        .prefix_cache()
        .map(|pc| {
            let st = pc.stats();
            (st.evictions, st.insert_rejects, st.bytes)
        })
        .unwrap_or((0, 0, 0));
    Ok(Row {
        shards,
        offered,
        tokens,
        wall_s,
        tok_s: tokens as f64 / wall_s,
        mean_ms,
        p95_ms,
        prefix_hits: hits,
        prefix_misses: misses,
        prefix_evictions: evictions,
        prefix_rejects: rejects,
        prefix_bytes: bytes,
    })
}

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::parse();
    let quick = args.flag("quick");
    let shard_counts: Vec<usize> = if quick { vec![1, 2] } else { vec![1, 2, 4] };
    let loads: Vec<usize> = if quick { vec![8] } else { vec![4, 8, 16] };
    let t_max = args.usize("t-max", 512);
    // 0 = unbounded; absent = the sized per-cell default (see run_cell)
    let prefix_budget = args.usize_opt("prefix-budget");

    println!(
        "{:>6} {:>8} {:>8} {:>9} {:>10} {:>10} {:>10} {:>6} {:>7} {:>6} {:>7}",
        "shards", "offered", "tokens", "wall s", "tok/s", "mean ms", "p95 ms", "hits",
        "misses", "evict", "reject"
    );
    let mut rows: Vec<Row> = vec![];
    for &shards in &shard_counts {
        for &offered in &loads {
            let r = run_cell(shards, offered, t_max, prefix_budget)?;
            println!(
                "{:>6} {:>8} {:>8} {:>9.3} {:>10.1} {:>10.1} {:>10.1} {:>6} {:>7} {:>6} {:>7}",
                r.shards,
                r.offered,
                r.tokens,
                r.wall_s,
                r.tok_s,
                r.mean_ms,
                r.p95_ms,
                r.prefix_hits,
                r.prefix_misses,
                r.prefix_evictions,
                r.prefix_rejects
            );
            rows.push(r);
        }
    }

    let items: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "{{\"shards\": {}, \"offered\": {}, \"tokens\": {}, \"wall_s\": {:.4}, \
                 \"tok_s\": {:.2}, \"mean_ms\": {:.2}, \"p95_ms\": {:.2}, \
                 \"prefix_hits\": {}, \"prefix_misses\": {}, \"prefix_evictions\": {}, \
                 \"prefix_rejects\": {}, \"prefix_bytes\": {}}}",
                r.shards,
                r.offered,
                r.tokens,
                r.wall_s,
                r.tok_s,
                r.mean_ms,
                r.p95_ms,
                r.prefix_hits,
                r.prefix_misses,
                r.prefix_evictions,
                r.prefix_rejects,
                r.prefix_bytes
            )
        })
        .collect();
    write_bench_json("serve", "reference", quick, &items)?;

    // functional gates: the shared-prefix burst must actually reuse, and a
    // budget that churned the families out must fail loudly rather than
    // ship a silently reuse-free number
    for r in &rows {
        anyhow::ensure!(
            r.prefix_hits > 0 || r.prefix_evictions + r.prefix_rejects > 0,
            "cell (shards {}, offered {}): a shared-prefix burst recorded zero prefix \
             hits with no budget pressure — the reuse path stopped engaging",
            r.shards,
            r.offered
        );
        anyhow::ensure!(
            r.prefix_hits > 0,
            "cell (shards {}, offered {}): the prefix budget churned the shared-prefix \
             families out of the cache ({} evictions, {} rejects, 0 hits) — raise \
             --prefix-budget so the families fit",
            r.shards,
            r.offered,
            r.prefix_evictions,
            r.prefix_rejects
        );
    }
    Ok(())
}
