//! Table 3 (Appendix B): relative compute overhead of KVzap.
//!
//! Prints the analytic Eq. 4-6 table for the paper's three models and
//! zap-lm, then *measures* the real surrogate overhead on this stack by
//! timing decode steps against a decode artifact where the surrogate cost
//! is included (it always is — the measurement shows it's in the noise).
//!
//!     cargo bench --bench bench_overhead

use kvzap::analysis::{overhead_table, LayerDims};
use kvzap::bench_support::{load_engine, results_dir, time_us, write_csv, BenchArgs};
use kvzap::coordinator::SamplingParams;
use kvzap::policies;
use kvzap::util::rng::Rng;
use kvzap::workload;

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::parse();
    let engine = load_engine().ok();

    let extra = engine.as_ref().map(|e| {
        let m = &e.rt.manifest.model;
        LayerDims {
            name: "zap-lm (this repo)".into(),
            h_q: m.n_q_heads,
            h_kv: m.n_kv_heads,
            d_head: m.d_head,
            d_model: m.d_model,
            d_int: m.d_int,
            d_surrogate: m.d_surrogate,
        }
    });

    println!("== Table 3 | relative compute overhead (linear projections only)");
    println!(
        "{:<24} {:>4} {:>3} {:>4} {:>6} {:>7} {:>9} {:>10}",
        "model", "H_Q", "H", "D", "D_h", "D_int", "MLP %", "Linear %"
    );
    let mut csv = vec![];
    for r in overhead_table(extra) {
        println!(
            "{:<24} {:>4} {:>3} {:>4} {:>6} {:>7} {:>8.2}% {:>9.2}%",
            r.dims.name, r.dims.h_q, r.dims.h_kv, r.dims.d_head, r.dims.d_model,
            r.dims.d_int, r.mlp_pct, r.linear_pct
        );
        csv.push(format!(
            "{},{},{},{},{},{},{:.4},{:.4}",
            r.dims.name, r.dims.h_q, r.dims.h_kv, r.dims.d_head, r.dims.d_model,
            r.dims.d_int, r.mlp_pct, r.linear_pct
        ));
    }
    write_csv(
        &results_dir().join("table3_overhead.csv"),
        "model,h_q,h_kv,d_head,d_model,d_int,mlp_pct,linear_pct",
        &csv,
    )?;
    println!("(paper bounds: MLP <= 1.1%, Linear <= 0.02% — matched above)");

    // ---- measured end-to-end overhead --------------------------------------
    if let Some(engine) = engine {
        let iters = args.usize("iters", 3);
        println!("\n== measured wall-clock: KVzap policy vs full cache (same artifact)");
        let mut rng = Rng::new(3);
        let task = workload::ruler_instance("niah_single_1", 240, &mut rng);
        for spec in ["full", "kvzap_mlp:-4"] {
            let policy = policies::by_name(spec, engine.window()).unwrap();
            let sp = SamplingParams::greedy(task.max_new);
            let us = time_us(1, iters, || {
                engine.generate(&task.prompt, policy.as_ref(), &sp).unwrap();
            });
            println!("  {spec:<14} median request {us:.0} us");
        }
        println!("(the surrogate matmuls are fused into the artifacts; the policy\n cost is mask bookkeeping only — Criterion 1)");
    }
    Ok(())
}
