//! Table 2: best-configuration summary across datasets.
//!
//! Picks the best KVzap configuration (Linear/MLP x τ) by the paper's
//! criterion — highest compression whose accuracy stays within 1 point of
//! the full-cache baseline on ruler-mini — then reports full->compressed
//! accuracy with compression ratios on ruler 4k/16k, longbench and aime,
//! exactly the Table 2 row structure.
//!
//!     cargo bench --bench bench_table2 -- --samples 4

use kvzap::bench_support::{
    aggregate, default_taus, eval_policy, load_engine, results_dir, write_csv, BenchArgs,
};
use kvzap::workload::{LONGBENCH_SUBSETS, RULER_SUBSETS};

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::parse();
    let samples = args.usize("samples", 2);
    let engine = load_engine()?;
    let taus = default_taus(&engine);

    // ---- select the best config on ruler-mini 4k ---------------------------
    let base = eval_policy(&engine, "ruler", RULER_SUBSETS, "full", samples, 248, 1)?;
    let (base_acc, _, base_nll) = aggregate(&base);
    let mut best: Option<(String, f64, f64)> = None;
    for kind in ["mlp", "linear"] {
        for t in &taus {
            let spec = format!("kvzap_{kind}:{t:.2}");
            let rows = eval_policy(&engine, "ruler", RULER_SUBSETS, &spec, samples, 248, 1)?;
            let (acc, comp, nll) = aggregate(&rows);
            eprintln!("  candidate {spec:<22} acc {:.1}% nll {nll:.3} comp {comp:.3}",
                      acc * 100.0);
            // paper criterion: accuracy within ~1pt of full cache; with a
            // weak substrate also require NLL within 10% of baseline
            if acc >= base_acc - 0.0101 && nll <= base_nll * 1.10 + 0.02 {
                if best.as_ref().map_or(true, |b| comp > b.1) {
                    best = Some((spec, comp, acc));
                }
            }
        }
    }
    let (best_spec, _, _) =
        best.unwrap_or_else(|| (format!("kvzap_mlp:{:.2}", taus[taus.len() / 2]), 0.0, 0.0));
    println!("\n== Table 2 | best KVzap configuration: {best_spec}");

    // ---- the four dataset rows ---------------------------------------------
    let mut csv = vec![];
    println!(
        "{:<16} {:>22} {:>14}",
        "dataset", "full -> compressed", "(compression)"
    );
    let mut comp_sum = 0.0;
    let mut n_rows = 0.0;
    for (label, suite, subsets, ctx) in [
        ("ruler 4k", "ruler", RULER_SUBSETS, 248usize),
        ("ruler 16k", "ruler", RULER_SUBSETS, 368),
        ("longbench", "longbench", LONGBENCH_SUBSETS, 248),
        ("aime", "aime", &["aime"][..], 0),
    ] {
        let full = eval_policy(&engine, suite, subsets, "full", samples, ctx, 5)?;
        let comp = eval_policy(&engine, suite, subsets, &best_spec, samples, ctx, 5)?;
        let (fa, _, fn_) = aggregate(&full);
        let (ca, cc, cn) = aggregate(&comp);
        println!(
            "{label:<16} {:>9.1} -> {:>9.1} {:>13.2}   nll {:.3} -> {:.3}",
            100.0 * fa,
            100.0 * ca,
            cc,
            fn_,
            cn
        );
        csv.push(format!("{label},{fa:.4},{ca:.4},{fn_:.4},{cn:.4},{cc:.4}"));
        comp_sum += cc;
        n_rows += 1.0;
    }
    let avg = comp_sum / n_rows;
    println!(
        "{:<16} {:>22} {:>10.2} ({:.1}x)",
        "average", "", avg,
        1.0 / (1.0 - avg).max(1e-9)
    );
    csv.push(format!("average,,,,,{avg:.4}"));
    write_csv(
        &results_dir().join("table2_summary.csv"),
        "dataset,acc_full,acc_compressed,nll_full,nll_compressed,compression",
        &csv,
    )?;
    Ok(())
}
