//! Figure 5 + §4.8 ablations.
//!
//! Left: distribution of per-prompt compression ratios for one threshold
//! across ruler-mini / longbench-mini / aime-mini (input-adaptivity).
//! Right: thresholding vs fixed-ratio top-k (per-head and per-layer/AdaKV)
//! at matched average compression.
//! Window ablation (§4.8): w ∈ {0, w, 4w} on the code-completion subset.
//!
//!     cargo bench --bench bench_adaptive -- --samples 6 [--window-ablation]

use kvzap::bench_support::{
    aggregate, default_taus, eval_policy, load_engine, results_dir, write_csv, BenchArgs,
};
use kvzap::coordinator::SamplingParams;
use kvzap::policies::{self, KVzap, PrunePolicy};
use kvzap::util::rng::Rng;
use kvzap::workload;

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::parse();
    let samples = args.usize("samples", 4);
    let engine = load_engine()?;
    let taus = default_taus(&engine);
    let tau_mid = taus[taus.len() / 2];

    // ---- Fig 5 left: per-prompt compression distribution ------------------
    println!("== Figure 5 (left) | per-prompt compression at tau={tau_mid:.2}");
    let policy = policies::by_name(&format!("kvzap_mlp:{tau_mid:.2}"), engine.window()).unwrap();
    let mut csv = vec![];
    let mut rng = Rng::new(99);
    for (suite, subset) in [
        ("ruler", "niah_single_1"),
        ("ruler", "vt"),
        ("longbench", "trec"),
        ("longbench", "lcc"),
        ("aime", "aime"),
    ] {
        let mut comps = vec![];
        for i in 0..samples {
            let mut r = rng.fork(i as u64);
            let task = match suite {
                "ruler" => workload::ruler_instance(subset, 248, &mut r),
                "longbench" => workload::longbench_instance(subset, 248, &mut r),
                _ => workload::aime_instance(&mut r).task,
            };
            let res = engine.generate(
                &task.prompt, policy.as_ref(), &SamplingParams::greedy(task.max_new))?;
            comps.push(res.compression);
            csv.push(format!("{suite},{subset},{:.4}", res.compression));
        }
        let mean = comps.iter().sum::<f64>() / comps.len() as f64;
        let lo = comps.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = comps.iter().cloned().fold(0.0f64, f64::max);
        println!("  {suite:<10}{subset:<16} mean {mean:.3}  range [{lo:.3}, {hi:.3}]");
    }
    write_csv(&results_dir().join("fig5_left_distribution.csv"),
              "suite,subset,compression", &csv)?;

    // ---- Fig 5 right: threshold vs top-k at matched compression -----------
    println!("\n== Figure 5 (right) | thresholding vs fixed-ratio top-k");
    let subsets = workload::RULER_SUBSETS;
    let mut rows_csv = vec![];
    // 1. threshold run establishes the achieved average compression
    let th_rows = eval_policy(
        &engine, "ruler", subsets, &format!("kvzap_mlp:{tau_mid:.2}"), samples, 248, 7)?;
    let (th_acc, th_comp, th_nll) = aggregate(&th_rows);
    println!("  kvzap threshold          comp {th_comp:.3} acc {:.1}% nll {th_nll:.3}",
             th_acc * 100.0);
    rows_csv.push(format!("threshold,{th_comp:.4},{th_acc:.4},{th_nll:.4}"));
    // 2. top-k variants at the same keep fraction
    let keep = format!("{:.3}", 1.0 - th_comp);
    for (label, spec) in [
        ("top-k per head", format!("kvzap_mlp_topk:{keep}")),
        ("top-k per layer (AdaKV)", format!("kvzap_mlp_toplayer:{keep}")),
    ] {
        let rows = eval_policy(&engine, "ruler", subsets, &spec, samples, 248, 7)?;
        let (acc, comp, nll) = aggregate(&rows);
        println!("  {label:<24} comp {comp:.3} acc {:.1}% nll {nll:.3}", acc * 100.0);
        rows_csv.push(format!("{label},{comp:.4},{acc:.4},{nll:.4}"));
    }
    write_csv(&results_dir().join("fig5_right_threshold_vs_topk.csv"),
              "method,compression,accuracy,nll", &rows_csv)?;

    // ---- §4.8 window ablation ---------------------------------------------
    if args.flag("window-ablation") {
        println!("\n== §4.8 | sliding-window ablation on longbench-mini lcc");
        let w = engine.window();
        let mut wcsv = vec![];
        for win in [0usize, w, 4 * w] {
            let pol = KVzap::mlp(tau_mid as f32, win);
            let mut rng = Rng::new(13);
            let mut ok = 0;
            let mut comp = 0.0;
            for i in 0..samples {
                let task = workload::longbench_instance("lcc", 248, &mut rng.fork(i as u64));
                let res = engine.generate(
                    &task.prompt, &pol, &SamplingParams::greedy(task.max_new))?;
                ok += task.score(&res.text) as usize;
                comp += res.compression;
            }
            let acc = ok as f64 / samples as f64;
            println!("  w={win:<4} acc {:.1}%  comp {:.3}",
                     acc * 100.0, comp / samples as f64);
            wcsv.push(format!("{win},{acc:.4},{:.4}", comp / samples as f64));
        }
        write_csv(&results_dir().join("window_ablation.csv"),
                  "window,accuracy,compression", &wcsv)?;
    }
    Ok(())
}
