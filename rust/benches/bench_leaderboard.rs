//! In-repo KVpress-style leaderboard: the full policy-catalog sweep.
//!
//! Every cataloged policy kind × RULER/LongBench/AIME × compression target
//! (τ for threshold kinds, keep-fraction for budget kinds), emitted as
//! `BENCH_leaderboard.json` plus per-suite accuracy/compression frontier
//! tables. Fails loudly if any cataloged kind is skipped.
//!
//!     cargo bench --bench bench_leaderboard -- [--quick] [--samples N]
//!         [--ctx T] [--seed S]

use kvzap::bench_support::{load_engine, BenchArgs};
use kvzap::leaderboard::{run, LeaderboardConfig};

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::parse();
    let engine = load_engine()?;
    let mut cfg = LeaderboardConfig::new(args.flag("quick"));
    cfg.samples = args.usize("samples", cfg.samples);
    cfg.ctx = args.usize("ctx", cfg.ctx);
    cfg.seed = args.usize("seed", cfg.seed as usize) as u64;
    let rows = run(&engine, &cfg)?;
    println!("leaderboard: {} rows", rows.len());
    Ok(())
}
