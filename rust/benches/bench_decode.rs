//! Decode-path data-movement benchmark: device-resident KV cache vs the
//! pre-refactor host repack path, swept over group size and t_max.
//!
//! Per step the repack path re-packs every sequence's dense
//! `[L, H, t_max, d_head]` caches + keep-mask into the group buffer,
//! uploads them, executes the legacy decode artifact, and fetches both
//! caches back (exactly what `Engine::decode_step` did before the
//! resident refactor). The resident path scatters once at join and then
//! moves only token/pos scalars up and one `[L, H, d_head]` row per
//! sequence down. Emits `BENCH_decode.json` at the repo root to seed the
//! perf trajectory.
//!
//!     cargo bench --bench bench_decode            # full sweep
//!     cargo bench --bench bench_decode -- --quick # CI smoke subset
//!
//! `--assert-speedup <factor>` makes the headline row (largest t_max,
//! largest group) a hard gate: the resident path must clear `<factor>`x
//! over the repack path or the bench exits nonzero.

use std::sync::Arc;
use std::time::Instant;

use kvzap::bench_support::{write_bench_json, BenchArgs};
use kvzap::runtime::{Arg, Runtime};

struct Row {
    t_max: usize,
    group: usize,
    resident_tok_s: f64,
    repack_tok_s: f64,
}

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::parse();
    let quick = args.flag("quick");
    let t_maxes: Vec<usize> = if quick { vec![512, 2048] } else { vec![512, 2048, 8192] };
    let groups: Vec<usize> = if quick { vec![1, 4] } else { vec![1, 2, 4, 8] };
    let base_steps = args.usize("steps", if quick { 6 } else { 24 });

    let mut rows: Vec<Row> = vec![];
    println!(
        "{:>6} {:>6} {:>16} {:>16} {:>9}",
        "t_max", "group", "resident tok/s", "repack tok/s", "speedup"
    );
    for &tm in &t_maxes {
        let rt = Arc::new(Runtime::reference_with_t_max(tm));
        let man = rt.manifest.clone();
        let (l, h, d) = (man.model.n_layers, man.model.n_kv_heads, man.model.d_head);

        // one prefill seeds every slot's host KV copy (b=1, shared rows)
        let pf = rt.artifact("prefill_b1_t128")?;
        let pt = pf.meta.t;
        let prompt = "AB = 1234. CD = 5678. the needle is 42.";
        let mut toks = vec![0i32; pt];
        toks[0] = 1;
        for (i, b) in prompt.bytes().enumerate() {
            toks[i + 1] = b as i32;
        }
        let n = prompt.len() + 1;
        let lens = [n as i32];
        let pouts = rt.exec(&pf, &[Arg::I32(&toks, &[1, pt]), Arg::I32(&lens, &[1])])?;
        let ki = pf.meta.output_index("kcache")?;
        let vi = pf.meta.output_index("vcache")?;
        let seq_k = rt.fetch_f32(&pouts[ki], &pf.meta.outputs[ki].shape)?.data;
        let seq_v = rt.fetch_f32(&pouts[vi], &pf.meta.outputs[vi].shape)?.data;
        let mut slot_mask = vec![0.0f32; l * h * tm];
        for li in 0..l {
            for hi in 0..h {
                for p in 0..n {
                    slot_mask[(li * h + hi) * tm + p] = 1.0;
                }
            }
        }

        for &g in &groups {
            let bucket = man
                .decode_bucket(g)
                .ok_or_else(|| anyhow::anyhow!("no decode bucket for {g}"))?;
            let dec = rt.artifact(&bucket)?;
            let db = dec.meta.batch;
            // larger caches move (and compute) more per step: keep the
            // wall time bounded by scaling the step count down
            let steps = (base_steps * 512 / tm).max(3);

            // ---- resident leg: scatter once, then row-only traffic ------
            let hd = rt.kv_alloc(db)?;
            for s in 0..g {
                rt.kv_scatter(&hd, s, &seq_k, &seq_v)?;
                rt.kv_write_mask(&hd, s, &slot_mask)?;
            }
            let mut cur = vec![0i32; db];
            let mut pos = vec![(tm - 1) as i32; db];
            for s in 0..g {
                cur[s] = b'4' as i32;
                pos[s] = n as i32;
            }
            let li_r = dec.meta.resident_output_index("logits")?;
            let li = dec.meta.output_index("logits")?;
            let mut k_row = vec![0.0f32; hd.row_elems()];
            let mut v_row = vec![0.0f32; hd.row_elems()];
            // warmup step
            rt.exec_decode_resident(&dec, &cur, &pos, &hd)?;
            for s in 0..g {
                pos[s] += 1;
            }
            let t0 = Instant::now();
            for _ in 0..steps {
                let outs = rt.exec_decode_resident(&dec, &cur, &pos, &hd)?;
                let _ = rt.fetch_f32(&outs[li_r], &dec.meta.outputs[li].shape)?;
                for s in 0..g {
                    rt.kv_fetch_row(&hd, s, pos[s] as usize, &mut k_row, &mut v_row)?;
                    pos[s] += 1;
                }
            }
            let resident_tok_s = (steps * g) as f64 / t0.elapsed().as_secs_f64();
            rt.kv_free(&hd);

            // ---- repack leg: the pre-refactor per-step round-trip -------
            let head_len = tm * d;
            let mut seqs_k: Vec<Vec<f32>> = (0..g).map(|_| seq_k.clone()).collect();
            let mut seqs_v: Vec<Vec<f32>> = (0..g).map(|_| seq_v.clone()).collect();
            let mut pos = vec![(tm - 1) as i32; db];
            for s in 0..g {
                pos[s] = n as i32;
            }
            let cache_dims = [l, db, h, tm, d];
            // per-sequence mask, grown by each decode fill (what the old
            // engine rebuilt from PagedKvCache every step)
            let mut live_mask = slot_mask.clone();
            let mut kc = vec![0.0f32; l * db * h * head_len];
            let mut vc = vec![0.0f32; l * db * h * head_len];
            let mut mask = vec![0.0f32; l * db * h * tm];
            let mut step = |seqs_k: &mut [Vec<f32>],
                            seqs_v: &mut [Vec<f32>],
                            pos: &mut [i32]|
             -> anyhow::Result<()> {
                for (s, (sk, sv)) in seqs_k.iter().zip(seqs_v.iter()).enumerate() {
                    for li in 0..l {
                        for hi in 0..h {
                            let so = (li * h + hi) * head_len;
                            let go = ((li * db + s) * h + hi) * head_len;
                            kc[go..go + head_len].copy_from_slice(&sk[so..so + head_len]);
                            vc[go..go + head_len].copy_from_slice(&sv[so..so + head_len]);
                            let sm = (li * h + hi) * tm;
                            let gm = ((li * db + s) * h + hi) * tm;
                            mask[gm..gm + tm].copy_from_slice(&live_mask[sm..sm + tm]);
                        }
                    }
                }
                let kb = rt.upload_f32(&kc, &cache_dims)?;
                let vb = rt.upload_f32(&vc, &cache_dims)?;
                let mb = rt.upload_f32(&mask, &[l, db, h, tm])?;
                let outs = rt.exec(
                    &dec,
                    &[
                        Arg::I32(&cur, &[db]),
                        Arg::I32(pos, &[db]),
                        Arg::Buf(&kb),
                        Arg::Buf(&vb),
                        Arg::Buf(&mb),
                    ],
                )?;
                let _ = rt.fetch_f32(&outs[li], &dec.meta.outputs[li].shape)?;
                let ko = dec.meta.output_index("kcache")?;
                let vo = dec.meta.output_index("vcache")?;
                let kc_out = rt.fetch_f32(&outs[ko], &dec.meta.outputs[ko].shape)?;
                let vc_out = rt.fetch_f32(&outs[vo], &dec.meta.outputs[vo].shape)?;
                let p_new = pos[0] as usize;
                for (s, (sk, sv)) in seqs_k.iter_mut().zip(seqs_v.iter_mut()).enumerate() {
                    let p = pos[s] as usize;
                    for li in 0..l {
                        for hi in 0..h {
                            let so = (li * h + hi) * head_len + p * d;
                            let go = ((li * db + s) * h + hi) * head_len + p * d;
                            sk[so..so + d].copy_from_slice(&kc_out.data[go..go + d]);
                            sv[so..so + d].copy_from_slice(&vc_out.data[go..go + d]);
                        }
                    }
                    pos[s] += 1;
                }
                // the decoded position becomes attendable next step
                for li in 0..l {
                    for hi in 0..h {
                        live_mask[(li * h + hi) * tm + p_new] = 1.0;
                    }
                }
                Ok(())
            };
            step(&mut seqs_k, &mut seqs_v, &mut pos)?; // warmup
            let t0 = Instant::now();
            for _ in 0..steps {
                step(&mut seqs_k, &mut seqs_v, &mut pos)?;
            }
            let repack_tok_s = (steps * g) as f64 / t0.elapsed().as_secs_f64();

            println!(
                "{:>6} {:>6} {:>16.1} {:>16.1} {:>8.2}x",
                tm,
                g,
                resident_tok_s,
                repack_tok_s,
                resident_tok_s / repack_tok_s
            );
            rows.push(Row { t_max: tm, group: g, resident_tok_s, repack_tok_s });
        }
    }

    // JSON seed for the perf trajectory
    let mut items: Vec<String> = vec![];
    for r in &rows {
        items.push(format!(
            "{{\"t_max\": {}, \"group\": {}, \"resident_tok_s\": {:.2}, \"repack_tok_s\": {:.2}, \"speedup\": {:.3}}}",
            r.t_max,
            r.group,
            r.resident_tok_s,
            r.repack_tok_s,
            r.resident_tok_s / r.repack_tok_s
        ));
    }
    write_bench_json("decode", "reference", quick, &items)?;

    // resident-vs-repack gate: `-- --assert-speedup 2` turns the headline
    // ratio (largest t_max, largest group) into a hard failure
    if let Ok(bar) = args.str("assert-speedup", "").parse::<f64>() {
        if let Some(head) =
            rows.iter().max_by(|a, b| (a.t_max, a.group).cmp(&(b.t_max, b.group)))
        {
            let sp = head.resident_tok_s / head.repack_tok_s;
            println!(
                "\ndecode gate: t_max={} group={} resident/repack {sp:.2}x (bar {bar}x)",
                head.t_max, head.group
            );
            if sp < bar {
                anyhow::bail!(
                    "resident/repack speedup {sp:.2}x at t_max={} group={} below the asserted {bar}x bar",
                    head.t_max,
                    head.group
                );
            }
        }
    }
    Ok(())
}
