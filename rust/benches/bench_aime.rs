//! Figure 4 / Table 4: AIME reasoning with decode-time pruning.
//!
//! pass@1 (mean over rollouts) and pass@4 across KVzap thresholds, plus the
//! per-rollout correct counts of Table 4. Rollouts use the paper's §4.3
//! reasoning sampling (T=0.6, top-p=0.95, top-k=20), 4 rollouts/question.
//!
//!     cargo bench --bench bench_aime -- --questions 10 [--table4]

use kvzap::bench_support::{default_taus, load_engine, results_dir, write_csv, BenchArgs};
use kvzap::coordinator::SamplingParams;
use kvzap::policies;
use kvzap::util::rng::Rng;
use kvzap::workload::{aime_instance, generators::parse_aime_answer};

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::parse();
    let n_q = args.usize("questions", 6);
    let rollouts = args.usize("rollouts", 4);
    let engine = load_engine()?;
    let taus = default_taus(&engine);

    let mut specs: Vec<String> = vec!["full".into()];
    for t in &taus {
        specs.push(format!("kvzap_mlp:{t:.2}"));
        specs.push(format!("kvzap_linear:{t:.2}"));
    }

    // Fixed question set (same across policies, like AIME's 30 problems).
    let mut qrng = Rng::new(2025);
    let questions: Vec<_> = (0..n_q).map(|i| aime_instance(&mut qrng.fork(i as u64))).collect();

    println!(
        "== Figure 4 | aime-mini ({n_q} questions x {rollouts} rollouts, reasoning sampling)"
    );
    println!(
        "{:<24} {:>8} {:>8} {:>12} {:>14}",
        "policy", "pass@1", "pass@4", "compression", "rollout counts"
    );
    let mut csv = vec![];
    let mut table4 = vec![];
    for spec in &specs {
        let policy = policies::by_name(spec, engine.window()).unwrap();
        let mut per_rollout_correct = vec![0usize; rollouts];
        let mut any_correct = 0usize;
        let mut comp = 0.0;
        for (qi, q) in questions.iter().enumerate() {
            let mut any = false;
            for r in 0..rollouts {
                let sp = SamplingParams::reasoning(
                    q.task.max_new, (qi * rollouts + r) as u64);
                let res = engine.generate(&q.task.prompt, policy.as_ref(), &sp)?;
                let ok = parse_aime_answer(&res.text).as_deref()
                    == Some(q.task.answer.as_str());
                per_rollout_correct[r] += ok as usize;
                any |= ok;
                comp += res.compression;
            }
            any_correct += any as usize;
        }
        let pass1 = per_rollout_correct.iter().sum::<usize>() as f64
            / (n_q * rollouts) as f64;
        let pass4 = any_correct as f64 / n_q as f64;
        let mean_comp = comp / (n_q * rollouts) as f64;
        let mut counts = per_rollout_correct.clone();
        counts.sort_unstable();
        let counts_str =
            counts.iter().map(|c| c.to_string()).collect::<Vec<_>>().join(", ");
        println!(
            "{spec:<24} {pass1:>8.2} {pass4:>8.2} {mean_comp:>12.3} {counts_str:>14}"
        );
        csv.push(format!("{spec},{pass1:.4},{pass4:.4},{mean_comp:.4}"));
        table4.push(format!("{spec},{counts_str}"));
    }
    write_csv(
        &results_dir().join("fig4_aime.csv"),
        "policy,pass1,pass4,compression",
        &csv,
    )?;
    if args.flag("table4") {
        write_csv(
            &results_dir().join("table4_rollouts.csv"),
            "policy,rollout_correct_counts",
            &table4,
        )?;
        println!("\nTable 4 | per-rollout correct counts (n={n_q}) written.");
    }
    Ok(())
}
