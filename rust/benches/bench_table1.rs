//! Table 1 + Figures 6-8: surrogate quality (R² against KVzip+ targets).
//!
//! Reads artifacts/surrogate_metrics.json (produced at `make artifacts` by
//! train_surrogate.py) and prints Table 1 plus the per-head R² heatmap and
//! the score-distribution summary the appendix figures show.
//!
//!     cargo bench --bench bench_table1

use kvzap::util::json::Json;

fn main() -> anyhow::Result<()> {
    let path = kvzap::artifacts_dir().join("surrogate_metrics.json");
    let text = std::fs::read_to_string(&path)
        .map_err(|e| anyhow::anyhow!("{e}: run `make artifacts` first"))?;
    let j = Json::parse(&text).map_err(|e| anyhow::anyhow!(e))?;

    let lin = j.req("r2_linear_mean").map_err(|e| anyhow::anyhow!(e))?.as_f64().unwrap();
    let mlp = j.req("r2_mlp_mean").map_err(|e| anyhow::anyhow!(e))?.as_f64().unwrap();
    println!("== Table 1 | average R² between KVzip+ scores and KVzap predictions");
    println!("{:<24} {:>8} {:>8}", "model", "Linear", "MLP");
    println!("{:<24} {:>8.3} {:>8.3}   (paper: 0.6-0.8 band, MLP > Linear)",
             "zap-lm (this repo)", lin, mlp);

    println!("\n== Figures 6-8 | per-(layer, head) R² heatmap");
    let rl = j.req("r2_linear").map_err(|e| anyhow::anyhow!(e))?.as_arr().unwrap();
    let rm = j.req("r2_mlp").map_err(|e| anyhow::anyhow!(e))?.as_arr().unwrap();
    println!("{:<8} {:<22} {:<22}", "layer", "linear per head", "mlp per head");
    for (l, (a, b)) in rl.iter().zip(rm).enumerate() {
        let fmt = |x: &Json| {
            x.as_arr().unwrap().iter()
                .map(|v| format!("{:+.2}", v.as_f64().unwrap()))
                .collect::<Vec<_>>().join(" ")
        };
        println!("{l:<8} {:<22} {:<22}", fmt(a), fmt(b));
    }

    println!("\n== Figures 6-8 | KVzip+ log-score distribution");
    let frac = j.req("below_median_frac").map_err(|e| anyhow::anyhow!(e))?.as_f64().unwrap();
    println!("fraction below median score: {frac:.3} (definitionally ~0.5)");
    if let Some(q) = j.get("target_quantiles").and_then(|x| x.as_obj()) {
        for (k, v) in q {
            println!("  q{k:<5} log s+ = {:+.3}", v.as_f64().unwrap());
        }
    }
    println!("\n(CSV versions: results/fig6_8_score_hist.csv, results/fig6_8_r2_heads.csv)");

    // sanity assertions, in the spirit of a regression bench
    assert!(mlp > 0.0 && lin > 0.0, "surrogates must have positive R²");
    Ok(())
}
