//! Analytic models: FLOPs overhead (paper Appendix B) and roofline notes.

pub mod flops;

pub use flops::{overhead_table, LayerDims, OverheadRow};
