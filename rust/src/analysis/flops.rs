//! Compute/memory overhead of KVzap — paper Appendix B, Eqs. (4)–(6).
//!
//! C        = 4·D_h·(H_Q·D + H·D) + 6·D_h·D_int          (linear projections)
//! C_MLP    = 2·D_h·D_m + 2·D_m·H   (D_m = D_h/8 in the paper)
//! C_Linear = 2·D_h·H
//!
//! `bench_overhead` reproduces Table 3 for the paper's three models AND for
//! zap-lm (from the manifest), verifying the <=1.1% / <=0.02% bounds.

#[derive(Debug, Clone)]
pub struct LayerDims {
    pub name: String,
    pub h_q: usize,
    pub h_kv: usize,
    pub d_head: usize,
    pub d_model: usize,
    pub d_int: usize,
    pub d_surrogate: usize,
}

#[derive(Debug, Clone)]
pub struct OverheadRow {
    pub dims: LayerDims,
    pub layer_flops: f64,
    pub mlp_flops: f64,
    pub linear_flops: f64,
    pub mlp_pct: f64,
    pub linear_pct: f64,
}

/// Per-token FLOPs of one decoder layer's linear projections (Eq. 4).
pub fn layer_flops(d: &LayerDims) -> f64 {
    let attn = 4.0 * d.d_model as f64 * (d.h_q * d.d_head + d.h_kv * d.d_head) as f64;
    let ffn = 6.0 * d.d_model as f64 * d.d_int as f64;
    attn + ffn
}

/// Eq. 5 with general hidden width D_m.
pub fn mlp_flops(d: &LayerDims) -> f64 {
    2.0 * (d.d_model * d.d_surrogate) as f64 + 2.0 * (d.d_surrogate * d.h_kv) as f64
}

/// Eq. 6.
pub fn linear_flops(d: &LayerDims) -> f64 {
    2.0 * (d.d_model * d.h_kv) as f64
}

pub fn row(dims: LayerDims) -> OverheadRow {
    let c = layer_flops(&dims);
    let m = mlp_flops(&dims);
    let l = linear_flops(&dims);
    OverheadRow {
        layer_flops: c,
        mlp_flops: m,
        linear_flops: l,
        mlp_pct: 100.0 * m / c,
        linear_pct: 100.0 * l / c,
        dims,
    }
}

/// The paper's Table 3 rows (Qwen3-8B / Llama-3.1-8B / Qwen3-32B) plus an
/// optional extra model (zap-lm from the manifest).
pub fn overhead_table(extra: Option<LayerDims>) -> Vec<OverheadRow> {
    let mut rows = vec![
        row(LayerDims {
            name: "Qwen3-8B".into(),
            h_q: 32,
            h_kv: 8,
            d_head: 128,
            d_model: 4096,
            d_int: 12288,
            d_surrogate: 512,
        }),
        row(LayerDims {
            name: "Llama-3.1-8B-Instruct".into(),
            h_q: 32,
            h_kv: 8,
            d_head: 128,
            d_model: 4096,
            d_int: 14336,
            d_surrogate: 512,
        }),
        row(LayerDims {
            name: "Qwen3-32B".into(),
            h_q: 64,
            h_kv: 8,
            d_head: 128,
            d_model: 5120,
            d_int: 25600,
            d_surrogate: 640,
        }),
    ];
    if let Some(d) = extra {
        rows.push(row(d));
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's Table 3 numbers: 1.09% / 0.96% / 0.67% for MLP and
    /// 0.02% / 0.02% / 0.01% for Linear.
    #[test]
    fn reproduces_paper_table3() {
        let rows = overhead_table(None);
        let expect_mlp = [1.09, 0.96, 0.67];
        let expect_lin = [0.02, 0.02, 0.01];
        for (i, r) in rows.iter().enumerate() {
            assert!(
                (r.mlp_pct - expect_mlp[i]).abs() < 0.02,
                "{}: mlp {:.3}% vs paper {}%",
                r.dims.name,
                r.mlp_pct,
                expect_mlp[i]
            );
            assert!(
                (r.linear_pct - expect_lin[i]).abs() < 0.01,
                "{}: linear {:.3}% vs paper {}%",
                r.dims.name,
                r.linear_pct,
                expect_lin[i]
            );
        }
    }

    #[test]
    fn overhead_bounded() {
        for r in overhead_table(None) {
            assert!(r.mlp_pct < 1.1, "paper's stated bound");
            assert!(r.linear_pct <= 0.02 + 1e-9);
        }
    }
}
