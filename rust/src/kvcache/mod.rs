//! Paged KV cache manager with per-head variable lengths.
//!
//! The paper's §5 implementation challenge: KVzap's per-head thresholding
//! produces *non-uniform cache lengths across heads*, which a production
//! engine must account for with PagedAttention-style block tables. XLA
//! needs static shapes, so the device-side cache stays a dense
//! `[L, H, t_max]` buffer with a keep-mask; everything vLLM's block manager
//! would do — block tables, free lists, residency accounting, freed-memory
//! reporting — lives here (DESIGN.md §4). Eviction flips mask bits; when
//! every slot of a block is evicted (or never filled) the block is returned
//! to the [`BlockPool`].

pub mod pool;

pub use pool::BlockPool;

use std::sync::Arc;

/// Slots per block (vLLM's default block size is 16).
pub const BLOCK_SLOTS: usize = 16;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CacheStats {
    /// KV pairs currently kept (filled and not evicted), summed over heads.
    pub kept: usize,
    /// KV pairs ever filled (prompt + decoded), summed over heads.
    pub filled: usize,
    /// Blocks currently resident (≥1 kept slot).
    pub resident_blocks: usize,
    /// Blocks freed by eviction (were resident, now empty).
    pub freed_blocks: usize,
}

impl CacheStats {
    /// Removed fraction — the paper's "compression ratio (removed
    /// fraction)" from Table 2.
    pub fn compression(&self) -> f64 {
        if self.filled == 0 {
            0.0
        } else {
            1.0 - self.kept as f64 / self.filled as f64
        }
    }

    /// Compression factor (e.g. 0.75 removed -> 4.0x).
    pub fn factor(&self) -> f64 {
        if self.filled == 0 || self.kept == 0 {
            1.0
        } else {
            self.filled as f64 / self.kept as f64
        }
    }
}

/// Per-sequence paged cache bookkeeping over the dense masked device cache.
pub struct PagedKvCache {
    pub layers: usize,
    pub heads: usize,
    pub t_max: usize,
    /// kept[l][h] is a t_max bitset (true = attendable).
    kept: Vec<u64>,
    words_per_head: usize,
    /// Highest filled position + 1 (same across heads: decode always fills).
    len: usize,
    /// Per-(l,h) kept count, maintained incrementally.
    kept_count: Vec<usize>,
    freed_blocks: usize,
    pool: Option<Arc<BlockPool>>,
    pool_blocks: usize,
    /// Dirty flag so the coordinator only re-uploads the mask when it
    /// changed in a way the backend cannot mirror itself. Evictions set
    /// it; `fill` does not — the resident decode path marks each decoded
    /// position attendable on its own (see runtime/backend.rs), so a
    /// no-eviction sequence performs zero mask uploads after its join.
    dirty: bool,
}

impl PagedKvCache {
    pub fn new(layers: usize, heads: usize, t_max: usize) -> PagedKvCache {
        let words_per_head = t_max.div_ceil(64);
        PagedKvCache {
            layers,
            heads,
            t_max,
            kept: vec![0; layers * heads * words_per_head],
            words_per_head,
            len: 0,
            kept_count: vec![0; layers * heads],
            freed_blocks: 0,
            pool: None,
            pool_blocks: 0,
            dirty: true,
        }
    }

    /// Attach a shared block pool; residency is charged against it.
    pub fn with_pool(mut self, pool: Arc<BlockPool>) -> PagedKvCache {
        self.pool = Some(pool);
        self
    }

    fn idx(&self, l: usize, h: usize) -> usize {
        l * self.heads + h
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn is_kept(&self, l: usize, h: usize, pos: usize) -> bool {
        let base = self.idx(l, h) * self.words_per_head;
        self.kept[base + pos / 64] >> (pos % 64) & 1 == 1
    }

    fn set_kept(&mut self, l: usize, h: usize, pos: usize, val: bool) {
        let head = self.idx(l, h);
        let word = head * self.words_per_head + pos / 64;
        let bit = 1u64 << (pos % 64);
        let was = self.kept[word] & bit != 0;
        if was == val {
            return;
        }
        if val {
            self.kept[word] |= bit;
            self.kept_count[head] += 1;
        } else {
            self.kept[word] &= !bit;
            self.kept_count[head] -= 1;
            // evictions are the unpredictable mask changes (fills are
            // mirrored by the resident decode path itself)
            self.dirty = true;
            // Block reclamation: did this empty the whole block?
            let b0 = pos / BLOCK_SLOTS * BLOCK_SLOTS;
            let b1 = (b0 + BLOCK_SLOTS).min(self.t_max);
            if (b0..b1).all(|p| !self.is_kept(l, h, p)) {
                self.freed_blocks += 1;
                if let Some(pool) = &self.pool {
                    pool.release(1);
                    self.pool_blocks -= 1;
                }
            }
        }
    }

    /// Mark positions [len, new_len) filled (kept) in every head.
    /// Returns false if the block pool is exhausted (admission control).
    pub fn fill(&mut self, new_len: usize) -> bool {
        assert!(new_len <= self.t_max, "fill beyond t_max");
        if new_len <= self.len {
            return true;
        }
        // Charge new blocks to the pool before mutating.
        if let Some(pool) = &self.pool {
            let old_blocks = self.len.div_ceil(BLOCK_SLOTS);
            let new_blocks = new_len.div_ceil(BLOCK_SLOTS);
            let need = (new_blocks - old_blocks) * self.layers * self.heads;
            if !pool.try_alloc(need) {
                return false;
            }
            self.pool_blocks += need;
        }
        for l in 0..self.layers {
            for h in 0..self.heads {
                for pos in self.len..new_len {
                    self.set_kept(l, h, pos, true);
                }
            }
        }
        self.len = new_len;
        true
    }

    /// Evict one KV pair (no-op if already evicted / never filled).
    /// Returns true only on a kept -> evicted transition, so callers that
    /// count evictions (the decode ScoreBuffer) don't double-count pairs
    /// that prefill pruning already removed.
    pub fn evict(&mut self, l: usize, h: usize, pos: usize) -> bool {
        if pos < self.len && self.is_kept(l, h, pos) {
            self.set_kept(l, h, pos, false);
            return true;
        }
        false
    }

    /// Apply a per-head keep decision over the prompt region [0, upto):
    /// keep position p iff `keep(p)`.
    pub fn retain(&mut self, l: usize, h: usize, upto: usize, keep: impl Fn(usize) -> bool) {
        for pos in 0..upto.min(self.len) {
            if !keep(pos) {
                self.set_kept(l, h, pos, false);
            }
        }
    }

    /// Dense f32 mask `[L, H, t_max]` for the decode artifact.
    pub fn mask_f32(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.layers * self.heads * self.t_max];
        for l in 0..self.layers {
            for h in 0..self.heads {
                let base = (l * self.heads + h) * self.t_max;
                for pos in 0..self.len {
                    if self.is_kept(l, h, pos) {
                        out[base + pos] = 1.0;
                    }
                }
            }
        }
        out
    }

    /// True if the mask changed since the last `take_dirty` call in a way
    /// the backend cannot mirror itself, i.e. by evictions. (`fill` does
    /// not set it: the resident decode step marks its own position
    /// attendable on the backend side.) The engine consumes this to skip
    /// the per-slot mask upload on no-eviction steps.
    pub fn take_dirty(&mut self) -> bool {
        std::mem::take(&mut self.dirty)
    }

    /// Non-consuming view of the dirty flag (see [`PagedKvCache::take_dirty`]).
    /// The simulation harness reads this to predict whether the engine's
    /// next resident decode step will re-upload this sequence's mask.
    pub fn is_dirty(&self) -> bool {
        self.dirty
    }

    pub fn kept_in_head(&self, l: usize, h: usize) -> usize {
        self.kept_count[self.idx(l, h)]
    }

    pub fn stats(&self) -> CacheStats {
        let kept: usize = self.kept_count.iter().sum();
        let filled = self.len * self.layers * self.heads;
        let mut resident = 0;
        for l in 0..self.layers {
            for h in 0..self.heads {
                for b in 0..self.len.div_ceil(BLOCK_SLOTS) {
                    let b0 = b * BLOCK_SLOTS;
                    let b1 = (b0 + BLOCK_SLOTS).min(self.t_max);
                    if (b0..b1).any(|p| self.is_kept(l, h, p)) {
                        resident += 1;
                    }
                }
            }
        }
        CacheStats { kept, filled, resident_blocks: resident, freed_blocks: self.freed_blocks }
    }

    /// Release all pool blocks (sequence finished).
    pub fn release(&mut self) {
        if let Some(pool) = &self.pool {
            pool.release(self.pool_blocks);
            self.pool_blocks = 0;
        }
    }
}

impl Drop for PagedKvCache {
    fn drop(&mut self) {
        self.release();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fill_and_evict_accounting() {
        let mut c = PagedKvCache::new(2, 2, 64);
        assert!(c.fill(40));
        let s = c.stats();
        assert_eq!(s.kept, 40 * 4);
        assert_eq!(s.filled, 40 * 4);
        assert_eq!(s.compression(), 0.0);

        // evict a full block in one head -> freed_blocks increments
        for pos in 0..16 {
            c.evict(0, 0, pos);
        }
        let s = c.stats();
        assert_eq!(s.kept, 40 * 4 - 16);
        assert_eq!(s.freed_blocks, 1);
        assert!(s.compression() > 0.0);
    }

    #[test]
    fn mask_matches_kept() {
        let mut c = PagedKvCache::new(1, 2, 32);
        c.fill(20);
        c.evict(0, 1, 5);
        let m = c.mask_f32();
        assert_eq!(m.len(), 1 * 2 * 32);
        assert_eq!(m[5], 1.0); // head 0 untouched
        assert_eq!(m[32 + 5], 0.0); // head 1 evicted
        assert_eq!(m[32 + 20], 0.0); // beyond len unfilled
    }

    #[test]
    fn retain_applies_predicate() {
        let mut c = PagedKvCache::new(1, 1, 64);
        c.fill(50);
        c.retain(0, 0, 50, |p| p % 2 == 0);
        assert_eq!(c.kept_in_head(0, 0), 25);
        assert!(c.is_kept(0, 0, 0) && !c.is_kept(0, 0, 1));
    }

    #[test]
    fn pool_admission_control() {
        let pool = Arc::new(BlockPool::new(4)); // 4 blocks total
        let mut c = PagedKvCache::new(1, 1, 256).with_pool(pool.clone());
        assert!(c.fill(64)); // 4 blocks
        assert!(!c.fill(80)); // would need a 5th
        c.release();
        assert_eq!(pool.free(), 4);
    }

    #[test]
    fn dirty_tracks_evictions_not_fills() {
        let mut c = PagedKvCache::new(1, 1, 64);
        assert!(c.take_dirty(), "fresh cache starts dirty (initial upload)");
        c.fill(10);
        assert!(!c.take_dirty(), "fills are backend-mirrored, not dirty");
        c.evict(0, 0, 3);
        assert!(c.take_dirty());
        assert!(!c.take_dirty(), "take_dirty clears the flag");
        c.fill(12);
        assert!(!c.take_dirty());
    }

    #[test]
    fn double_evict_idempotent() {
        let mut c = PagedKvCache::new(1, 1, 32);
        c.fill(10);
        assert!(c.evict(0, 0, 3), "first evict is a kept -> evicted transition");
        assert!(!c.evict(0, 0, 3), "second evict is a no-op");
        assert!(!c.evict(0, 0, 20), "beyond len is a no-op");
        assert_eq!(c.kept_in_head(0, 0), 9);
    }
}
