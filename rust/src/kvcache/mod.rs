//! Paged KV cache manager with per-head variable lengths and a two-tier
//! position lifecycle.
//!
//! The paper's §5 implementation challenge: KVzap's per-head thresholding
//! produces *non-uniform cache lengths across heads*, which a production
//! engine must account for with PagedAttention-style block tables. XLA
//! needs static shapes, so the device-side cache stays a dense
//! `[L, H, t_max]` buffer with a keep-mask; everything vLLM's block manager
//! would do — block tables, free lists, residency accounting, freed-memory
//! reporting — lives here (DESIGN.md §4).
//!
//! Every filled position is in exactly one of three states:
//!
//! ```text
//!   kept ──demote()──▶ demoted ──drop_demoted()──▶ dropped
//!     │                   │
//!     │                rehydrate()
//!     │                   ▼
//!     │◀──────────────── kept
//!     └────evict()──────────────────────────────▶ dropped
//! ```
//!
//! *kept* positions are attendable and charged to the resident
//! [`BlockPool`] in [`BLOCK_SLOTS`]-sized blocks; *demoted* positions are
//! masked off but retained as a quantized side-pool payload (charged in
//! bytes, see [`TierConfig`]) so they can be rehydrated; *dropped*
//! positions are gone. Eviction flips mask bits; when every slot of a
//! block is evicted or demoted the block is returned to the pool, and a
//! rehydrate re-charges it.

pub mod pool;

pub use pool::BlockPool;

use crate::runtime::kernels::{quant_row_bytes, QuantBits};
use std::sync::Arc;

/// Slots per block (vLLM's default block size is 16).
pub const BLOCK_SLOTS: usize = 16;

/// Shape and encoding of the demoted (quantized) tier for one cache.
///
/// `d_head == 0` disables the tier: [`PagedKvCache::demote`] refuses and
/// byte accounting reports zero (the pre-tier behavior).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TierConfig {
    /// Channels per K (and per V) row; 0 disables the demoted tier.
    pub d_head: usize,
    /// Code width of the quantized payload.
    pub bits: QuantBits,
    /// Channels per quantization group (scale + zero point stored per
    /// group, see `runtime::kernels::quantize_row`).
    pub group: usize,
}

impl TierConfig {
    /// A disabled tier (demotion refused, zero byte accounting).
    pub fn disabled() -> TierConfig {
        TierConfig { d_head: 0, bits: QuantBits::Int8, group: 8 }
    }

    /// Whether demotion is available.
    pub fn enabled(&self) -> bool {
        self.d_head > 0
    }

    /// Side-pool bytes one demoted position costs in one head: quantized
    /// K row + quantized V row, each with per-group scale/zero overhead.
    pub fn bytes_per_entry(&self) -> usize {
        2 * quant_row_bytes(self.d_head, self.group, self.bits)
    }

    /// Resident-tier bytes one charged block represents (f32 K + V rows
    /// for [`BLOCK_SLOTS`] positions of one head).
    pub fn resident_block_bytes(&self) -> usize {
        BLOCK_SLOTS * 2 * self.d_head * 4
    }
}

/// How a sequence cache charges shared pools — the engine-level admission
/// configuration ([`crate::coordinator::Engine::set_kv_pools`]).
///
/// *Split* is the historical shape: resident blocks charge a
/// block-denominated pool, demoted entries a byte-denominated side pool,
/// and either can be absent (uncharged). *Unified* is the
/// memory-governance shape: one byte-denominated pool is charged by both
/// tiers — a resident block costs [`TierConfig::resident_block_bytes`],
/// a demoted entry [`TierConfig::bytes_per_entry`] — so demotion competes
/// with residency for the same budget and fails gracefully into drop
/// when the pool is exhausted.
#[derive(Debug, Clone)]
pub enum KvPools {
    /// One byte-denominated pool charged by both tiers.
    Unified(Arc<BlockPool>),
    /// Block-denominated resident pool + byte-denominated side pool.
    Split {
        /// Resident-tier pool (units: blocks); `None` leaves residency
        /// uncharged.
        blocks: Option<Arc<BlockPool>>,
        /// Demoted-tier pool (units: bytes); `None` leaves the side tier
        /// uncharged.
        side: Option<Arc<BlockPool>>,
    },
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CacheStats {
    /// KV pairs currently kept (filled, attendable), summed over heads.
    pub kept: usize,
    /// KV pairs currently demoted to the quantized side tier.
    pub demoted: usize,
    /// KV pairs ever filled (prompt + decoded), summed over heads.
    pub filled: usize,
    /// Blocks currently charged to the resident pool (≥1 kept slot).
    pub resident_blocks: usize,
    /// Blocks freed so far by eviction/demotion (cumulative).
    pub freed_blocks: usize,
    /// Resident-tier bytes: charged blocks at full f32 K+V width
    /// (allocation-granular, so partially-kept blocks price honestly).
    pub resident_bytes: usize,
    /// Demoted-tier bytes: quantized payload + per-group parameters.
    pub side_bytes: usize,
    /// Cumulative demoted entries attended in place (no rehydrate) by the
    /// quantized decode path.
    pub quant_attended_rows: usize,
    /// Cumulative quantized bytes those in-place attends read
    /// (rows × [`TierConfig::bytes_per_entry`]).
    pub quant_attended_bytes: usize,
    /// Cumulative demotions refused under pool pressure (the caller fell
    /// back to dropping the entry instead).
    pub demote_refusals: usize,
}

impl CacheStats {
    /// Removed fraction — the paper's "compression ratio (removed
    /// fraction)" from Table 2. Demoted positions count as removed (they
    /// are not attendable); the bytes they still occupy show up in
    /// [`CacheStats::kv_bytes`] instead.
    pub fn compression(&self) -> f64 {
        if self.filled == 0 {
            0.0
        } else {
            1.0 - self.kept as f64 / self.filled as f64
        }
    }

    /// Compression factor (e.g. 0.75 removed -> 4.0x). A fully-pruned
    /// cache (`kept == 0` with `filled > 0`) is infinitely compressed —
    /// reporting 1.0 here would make the most aggressive policy setting
    /// read as "no compression" in the leaderboard.
    pub fn factor(&self) -> f64 {
        if self.filled == 0 {
            1.0
        } else if self.kept == 0 {
            f64::INFINITY
        } else {
            self.filled as f64 / self.kept as f64
        }
    }

    /// Positions dropped outright (never demoted, or demoted then dropped).
    pub fn dropped(&self) -> usize {
        self.filled - self.kept - self.demoted
    }

    /// Total cache footprint in bytes across both tiers. This is the
    /// honest memory axis for the leaderboard frontier: a demoted
    /// position is cheaper than a kept one but not free.
    pub fn kv_bytes(&self) -> usize {
        self.resident_bytes + self.side_bytes
    }
}

/// Per-sequence paged cache bookkeeping over the dense masked device cache.
pub struct PagedKvCache {
    pub layers: usize,
    pub heads: usize,
    pub t_max: usize,
    /// kept[l][h] is a t_max bitset (true = attendable).
    kept: Vec<u64>,
    /// demoted[l][h] is a t_max bitset (true = in the quantized side tier).
    /// Disjoint from `kept` by construction.
    demoted: Vec<u64>,
    words_per_head: usize,
    /// Highest filled position + 1 (same across heads: decode always fills).
    len: usize,
    /// Per-(l,h) kept count, maintained incrementally.
    kept_count: Vec<usize>,
    /// Per-(l,h) demoted count, maintained incrementally.
    demoted_count: Vec<usize>,
    /// resident[l][h] is a per-block bitset: true = charged to the pool.
    resident: Vec<u64>,
    block_words: usize,
    freed_blocks: usize,
    pool: Option<Arc<BlockPool>>,
    pool_blocks: usize,
    /// Side pool charged in bytes per demoted entry (admission control for
    /// the quantized tier); byte count maintained even without a pool.
    side_pool: Option<Arc<BlockPool>>,
    side_bytes: usize,
    /// Unified-pool mode: `pool` is byte-denominated and charged by both
    /// tiers (blocks at [`TierConfig::resident_block_bytes`], demoted
    /// entries at [`TierConfig::bytes_per_entry`]); `side_pool` is unused.
    unified: bool,
    /// Cumulative demotions refused because a pool was exhausted
    /// (pressure-driven refusals only — not disabled-tier or not-kept
    /// refusals). The graceful-degradation observable: each one means a
    /// caller fell back from demote to drop.
    demote_refusals: usize,
    /// Cumulative demoted entries the quantized decode path attended in
    /// place (see [`PagedKvCache::note_quant_attend`]). Pure telemetry —
    /// no pool charge moves, so `accounting_ok` ignores it.
    quant_attended_rows: usize,
    tier: TierConfig,
    /// Dirty flag so the coordinator only re-uploads the mask when it
    /// changed in a way the backend cannot mirror itself. Evictions,
    /// demotions and rehydrations set it; `fill` does not — the resident
    /// decode path marks each decoded position attendable on its own (see
    /// runtime/backend.rs), so a no-eviction sequence performs zero mask
    /// uploads after its join.
    dirty: bool,
}

impl PagedKvCache {
    pub fn new(layers: usize, heads: usize, t_max: usize) -> PagedKvCache {
        PagedKvCache::new_tiered(layers, heads, t_max, TierConfig::disabled())
    }

    /// A cache with an enabled demoted tier (the engine path: `d_head`
    /// from the model, int8/int4 groupwise encoding).
    pub fn new_tiered(layers: usize, heads: usize, t_max: usize, tier: TierConfig) -> PagedKvCache {
        let words_per_head = t_max.div_ceil(64);
        let block_words = t_max.div_ceil(BLOCK_SLOTS).div_ceil(64);
        PagedKvCache {
            layers,
            heads,
            t_max,
            kept: vec![0; layers * heads * words_per_head],
            demoted: vec![0; layers * heads * words_per_head],
            words_per_head,
            len: 0,
            kept_count: vec![0; layers * heads],
            demoted_count: vec![0; layers * heads],
            resident: vec![0; layers * heads * block_words],
            block_words,
            freed_blocks: 0,
            pool: None,
            pool_blocks: 0,
            side_pool: None,
            side_bytes: 0,
            unified: false,
            demote_refusals: 0,
            quant_attended_rows: 0,
            tier,
            dirty: true,
        }
    }

    /// Attach a shared block pool; residency is charged against it.
    pub fn with_pool(mut self, pool: Arc<BlockPool>) -> PagedKvCache {
        self.pool = Some(pool);
        self
    }

    /// Attach a shared side pool (byte-denominated); demotions are charged
    /// against it and refused when it is exhausted.
    pub fn with_side_pool(mut self, pool: Arc<BlockPool>) -> PagedKvCache {
        self.side_pool = Some(pool);
        self
    }

    /// Attach one byte-denominated pool charged by *both* tiers (see
    /// [`KvPools::Unified`]): resident blocks cost
    /// [`TierConfig::resident_block_bytes`] each, demoted entries
    /// [`TierConfig::bytes_per_entry`] each. Demotion now competes with
    /// residency for the same budget.
    pub fn with_unified_pool(mut self, pool: Arc<BlockPool>) -> PagedKvCache {
        self.pool = Some(pool);
        self.unified = true;
        self
    }

    /// Attach an engine-level pool configuration, charging this cache's
    /// *current* holdings (resident blocks + demoted bytes) against the
    /// pools — the snapshot-install path, where a cloned cache arrives
    /// with non-zero counters but detached handles. Returns false (cache
    /// left detached, nothing charged) if the pools cannot admit the
    /// holdings. On an empty cache this always succeeds.
    pub fn adopt_pools(&mut self, pools: &KvPools) -> bool {
        debug_assert!(
            self.pool.is_none() && self.side_pool.is_none(),
            "adopt_pools on a cache that already has pools"
        );
        match pools {
            KvPools::Unified(p) => {
                let cost = self.tier.resident_block_bytes().max(1);
                if !p.try_alloc(self.pool_blocks * cost + self.side_bytes) {
                    return false;
                }
                self.pool = Some(p.clone());
                self.unified = true;
            }
            KvPools::Split { blocks, side } => {
                if let Some(bp) = blocks {
                    if !bp.try_alloc(self.pool_blocks) {
                        return false;
                    }
                }
                if let Some(sp) = side {
                    if !sp.try_alloc(self.side_bytes) {
                        if let Some(bp) = blocks {
                            bp.release(self.pool_blocks);
                        }
                        return false;
                    }
                }
                self.pool = blocks.clone();
                self.side_pool = side.clone();
                self.unified = false;
            }
        }
        true
    }

    /// Pool units one resident block costs: bytes in unified mode, 1 in
    /// block-denominated mode.
    fn block_cost(&self) -> usize {
        if self.unified {
            self.tier.resident_block_bytes().max(1)
        } else {
            1
        }
    }

    /// Release `bytes` of demoted-tier charge back to whichever pool holds
    /// it (the unified pool, or the split-mode side pool).
    fn release_side_charge(&self, bytes: usize) {
        if self.unified {
            if let Some(p) = &self.pool {
                p.release(bytes);
            }
        } else if let Some(sp) = &self.side_pool {
            sp.release(bytes);
        }
    }

    /// Whether both tiers charge one shared byte pool.
    pub fn is_unified(&self) -> bool {
        self.unified
    }

    /// Total bytes this cache has charged across both tiers (resident
    /// blocks priced at full f32 width + demoted side bytes) — the
    /// memory-governance observable the simulation harness sums across
    /// live sequences against the pool budget.
    pub fn charged_bytes(&self) -> usize {
        self.pool_blocks * self.tier.resident_block_bytes() + self.side_bytes
    }

    /// Cumulative pressure-driven demotion refusals (pool exhausted; the
    /// caller fell back to dropping the entry).
    pub fn demote_refusals(&self) -> usize {
        self.demote_refusals
    }

    /// The demoted-tier configuration this cache was built with.
    pub fn tier(&self) -> TierConfig {
        self.tier
    }

    fn idx(&self, l: usize, h: usize) -> usize {
        l * self.heads + h
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn is_kept(&self, l: usize, h: usize, pos: usize) -> bool {
        let base = self.idx(l, h) * self.words_per_head;
        self.kept[base + pos / 64] >> (pos % 64) & 1 == 1
    }

    /// True if `(l, h, pos)` currently sits in the quantized side tier.
    pub fn is_demoted(&self, l: usize, h: usize, pos: usize) -> bool {
        let base = self.idx(l, h) * self.words_per_head;
        self.demoted[base + pos / 64] >> (pos % 64) & 1 == 1
    }

    fn set_demoted_bit(&mut self, l: usize, h: usize, pos: usize, val: bool) {
        let head = self.idx(l, h);
        let word = head * self.words_per_head + pos / 64;
        let bit = 1u64 << (pos % 64);
        if val {
            debug_assert!(self.demoted[word] & bit == 0);
            self.demoted[word] |= bit;
            self.demoted_count[head] += 1;
        } else {
            debug_assert!(self.demoted[word] & bit != 0);
            self.demoted[word] &= !bit;
            self.demoted_count[head] -= 1;
        }
    }

    fn block_resident(&self, l: usize, h: usize, b: usize) -> bool {
        let base = self.idx(l, h) * self.block_words;
        self.resident[base + b / 64] >> (b % 64) & 1 == 1
    }

    fn set_block_resident(&mut self, l: usize, h: usize, b: usize, val: bool) {
        let base = self.idx(l, h) * self.block_words;
        let bit = 1u64 << (b % 64);
        if val {
            self.resident[base + b / 64] |= bit;
        } else {
            self.resident[base + b / 64] &= !bit;
        }
    }

    fn kept_in_block(&self, l: usize, h: usize, b: usize) -> usize {
        let b0 = b * BLOCK_SLOTS;
        let b1 = (b0 + BLOCK_SLOTS).min(self.t_max);
        (b0..b1).filter(|&p| self.is_kept(l, h, p)).count()
    }

    fn set_kept(&mut self, l: usize, h: usize, pos: usize, val: bool) {
        let head = self.idx(l, h);
        let word = head * self.words_per_head + pos / 64;
        let bit = 1u64 << (pos % 64);
        let was = self.kept[word] & bit != 0;
        if was == val {
            return;
        }
        if val {
            debug_assert!(
                self.block_resident(l, h, pos / BLOCK_SLOTS),
                "set_kept(true) into an uncharged block"
            );
            self.kept[word] |= bit;
            self.kept_count[head] += 1;
        } else {
            self.kept[word] &= !bit;
            self.kept_count[head] -= 1;
            // evictions are the unpredictable mask changes (fills are
            // mirrored by the resident decode path itself)
            self.dirty = true;
            // Block reclamation: did this empty the whole block?
            let b = pos / BLOCK_SLOTS;
            if self.kept_in_block(l, h, b) == 0 && self.block_resident(l, h, b) {
                self.set_block_resident(l, h, b, false);
                self.freed_blocks += 1;
                self.pool_blocks -= 1;
                let cost = self.block_cost();
                if let Some(pool) = &self.pool {
                    pool.release(cost);
                }
            }
        }
    }

    /// Mark positions [len, new_len) filled (kept) in every head.
    /// Returns false if the block pool is exhausted (admission control).
    pub fn fill(&mut self, new_len: usize) -> bool {
        assert!(new_len <= self.t_max, "fill beyond t_max");
        if new_len <= self.len {
            return true;
        }
        // Charge exactly the not-currently-resident blocks the new range
        // touches (a freed partial tail block is re-charged here).
        let b0 = self.len / BLOCK_SLOTS;
        let b1 = new_len.div_ceil(BLOCK_SLOTS);
        let mut need = 0;
        for l in 0..self.layers {
            for h in 0..self.heads {
                need += (b0..b1).filter(|&b| !self.block_resident(l, h, b)).count();
            }
        }
        if let Some(pool) = &self.pool {
            if !pool.try_alloc(need * self.block_cost()) {
                return false;
            }
        }
        self.pool_blocks += need;
        for l in 0..self.layers {
            for h in 0..self.heads {
                for b in b0..b1 {
                    self.set_block_resident(l, h, b, true);
                }
                for pos in self.len..new_len {
                    self.set_kept(l, h, pos, true);
                }
            }
        }
        self.len = new_len;
        true
    }

    /// Evict one KV pair outright (no-op if not currently kept).
    /// Returns true only on a kept -> dropped transition, so callers that
    /// count evictions (the decode ScoreBuffer) don't double-count pairs
    /// that prefill pruning already removed. Demoted positions are not
    /// touched — use [`PagedKvCache::drop_demoted`] for that edge.
    pub fn evict(&mut self, l: usize, h: usize, pos: usize) -> bool {
        if pos < self.len && self.is_kept(l, h, pos) {
            self.set_kept(l, h, pos, false);
            return true;
        }
        false
    }

    /// Demote one kept KV pair into the quantized side tier: it stops
    /// being attendable (mask off, resident block reclaimable) but its
    /// side-pool bytes are charged so it can be rehydrated later.
    /// Returns false — leaving the position kept — if the tier is
    /// disabled, the position is not kept, or the side pool is exhausted
    /// (callers fall back to a plain [`PagedKvCache::evict`]).
    pub fn demote(&mut self, l: usize, h: usize, pos: usize) -> bool {
        if !self.tier.enabled() || pos >= self.len || !self.is_kept(l, h, pos) {
            return false;
        }
        let bytes = self.tier.bytes_per_entry();
        if self.unified {
            // Demotion competes with residency for the one byte budget.
            if let Some(p) = &self.pool {
                if !p.try_alloc(bytes) {
                    self.demote_refusals += 1;
                    return false;
                }
            }
        } else if let Some(sp) = &self.side_pool {
            if !sp.try_alloc(bytes) {
                self.demote_refusals += 1;
                return false;
            }
        }
        self.side_bytes += bytes;
        self.set_demoted_bit(l, h, pos, true);
        self.set_kept(l, h, pos, false);
        true
    }

    /// Rehydrate one demoted KV pair back to kept (score rebound or
    /// window re-entry). Re-charges the resident block if reclamation
    /// freed it; returns false — leaving the position demoted — if the
    /// position is not demoted or the resident pool is exhausted.
    pub fn rehydrate(&mut self, l: usize, h: usize, pos: usize) -> bool {
        if pos >= self.len || !self.is_demoted(l, h, pos) {
            return false;
        }
        let b = pos / BLOCK_SLOTS;
        if !self.block_resident(l, h, b) {
            let cost = self.block_cost();
            if let Some(pool) = &self.pool {
                if !pool.try_alloc(cost) {
                    return false;
                }
            }
            self.set_block_resident(l, h, b, true);
            self.pool_blocks += 1;
        }
        self.set_demoted_bit(l, h, pos, false);
        let bytes = self.tier.bytes_per_entry();
        self.side_bytes -= bytes;
        self.release_side_charge(bytes);
        self.set_kept(l, h, pos, true);
        // mask 0 -> 1 is a change the backend cannot mirror itself
        self.dirty = true;
        true
    }

    /// Drop a demoted KV pair for good (demoted -> dropped), releasing its
    /// side-pool bytes. Returns true on the transition.
    pub fn drop_demoted(&mut self, l: usize, h: usize, pos: usize) -> bool {
        if pos >= self.len || !self.is_demoted(l, h, pos) {
            return false;
        }
        self.set_demoted_bit(l, h, pos, false);
        let bytes = self.tier.bytes_per_entry();
        self.side_bytes -= bytes;
        self.release_side_charge(bytes);
        true
    }

    /// Apply a per-head keep decision over the prompt region [0, upto):
    /// keep position p iff `keep(p)`. Drop-only (budget policies have no
    /// demotion band); demoted positions are untouched.
    pub fn retain(&mut self, l: usize, h: usize, upto: usize, keep: impl Fn(usize) -> bool) {
        for pos in 0..upto.min(self.len) {
            if !keep(pos) {
                self.set_kept(l, h, pos, false);
            }
        }
    }

    /// Dense f32 mask `[L, H, t_max]` for the decode artifact. Demoted
    /// positions read 0.0 — they are not attendable until rehydrated.
    pub fn mask_f32(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.layers * self.heads * self.t_max];
        for l in 0..self.layers {
            for h in 0..self.heads {
                let base = (l * self.heads + h) * self.t_max;
                for pos in 0..self.len {
                    if self.is_kept(l, h, pos) {
                        out[base + pos] = 1.0;
                    }
                }
            }
        }
        out
    }

    /// True if the mask changed since the last `take_dirty` call in a way
    /// the backend cannot mirror itself, i.e. by evictions, demotions or
    /// rehydrations. (`fill` does not set it: the resident decode step
    /// marks its own position attendable on the backend side.) The engine
    /// consumes this to skip the per-slot mask upload on no-change steps.
    pub fn take_dirty(&mut self) -> bool {
        std::mem::take(&mut self.dirty)
    }

    /// Non-consuming view of the dirty flag (see [`PagedKvCache::take_dirty`]).
    /// The simulation harness reads this to predict whether the engine's
    /// next resident decode step will re-upload this sequence's mask.
    pub fn is_dirty(&self) -> bool {
        self.dirty
    }

    pub fn kept_in_head(&self, l: usize, h: usize) -> usize {
        self.kept_count[self.idx(l, h)]
    }

    /// Demoted entries currently held for one head.
    pub fn demoted_in_head(&self, l: usize, h: usize) -> usize {
        self.demoted_count[self.idx(l, h)]
    }

    /// Positions currently demoted in one head, ascending.
    pub fn demoted_positions(&self, l: usize, h: usize) -> Vec<usize> {
        (0..self.len).filter(|&p| self.is_demoted(l, h, p)).collect()
    }

    /// Demoted entries at positions `>= from`, summed over heads — the
    /// window re-entry probe: for window-protected policies this must be
    /// 0 at `from = len - window` after every step.
    pub fn demoted_at_or_after(&self, from: usize) -> usize {
        let mut n = 0;
        for l in 0..self.layers {
            for h in 0..self.heads {
                n += (from..self.len).filter(|&p| self.is_demoted(l, h, p)).count();
            }
        }
        n
    }

    /// Record that the quantized decode path attended `rows` of this
    /// sequence's demoted entries in place this step. Telemetry only: no
    /// tier state changes (the entries stay demoted, their bytes stay
    /// charged to the side pool), so resident accounting is untouched.
    pub fn note_quant_attend(&mut self, rows: usize) {
        self.quant_attended_rows += rows;
    }

    /// Cumulative quant-attended rows (see [`PagedKvCache::note_quant_attend`]).
    pub fn quant_attended_rows(&self) -> usize {
        self.quant_attended_rows
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            kept: self.kept_count.iter().sum(),
            demoted: self.demoted_count.iter().sum(),
            filled: self.len * self.layers * self.heads,
            resident_blocks: self.pool_blocks,
            freed_blocks: self.freed_blocks,
            resident_bytes: self.pool_blocks * self.tier.resident_block_bytes(),
            side_bytes: self.side_bytes,
            quant_attended_rows: self.quant_attended_rows,
            quant_attended_bytes: self.quant_attended_rows * self.tier.bytes_per_entry(),
            demote_refusals: self.demote_refusals,
        }
    }

    /// Authoritative tier/pool recount for the simulation harness: checks
    /// that the incremental counters match the bitsets, that kept/demoted
    /// are disjoint and inside `[0, len)`, and that a block is charged iff
    /// it has a kept slot. Returns a description of the first mismatch.
    pub fn accounting_ok(&self) -> Result<(), String> {
        let mut kept_total = 0;
        let mut demoted_total = 0;
        let mut resident_total = 0;
        for l in 0..self.layers {
            for h in 0..self.heads {
                let head = self.idx(l, h);
                let mut kept = 0;
                let mut demoted = 0;
                for p in 0..self.t_max {
                    let k = self.is_kept(l, h, p);
                    let d = self.is_demoted(l, h, p);
                    if k && d {
                        return Err(format!("({l},{h},{p}) both kept and demoted"));
                    }
                    if (k || d) && p >= self.len {
                        return Err(format!("({l},{h},{p}) marked beyond len {}", self.len));
                    }
                    kept += k as usize;
                    demoted += d as usize;
                }
                if kept != self.kept_count[head] {
                    return Err(format!(
                        "({l},{h}) kept recount {kept} != counter {}",
                        self.kept_count[head]
                    ));
                }
                if demoted != self.demoted_count[head] {
                    return Err(format!(
                        "({l},{h}) demoted recount {demoted} != counter {}",
                        self.demoted_count[head]
                    ));
                }
                kept_total += kept;
                demoted_total += demoted;
                for b in 0..self.t_max.div_ceil(BLOCK_SLOTS) {
                    let charged = self.block_resident(l, h, b);
                    let occupied = self.kept_in_block(l, h, b) > 0;
                    if charged != occupied {
                        return Err(format!(
                            "({l},{h}) block {b}: charged={charged} but kept-in-block>0={occupied}"
                        ));
                    }
                    resident_total += charged as usize;
                }
            }
        }
        if resident_total != self.pool_blocks {
            return Err(format!(
                "resident recount {resident_total} != pool_blocks {}",
                self.pool_blocks
            ));
        }
        let want_side = demoted_total * self.tier.bytes_per_entry();
        if want_side != self.side_bytes {
            return Err(format!("side bytes {} != {demoted_total} entries", self.side_bytes));
        }
        let _ = kept_total;
        Ok(())
    }

    /// Release all pool charges (sequence finished): resident blocks and
    /// demoted-tier bytes both go back to their pools.
    pub fn release(&mut self) {
        if let Some(pool) = &self.pool {
            pool.release(self.pool_blocks * self.block_cost());
        }
        self.pool_blocks = 0;
        self.resident.fill(0);
        self.release_side_charge(self.side_bytes);
        self.side_bytes = 0;
    }
}

impl Clone for PagedKvCache {
    /// Snapshot clone (the prefix-reuse cache stores one per cached
    /// prefill): every bitset and counter is copied, but the pool handles
    /// are detached so the clone never releases charges it did not
    /// allocate. Engine-path sequence caches carry no pools, so the clone
    /// is a full-fidelity snapshot there; re-attach with
    /// [`PagedKvCache::with_pool`] if admission control is wanted.
    fn clone(&self) -> PagedKvCache {
        PagedKvCache {
            layers: self.layers,
            heads: self.heads,
            t_max: self.t_max,
            kept: self.kept.clone(),
            demoted: self.demoted.clone(),
            words_per_head: self.words_per_head,
            len: self.len,
            kept_count: self.kept_count.clone(),
            demoted_count: self.demoted_count.clone(),
            resident: self.resident.clone(),
            block_words: self.block_words,
            freed_blocks: self.freed_blocks,
            pool: None,
            pool_blocks: self.pool_blocks,
            side_pool: None,
            side_bytes: self.side_bytes,
            unified: false,
            demote_refusals: self.demote_refusals,
            quant_attended_rows: self.quant_attended_rows,
            tier: self.tier,
            dirty: self.dirty,
        }
    }
}

impl Drop for PagedKvCache {
    fn drop(&mut self) {
        self.release();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tier() -> TierConfig {
        TierConfig { d_head: 16, bits: QuantBits::Int8, group: 8 }
    }

    #[test]
    fn fill_and_evict_accounting() {
        let mut c = PagedKvCache::new(2, 2, 64);
        assert!(c.fill(40));
        let s = c.stats();
        assert_eq!(s.kept, 40 * 4);
        assert_eq!(s.filled, 40 * 4);
        assert_eq!(s.compression(), 0.0);

        // evict a full block in one head -> freed_blocks increments
        for pos in 0..16 {
            c.evict(0, 0, pos);
        }
        let s = c.stats();
        assert_eq!(s.kept, 40 * 4 - 16);
        assert_eq!(s.freed_blocks, 1);
        assert!(s.compression() > 0.0);
        c.accounting_ok().unwrap();
    }

    #[test]
    fn mask_matches_kept() {
        let mut c = PagedKvCache::new(1, 2, 32);
        c.fill(20);
        c.evict(0, 1, 5);
        let m = c.mask_f32();
        assert_eq!(m.len(), 1 * 2 * 32);
        assert_eq!(m[5], 1.0); // head 0 untouched
        assert_eq!(m[32 + 5], 0.0); // head 1 evicted
        assert_eq!(m[32 + 20], 0.0); // beyond len unfilled
    }

    #[test]
    fn retain_applies_predicate() {
        let mut c = PagedKvCache::new(1, 1, 64);
        c.fill(50);
        c.retain(0, 0, 50, |p| p % 2 == 0);
        assert_eq!(c.kept_in_head(0, 0), 25);
        assert!(c.is_kept(0, 0, 0) && !c.is_kept(0, 0, 1));
    }

    #[test]
    fn pool_admission_control() {
        let pool = Arc::new(BlockPool::new(4)); // 4 blocks total
        let mut c = PagedKvCache::new(1, 1, 256).with_pool(pool.clone());
        assert!(c.fill(64)); // 4 blocks
        assert!(!c.fill(80)); // would need a 5th
        c.release();
        assert_eq!(pool.free(), 4);
    }

    #[test]
    fn dirty_tracks_evictions_not_fills() {
        let mut c = PagedKvCache::new(1, 1, 64);
        assert!(c.take_dirty(), "fresh cache starts dirty (initial upload)");
        c.fill(10);
        assert!(!c.take_dirty(), "fills are backend-mirrored, not dirty");
        c.evict(0, 0, 3);
        assert!(c.take_dirty());
        assert!(!c.take_dirty(), "take_dirty clears the flag");
        c.fill(12);
        assert!(!c.take_dirty());
    }

    #[test]
    fn double_evict_idempotent() {
        let mut c = PagedKvCache::new(1, 1, 32);
        c.fill(10);
        assert!(c.evict(0, 0, 3), "first evict is a kept -> evicted transition");
        assert!(!c.evict(0, 0, 3), "second evict is a no-op");
        assert!(!c.evict(0, 0, 20), "beyond len is a no-op");
        assert_eq!(c.kept_in_head(0, 0), 9);
    }

    #[test]
    fn demote_rehydrate_lifecycle() {
        let mut c = PagedKvCache::new_tiered(1, 1, 64, tier());
        c.fill(20);
        let bpe = tier().bytes_per_entry();
        assert!(c.demote(0, 0, 3));
        assert!(!c.demote(0, 0, 3), "demote is kept-only");
        assert!(!c.evict(0, 0, 3), "evict must not touch demoted positions");
        let s = c.stats();
        assert_eq!((s.kept, s.demoted, s.dropped()), (19, 1, 0));
        assert_eq!(s.side_bytes, bpe);
        assert!(!c.is_kept(0, 0, 3) && c.is_demoted(0, 0, 3));
        assert_eq!(c.mask_f32()[3], 0.0, "demoted is not attendable");
        c.take_dirty();

        assert!(c.rehydrate(0, 0, 3));
        assert!(!c.rehydrate(0, 0, 3), "rehydrate is demoted-only");
        let s = c.stats();
        assert_eq!((s.kept, s.demoted, s.side_bytes), (20, 0, 0));
        assert!(c.is_kept(0, 0, 3));
        assert!(c.is_dirty(), "rehydration re-dirties the mask");
        c.accounting_ok().unwrap();
    }

    #[test]
    fn quant_attend_is_telemetry_only() {
        let mut c = PagedKvCache::new_tiered(1, 1, 64, tier());
        c.fill(20);
        assert!(c.demote(0, 0, 3));
        let before = c.stats();
        c.note_quant_attend(5);
        c.note_quant_attend(2);
        let s = c.stats();
        assert_eq!(s.quant_attended_rows, 7);
        assert_eq!(s.quant_attended_bytes, 7 * tier().bytes_per_entry());
        assert_eq!(
            (s.kept, s.demoted, s.side_bytes, s.resident_blocks),
            (before.kept, before.demoted, before.side_bytes, before.resident_blocks),
            "quant attends must not move tier state"
        );
        c.accounting_ok().unwrap();
    }

    #[test]
    fn demote_disabled_without_tier() {
        let mut c = PagedKvCache::new(1, 1, 32);
        c.fill(10);
        assert!(!c.demote(0, 0, 3), "disabled tier refuses demotion");
        assert!(c.is_kept(0, 0, 3));
    }

    #[test]
    fn demoting_whole_block_frees_it_and_rehydrate_recharges() {
        let pool = Arc::new(BlockPool::new(4));
        let mut c = PagedKvCache::new_tiered(1, 1, 64, tier()).with_pool(pool.clone());
        assert!(c.fill(64));
        assert_eq!(pool.free(), 0);
        for pos in 0..16 {
            assert!(c.demote(0, 0, pos));
        }
        assert_eq!(pool.free(), 1, "fully-demoted block returns to the pool");
        assert_eq!(c.stats().freed_blocks, 1);

        // the freed block can be claimed by someone else -> rehydrate fails
        assert!(pool.try_alloc(1));
        assert!(!c.rehydrate(0, 0, 0), "no resident block available");
        assert!(c.is_demoted(0, 0, 0), "failed rehydrate leaves the entry demoted");
        pool.release(1);

        assert!(c.rehydrate(0, 0, 0));
        assert_eq!(pool.free(), 0, "rehydrate re-charges the block");
        let s = c.stats();
        assert_eq!((s.kept, s.demoted), (49, 15));
        c.accounting_ok().unwrap();
    }

    #[test]
    fn side_pool_admission_control() {
        let bpe = tier().bytes_per_entry();
        let side = Arc::new(BlockPool::new(2 * bpe)); // room for two entries
        let mut c = PagedKvCache::new_tiered(1, 1, 64, tier()).with_side_pool(side.clone());
        c.fill(10);
        assert!(c.demote(0, 0, 0));
        assert!(c.demote(0, 0, 1));
        assert!(!c.demote(0, 0, 2), "side pool exhausted -> demotion refused");
        assert!(c.is_kept(0, 0, 2), "refused demotion leaves the entry kept");
        assert!(c.drop_demoted(0, 0, 0), "demoted -> dropped frees side bytes");
        assert_eq!(side.free(), bpe);
        assert!(c.demote(0, 0, 2));
        let s = c.stats();
        assert_eq!((s.kept, s.demoted, s.dropped()), (7, 2, 1));
        c.release();
        assert_eq!(side.free(), 2 * bpe);
    }

    #[test]
    fn unified_pool_charges_both_tiers_in_bytes() {
        let t = tier();
        let bpe = t.bytes_per_entry();
        let bb = t.resident_block_bytes();
        // Room for two resident blocks plus three demoted entries.
        let pool = Arc::new(BlockPool::new(2 * bb + 3 * bpe));
        let mut c = PagedKvCache::new_tiered(1, 1, 64, t).with_unified_pool(pool.clone());
        assert!(c.is_unified());
        assert!(c.fill(32), "two blocks fit");
        assert_eq!(pool.free(), 3 * bpe);
        assert_eq!(c.charged_bytes(), 2 * bb);
        assert!(!c.fill(33), "a third block does not fit");

        assert!(c.demote(0, 0, 0));
        assert!(c.demote(0, 0, 1));
        assert!(c.demote(0, 0, 2));
        assert_eq!(pool.free(), 0);
        assert_eq!(c.charged_bytes(), 2 * bb + 3 * bpe);
        assert!(!c.demote(0, 0, 3), "pool exhausted -> demotion refused");
        assert!(c.is_kept(0, 0, 3), "refused demotion leaves the entry kept");
        assert_eq!(c.stats().demote_refusals, 1);
        assert!(c.evict(0, 0, 3), "caller falls back to dropping outright");
        c.accounting_ok().unwrap();

        // Dropping a demoted entry returns its bytes to the shared budget,
        // letting the next demotion through.
        assert!(c.drop_demoted(0, 0, 0));
        assert_eq!(pool.free(), bpe);
        assert!(c.demote(0, 0, 4));
        assert_eq!(pool.free(), 0);

        // Evicting the rest of block 0 vacates it; its block-bytes flow
        // back into the same budget and cover a block re-charge on
        // rehydrate.
        for pos in 5..16 {
            assert!(c.evict(0, 0, pos));
        }
        assert_eq!(pool.free(), bb, "vacated block returns byte-priced charge");
        assert!(c.rehydrate(0, 0, 1), "freed block bytes cover the re-charge");
        assert_eq!(c.charged_bytes(), 2 * bb + 2 * bpe);
        assert_eq!(pool.free(), 2 * bb + 3 * bpe - c.charged_bytes());
        c.accounting_ok().unwrap();
        c.release();
        assert_eq!(pool.free(), 2 * bb + 3 * bpe, "release returns every byte");
    }

    #[test]
    fn adopt_pools_charges_existing_holdings() {
        let t = tier();
        let bb = t.resident_block_bytes();
        let bpe = t.bytes_per_entry();
        let mut donor = PagedKvCache::new_tiered(1, 1, 64, t);
        donor.fill(32);
        donor.demote(0, 0, 0);
        let snap = donor.clone();
        assert_eq!(snap.charged_bytes(), 2 * bb + bpe);

        // Too small: adoption refused, pool untouched, cache detached.
        let tiny = Arc::new(BlockPool::new(bb));
        let mut c = snap.clone();
        assert!(!c.adopt_pools(&KvPools::Unified(tiny.clone())));
        assert_eq!(tiny.free(), bb);
        c.release();
        assert_eq!(tiny.free(), bb, "detached cache releases nothing");

        // Big enough: holdings charged, release returns them.
        let pool = Arc::new(BlockPool::new(4 * bb));
        let mut c = snap.clone();
        assert!(c.adopt_pools(&KvPools::Unified(pool.clone())));
        assert_eq!(pool.free(), 4 * bb - (2 * bb + bpe));
        drop(c);
        assert_eq!(pool.free(), 4 * bb);

        // Split adoption rolls back the block charge if the side pool
        // refuses.
        let blocks = Arc::new(BlockPool::new(8));
        let no_side = Arc::new(BlockPool::new(0));
        let mut c = snap.clone();
        assert!(!c.adopt_pools(&KvPools::Split {
            blocks: Some(blocks.clone()),
            side: Some(no_side),
        }));
        assert_eq!(blocks.free(), 8, "failed split adoption rolls back block charge");
    }

    #[test]
    fn fill_into_freed_tail_block_recharges() {
        let pool = Arc::new(BlockPool::new(8));
        let mut c = PagedKvCache::new(1, 1, 128).with_pool(pool.clone());
        assert!(c.fill(20)); // blocks 0,1
        assert_eq!(pool.free(), 6);
        for pos in 16..20 {
            c.evict(0, 0, pos);
        }
        assert_eq!(pool.free(), 7, "emptied tail block freed");
        assert!(c.fill(25), "extend into the freed tail block");
        assert_eq!(pool.free(), 6, "tail block re-charged exactly once");
        c.accounting_ok().unwrap();
    }

    #[test]
    fn factor_of_fully_pruned_head_is_infinite() {
        let mut c = PagedKvCache::new(1, 1, 32);
        c.fill(10);
        for pos in 0..10 {
            c.evict(0, 0, pos);
        }
        let s = c.stats();
        assert_eq!(s.kept, 0);
        assert!(s.factor().is_infinite(), "kept==0, filled>0 must read as infinite factor");
        assert_eq!(s.compression(), 1.0);
        let empty = PagedKvCache::new(1, 1, 32).stats();
        assert_eq!(empty.factor(), 1.0, "empty cache stays neutral");
    }
}
