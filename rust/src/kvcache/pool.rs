//! Shared block pool: global KV memory accounting across sequences
//! (the vLLM block-allocator role — admission control for the batcher).

use std::sync::atomic::{AtomicUsize, Ordering};

pub struct BlockPool {
    total: usize,
    free: AtomicUsize,
}

impl BlockPool {
    pub fn new(total: usize) -> BlockPool {
        BlockPool { total, free: AtomicUsize::new(total) }
    }

    pub fn total(&self) -> usize {
        self.total
    }

    pub fn free(&self) -> usize {
        self.free.load(Ordering::Relaxed)
    }

    pub fn used(&self) -> usize {
        self.total - self.free()
    }

    /// Try to reserve `n` blocks; false (and no change) if unavailable.
    pub fn try_alloc(&self, n: usize) -> bool {
        let mut cur = self.free.load(Ordering::Relaxed);
        loop {
            if cur < n {
                return false;
            }
            match self.free.compare_exchange_weak(
                cur,
                cur - n,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => return true,
                Err(c) => cur = c,
            }
        }
    }

    pub fn release(&self, n: usize) {
        let prev = self.free.fetch_add(n, Ordering::AcqRel);
        debug_assert!(prev + n <= self.total, "pool over-release");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn alloc_release() {
        let p = BlockPool::new(10);
        assert!(p.try_alloc(7));
        assert!(!p.try_alloc(4));
        assert!(p.try_alloc(3));
        p.release(10);
        assert_eq!(p.free(), 10);
    }

    #[test]
    fn concurrent_alloc_never_oversubscribes() {
        let p = Arc::new(BlockPool::new(1000));
        let mut handles = vec![];
        for _ in 0..8 {
            let p = p.clone();
            handles.push(std::thread::spawn(move || {
                let mut got = 0;
                for _ in 0..1000 {
                    if p.try_alloc(1) {
                        got += 1;
                    }
                }
                got
            }));
        }
        let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, 1000);
        assert_eq!(p.free(), 0);
    }
}
