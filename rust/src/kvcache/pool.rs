//! Shared block pool: global KV memory accounting across sequences
//! (the vLLM block-allocator role — admission control for the batcher).
//!
//! The same counter type backs both tiers of the cache: the resident
//! pool is denominated in blocks of [`crate::kvcache::BLOCK_SLOTS`]
//! f32 KV rows, the demoted side pool in *bytes* of quantized payload
//! (see [`crate::kvcache::TierConfig`]). Only the unit differs; the
//! admission-control contract is identical.

use std::sync::atomic::{AtomicUsize, Ordering};

pub struct BlockPool {
    total: usize,
    free: AtomicUsize,
    /// Units that `release` had to discard because they would have pushed
    /// `free` past `total`. Always 0 in a correct system; counted (rather
    /// than asserted away) so release builds clamp instead of silently
    /// corrupting the free counter, and the simulation harness can fail
    /// loudly on any nonzero value.
    over_release: AtomicUsize,
    /// High-water mark of `used()`, maintained on every successful
    /// allocation. Lets harnesses size a budget to a probed workload
    /// ("rerun with budget = peak - 1") without replaying allocation
    /// history themselves.
    peak: AtomicUsize,
}

impl BlockPool {
    pub fn new(total: usize) -> BlockPool {
        BlockPool {
            total,
            free: AtomicUsize::new(total),
            over_release: AtomicUsize::new(0),
            peak: AtomicUsize::new(0),
        }
    }

    pub fn total(&self) -> usize {
        self.total
    }

    pub fn free(&self) -> usize {
        self.free.load(Ordering::Relaxed)
    }

    pub fn used(&self) -> usize {
        self.total - self.free()
    }

    /// Cumulative units discarded by over-releases (see field docs).
    pub fn over_released(&self) -> usize {
        self.over_release.load(Ordering::Relaxed)
    }

    /// Highest `used()` any successful allocation has reached so far.
    pub fn peak(&self) -> usize {
        self.peak.load(Ordering::Relaxed)
    }

    /// Try to reserve `n` blocks; false (and no change) if unavailable.
    pub fn try_alloc(&self, n: usize) -> bool {
        let mut cur = self.free.load(Ordering::Relaxed);
        loop {
            if cur < n {
                return false;
            }
            match self.free.compare_exchange_weak(
                cur,
                cur - n,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => {
                    self.peak.fetch_max(self.total - (cur - n), Ordering::Relaxed);
                    return true;
                }
                Err(c) => cur = c,
            }
        }
    }

    /// Return `n` units to the pool. Saturates at `total`: an over-release
    /// (an accounting bug upstream) clamps `free` to `total` and counts the
    /// excess in [`BlockPool::over_released`] instead of corrupting the
    /// counter. Debug builds still assert so tests catch the bug at source.
    pub fn release(&self, n: usize) {
        let mut cur = self.free.load(Ordering::Relaxed);
        loop {
            let want = (cur + n).min(self.total);
            match self.free.compare_exchange_weak(cur, want, Ordering::AcqRel, Ordering::Relaxed) {
                Ok(_) => {
                    let excess = (cur + n) - want;
                    if excess > 0 {
                        self.over_release.fetch_add(excess, Ordering::Relaxed);
                        debug_assert!(false, "pool over-release by {excess}");
                    }
                    return;
                }
                Err(c) => cur = c,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn alloc_release() {
        let p = BlockPool::new(10);
        assert!(p.try_alloc(7));
        assert!(!p.try_alloc(4));
        assert!(p.try_alloc(3));
        p.release(10);
        assert_eq!(p.free(), 10);
    }

    #[test]
    fn peak_tracks_high_water_mark_not_current_usage() {
        let p = BlockPool::new(10);
        assert_eq!(p.peak(), 0);
        assert!(p.try_alloc(4));
        assert_eq!(p.peak(), 4);
        p.release(4);
        assert_eq!(p.peak(), 4, "peak survives release");
        assert!(p.try_alloc(7));
        assert_eq!(p.peak(), 7);
        assert!(!p.try_alloc(9), "refusal must not move the peak");
        assert_eq!(p.peak(), 7);
    }

    #[test]
    fn concurrent_alloc_never_oversubscribes() {
        let p = Arc::new(BlockPool::new(1000));
        let mut handles = vec![];
        for _ in 0..8 {
            let p = p.clone();
            handles.push(std::thread::spawn(move || {
                let mut got = 0;
                for _ in 0..1000 {
                    if p.try_alloc(1) {
                        got += 1;
                    }
                }
                got
            }));
        }
        let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, 1000);
        assert_eq!(p.free(), 0);
    }

    #[test]
    #[cfg_attr(debug_assertions, should_panic(expected = "pool over-release"))]
    fn over_release_clamps_and_counts() {
        let p = BlockPool::new(4);
        assert!(p.try_alloc(3));
        p.release(5); // 2 over: free would be 6 > total 4
        assert_eq!(p.free(), 4, "free clamps to total");
        assert_eq!(p.over_released(), 2, "excess is counted, not absorbed");
        p.release(1); // further over-release keeps counting
        assert_eq!(p.free(), 4);
        assert_eq!(p.over_released(), 3);
    }
}
