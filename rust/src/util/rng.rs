//! PRNG substrate (the `rand` crate is unavailable offline — DESIGN.md §7).
//!
//! SplitMix64 for seeding + Xoshiro256** as the workhorse generator: fast,
//! well-tested algorithms with public-domain reference implementations.
//! Used by the workload generators (deterministic per-sample seeds so every
//! bench run is reproducible) and by the sampling engine.

#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream (e.g. per benchmark sample).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, n) without modulo bias (Lemire's method).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform integer in [lo, hi) — mirrors numpy's `integers`.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as usize) as i64
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// k distinct indices from [0, n) (partial Fisher-Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        let k = k.min(n);
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(11);
        let idx = r.sample_indices(20, 8);
        assert_eq!(idx.len(), 8);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 8);
    }
}
