//! Substrates built in-repo because crates.io is unreachable offline:
//! JSON codec, PRNG, latency histograms, property testing (DESIGN.md §7).

pub mod histogram;
pub mod json;
pub mod propcheck;
pub mod rng;

/// Monotonic microsecond clock for latency metrics.
pub fn now_micros() -> u64 {
    use std::time::Instant;
    use once_cell::sync::Lazy;
    static START: Lazy<Instant> = Lazy::new(Instant::now);
    START.elapsed().as_micros() as u64
}
