//! Minimal JSON codec (serde is unavailable offline — DESIGN.md §7).
//!
//! Supports the full JSON grammar needed by artifacts/manifest.json, the
//! surrogate metrics file and the JSON-lines serving protocol: objects,
//! arrays, strings with escapes, numbers (f64), booleans, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing bytes at {}", p.i));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn req(&self, key: &str) -> Result<&Json, String> {
        self.get(key).ok_or_else(|| format!("missing key '{key}'"))
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    /// Array of numbers -> Vec<usize> (shape lists etc.).
    pub fn usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }
    pub fn f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    // -- builders ---------------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| "bad \\u escape".to_string())?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 code point
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf-8".to_string())?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut out = vec![];
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected ',' or ']' at {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected ',' or '}}' at {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3], "b": "x\ny", "c": true, "d": null}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.req("a").unwrap().f64_vec().unwrap(), vec![1.0, 2.5, -3.0]);
        assert_eq!(v.req("b").unwrap().as_str().unwrap(), "x\ny");
        let again = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn nested_and_unicode() {
        let src = r#"{"m": {"k": [{"deep": "ok"}]}, "u": "é✓"}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.req("u").unwrap().as_str().unwrap(), "é✓");
        assert_eq!(
            v.req("m").unwrap().req("k").unwrap().as_arr().unwrap()[0]
                .req("deep")
                .unwrap()
                .as_str()
                .unwrap(),
            "ok"
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("{} x").is_err());
    }
}
