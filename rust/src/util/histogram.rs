//! Latency histogram substrate (hdrhistogram is unavailable offline).
//!
//! Log-bucketed histogram over microseconds: 64 major buckets (powers of
//! two) × 16 minor — <7% relative error, constant memory, O(1) record.

#[derive(Clone)]
pub struct Histogram {
    buckets: Vec<u64>,
    count: u64,
    sum: u64,
    max: u64,
    min: u64,
}

const MINOR: usize = 16;

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: vec![0; 64 * MINOR],
            count: 0,
            sum: 0,
            max: 0,
            min: u64::MAX,
        }
    }

    fn index(v: u64) -> usize {
        if v < MINOR as u64 {
            return v as usize;
        }
        let major = 63 - v.leading_zeros() as usize;
        let minor = ((v >> (major - 4)) & (MINOR as u64 - 1)) as usize;
        (major * MINOR + minor).min(64 * MINOR - 1)
    }

    fn bucket_value(i: usize) -> u64 {
        let major = i / MINOR;
        let minor = (i % MINOR) as u64;
        if major < 4 {
            return i as u64;
        }
        (1u64 << major) + (minor << (major - 4))
    }

    pub fn record(&mut self, v: u64) {
        self.buckets[Self::index(v)] += 1;
        self.count += 1;
        // saturating: a long simulate run recording large values would
        // otherwise overflow the u64 sum (a panic in debug builds)
        self.sum = self.sum.saturating_add(v);
        self.max = self.max.max(v);
        self.min = self.min.min(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn min(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn percentile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        if p <= 0.0 {
            // p0 is the observed minimum, not bucket 0's lower edge
            return self.min;
        }
        let target = ((p / 100.0) * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Self::bucket_value(i).min(self.max).max(self.min);
            }
        }
        self.max
    }

    pub fn summary(&self, unit: &str) -> String {
        format!(
            "n={} mean={:.1}{u} p50={}{u} p95={}{u} p99={}{u} max={}{u}",
            self.count,
            self.mean(),
            self.percentile(50.0),
            self.percentile(95.0),
            self.percentile(99.0),
            self.max,
            u = unit
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_monotone() {
        let mut h = Histogram::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        let p50 = h.percentile(50.0);
        let p95 = h.percentile(95.0);
        let p99 = h.percentile(99.0);
        assert!(p50 <= p95 && p95 <= p99 && p99 <= h.max());
        // log-bucket relative error bound
        assert!((p50 as f64 - 500.0).abs() / 500.0 < 0.08, "p50={p50}");
        assert!((p95 as f64 - 950.0).abs() / 950.0 < 0.08, "p95={p95}");
    }

    #[test]
    fn empty_is_zero() {
        let h = Histogram::new();
        assert_eq!(h.percentile(99.0), 0);
        assert_eq!(h.percentile(0.0), 0);
        assert_eq!(h.min(), 0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn p0_is_the_observed_minimum() {
        let mut h = Histogram::new();
        for v in [700u64, 40, 9000] {
            h.record(v);
        }
        assert_eq!(h.percentile(0.0), 40);
        assert_eq!(h.min(), 40);
    }

    #[test]
    fn sum_saturates_instead_of_overflowing() {
        let mut h = Histogram::new();
        h.record(u64::MAX);
        h.record(u64::MAX); // would overflow the running sum without saturation
        assert_eq!(h.count(), 2);
        assert_eq!(h.max(), u64::MAX);
        assert!(h.mean().is_finite());
    }

    /// Property: percentiles are non-decreasing in p and clamped to
    /// [min, max], over random value sets spanning every bucket regime.
    #[test]
    fn percentile_monotone_property() {
        use crate::util::propcheck;
        propcheck::check(
            96,
            |r| {
                (0..r.below(40) + 1)
                    .map(|_| match r.below(4) {
                        0 => r.below(MINOR) as u64,
                        1 => r.below(4096) as u64,
                        2 => r.below(1 << 30) as u64,
                        _ => u64::MAX - r.below(1024) as u64,
                    })
                    .collect::<Vec<u64>>()
            },
            |vals| {
                let mut h = Histogram::new();
                for &v in vals {
                    h.record(v);
                }
                if h.percentile(0.0) != h.min() {
                    return Err(format!("p0 {} != min {}", h.percentile(0.0), h.min()));
                }
                let ps = [0.0, 1.0, 10.0, 25.0, 50.0, 75.0, 90.0, 95.0, 99.0, 100.0];
                let mut prev = 0u64;
                for p in ps {
                    let v = h.percentile(p);
                    if v < prev {
                        return Err(format!("p{p} = {v} < previous {prev}"));
                    }
                    if v < h.min() || v > h.max() {
                        return Err(format!("p{p} = {v} outside [{}, {}]", h.min(), h.max()));
                    }
                    prev = v;
                }
                Ok(())
            },
        );
    }
}
