//! `propcheck`: a property-testing mini-framework (proptest is unavailable
//! offline — DESIGN.md §7).
//!
//! Runs a property over `cases` random inputs drawn from a generator
//! closure; on failure it performs greedy shrinking via the user-supplied
//! `shrink` steps (each yields candidate smaller inputs) and reports the
//! minimal counterexample. Used by rust/tests/ for the coordinator
//! invariants (cache accounting, policy monotonicity, batching).

use crate::util::rng::Rng;

pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 128, seed: 0xC0FFEE }
    }
}

/// Check `prop` over `cases` inputs from `gen`; shrink failures with
/// `shrink` (return candidate simpler inputs; first failing one recurses).
pub fn check_with<T: Clone + std::fmt::Debug>(
    cfg: Config,
    mut gen: impl FnMut(&mut Rng) -> T,
    shrink: impl Fn(&T) -> Vec<T>,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    let mut rng = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            // greedy shrink
            let mut cur = input;
            let mut cur_msg = msg;
            'outer: loop {
                for cand in shrink(&cur) {
                    if let Err(m) = prop(&cand) {
                        cur = cand;
                        cur_msg = m;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "propcheck failed (case {case}, seed {:#x}):\n  input: {:?}\n  error: {}",
                cfg.seed, cur, cur_msg
            );
        }
    }
}

/// Convenience: no shrinking.
pub fn check<T: Clone + std::fmt::Debug>(
    cases: usize,
    gen: impl FnMut(&mut Rng) -> T,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    check_with(Config { cases, ..Config::default() }, gen, |_| vec![], prop);
}

/// Shrinker for Vec<T>: halves and single-removals.
pub fn shrink_vec<T: Clone>(v: &[T]) -> Vec<Vec<T>> {
    let mut out = vec![];
    if v.is_empty() {
        return out;
    }
    out.push(v[..v.len() / 2].to_vec());
    out.push(v[v.len() / 2..].to_vec());
    if v.len() <= 12 {
        for i in 0..v.len() {
            let mut c = v.to_vec();
            c.remove(i);
            out.push(c);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_true_property() {
        check(64, |r| r.below(100), |&x| {
            if x < 100 {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "propcheck failed")]
    fn fails_and_shrinks() {
        check_with(
            Config::default(),
            |r| (0..r.below(20) + 5).map(|i| i as u32).collect::<Vec<u32>>(),
            |v| shrink_vec(v),
            |v| {
                if v.len() < 3 {
                    Ok(())
                } else {
                    Err("too long".into())
                }
            },
        );
    }
}
