//! `propcheck`: a property-testing mini-framework (proptest is unavailable
//! offline — DESIGN.md §7).
//!
//! Runs a property over `cases` random inputs drawn from a generator
//! closure; on failure it performs greedy shrinking via the user-supplied
//! `shrink` steps (each yields candidate smaller inputs) and reports the
//! minimal counterexample. Used by rust/tests/ for the coordinator
//! invariants (cache accounting, policy monotonicity, batching) and by the
//! simulation harness ([`crate::simharness`]) to minimize failing
//! scenarios.
//!
//! Every failure report carries the effective seed and the shrunk input,
//! and setting `KVZAP_PROP_SEED` (decimal or `0x`-hex) overrides the
//! built-in seed so a failure printed by CI can be replayed locally from
//! the test output alone.

use crate::util::rng::Rng;

pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Config {
    /// The seed a run will actually use: the `KVZAP_PROP_SEED` environment
    /// override when set (and parseable), the configured seed otherwise.
    pub fn effective_seed(&self) -> u64 {
        resolve_seed(std::env::var("KVZAP_PROP_SEED").ok().as_deref(), self.seed)
    }
}

/// Seed-resolution rule, split from the environment read so it is testable
/// without mutating process-global state (tests run multithreaded; a
/// `set_var` racing a `getenv` elsewhere is undefined behavior on glibc).
fn resolve_seed(env: Option<&str>, fallback: u64) -> u64 {
    env.and_then(parse_seed).unwrap_or(fallback)
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 128, seed: 0xC0FFEE }
    }
}

/// Parse a seed value as printed by a failure report: decimal or 0x-hex.
pub fn parse_seed(s: &str) -> Option<u64> {
    let s = s.trim();
    if let Some(hex) = s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
        u64::from_str_radix(hex, 16).ok()
    } else {
        s.parse().ok()
    }
}

/// Greedy shrink: repeatedly replace `input` with the first still-failing
/// candidate from `shrink` until none fails. Returns the minimal failing
/// input and its error. `shrink` must make strict progress (candidates
/// smaller by some measure) or this loops forever — the same contract the
/// in-test shrinkers and the scenario shrinker follow.
pub fn minimize<T: Clone>(
    input: T,
    msg: String,
    shrink: impl Fn(&T) -> Vec<T>,
    prop: impl Fn(&T) -> Result<(), String>,
) -> (T, String) {
    let mut cur = input;
    let mut cur_msg = msg;
    'outer: loop {
        for cand in shrink(&cur) {
            if let Err(m) = prop(&cand) {
                cur = cand;
                cur_msg = m;
                continue 'outer;
            }
        }
        break;
    }
    (cur, cur_msg)
}

/// Check `prop` over `cases` inputs from `gen`; shrink failures with
/// `shrink` (return candidate simpler inputs; first failing one recurses).
pub fn check_with<T: Clone + std::fmt::Debug>(
    cfg: Config,
    mut gen: impl FnMut(&mut Rng) -> T,
    shrink: impl Fn(&T) -> Vec<T>,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    let seed = cfg.effective_seed();
    let mut rng = Rng::new(seed);
    for case in 0..cfg.cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            let original = format!("{input:?}");
            let (cur, cur_msg) = minimize(input, msg, &shrink, &prop);
            panic!(
                "propcheck failed (case {case}, seed {seed:#x}):\n  original: {original}\n  \
                 shrunk: {cur:?}\n  error: {cur_msg}\n  replay: KVZAP_PROP_SEED={seed:#x} \
                 re-runs this exact input sequence"
            );
        }
    }
}

/// Convenience: no shrinking.
pub fn check<T: Clone + std::fmt::Debug>(
    cases: usize,
    gen: impl FnMut(&mut Rng) -> T,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    check_with(Config { cases, ..Config::default() }, gen, |_| vec![], prop);
}

/// Shrinker for Vec<T>: halves and single-removals.
pub fn shrink_vec<T: Clone>(v: &[T]) -> Vec<Vec<T>> {
    let mut out = vec![];
    if v.is_empty() {
        return out;
    }
    out.push(v[..v.len() / 2].to_vec());
    out.push(v[v.len() / 2..].to_vec());
    if v.len() <= 12 {
        for i in 0..v.len() {
            let mut c = v.to_vec();
            c.remove(i);
            out.push(c);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_true_property() {
        check(64, |r| r.below(100), |&x| {
            if x < 100 {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "propcheck failed")]
    fn fails_and_shrinks() {
        check_with(
            Config::default(),
            |r| (0..r.below(20) + 5).map(|i| i as u32).collect::<Vec<u32>>(),
            |v| shrink_vec(v),
            |v| {
                if v.len() < 3 {
                    Ok(())
                } else {
                    Err("too long".into())
                }
            },
        );
    }

    #[test]
    fn seed_parses_decimal_and_hex() {
        assert_eq!(parse_seed("42"), Some(42));
        assert_eq!(parse_seed(" 0xC0FFEE "), Some(0xC0FFEE));
        assert_eq!(parse_seed("0XFF"), Some(255));
        assert_eq!(parse_seed("nope"), None);
        assert_eq!(parse_seed(""), None);
    }

    #[test]
    fn env_override_wins_over_configured_seed() {
        assert_eq!(resolve_seed(Some("0x1234"), 7), 0x1234);
        assert_eq!(resolve_seed(Some("42"), 7), 42);
        assert_eq!(resolve_seed(None, 7), 7, "without the env var the config seed is used");
        assert_eq!(resolve_seed(Some("garbage"), 7), 7, "unparseable overrides are ignored");
    }

    #[test]
    fn failure_report_names_the_replay_env_var() {
        let result = std::panic::catch_unwind(|| {
            check(4, |r| r.below(10), |_| Err::<(), String>("always".into()));
        });
        let payload = result.expect_err("property must fail");
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("propcheck failed"), "{msg}");
        assert!(msg.contains("KVZAP_PROP_SEED"), "replay hint missing: {msg}");
        assert!(msg.contains("seed"), "{msg}");
    }

    #[test]
    fn minimize_reaches_a_local_minimum() {
        let (min, msg) = minimize(
            (0..16u32).collect::<Vec<u32>>(),
            "too long".into(),
            |v| shrink_vec(v),
            |v| {
                if v.len() < 3 {
                    Ok(())
                } else {
                    Err("too long".into())
                }
            },
        );
        assert_eq!(min.len(), 3, "greedy shrink stops at the smallest failing size");
        assert_eq!(msg, "too long");
    }
}
