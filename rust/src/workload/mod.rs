//! Evaluation workloads: ruler-mini / longbench-mini / aime-mini.
//!
//! These generators mirror the *task grammar* of python/compile/corpus.py
//! byte-for-byte in format (the template lists are the contract — see that
//! file's docstring). They produce the scaled equivalents of the paper's
//! benchmark suites:
//!
//! * ruler-mini: 13 subsets (retrieval / multi-hop tracing / aggregation /
//!   QA) — RULER (paper §4.4), contexts 256 ("4k") and 384–512 ("16k").
//! * longbench-mini: 10 subsets incl. a TREC-proxy few-shot classification
//!   subset for the over-prompting outlier analysis — LongBench (§4.5).
//! * aime-mini: multi-step arithmetic with chain-of-thought decoding —
//!   AIME25 (§4.6), the decode-phase pruning regime.

pub mod generators;
pub mod tokenizer;

pub use generators::{
    aime_instance, longbench_instance, prefix_families, ruler_instance, AimeInstance,
};
pub use tokenizer::ByteTokenizer;

/// One evaluation sample.
#[derive(Debug, Clone)]
pub struct TaskInstance {
    pub suite: &'static str,
    pub subset: String,
    pub prompt: String,
    pub answer: String,
    pub max_new: usize,
}

impl TaskInstance {
    /// Exact-match scoring: the generation, trimmed at the first newline,
    /// must equal the reference answer (RULER-style string match).
    pub fn score(&self, generated: &str) -> bool {
        let got = generated.split('\n').next().unwrap_or("").trim();
        got == self.answer
    }
}

pub const RULER_SUBSETS: &[&str] = &[
    "niah_single_1",
    "niah_single_2",
    "niah_single_3",
    "niah_multikey_1",
    "niah_multikey_2",
    "niah_multikey_3",
    "niah_multiquery",
    "niah_multivalue",
    "vt",
    "cwe",
    "fwe",
    "qa_1",
    "qa_2",
];

/// Evaluation suites the leaderboard and eval benches sweep.
pub const SUITES: &[&str] = &["ruler", "longbench", "aime"];

/// Representative subsets per suite for full-sweep evals; `quick` narrows
/// to one subset per suite (the hermetic CI smoke lane). The full RULER /
/// LongBench lists stay available as [`RULER_SUBSETS`] /
/// [`LONGBENCH_SUBSETS`] for exhaustive runs.
pub fn eval_subsets(suite: &str, quick: bool) -> &'static [&'static str] {
    match (suite, quick) {
        ("ruler", true) => &["niah_single_1"],
        ("ruler", false) => &["niah_single_1", "niah_multikey_1", "qa_1"],
        ("longbench", true) => &["trec"],
        ("longbench", false) => &["trec", "lcc", "sdqa"],
        // aime has a single generator (chain-of-thought arithmetic)
        ("aime", _) => &["aime"],
        _ => &[],
    }
}

pub const LONGBENCH_SUBSETS: &[&str] = &[
    "sdqa",
    "mdqa",
    "summ",
    "trec",
    "fewshot_math",
    "count",
    "passage_ret",
    "lcc",
    "repobench",
    "kvret",
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn all_ruler_subsets_generate_within_budget() {
        let mut rng = Rng::new(1);
        for subset in RULER_SUBSETS {
            for _ in 0..5 {
                let inst = ruler_instance(subset, 248, &mut rng.fork(7));
                assert!(inst.prompt.len() <= 248, "{subset}: {}", inst.prompt.len());
                assert!(!inst.answer.is_empty(), "{subset}");
                assert!(inst.prompt.ends_with("A ") || inst.prompt.ends_with("-> "),
                        "{subset} prompt tail");
            }
        }
    }

    #[test]
    fn all_longbench_subsets_generate_within_budget() {
        let mut rng = Rng::new(2);
        for subset in LONGBENCH_SUBSETS {
            for i in 0..5 {
                let inst = longbench_instance(subset, 248, &mut rng.fork(i));
                assert!(inst.prompt.len() <= 248, "{subset}: {}", inst.prompt.len());
                assert!(!inst.answer.is_empty(), "{subset}");
            }
        }
    }

    #[test]
    fn scoring_is_exact_prefix_match() {
        let inst = TaskInstance {
            suite: "ruler",
            subset: "x".into(),
            prompt: "p".into(),
            answer: "12345".into(),
            max_new: 8,
        };
        assert!(inst.score("12345\ngarbage"));
        assert!(inst.score("12345"));
        assert!(!inst.score("12346\n"));
        assert!(!inst.score(""));
    }

    #[test]
    fn aime_chain_is_consistent() {
        let mut rng = Rng::new(3);
        for i in 0..10 {
            let a = aime_instance(&mut rng.fork(i));
            // replay the ops from the prompt and check the answer
            let ops_line = a.task.prompt.lines().nth(1).unwrap();
            let start: i64 = a.task.prompt.lines().next().unwrap()[6..].parse().unwrap();
            let mut cur = start;
            for op in ops_line[4..].split(' ') {
                let (sym, n) = op.split_at(1);
                let n: i64 = n.parse().unwrap();
                cur = match sym {
                    "+" => cur + n,
                    "-" => cur - n,
                    "*" => cur * n,
                    _ => panic!("bad op {sym}"),
                };
                assert!(cur > 0 && cur < 9000);
            }
            assert_eq!(cur.to_string(), a.task.answer);
            assert!(a.cot.ends_with(&format!("ANSWER {cur}")));
        }
    }
}
