//! Byte-level tokenizer (the HF-tokenizers substitute, DESIGN.md §7).
//!
//! zap-lm is byte-level with reserved low bytes: PAD=0, BOS=1, EOS=2,
//! SEP=3 (the corpus generators never emit bytes < 16).

#[derive(Debug, Clone, Copy)]
pub struct ByteTokenizer {
    pub pad: u8,
    pub bos: u8,
    pub eos: u8,
}

impl Default for ByteTokenizer {
    fn default() -> Self {
        ByteTokenizer { pad: 0, bos: 1, eos: 2 }
    }
}

impl ByteTokenizer {
    /// BOS + utf-8 bytes, truncated to `max_len`.
    pub fn encode(&self, text: &str, max_len: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(text.len() + 1);
        out.push(self.bos as i32);
        out.extend(text.bytes().map(|b| b as i32));
        out.truncate(max_len);
        out
    }

    /// Pad to `len` with PAD.
    pub fn pad_to(&self, mut tokens: Vec<i32>, len: usize) -> Vec<i32> {
        tokens.resize(len, self.pad as i32);
        tokens
    }

    /// Decode generated token ids back to text, stopping at EOS/PAD.
    pub fn decode(&self, tokens: &[i32]) -> String {
        let bytes: Vec<u8> = tokens
            .iter()
            .take_while(|&&t| t != self.eos as i32 && t != self.pad as i32)
            .filter_map(|&t| u8::try_from(t).ok())
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }

    /// True when the generation should stop (EOS or newline — answers are
    /// newline-terminated in the task grammar).
    pub fn is_stop(&self, token: i32, stop_at_newline: bool) -> bool {
        token == self.eos as i32
            || token == self.pad as i32
            || (stop_at_newline && token == b'\n' as i32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let t = ByteTokenizer::default();
        let ids = t.encode("hi there", 64);
        assert_eq!(ids[0], 1);
        assert_eq!(t.decode(&ids[1..]), "hi there");
    }

    #[test]
    fn truncation_and_padding() {
        let t = ByteTokenizer::default();
        let ids = t.encode("abcdef", 4);
        assert_eq!(ids.len(), 4);
        let padded = t.pad_to(ids, 8);
        assert_eq!(padded.len(), 8);
        assert_eq!(padded[7], 0);
    }

    #[test]
    fn stop_conditions() {
        let t = ByteTokenizer::default();
        assert!(t.is_stop(2, false));
        assert!(t.is_stop(b'\n' as i32, true));
        assert!(!t.is_stop(b'\n' as i32, false));
        assert!(!t.is_stop(b'a' as i32, true));
    }
}
