//! Task-instance generators — the rust mirror of python/compile/corpus.py.
//!
//! IMPORTANT: the template lists and byte formats here are a contract with
//! corpus.py (the model was trained on exactly these formats). Keep in sync.

use super::TaskInstance;
use crate::util::rng::Rng;

pub const FILLERS: &[&str] = &[
    "the sky was clear and the wind moved over the hills. ",
    "a river runs past the old mill near the stone bridge. ",
    "people walked slowly through the quiet market square. ",
    "the train left the station two minutes after noon. ",
    "rain fell softly on the roof of the wooden cabin. ",
    "the library keeps its oldest maps in the north wing. ",
    "a grey cat slept on the warm step by the door. ",
    "the garden path was lined with small white stones. ",
];

pub const NAMES: &[&str] = &["amir", "bella", "chen", "dara", "elif", "farid", "gita", "hana"];
pub const CITIES: &[&str] = &["oslo", "lima", "kyoto", "accra", "quito", "perth", "turin", "hanoi"];
pub const JOBS: &[&str] = &["baker", "pilot", "nurse", "coder", "judge", "miner", "actor", "clerk"];
pub const WORDS: &[&str] = &[
    "apple", "stone", "cloud", "tiger", "brick", "olive", "comet", "fern", "maple", "ridge",
    "pearl", "wolf", "cedar", "lark", "moss", "dune",
];

pub const TREC_LABELS: &[&str] = &["loc", "num", "person", "desc", "entity", "abbr"];

pub fn trec_patterns(label: &str) -> &'static [&'static str] {
    match label {
        "loc" => &["where is {w}", "where can one find {w}", "what country is {w} in"],
        "num" => &["how many {w} are there", "what is the count of {w}", "how much {w} is needed"],
        "person" => &["who made {w}", "who leads {w}", "who found {w}"],
        "desc" => &["what is {w}", "what does {w} mean", "how does {w} work"],
        "entity" => &["what kind of {w} is it", "which {w} is best", "name a type of {w}"],
        "abbr" => &["what does {w} stand for", "expand the term {w}", "what is short for {w}"],
        _ => panic!("unknown trec label {label}"),
    }
}

fn key(r: &mut Rng) -> String {
    (0..4).map(|_| (b'A' + r.below(26) as u8) as char).collect()
}

fn val(r: &mut Rng) -> String {
    (0..5).map(|_| (b'0' + r.below(10) as u8) as char).collect()
}

fn filler_block(r: &mut Rng, n_bytes: usize) -> String {
    let mut out = String::new();
    while out.len() < n_bytes {
        out.push_str(*r.choice(FILLERS));
    }
    out
}

/// Scatter item lines at random depths inside filler, like corpus._haystack.
fn haystack(r: &mut Rng, items: &[String], target_len: usize) -> String {
    let items_len: usize = items.iter().map(|i| i.len() + 1).sum();
    let budget = target_len.saturating_sub(items_len + 16).max(32);
    let mut cuts: Vec<usize> = (0..items.len()).map(|_| r.below(budget + 1)).collect();
    cuts.sort_unstable();
    let fill = filler_block(r, budget);
    let fill = &fill[..budget];
    let mut segs = String::new();
    let mut prev = 0;
    for (c, item) in cuts.iter().zip(items) {
        segs.push_str(&fill[prev..*c]);
        segs.push_str(item);
        segs.push('\n');
        prev = *c;
    }
    segs.push_str(&fill[prev..budget]);
    segs
}

fn pattern_fill(pat: &str, w: &str) -> String {
    pat.replace("{w}", w)
}

// ---------------------------------------------------------------------------
// ruler-mini

fn inst(suite: &'static str, subset: &str, prompt: String, answer: String) -> TaskInstance {
    let max_new = answer.len() + 3;
    TaskInstance { suite, subset: subset.to_string(), prompt, answer, max_new }
}

fn niah_single(r: &mut Rng, target: usize, variant: u8, subset: &str) -> TaskInstance {
    let (k, v) = (key(r), val(r));
    let line = match variant {
        1 => format!("{k} = {v}."),
        2 => format!("note {k} holds {v}."),
        _ => format!("remember that {k} maps to {v}."),
    };
    let hay = haystack(r, &[line], target);
    inst("ruler", subset, format!("{hay}Q {k}\nA "), v)
}

fn niah_multikey(r: &mut Rng, target: usize, n_keys: usize, subset: &str) -> TaskInstance {
    let pairs: Vec<(String, String)> = (0..n_keys).map(|_| (key(r), val(r))).collect();
    let lines: Vec<String> = pairs.iter().map(|(k, v)| format!("{k} = {v}.")).collect();
    let hay = haystack(r, &lines, target);
    let (k, v) = &pairs[r.below(n_keys)];
    inst("ruler", subset, format!("{hay}Q {k}\nA "), v.clone())
}

fn niah_multiquery(r: &mut Rng, target: usize) -> TaskInstance {
    let pairs: Vec<(String, String)> = (0..3).map(|_| (key(r), val(r))).collect();
    let lines: Vec<String> = pairs.iter().map(|(k, v)| format!("{k} = {v}.")).collect();
    let hay = haystack(r, &lines, target);
    let (k1, v1) = &pairs[0];
    let (k2, v2) = &pairs[2];
    inst("ruler", "niah_multiquery", format!("{hay}Q {k1} {k2}\nA "), format!("{v1} {v2}"))
}

fn niah_multivalue(r: &mut Rng, target: usize) -> TaskInstance {
    let (k, v1, v2) = (key(r), val(r), val(r));
    let hay = haystack(r, &[format!("{k} = {v1} {v2}.")], target);
    inst("ruler", "niah_multivalue", format!("{hay}Q {k}\nA "), format!("{v1} {v2}"))
}

fn vt(r: &mut Rng, target: usize) -> TaskInstance {
    let hops = 3;
    let root = val(r);
    let names: Vec<String> = (0..hops + 2).map(|_| format!("V{}", r.range(10, 99))).collect();
    let mut lines = vec![format!("{} = {root}.", names[0])];
    for i in 1..hops {
        lines.push(format!("{} = {}.", names[i], names[i - 1]));
    }
    let decoy = val(r);
    lines.push(format!("{} = {decoy}.", names[hops]));
    lines.push(format!("{} = {}.", names[hops + 1], names[hops]));
    r.shuffle(&mut lines);
    let hay = haystack(r, &lines, target);
    inst("ruler", "vt", format!("{hay}Q {}\nA ", names[hops - 1]), root)
}

fn cwe(r: &mut Rng, target: usize) -> TaskInstance {
    let common = *r.choice(WORDS);
    let others: Vec<&str> = WORDS.iter().copied().filter(|w| *w != common).collect();
    let mut seq: Vec<&str> = vec![common; 6];
    for _ in 0..10 {
        seq.push(*r.choice(&others));
    }
    r.shuffle(&mut seq);
    let lst = format!("list: {}.", seq.join(" "));
    let hay = haystack(r, &[lst], target);
    inst("ruler", "cwe", format!("{hay}Q most\nA "), common.to_string())
}

fn fwe(r: &mut Rng, target: usize) -> TaskInstance {
    let picks = r.sample_indices(WORDS.len(), 3);
    let (a, b, c) = (WORDS[picks[0]], WORDS[picks[1]], WORDS[picks[2]]);
    let mut seq: Vec<&str> = vec![];
    seq.extend(std::iter::repeat(a).take(5));
    seq.extend(std::iter::repeat(b).take(3));
    seq.extend(std::iter::repeat(c).take(2));
    r.shuffle(&mut seq);
    let lst = format!("list: {}.", seq.join(" "));
    let hay = haystack(r, &[lst], target);
    inst("ruler", "fwe", format!("{hay}Q most\nA "), a.to_string())
}

fn qa1(r: &mut Rng, target: usize, subset: &str) -> TaskInstance {
    let n = *r.choice(NAMES);
    let c = *r.choice(CITIES);
    let d1 = *r.choice(NAMES);
    let j = *r.choice(JOBS);
    let lines = vec![format!("{n} lives in {c}."), format!("{d1} works as a {j}.")];
    let hay = haystack(r, &lines, target);
    inst("ruler", subset, format!("{hay}Q where {n}\nA "), c.to_string())
}

fn qa2(r: &mut Rng, target: usize, subset: &str) -> TaskInstance {
    let picks = r.sample_indices(NAMES.len(), 2);
    let (n1, n2) = (NAMES[picks[0]], NAMES[picks[1]]);
    let c = *r.choice(CITIES);
    let j = *r.choice(JOBS);
    let lines = vec![format!("doc1: {n1} lives in {c}."), format!("doc2: {n2} works as a {j}.")];
    let hay = haystack(r, &lines, target);
    inst("ruler", subset, format!("{hay}Q job {n2}\nA "), j.to_string())
}

/// Shared-prefix prompt families for the prefix-reuse serving path: each
/// family draws one RULER instance and duplicates it `members` times, so
/// every member of a family shares the *identical* prompt byte-for-byte
/// (the unit of cross-request prefix reuse — the router's prefix cache
/// keys on the full prompt). Deterministic in the caller's `r`: the same
/// seed yields the same family partition (prompts, sizes, order).
pub fn prefix_families(
    r: &mut Rng,
    n_families: usize,
    members: usize,
    target_len: usize,
) -> Vec<Vec<TaskInstance>> {
    (0..n_families)
        .map(|_| {
            let subset = *r.choice(super::RULER_SUBSETS);
            let t = ruler_instance(subset, target_len, r);
            (0..members).map(|_| t.clone()).collect()
        })
        .collect()
}

pub fn ruler_instance(subset: &str, target_len: usize, r: &mut Rng) -> TaskInstance {
    match subset {
        "niah_single_1" => niah_single(r, target_len, 1, subset),
        "niah_single_2" => niah_single(r, target_len, 2, subset),
        "niah_single_3" => niah_single(r, target_len, 3, subset),
        "niah_multikey_1" => niah_multikey(r, target_len, 3, subset),
        "niah_multikey_2" => niah_multikey(r, target_len, 4, subset),
        "niah_multikey_3" => niah_multikey(r, target_len, 5, subset),
        "niah_multiquery" => niah_multiquery(r, target_len),
        "niah_multivalue" => niah_multivalue(r, target_len),
        "vt" => vt(r, target_len),
        "cwe" => cwe(r, target_len),
        "fwe" => fwe(r, target_len),
        "qa_1" => qa1(r, target_len, subset),
        "qa_2" => qa2(r, target_len, subset),
        _ => panic!("unknown ruler subset {subset}"),
    }
}

// ---------------------------------------------------------------------------
// longbench-mini

fn summ(r: &mut Rng, target: usize) -> TaskInstance {
    let w = *r.choice(WORDS);
    let hay = haystack(r, &[format!("!! topic {w}.")], target);
    let mut t = inst("longbench", "summ", format!("{hay}Q topic\nA "), w.to_string());
    t.suite = "longbench";
    t
}

/// Few-shot question-type classification (TREC proxy). `n_shots` caps the
/// number of examples for the over-prompting ablation; None = fill budget.
pub fn trec(r: &mut Rng, target: usize, n_shots: Option<usize>) -> TaskInstance {
    let mut lines: Vec<String> = vec![];
    let budget = target.saturating_sub(40);
    let mut used = 0;
    let mut shots = 0;
    while n_shots.map_or(true, |n| shots < n) {
        let lbl = *r.choice(TREC_LABELS);
        let pat = *r.choice(trec_patterns(lbl));
        let w = *r.choice(WORDS);
        let line = format!("{} -> {lbl}", pattern_fill(pat, w));
        if used + line.len() + 1 > budget {
            break;
        }
        used += line.len() + 1;
        lines.push(line);
        shots += 1;
    }
    let lbl = *r.choice(TREC_LABELS);
    let pat = *r.choice(trec_patterns(lbl));
    let w = *r.choice(WORDS);
    let prompt = format!("{}\n{} -> ", lines.join("\n"), pattern_fill(pat, w));
    let mut t = inst("longbench", "trec", prompt, lbl.to_string());
    t.suite = "longbench";
    t
}

fn fewshot_math(r: &mut Rng, target: usize) -> TaskInstance {
    let mut lines = vec![];
    let mut used = 0;
    while used < target.saturating_sub(30) {
        let a = r.range(10, 90);
        let b = r.range(10, 90);
        let line = format!("{a} plus {b} is {}.", a + b);
        used += line.len() + 1;
        lines.push(line);
    }
    let a = r.range(10, 90);
    let b = r.range(10, 90);
    let prompt = format!("{}\n{a} plus {b} is ", lines.join("\n"));
    inst("longbench", "fewshot_math", prompt, (a + b).to_string())
}

fn count_task(r: &mut Rng, target: usize) -> TaskInstance {
    let n = r.range(2, 8) as usize;
    let marks: Vec<String> = vec!["## section".to_string(); n];
    let hay = haystack(r, &marks, target);
    inst("longbench", "count", format!("{hay}Q sections\nA "), n.to_string())
}

fn passage_ret(r: &mut Rng, target: usize) -> TaskInstance {
    let n_docs = 4usize;
    let w = *r.choice(WORDS);
    let target_doc = r.range(1, n_docs as i64 + 1) as usize;
    let per = ((target.saturating_sub(40)) / n_docs).max(24);
    let mut segs = String::new();
    for i in 1..=n_docs {
        segs.push_str(&format!("doc{i}: "));
        let block = filler_block(r, per.saturating_sub(20));
        segs.push_str(&block[..per.saturating_sub(20).min(block.len())]);
        if i == target_doc {
            segs.push_str(&format!("the word {w} is here. "));
        }
    }
    inst("longbench", "passage_ret", format!("{segs}Q doc {w}\nA "), target_doc.to_string())
}

fn lcc(r: &mut Rng, target: usize) -> TaskInstance {
    let mut lines = vec![];
    let mut vals = vec![];
    let mut used = 0;
    let mut i = 0;
    while used < target.saturating_sub(30) {
        i += 1;
        let v = r.range(100, 999);
        vals.push(v);
        let line = format!("let a{i} = {v};");
        used += line.len() + 1;
        lines.push(line);
    }
    let k = r.range(1, i as i64 + 1) as usize;
    let prompt = format!("{}\na{k} == ", lines.join("\n"));
    inst("longbench", "lcc", prompt, vals[k - 1].to_string())
}

fn repobench(r: &mut Rng, target: usize) -> TaskInstance {
    let mut lines = vec![];
    let mut vals = vec![];
    let mut used = 0;
    let mut i = 0usize;
    while used < target.saturating_sub(40) {
        i += 1;
        let v = r.range(100, 999);
        vals.push(v);
        let line = format!("file{}.rs: let b{i} = {v};", (i % 3) + 1);
        used += line.len() + 1;
        lines.push(line);
    }
    let k = r.range(1, i as i64 + 1) as usize;
    let prompt = format!("{}\nb{k} == ", lines.join("\n"));
    inst("longbench", "repobench", prompt, vals[k - 1].to_string())
}

pub fn longbench_instance(subset: &str, target_len: usize, r: &mut Rng) -> TaskInstance {
    let mut t = match subset {
        "sdqa" => qa1(r, target_len, "sdqa"),
        "mdqa" => qa2(r, target_len, "mdqa"),
        "summ" => summ(r, target_len),
        "trec" => trec(r, target_len, None),
        "fewshot_math" => fewshot_math(r, target_len),
        "count" => count_task(r, target_len),
        "passage_ret" => passage_ret(r, target_len),
        "lcc" => lcc(r, target_len),
        "repobench" => repobench(r, target_len),
        "kvret" => {
            let mut t = niah_multikey(r, target_len, 5, "kvret");
            t.suite = "longbench";
            t
        }
        _ => panic!("unknown longbench subset {subset}"),
    };
    t.suite = "longbench";
    t
}

// ---------------------------------------------------------------------------
// aime-mini

#[derive(Debug, Clone)]
pub struct AimeInstance {
    pub task: TaskInstance,
    /// Reference chain-of-thought (what the model was trained to emit).
    pub cot: String,
}

pub fn aime_instance(r: &mut Rng) -> AimeInstance {
    let n_steps = r.range(6, 11) as usize;
    let x = r.range(10, 90);
    let mut ops: Vec<(char, i64)> = vec![];
    let mut cur = x;
    for _ in 0..n_steps {
        loop {
            let op = *r.choice(&['+', '-', '*']);
            let n = if op == '*' { r.range(2, 9) } else { r.range(2, 99) };
            let nxt = match op {
                '*' => cur * n,
                '+' => cur + n,
                _ => cur - n,
            };
            if nxt > 0 && nxt < 9000 {
                ops.push((op, n));
                cur = nxt;
                break;
            }
        }
    }
    let ops_str: Vec<String> = ops.iter().map(|(o, n)| format!("{o}{n}")).collect();
    let prompt = format!("start {x}\nops {}\nA ", ops_str.join(" "));
    let mut steps = vec![];
    let mut v = x;
    for (o, n) in &ops {
        v = match o {
            '*' => v * n,
            '+' => v + n,
            _ => v - n,
        };
        steps.push(format!("{o}{n} -> {v}"));
    }
    let cot = format!("{}\nANSWER {cur}", steps.join("\n"));
    let max_new = cot.len() + 8;
    AimeInstance {
        task: TaskInstance {
            suite: "aime",
            subset: "aime".into(),
            prompt,
            answer: cur.to_string(),
            max_new,
        },
        cot,
    }
}

/// Parse the final "ANSWER n" line from an AIME generation.
///
/// Lenient about the formatting noise simulation runs surfaced: leading /
/// trailing whitespace around the line or the value and a `\boxed{...}`
/// wrapper are all accepted. The token after `ANSWER` must still be
/// separated by whitespace (or be a `\boxed{}` group), so a line like
/// `ANSWERED 42` never matches.
pub fn parse_aime_answer(generated: &str) -> Option<String> {
    generated.lines().rev().find_map(|l| {
        let rest = l.trim().strip_prefix("ANSWER")?;
        if let Some(inner) =
            rest.trim_start().strip_prefix("\\boxed{").and_then(|r| r.strip_suffix('}'))
        {
            let inner = inner.trim();
            return if inner.is_empty() { None } else { Some(inner.to_string()) };
        }
        if !rest.starts_with(char::is_whitespace) {
            return None;
        }
        let rest = rest.trim();
        if rest.is_empty() {
            None
        } else {
            Some(rest.to_string())
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aime_answer_parsing_is_lenient() {
        let table: &[(&str, Option<&str>)] = &[
            // the trained format
            ("+3 -> 45\nANSWER 45", Some("45")),
            // whitespace padding around line and value
            ("  ANSWER   45  ", Some("45")),
            ("steps\n\tANSWER 7\n", Some("7")),
            // boxed answers
            ("ANSWER \\boxed{123}", Some("123")),
            ("ANSWER \\boxed{ 123 }", Some("123")),
            ("ANSWER\\boxed{9}", Some("9")),
            // the last ANSWER line wins
            ("ANSWER 1\nANSWER 2", Some("2")),
            // non-answers must not match
            ("ANSWERED 42", None),
            ("ANSWER\\frac{12}{5}", None),
            ("ANSWER", None),
            ("ANSWER ", None),
            ("no answer here", None),
            ("", None),
        ];
        for (input, want) in table {
            assert_eq!(
                parse_aime_answer(input).as_deref(),
                *want,
                "input {input:?}"
            );
        }
    }

    /// Determinism of the shared-prefix family partition: the same seed
    /// must yield the same families (count, sizes, prompts — and every
    /// member of a family the identical prompt), across a table of shapes.
    #[test]
    fn prefix_family_partition_is_deterministic_per_seed() {
        let table: &[(u64, usize, usize, usize)] = &[
            (1, 1, 2, 120),
            (7, 2, 3, 200),
            (42, 3, 2, 300),
            (9009, 4, 4, 460),
        ];
        for &(seed, fams, members, target) in table {
            let a = prefix_families(&mut Rng::new(seed), fams, members, target);
            let b = prefix_families(&mut Rng::new(seed), fams, members, target);
            assert_eq!(a.len(), fams, "seed {seed}: family count");
            let parts = |fs: &[Vec<TaskInstance>]| -> Vec<Vec<String>> {
                fs.iter()
                    .map(|f| f.iter().map(|t| t.prompt.clone()).collect())
                    .collect()
            };
            assert_eq!(parts(&a), parts(&b), "seed {seed}: partition must repeat");
            for (i, fam) in a.iter().enumerate() {
                assert_eq!(fam.len(), members, "seed {seed} family {i}: size");
                for t in fam {
                    assert_eq!(
                        t.prompt, fam[0].prompt,
                        "seed {seed} family {i}: members share one prompt"
                    );
                    assert!(t.prompt.len() <= target, "seed {seed} family {i}: budget");
                }
            }
            // distinct families carry distinct prompts (random keys/values
            // make a collision a generator bug, not chance)
            for i in 0..a.len() {
                for j in 0..i {
                    assert_ne!(
                        a[i][0].prompt, a[j][0].prompt,
                        "seed {seed}: families {j} and {i} collide"
                    );
                }
            }
        }
    }
}
