//! Typed policy specifications — the press-style public policy API.
//!
//! [`PolicySpec`] is the single source of truth for "which pruning policy,
//! with which parameters". It replaces the stringly-typed `policy: String`
//! plumbing: clients (CLI flags, server requests, bench sweeps) either
//! parse the compact string form (`"kvzap_mlp:-4"`) or send a structured
//! JSON object (`{"kind": "kvzap", "surrogate": "mlp", "tau": -4.0}`), and
//! everything downstream carries the typed value.
//!
//! Threshold policies (`kvzap_*`, `fastkvzip`) additionally accept a
//! **two-threshold** form for the tiered demotion path: a trailing
//! `:floor=<value>` segment (string form) or a `"floor"` field (JSON)
//! sets τ_floor ≤ τ — scores in `[floor, τ)` are demoted into the
//! quantized side tier instead of dropped, and only scores below the
//! floor are truly evicted. A further trailing `:bits=<8|4|2>` segment
//! (JSON: `"bits"`) picks the tier's code width — int8 default, int4/int2
//! trade side-pool bytes for round-trip error; the canonical order is
//! `form:τ:floor=<f>:bits=<b>`. Threshold positions also accept `qNN`
//! quantile sugar over the reference surrogate score distribution
//! (`kvzap_mlp:q50:floor=q90`): in the τ position `qNN` is the NN-th
//! score quantile; in the floor position it spares the top NN% of the
//! sub-τ mass, i.e. resolves to the (100−NN)-th quantile. `qNN` is
//! input-only sugar — canonical forms always carry resolved floats. The spec round-trips
//! through [`PolicySpec::parse`] / `Display` and through
//! [`PolicySpec::to_json`] / [`PolicySpec::from_json`], and
//! [`PolicySpec::build`] instantiates the runnable [`PrunePolicy`].
//!
//! [`CATALOG`] describes every variant with its parameters and defaults —
//! the server's `{"cmd": "policies"}` introspection and the `kvzap
//! policies` CLI subcommand render it, so the protocol is discoverable
//! without reading this file.
//!
//! ```
//! use kvzap::policies::{PolicySpec, PrunePolicy};
//!
//! let spec = PolicySpec::parse("kvzap_mlp:-4").unwrap();
//! assert_eq!(spec.kind(), "kvzap");
//! assert_eq!(spec.to_string(), "kvzap_mlp:-4");
//! let policy = spec.build(16); // runnable PrunePolicy for window w=16
//! assert!(!policy.name().is_empty());
//! ```

#![warn(missing_docs)]

use std::fmt;

use anyhow::{anyhow, Result};

use super::{
    adakv, expected_attention, expected_attention_vnorm, h2o, keyformer, knorm, kvzap_topk,
    kvzip_oracle, kvzip_plus_oracle, observed_attention, snapkv, tova, FastKvzip, KVzap,
    NoPress, PrunePolicy, RandomPress, StreamingLlm,
};
use crate::runtime::kernels::QuantBits;
use crate::util::json::Json;

/// Which surrogate scorer drives a KVzap variant (paper §3.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Surrogate {
    /// Single linear head over the hidden state (`score_lin`).
    Linear,
    /// Two-layer gelu MLP head (`score_mlp`, the paper's default).
    Mlp,
}

impl Surrogate {
    /// Wire name of the surrogate (`"linear"` / `"mlp"`), as used in both
    /// the string and JSON policy forms.
    pub fn as_str(self) -> &'static str {
        match self {
            Surrogate::Linear => "linear",
            Surrogate::Mlp => "mlp",
        }
    }

    fn parse(s: &str) -> Result<Surrogate> {
        match s {
            "linear" => Ok(Surrogate::Linear),
            "mlp" => Ok(Surrogate::Mlp),
            _ => Err(anyhow!("unknown surrogate '{s}' (expected 'linear' or 'mlp')")),
        }
    }
}

/// Default KVzap eviction threshold τ (log s+ units) when a spec omits it.
pub const DEFAULT_TAU: f64 = -4.0;
/// Default keep-fraction for budget policies when a spec omits it.
pub const DEFAULT_KEEP_FRAC: f64 = 0.5;
/// Default number of always-kept attention-sink tokens (StreamingLLM).
pub const DEFAULT_SINKS: usize = 4;
/// Default Keyformer mix weight (max-attn share of the key-token score).
pub const DEFAULT_MIX: f64 = 0.5;

/// Deciles of the reference surrogate score distribution (log s+ units),
/// backing the `qNN` threshold sugar. Pinned as a static table — the
/// reference model's weights are deterministic, so these are stable wire
/// constants, not a per-run calibration.
pub const SCORE_QUANTILES: &[(&str, f64)] = &[
    ("q10", -10.0),
    ("q20", -9.0),
    ("q30", -8.0),
    ("q40", -7.0),
    ("q50", -6.0),
    ("q60", -5.0),
    ("q70", -4.0),
    ("q80", -3.0),
    ("q90", -2.0),
];

/// Resolve `qNN` sugar in a τ position: the NN-th score quantile.
fn quantile(tag: &str) -> Option<f64> {
    SCORE_QUANTILES.iter().find(|(t, _)| *t == tag).map(|&(_, v)| v)
}

/// Resolve `qNN` sugar in a floor position: `floor=qNN` spares the top
/// NN% of the sub-τ score mass, so it resolves to the (100−NN)-th
/// quantile (`floor=q90` → the q10 value, a *low* floor sparing most).
fn complement_quantile(tag: &str) -> Option<f64> {
    let i = SCORE_QUANTILES.iter().position(|(t, _)| *t == tag)?;
    Some(SCORE_QUANTILES[SCORE_QUANTILES.len() - 1 - i].1)
}

/// A fully-specified pruning policy configuration.
#[derive(Debug, Clone, PartialEq)]
pub enum PolicySpec {
    /// Keep the full KV cache (no pruning).
    Full,
    /// KVzap thresholding (paper §3.3): evict below τ, decode-capable.
    /// With `floor` set, scores in `[floor, τ)` demote to the quantized
    /// side tier instead of dropping (two-threshold tiered form); `bits`
    /// picks the tier's code width (int8 default, `:bits=4|2` narrows it).
    Kvzap { surrogate: Surrogate, tau: f64, floor: Option<f64>, bits: QuantBits },
    /// Fixed-ratio top-k on the KVzap surrogate (Fig. 5 right ablation).
    KvzapTopk { surrogate: Surrogate, keep_frac: f64, per_layer: bool },
    /// KVzip oracle (double-pass) budget policy; `plus` uses s+.
    Kvzip { plus: bool, keep_frac: f64 },
    /// H2O: cumulative-attention budget, per head.
    H2o { keep_frac: f64 },
    /// SnapKV: windowed-attention budget, per head.
    SnapKv { keep_frac: f64 },
    /// AdaKV: windowed-attention budget pooled per layer.
    AdaKv { keep_frac: f64 },
    /// TOVA: max-attention budget, per head.
    Tova { keep_frac: f64 },
    /// Observed attention: max-attention budget, global pool.
    ObservedAttn { keep_frac: f64 },
    /// Expected attention: forward-looking attention budget, per head.
    ExpectedAttn { keep_frac: f64 },
    /// Knorm: keep the smallest key norms, per head.
    Knorm { keep_frac: f64 },
    /// StreamingLLM: attention sinks + recency, no scores.
    StreamingLlm { keep_frac: f64, sinks: usize },
    /// Random eviction (sanity-check lower bound).
    Random { keep_frac: f64, seed: u64 },
    /// Keyformer: cum/max-attention key-token mix, per-head budget.
    Keyformer { keep_frac: f64, mix: f64 },
    /// Fast-KVzip: gated thresholding — eviction needs the MLP score
    /// below `tau` *and* the linear score below `gate_tau`; decode-capable.
    /// `floor`/`bits` enable the same tiered demotion band as [`Self::Kvzap`].
    FastKvzip { tau: f64, gate_tau: f64, floor: Option<f64>, bits: QuantBits },
    /// Expected attention rescaled by value norm, per-head budget.
    ExpectedAttnVnorm { keep_frac: f64 },
}

impl PolicySpec {
    /// Canonical kind tag (the `"kind"` field of the JSON form).
    pub fn kind(&self) -> &'static str {
        match self {
            PolicySpec::Full => "full",
            PolicySpec::Kvzap { .. } => "kvzap",
            PolicySpec::KvzapTopk { .. } => "kvzap_topk",
            PolicySpec::Kvzip { .. } => "kvzip",
            PolicySpec::H2o { .. } => "h2o",
            PolicySpec::SnapKv { .. } => "snapkv",
            PolicySpec::AdaKv { .. } => "adakv",
            PolicySpec::Tova { .. } => "tova",
            PolicySpec::ObservedAttn { .. } => "observed_attn",
            PolicySpec::ExpectedAttn { .. } => "expected_attn",
            PolicySpec::Knorm { .. } => "knorm",
            PolicySpec::StreamingLlm { .. } => "streaming_llm",
            PolicySpec::Random { .. } => "random",
            PolicySpec::Keyformer { .. } => "keyformer",
            PolicySpec::FastKvzip { .. } => "fastkvzip",
            PolicySpec::ExpectedAttnVnorm { .. } => "expected_attn_vnorm",
        }
    }

    /// Parse the compact string form, e.g. `"kvzap_mlp:-4"`, `"h2o:0.5"`,
    /// `"full"`. Parameters after `:` are τ for threshold policies and the
    /// keep-fraction for budget policies; `streaming_llm` and `random`
    /// accept a second parameter (sinks / seed). Threshold policies accept
    /// a trailing `:floor=<raw|qNN>` segment and `qNN` quantile sugar in τ
    /// positions (`"kvzap_mlp:q50:floor=q90"`) — see the module docs.
    pub fn parse(spec: &str) -> Result<PolicySpec> {
        let mut it = spec.split(':');
        let name = it.next().unwrap_or("");
        let mut params: Vec<&str> = it.collect();
        // the two-threshold floor (and its optional code width) ride as
        // named trailing segments so the positional parameters keep their
        // one-threshold meaning; canonical order is `...:floor=f:bits=b`
        let mut bits_seg: Option<&str> = None;
        if let Some(rest) = params.last().and_then(|s| s.strip_prefix("bits=")) {
            bits_seg = Some(rest);
            params.pop();
        }
        let mut floor_seg: Option<&str> = None;
        if let Some(rest) = params.last().and_then(|s| s.strip_prefix("floor=")) {
            floor_seg = Some(rest);
            params.pop();
        }
        if floor_seg.is_some()
            && !matches!(name, "kvzap_mlp" | "kvzap_linear" | "fastkvzip")
        {
            return Err(anyhow!(
                "policy '{name}' does not take a ':floor=' parameter (threshold policies only)"
            ));
        }
        if bits_seg.is_some() && floor_seg.is_none() {
            return Err(anyhow!(
                "policy '{name}': ':bits=' needs a ':floor=' demotion band to apply to \
                 (canonical order is ':floor=<f>:bits=<8|4|2>')"
            ));
        }
        let bits = bits_seg.map(|s| bits_param(name, s)).transpose()?.unwrap_or(QuantBits::Int8);
        let num = |i: usize, default: f64| -> Result<f64> {
            match params.get(i) {
                None => Ok(default),
                Some(s) => s
                    .parse::<f64>()
                    .ok()
                    .filter(|v| v.is_finite())
                    .ok_or_else(|| anyhow!("policy '{name}': bad numeric parameter '{s}'")),
            }
        };
        let max_params = |n: usize| -> Result<()> {
            if params.len() > n {
                Err(anyhow!("policy '{name}' takes at most {n} parameter(s), got '{spec}'"))
            } else {
                Ok(())
            }
        };
        let keep = |i: usize| -> Result<f64> {
            let v = num(i, DEFAULT_KEEP_FRAC)?;
            check_keep_frac(name, v)?;
            Ok(v)
        };
        let tau_at = |i: usize, default: f64| -> Result<f64> {
            match params.get(i) {
                None => Ok(default),
                Some(s) => tau_param(name, s),
            }
        };
        let spec = match name {
            "full" => {
                max_params(0)?;
                PolicySpec::Full
            }
            "kvzap_mlp" | "kvzap_linear" => {
                max_params(1)?;
                let tau = tau_at(0, DEFAULT_TAU)?;
                PolicySpec::Kvzap {
                    surrogate: surrogate_of(name),
                    tau,
                    floor: floor_seg.map(|s| floor_param(name, s, tau)).transpose()?,
                    bits,
                }
            }
            "kvzap_mlp_topk" | "kvzap_linear_topk" => {
                max_params(1)?;
                PolicySpec::KvzapTopk {
                    surrogate: surrogate_of(name),
                    keep_frac: keep(0)?,
                    per_layer: false,
                }
            }
            "kvzap_mlp_toplayer" | "kvzap_linear_toplayer" => {
                max_params(1)?;
                PolicySpec::KvzapTopk {
                    surrogate: surrogate_of(name),
                    keep_frac: keep(0)?,
                    per_layer: true,
                }
            }
            "kvzip" => {
                max_params(1)?;
                PolicySpec::Kvzip { plus: false, keep_frac: keep(0)? }
            }
            "kvzip_plus" => {
                max_params(1)?;
                PolicySpec::Kvzip { plus: true, keep_frac: keep(0)? }
            }
            "h2o" => {
                max_params(1)?;
                PolicySpec::H2o { keep_frac: keep(0)? }
            }
            "snapkv" => {
                max_params(1)?;
                PolicySpec::SnapKv { keep_frac: keep(0)? }
            }
            "adakv" => {
                max_params(1)?;
                PolicySpec::AdaKv { keep_frac: keep(0)? }
            }
            "tova" => {
                max_params(1)?;
                PolicySpec::Tova { keep_frac: keep(0)? }
            }
            "observed_attn" => {
                max_params(1)?;
                PolicySpec::ObservedAttn { keep_frac: keep(0)? }
            }
            "expected_attn" => {
                max_params(1)?;
                PolicySpec::ExpectedAttn { keep_frac: keep(0)? }
            }
            "knorm" => {
                max_params(1)?;
                PolicySpec::Knorm { keep_frac: keep(0)? }
            }
            "streaming_llm" => {
                max_params(2)?;
                PolicySpec::StreamingLlm {
                    keep_frac: keep(0)?,
                    sinks: check_count(name, "sinks", num(1, DEFAULT_SINKS as f64)?)? as usize,
                }
            }
            "random" => {
                max_params(2)?;
                PolicySpec::Random {
                    keep_frac: keep(0)?,
                    seed: check_count(name, "seed", num(1, 0.0)?)?,
                }
            }
            "keyformer" => {
                max_params(2)?;
                PolicySpec::Keyformer {
                    keep_frac: keep(0)?,
                    mix: check_mix(name, num(1, DEFAULT_MIX)?)?,
                }
            }
            "fastkvzip" => {
                max_params(2)?;
                let tau = tau_at(0, DEFAULT_TAU)?;
                PolicySpec::FastKvzip {
                    tau,
                    // the agreement gate follows τ unless set explicitly
                    gate_tau: tau_at(1, tau)?,
                    floor: floor_seg.map(|s| floor_param(name, s, tau)).transpose()?,
                    bits,
                }
            }
            "expected_attn_vnorm" => {
                max_params(1)?;
                PolicySpec::ExpectedAttnVnorm { keep_frac: keep(0)? }
            }
            _ => return Err(anyhow!("unknown policy '{name}'")),
        };
        Ok(spec)
    }

    /// Parse either form a client may send: a JSON string (compact form)
    /// or a structured object with a `"kind"` field.
    pub fn from_json(j: &Json) -> Result<PolicySpec> {
        let obj = match j {
            Json::Str(s) => return PolicySpec::parse(s),
            Json::Obj(_) => j,
            _ => return Err(anyhow!("policy must be a string or an object")),
        };
        let kind = obj
            .get("kind")
            .and_then(|k| k.as_str())
            .ok_or_else(|| anyhow!("policy object missing string field 'kind'"))?;
        let num = |key: &str, default: f64| -> Result<f64> {
            match obj.get(key) {
                None => Ok(default),
                Some(v) => v
                    .as_f64()
                    .filter(|x| x.is_finite())
                    .ok_or_else(|| anyhow!("policy '{kind}': field '{key}' must be a number")),
            }
        };
        let keep = |key: &str| -> Result<f64> {
            let v = num(key, DEFAULT_KEEP_FRAC)?;
            check_keep_frac(kind, v)?;
            Ok(v)
        };
        let surrogate = || -> Result<Surrogate> {
            match obj.get("surrogate") {
                None => Ok(Surrogate::Mlp),
                Some(v) => Surrogate::parse(
                    v.as_str().ok_or_else(|| anyhow!("'surrogate' must be a string"))?,
                ),
            }
        };
        // τ-like fields accept a number or "qNN" quantile-sugar string
        let thresh = |key: &str, default: f64| -> Result<f64> {
            match obj.get(key) {
                None => Ok(default),
                Some(v) => match v.as_str() {
                    Some(tag) => tau_param(kind, tag),
                    None => v.as_f64().filter(|x| x.is_finite()).ok_or_else(|| {
                        anyhow!("policy '{kind}': field '{key}' must be a number or q10..q90")
                    }),
                },
            }
        };
        let floor_field = |tau: f64| -> Result<Option<f64>> {
            match obj.get("floor") {
                None => Ok(None),
                Some(v) => match v.as_str() {
                    Some(tag) => floor_param(kind, tag, tau).map(Some),
                    None => {
                        let f = v.as_f64().filter(|x| x.is_finite()).ok_or_else(|| {
                            anyhow!(
                                "policy '{kind}': field 'floor' must be a number or q10..q90"
                            )
                        })?;
                        check_floor(kind, f, tau).map(Some)
                    }
                },
            }
        };
        let bits_field = |floor: &Option<f64>| -> Result<QuantBits> {
            match obj.get("bits") {
                None => Ok(QuantBits::Int8),
                Some(_) if floor.is_none() => Err(anyhow!(
                    "policy '{kind}': 'bits' needs a 'floor' demotion band to apply to"
                )),
                Some(v) => {
                    let w = v.as_f64().filter(|x| x.fract() == 0.0).ok_or_else(|| {
                        anyhow!("policy '{kind}': field 'bits' must be 8, 4 or 2")
                    })?;
                    QuantBits::from_width(w as u64)
                        .ok_or_else(|| anyhow!("policy '{kind}': bad code width {w} (want 8|4|2)"))
                }
            }
        };
        let spec = match kind {
            "full" => PolicySpec::Full,
            "kvzap" => {
                let tau = thresh("tau", DEFAULT_TAU)?;
                let floor = floor_field(tau)?;
                let bits = bits_field(&floor)?;
                PolicySpec::Kvzap { surrogate: surrogate()?, tau, floor, bits }
            }
            "kvzap_topk" => PolicySpec::KvzapTopk {
                surrogate: surrogate()?,
                keep_frac: keep("keep_frac")?,
                per_layer: obj.get("per_layer").and_then(|v| v.as_bool()).unwrap_or(false),
            },
            "kvzip" => PolicySpec::Kvzip {
                plus: obj.get("plus").and_then(|v| v.as_bool()).unwrap_or(false),
                keep_frac: keep("keep_frac")?,
            },
            "h2o" => PolicySpec::H2o { keep_frac: keep("keep_frac")? },
            "snapkv" => PolicySpec::SnapKv { keep_frac: keep("keep_frac")? },
            "adakv" => PolicySpec::AdaKv { keep_frac: keep("keep_frac")? },
            "tova" => PolicySpec::Tova { keep_frac: keep("keep_frac")? },
            "observed_attn" => PolicySpec::ObservedAttn { keep_frac: keep("keep_frac")? },
            "expected_attn" => PolicySpec::ExpectedAttn { keep_frac: keep("keep_frac")? },
            "knorm" => PolicySpec::Knorm { keep_frac: keep("keep_frac")? },
            "streaming_llm" => PolicySpec::StreamingLlm {
                keep_frac: keep("keep_frac")?,
                sinks: check_count(kind, "sinks", num("sinks", DEFAULT_SINKS as f64)?)? as usize,
            },
            "random" => PolicySpec::Random {
                keep_frac: keep("keep_frac")?,
                seed: check_count(kind, "seed", num("seed", 0.0)?)?,
            },
            "keyformer" => PolicySpec::Keyformer {
                keep_frac: keep("keep_frac")?,
                mix: check_mix(kind, num("mix", DEFAULT_MIX)?)?,
            },
            "fastkvzip" => {
                let tau = thresh("tau", DEFAULT_TAU)?;
                let floor = floor_field(tau)?;
                let bits = bits_field(&floor)?;
                PolicySpec::FastKvzip { tau, gate_tau: thresh("gate_tau", tau)?, floor, bits }
            }
            "expected_attn_vnorm" => {
                PolicySpec::ExpectedAttnVnorm { keep_frac: keep("keep_frac")? }
            }
            _ => return Err(anyhow!("unknown policy kind '{kind}'")),
        };
        Ok(spec)
    }

    /// Structured JSON form (canonical: always carries every field).
    pub fn to_json(&self) -> Json {
        let kind = Json::str(self.kind());
        match *self {
            PolicySpec::Full => Json::obj(vec![("kind", kind)]),
            PolicySpec::Kvzap { surrogate, tau, floor, bits } => {
                let mut fields = vec![
                    ("kind", kind),
                    ("surrogate", Json::str(surrogate.as_str())),
                    ("tau", Json::num(tau)),
                ];
                if let Some(f) = floor {
                    fields.push(("floor", Json::num(f)));
                    if bits != QuantBits::Int8 {
                        fields.push(("bits", Json::num(bits.width() as f64)));
                    }
                }
                Json::obj(fields)
            }
            PolicySpec::KvzapTopk { surrogate, keep_frac, per_layer } => Json::obj(vec![
                ("kind", kind),
                ("surrogate", Json::str(surrogate.as_str())),
                ("keep_frac", Json::num(keep_frac)),
                ("per_layer", Json::Bool(per_layer)),
            ]),
            PolicySpec::Kvzip { plus, keep_frac } => Json::obj(vec![
                ("kind", kind),
                ("plus", Json::Bool(plus)),
                ("keep_frac", Json::num(keep_frac)),
            ]),
            PolicySpec::H2o { keep_frac }
            | PolicySpec::SnapKv { keep_frac }
            | PolicySpec::AdaKv { keep_frac }
            | PolicySpec::Tova { keep_frac }
            | PolicySpec::ObservedAttn { keep_frac }
            | PolicySpec::ExpectedAttn { keep_frac }
            | PolicySpec::ExpectedAttnVnorm { keep_frac }
            | PolicySpec::Knorm { keep_frac } => {
                Json::obj(vec![("kind", kind), ("keep_frac", Json::num(keep_frac))])
            }
            PolicySpec::Keyformer { keep_frac, mix } => Json::obj(vec![
                ("kind", kind),
                ("keep_frac", Json::num(keep_frac)),
                ("mix", Json::num(mix)),
            ]),
            PolicySpec::FastKvzip { tau, gate_tau, floor, bits } => {
                let mut fields =
                    vec![("kind", kind), ("tau", Json::num(tau)), ("gate_tau", Json::num(gate_tau))];
                if let Some(f) = floor {
                    fields.push(("floor", Json::num(f)));
                    if bits != QuantBits::Int8 {
                        fields.push(("bits", Json::num(bits.width() as f64)));
                    }
                }
                Json::obj(fields)
            }
            PolicySpec::StreamingLlm { keep_frac, sinks } => Json::obj(vec![
                ("kind", kind),
                ("keep_frac", Json::num(keep_frac)),
                ("sinks", Json::num(sinks as f64)),
            ]),
            PolicySpec::Random { keep_frac, seed } => Json::obj(vec![
                ("kind", kind),
                ("keep_frac", Json::num(keep_frac)),
                ("seed", Json::num(seed as f64)),
            ]),
        }
    }

    /// Instantiate the runnable policy. `window` is the engine's sliding
    /// window (manifest `w`).
    pub fn build(&self, window: usize) -> Box<dyn PrunePolicy> {
        match *self {
            PolicySpec::Full => Box::new(NoPress),
            PolicySpec::Kvzap { surrogate, tau, floor, bits } => Box::new(
                match surrogate {
                    Surrogate::Mlp => KVzap::mlp(tau as f32, window),
                    Surrogate::Linear => KVzap::linear(tau as f32, window),
                }
                .with_floor(floor.map(|f| f as f32))
                .with_bits(bits),
            ),
            PolicySpec::KvzapTopk { surrogate, keep_frac, per_layer } => Box::new(kvzap_topk(
                matches!(surrogate, Surrogate::Mlp),
                keep_frac,
                window,
                per_layer,
            )),
            PolicySpec::Kvzip { plus, keep_frac } => Box::new(if plus {
                kvzip_plus_oracle(keep_frac, window)
            } else {
                kvzip_oracle(keep_frac, window)
            }),
            PolicySpec::H2o { keep_frac } => Box::new(h2o(keep_frac, window)),
            PolicySpec::SnapKv { keep_frac } => Box::new(snapkv(keep_frac, window)),
            PolicySpec::AdaKv { keep_frac } => Box::new(adakv(keep_frac, window)),
            PolicySpec::Tova { keep_frac } => Box::new(tova(keep_frac, window)),
            PolicySpec::ObservedAttn { keep_frac } => {
                Box::new(observed_attention(keep_frac, window))
            }
            PolicySpec::ExpectedAttn { keep_frac } => {
                Box::new(expected_attention(keep_frac, window))
            }
            PolicySpec::Knorm { keep_frac } => Box::new(knorm(keep_frac, window)),
            PolicySpec::StreamingLlm { keep_frac, sinks } => {
                Box::new(StreamingLlm { keep_frac, sinks })
            }
            PolicySpec::Random { keep_frac, seed } => {
                Box::new(RandomPress { keep_frac, seed, window })
            }
            PolicySpec::Keyformer { keep_frac, mix } => {
                Box::new(keyformer(keep_frac, mix, window))
            }
            PolicySpec::FastKvzip { tau, gate_tau, floor, bits } => Box::new(FastKvzip {
                tau: tau as f32,
                gate_tau: gate_tau as f32,
                floor: floor.map(|f| f as f32),
                bits,
                window,
            }),
            PolicySpec::ExpectedAttnVnorm { keep_frac } => {
                Box::new(expected_attention_vnorm(keep_frac, window))
            }
        }
    }
}

fn surrogate_of(name: &str) -> Surrogate {
    if name.starts_with("kvzap_mlp") {
        Surrogate::Mlp
    } else {
        Surrogate::Linear
    }
}

/// A τ-position threshold: a finite float or `qNN` quantile sugar.
fn tau_param(name: &str, s: &str) -> Result<f64> {
    if let Some(v) = quantile(s) {
        return Ok(v);
    }
    s.parse::<f64>().ok().filter(|v| v.is_finite()).ok_or_else(|| {
        anyhow!("policy '{name}': bad threshold '{s}' (expected a finite number or q10..q90)")
    })
}

/// A floor-position threshold: a finite float, or `qNN` sugar resolving
/// to the complementary quantile. Must land at or below τ.
fn floor_param(name: &str, s: &str, tau: f64) -> Result<f64> {
    let v = if s.starts_with('q') {
        complement_quantile(s).ok_or_else(|| {
            anyhow!("policy '{name}': bad floor quantile '{s}' (expected q10..q90)")
        })?
    } else {
        s.parse::<f64>().ok().filter(|v| v.is_finite()).ok_or_else(|| {
            anyhow!("policy '{name}': bad floor '{s}' (expected a finite number or q10..q90)")
        })?
    };
    check_floor(name, v, tau)
}

/// A `bits=` code width: 8, 4 or 2 (the [`QuantBits`] wire widths).
fn bits_param(name: &str, s: &str) -> Result<QuantBits> {
    s.parse::<u64>().ok().and_then(QuantBits::from_width).ok_or_else(|| {
        anyhow!("policy '{name}': bad code width '{s}' (expected bits=8, bits=4 or bits=2)")
    })
}

/// The demotion floor must sit at or below τ — a floor above τ would
/// claim to demote positions the τ test already keeps.
fn check_floor(name: &str, floor: f64, tau: f64) -> Result<f64> {
    if floor <= tau {
        Ok(floor)
    } else {
        Err(anyhow!("policy '{name}': floor {floor} above tau {tau} (need floor <= tau)"))
    }
}

fn check_keep_frac(name: &str, v: f64) -> Result<()> {
    // strictly positive: a zero budget keeps nothing beyond the forced
    // window, which every caller treats as a spec error, not a policy
    if v > 0.0 && v <= 1.0 {
        Ok(())
    } else {
        Err(anyhow!("policy '{name}': keep fraction {v} outside (0, 1]"))
    }
}

/// Keyformer's mix must be a proper interpolation weight.
fn check_mix(name: &str, v: f64) -> Result<f64> {
    if (0.0..=1.0).contains(&v) {
        Ok(v)
    } else {
        Err(anyhow!("policy '{name}': mix {v} outside [0, 1]"))
    }
}

/// Count-like parameters (sinks, seed) must be non-negative integers —
/// `as usize`/`as u64` would otherwise silently saturate or truncate.
fn check_count(name: &str, field: &str, v: f64) -> Result<u64> {
    if v >= 0.0 && v.fract() == 0.0 && v <= u64::MAX as f64 {
        Ok(v as u64)
    } else {
        Err(anyhow!("policy '{name}': '{field}' must be a non-negative integer, got {v}"))
    }
}

impl fmt::Display for PolicySpec {
    /// Canonical compact string form; `parse(x.to_string()) == x`.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            PolicySpec::Full => write!(f, "full"),
            PolicySpec::Kvzap { surrogate, tau, floor, bits } => {
                write!(f, "kvzap_{}:{}", surrogate.as_str(), tau)?;
                if let Some(fl) = floor {
                    write!(f, ":floor={fl}")?;
                    if bits != QuantBits::Int8 {
                        write!(f, ":bits={}", bits.width())?;
                    }
                }
                Ok(())
            }
            PolicySpec::KvzapTopk { surrogate, keep_frac, per_layer } => write!(
                f,
                "kvzap_{}_{}:{}",
                surrogate.as_str(),
                if per_layer { "toplayer" } else { "topk" },
                keep_frac
            ),
            PolicySpec::Kvzip { plus, keep_frac } => {
                write!(f, "kvzip{}:{}", if plus { "_plus" } else { "" }, keep_frac)
            }
            PolicySpec::H2o { keep_frac } => write!(f, "h2o:{keep_frac}"),
            PolicySpec::SnapKv { keep_frac } => write!(f, "snapkv:{keep_frac}"),
            PolicySpec::AdaKv { keep_frac } => write!(f, "adakv:{keep_frac}"),
            PolicySpec::Tova { keep_frac } => write!(f, "tova:{keep_frac}"),
            PolicySpec::ObservedAttn { keep_frac } => write!(f, "observed_attn:{keep_frac}"),
            PolicySpec::ExpectedAttn { keep_frac } => write!(f, "expected_attn:{keep_frac}"),
            PolicySpec::Knorm { keep_frac } => write!(f, "knorm:{keep_frac}"),
            PolicySpec::StreamingLlm { keep_frac, sinks } => {
                if sinks == DEFAULT_SINKS {
                    write!(f, "streaming_llm:{keep_frac}")
                } else {
                    write!(f, "streaming_llm:{keep_frac}:{sinks}")
                }
            }
            PolicySpec::Random { keep_frac, seed } => {
                if seed == 0 {
                    write!(f, "random:{keep_frac}")
                } else {
                    write!(f, "random:{keep_frac}:{seed}")
                }
            }
            PolicySpec::Keyformer { keep_frac, mix } => {
                if mix == DEFAULT_MIX {
                    write!(f, "keyformer:{keep_frac}")
                } else {
                    write!(f, "keyformer:{keep_frac}:{mix}")
                }
            }
            PolicySpec::FastKvzip { tau, gate_tau, floor, bits } => {
                if gate_tau == tau {
                    write!(f, "fastkvzip:{tau}")?;
                } else {
                    write!(f, "fastkvzip:{tau}:{gate_tau}")?;
                }
                if let Some(fl) = floor {
                    write!(f, ":floor={fl}")?;
                    if bits != QuantBits::Int8 {
                        write!(f, ":bits={}", bits.width())?;
                    }
                }
                Ok(())
            }
            PolicySpec::ExpectedAttnVnorm { keep_frac } => {
                write!(f, "expected_attn_vnorm:{keep_frac}")
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Introspection catalog

/// One tunable parameter of a policy kind.
pub struct PolicyParam {
    /// Parameter name as it appears in the JSON form.
    pub name: &'static str,
    /// Value used when the spec omits the parameter.
    pub default: f64,
    /// One-line human-readable description.
    pub doc: &'static str,
}

/// One policy kind: its structured tag, accepted string forms, parameters.
pub struct PolicyInfo {
    /// Canonical kind tag (matches [`PolicySpec::kind`]).
    pub kind: &'static str,
    /// Accepted compact string spellings (e.g. `kvzap_mlp`, `kvzap_lin`).
    pub string_forms: &'static [&'static str],
    /// Tunable parameters with defaults.
    pub params: &'static [PolicyParam],
    /// One-line human-readable description.
    pub doc: &'static str,
}

const P_TAU: PolicyParam =
    PolicyParam { name: "tau", default: DEFAULT_TAU, doc: "log s+ eviction threshold" };
const P_KEEP: PolicyParam = PolicyParam {
    name: "keep_frac",
    default: DEFAULT_KEEP_FRAC,
    doc: "fraction of prompt KV pairs to keep, in (0, 1]",
};
const P_SINKS: PolicyParam = PolicyParam {
    name: "sinks",
    default: 4.0, // == DEFAULT_SINKS
    doc: "always-kept leading attention-sink tokens",
};
const P_SEED: PolicyParam =
    PolicyParam { name: "seed", default: 0.0, doc: "rng seed for the eviction pattern" };
const P_MIX: PolicyParam = PolicyParam {
    name: "mix",
    default: DEFAULT_MIX,
    doc: "max-attn share of the key-token score, in [0, 1]",
};
const P_GATE: PolicyParam = PolicyParam {
    name: "gate_tau",
    default: DEFAULT_TAU, // when omitted it follows tau
    doc: "linear-surrogate agreement threshold (defaults to tau)",
};
const P_FLOOR: PolicyParam = PolicyParam {
    name: "floor",
    // when omitted the demote band is empty — equivalent to floor == tau
    default: DEFAULT_TAU,
    doc: "demotion floor <= tau: scores in [floor, tau) quantize to the side tier instead of dropping",
};
const P_BITS: PolicyParam = PolicyParam {
    name: "bits",
    default: 8.0,
    doc: "side-tier code width (8|4|2); narrower widths shrink side-pool bytes at higher round-trip error",
};

/// Every policy kind the stack understands, with parameters and defaults.
pub const CATALOG: &[PolicyInfo] = &[
    PolicyInfo {
        kind: "full",
        string_forms: &["full"],
        params: &[],
        doc: "keep the full KV cache (no pruning)",
    },
    PolicyInfo {
        kind: "kvzap",
        string_forms: &["kvzap_mlp", "kvzap_linear"],
        params: &[P_TAU, P_FLOOR, P_BITS],
        doc: "KVzap thresholding (surrogate: mlp|linear); prunes during decode; \
              ':floor=' enables the tiered demotion band, ':bits=' its code width",
    },
    PolicyInfo {
        kind: "fastkvzip",
        string_forms: &["fastkvzip"],
        params: &[P_TAU, P_GATE, P_FLOOR, P_BITS],
        doc: "Fast-KVzip rival: gated thresholding (mlp AND linear agree); prunes during decode; \
              ':floor=' enables the tiered demotion band, ':bits=' its code width",
    },
    PolicyInfo {
        kind: "kvzap_topk",
        string_forms: &[
            "kvzap_mlp_topk",
            "kvzap_linear_topk",
            "kvzap_mlp_toplayer",
            "kvzap_linear_toplayer",
        ],
        params: &[P_KEEP],
        doc: "fixed-ratio top-k on KVzap surrogate scores (per_layer pools per layer)",
    },
    PolicyInfo {
        kind: "kvzip",
        string_forms: &["kvzip", "kvzip_plus"],
        params: &[P_KEEP],
        doc: "KVzip oracle budget policy (double prefill pass; plus uses s+)",
    },
    PolicyInfo {
        kind: "h2o",
        string_forms: &["h2o"],
        params: &[P_KEEP],
        doc: "heavy-hitter oracle: cumulative attention, per-head budget",
    },
    PolicyInfo {
        kind: "keyformer",
        string_forms: &["keyformer"],
        params: &[P_KEEP, P_MIX],
        doc: "Keyformer rival: cum/max-attention key-token mix, per-head budget",
    },
    PolicyInfo {
        kind: "snapkv",
        string_forms: &["snapkv"],
        params: &[P_KEEP],
        doc: "SnapKV: observation-window attention, per-head budget",
    },
    PolicyInfo {
        kind: "adakv",
        string_forms: &["adakv"],
        params: &[P_KEEP],
        doc: "AdaKV: observation-window attention, budget pooled per layer",
    },
    PolicyInfo {
        kind: "tova",
        string_forms: &["tova"],
        params: &[P_KEEP],
        doc: "TOVA: max attention, per-head budget",
    },
    PolicyInfo {
        kind: "observed_attn",
        string_forms: &["observed_attn"],
        params: &[P_KEEP],
        doc: "observed attention: max attention, global budget pool",
    },
    PolicyInfo {
        kind: "expected_attn",
        string_forms: &["expected_attn"],
        params: &[P_KEEP],
        doc: "expected attention: forward-looking attention, per-head budget",
    },
    PolicyInfo {
        kind: "expected_attn_vnorm",
        string_forms: &["expected_attn_vnorm"],
        params: &[P_KEEP],
        doc: "ExpectedAttention rival: forecast attention x value norm, per-head budget",
    },
    PolicyInfo {
        kind: "knorm",
        string_forms: &["knorm"],
        params: &[P_KEEP],
        doc: "key-norm heuristic: keep the smallest ||k||, per-head budget",
    },
    PolicyInfo {
        kind: "streaming_llm",
        string_forms: &["streaming_llm"],
        params: &[P_KEEP, P_SINKS],
        doc: "StreamingLLM: attention sinks + recency window, score-free",
    },
    PolicyInfo {
        kind: "random",
        string_forms: &["random"],
        params: &[P_KEEP, P_SEED],
        doc: "random eviction (sanity-check lower bound)",
    },
];

/// The catalog as JSON (served by `{"cmd": "policies"}`).
pub fn catalog_json() -> Json {
    Json::Arr(
        CATALOG
            .iter()
            .map(|info| {
                Json::obj(vec![
                    ("kind", Json::str(info.kind)),
                    (
                        "string_forms",
                        Json::Arr(info.string_forms.iter().map(|s| Json::str(*s)).collect()),
                    ),
                    (
                        "params",
                        Json::Arr(
                            info.params
                                .iter()
                                .map(|p| {
                                    Json::obj(vec![
                                        ("name", Json::str(p.name)),
                                        ("default", Json::num(p.default)),
                                        ("doc", Json::str(p.doc)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                    ("doc", Json::str(info.doc)),
                ])
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_specs() -> Vec<PolicySpec> {
        vec![
            PolicySpec::Full,
            PolicySpec::Kvzap { surrogate: Surrogate::Mlp, tau: -4.0, floor: None, bits: QuantBits::Int8 },
            PolicySpec::Kvzap { surrogate: Surrogate::Linear, tau: -6.5, floor: None, bits: QuantBits::Int8 },
            PolicySpec::Kvzap { surrogate: Surrogate::Mlp, tau: -4.0, floor: Some(-9.0), bits: QuantBits::Int8 },
            PolicySpec::Kvzap { surrogate: Surrogate::Linear, tau: -2.0, floor: Some(-2.0), bits: QuantBits::Int8 },
            PolicySpec::Kvzap { surrogate: Surrogate::Mlp, tau: -4.0, floor: Some(-9.0), bits: QuantBits::Int4 },
            PolicySpec::Kvzap { surrogate: Surrogate::Linear, tau: -4.0, floor: Some(-7.0), bits: QuantBits::Int2 },
            PolicySpec::KvzapTopk {
                surrogate: Surrogate::Mlp,
                keep_frac: 0.5,
                per_layer: false,
            },
            PolicySpec::KvzapTopk {
                surrogate: Surrogate::Linear,
                keep_frac: 0.25,
                per_layer: true,
            },
            PolicySpec::Kvzip { plus: false, keep_frac: 0.5 },
            PolicySpec::Kvzip { plus: true, keep_frac: 0.75 },
            PolicySpec::H2o { keep_frac: 0.5 },
            PolicySpec::SnapKv { keep_frac: 0.4 },
            PolicySpec::AdaKv { keep_frac: 0.6 },
            PolicySpec::Tova { keep_frac: 0.8 },
            PolicySpec::ObservedAttn { keep_frac: 0.3 },
            PolicySpec::ExpectedAttn { keep_frac: 0.7 },
            PolicySpec::Knorm { keep_frac: 0.2 },
            PolicySpec::StreamingLlm { keep_frac: 0.3, sinks: 4 },
            PolicySpec::StreamingLlm { keep_frac: 0.3, sinks: 8 },
            PolicySpec::Random { keep_frac: 0.5, seed: 0 },
            PolicySpec::Random { keep_frac: 0.5, seed: 7 },
            PolicySpec::Keyformer { keep_frac: 0.5, mix: DEFAULT_MIX },
            PolicySpec::Keyformer { keep_frac: 0.25, mix: 1.0 },
            PolicySpec::FastKvzip { tau: -4.0, gate_tau: -4.0, floor: None, bits: QuantBits::Int8 },
            PolicySpec::FastKvzip { tau: -4.0, gate_tau: -7.5, floor: None, bits: QuantBits::Int8 },
            PolicySpec::FastKvzip { tau: -4.0, gate_tau: -4.0, floor: Some(-10.0), bits: QuantBits::Int8 },
            PolicySpec::FastKvzip { tau: -3.0, gate_tau: -5.0, floor: Some(-8.5), bits: QuantBits::Int8 },
            PolicySpec::FastKvzip { tau: -4.0, gate_tau: -5.0, floor: Some(-9.0), bits: QuantBits::Int4 },
            PolicySpec::ExpectedAttnVnorm { keep_frac: 0.35 },
        ]
    }

    #[test]
    fn string_round_trip_every_variant() {
        for spec in sample_specs() {
            let s = spec.to_string();
            let back = PolicySpec::parse(&s).unwrap_or_else(|e| panic!("parse '{s}': {e}"));
            assert_eq!(back, spec, "string round trip via '{s}'");
        }
    }

    #[test]
    fn json_round_trip_every_variant() {
        for spec in sample_specs() {
            let j = spec.to_json();
            // through the actual codec, not just the in-memory value
            let wire = Json::parse(&j.dump()).unwrap();
            let back = PolicySpec::from_json(&wire)
                .unwrap_or_else(|e| panic!("from_json {}: {e}", j.dump()));
            assert_eq!(back, spec, "json round trip via {}", j.dump());
        }
    }

    #[test]
    fn json_string_form_accepted() {
        let spec = PolicySpec::from_json(&Json::str("kvzap_mlp:-4")).unwrap();
        assert_eq!(spec, PolicySpec::Kvzap { surrogate: Surrogate::Mlp, tau: -4.0, floor: None, bits: QuantBits::Int8 });
    }

    #[test]
    fn two_threshold_and_quantile_sugar_parse() {
        // qNN in the τ position is a direct decile lookup
        assert_eq!(
            PolicySpec::parse("kvzap_mlp:q50").unwrap(),
            PolicySpec::Kvzap { surrogate: Surrogate::Mlp, tau: -6.0, floor: None, bits: QuantBits::Int8 }
        );
        // floor=qNN spares the top NN% of sub-τ mass → complementary decile
        assert_eq!(
            PolicySpec::parse("kvzap_mlp:q50:floor=q90").unwrap(),
            PolicySpec::Kvzap { surrogate: Surrogate::Mlp, tau: -6.0, floor: Some(-10.0), bits: QuantBits::Int8 }
        );
        // raw floats work in both positions
        assert_eq!(
            PolicySpec::parse("kvzap_linear:-4:floor=-9").unwrap(),
            PolicySpec::Kvzap { surrogate: Surrogate::Linear, tau: -4.0, floor: Some(-9.0), bits: QuantBits::Int8 }
        );
        // fastkvzip: floor rides after the optional gate, and the bare
        // floor form leaves τ at its default
        assert_eq!(
            PolicySpec::parse("fastkvzip:-4:-5:floor=q80").unwrap(),
            PolicySpec::FastKvzip { tau: -4.0, gate_tau: -5.0, floor: Some(-9.0), bits: QuantBits::Int8 }
        );
        assert_eq!(
            PolicySpec::parse("kvzap_mlp:floor=q90").unwrap(),
            PolicySpec::Kvzap { surrogate: Surrogate::Mlp, tau: DEFAULT_TAU, floor: Some(-10.0), bits: QuantBits::Int8 }
        );
    }

    #[test]
    fn bits_segment_parses_and_round_trips() {
        // string form, canonical trailing order form:τ:floor=f:bits=b
        assert_eq!(
            PolicySpec::parse("kvzap_mlp:-4:floor=-9:bits=4").unwrap(),
            PolicySpec::Kvzap {
                surrogate: Surrogate::Mlp,
                tau: -4.0,
                floor: Some(-9.0),
                bits: QuantBits::Int4
            }
        );
        // quantile sugar composes with bits
        assert_eq!(
            PolicySpec::parse("fastkvzip:-4:-5:floor=q80:bits=2").unwrap(),
            PolicySpec::FastKvzip {
                tau: -4.0,
                gate_tau: -5.0,
                floor: Some(-9.0),
                bits: QuantBits::Int2
            }
        );
        // bits=8 is the default and canonicalizes away
        let spec = PolicySpec::parse("kvzap_mlp:-4:floor=-9:bits=8").unwrap();
        assert_eq!(spec.to_string(), "kvzap_mlp:-4:floor=-9");
        // JSON form
        let j = Json::parse(r#"{"kind": "kvzap", "tau": -4.0, "floor": -9.0, "bits": 4}"#).unwrap();
        assert_eq!(
            PolicySpec::from_json(&j).unwrap(),
            PolicySpec::parse("kvzap_mlp:-4:floor=-9:bits=4").unwrap()
        );
    }

    #[test]
    fn bits_segment_rejects_bad_forms() {
        for bad in [
            "kvzap_mlp:-4:bits=4",          // bits without a floor band
            "kvzap_mlp:-4:floor=-9:bits=3", // unsupported width
            "kvzap_mlp:-4:floor=-9:bits=",  // empty width
            "kvzap_mlp:-4:bits=4:floor=-9", // wrong trailing order
            "h2o:0.5:bits=4",               // budget policies take no bits
        ] {
            assert!(PolicySpec::parse(bad).is_err(), "'{bad}' must be rejected");
        }
        for bad in [
            r#"{"kind": "kvzap", "tau": -4.0, "bits": 4}"#,
            r#"{"kind": "kvzap", "tau": -4.0, "floor": -9.0, "bits": 3}"#,
            r#"{"kind": "kvzap", "tau": -4.0, "floor": -9.0, "bits": 4.5}"#,
            r#"{"kind": "fastkvzip", "bits": 2}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(PolicySpec::from_json(&j).is_err(), "'{bad}' must be rejected");
        }
    }

    #[test]
    fn two_threshold_json_forms() {
        let j = Json::parse(r#"{"kind": "kvzap", "tau": -4.0, "floor": -9.0}"#).unwrap();
        assert_eq!(
            PolicySpec::from_json(&j).unwrap(),
            PolicySpec::parse("kvzap_mlp:-4:floor=-9").unwrap()
        );
        // quantile sugar as JSON strings, both fields
        let j = Json::parse(r#"{"kind": "kvzap", "tau": "q50", "floor": "q90"}"#).unwrap();
        assert_eq!(
            PolicySpec::from_json(&j).unwrap(),
            PolicySpec::parse("kvzap_mlp:q50:floor=q90").unwrap()
        );
        let j = Json::parse(r#"{"kind": "fastkvzip", "tau": -4.0, "floor": "q80"}"#).unwrap();
        assert_eq!(
            PolicySpec::from_json(&j).unwrap(),
            PolicySpec::FastKvzip { tau: -4.0, gate_tau: -4.0, floor: Some(-9.0), bits: QuantBits::Int8 }
        );
    }

    #[test]
    fn two_threshold_rejects_bad_forms() {
        for bad in [
            "kvzap_mlp:-8:floor=-4",   // floor above τ
            "kvzap_mlp:-4:floor=q00",  // unknown quantile tag
            "kvzap_mlp:q55",           // unknown quantile tag in τ position
            "kvzap_mlp:-4:floor=nan",  // non-finite floor
            "kvzap_mlp:-4:floor=",     // empty floor
            "h2o:0.5:floor=-4",        // budget policies take no floor
            "full:floor=-4",           // no-op policy takes no floor
            "kvzap_mlp:floor=-2:-8",   // floor must be the trailing segment
        ] {
            assert!(PolicySpec::parse(bad).is_err(), "'{bad}' must be rejected");
        }
        for bad in [
            r#"{"kind": "kvzap", "tau": -8.0, "floor": -4.0}"#,
            r#"{"kind": "kvzap", "floor": "q5"}"#,
            r#"{"kind": "kvzap", "floor": "x"}"#,
            r#"{"kind": "fastkvzip", "tau": "q99"}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(PolicySpec::from_json(&j).is_err(), "'{bad}' must be rejected");
        }
    }

    #[test]
    fn structured_matches_string_form() {
        let j = Json::parse(r#"{"kind": "kvzap", "surrogate": "mlp", "tau": -4.0}"#).unwrap();
        assert_eq!(PolicySpec::from_json(&j).unwrap(), PolicySpec::parse("kvzap_mlp:-4").unwrap());
        let j = Json::parse(r#"{"kind": "h2o", "keep_frac": 0.5}"#).unwrap();
        assert_eq!(PolicySpec::from_json(&j).unwrap(), PolicySpec::parse("h2o:0.5").unwrap());
        let j = Json::parse(r#"{"kind": "fastkvzip", "tau": -4.0}"#).unwrap();
        assert_eq!(
            PolicySpec::from_json(&j).unwrap(),
            PolicySpec::parse("fastkvzip:-4").unwrap()
        );
        let j = Json::parse(r#"{"kind": "keyformer", "keep_frac": 0.5, "mix": 0.25}"#).unwrap();
        assert_eq!(
            PolicySpec::from_json(&j).unwrap(),
            PolicySpec::parse("keyformer:0.5:0.25").unwrap()
        );
    }

    #[test]
    fn defaults_applied() {
        assert_eq!(
            PolicySpec::parse("kvzap_mlp").unwrap(),
            PolicySpec::Kvzap { surrogate: Surrogate::Mlp, tau: DEFAULT_TAU, floor: None, bits: QuantBits::Int8 }
        );
        let j = Json::parse(r#"{"kind": "kvzap"}"#).unwrap();
        assert_eq!(
            PolicySpec::from_json(&j).unwrap(),
            PolicySpec::Kvzap { surrogate: Surrogate::Mlp, tau: DEFAULT_TAU, floor: None, bits: QuantBits::Int8 }
        );
        assert_eq!(
            PolicySpec::parse("streaming_llm").unwrap(),
            PolicySpec::StreamingLlm { keep_frac: DEFAULT_KEEP_FRAC, sinks: DEFAULT_SINKS }
        );
    }

    #[test]
    fn rejects_malformed() {
        for bad in [
            "kvzap_mlp:",       // empty parameter
            "kvzap_mlp:abc",    // non-numeric τ
            "kvzap_mlp:nan",    // non-finite τ
            "nope",             // unknown kind
            "nope:0.5",         // unknown kind with param
            "h2o:-0.1",         // keep fraction out of range
            "h2o:1.5",          // keep fraction out of range
            "h2o:0",            // keep fraction must be strictly positive
            "keyformer:0.5:1.5", // mix out of range
            "keyformer:0.5:-0.1", // mix out of range
            "expected_attn_vnorm:0", // keep fraction must be strictly positive
            "full:0.5",         // full takes no parameter
            "h2o:0.5:9",        // too many parameters
            "streaming_llm:0.3:-3", // negative sinks
            "random:0.5:1.9",   // fractional seed
            "",                 // empty
        ] {
            assert!(PolicySpec::parse(bad).is_err(), "'{bad}' must be rejected");
        }
        for bad in [
            r#"{"nokinds": 1}"#,
            r#"{"kind": "nope"}"#,
            r#"{"kind": "kvzap", "tau": "x"}"#,
            r#"{"kind": "kvzap", "surrogate": "quadratic"}"#,
            r#"{"kind": "h2o", "keep_frac": 1.5}"#,
            r#"{"kind": "h2o", "keep_frac": 0}"#,
            r#"{"kind": "keyformer", "mix": 2.0}"#,
            r#"[1, 2]"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(PolicySpec::from_json(&j).is_err(), "'{bad}' must be rejected");
        }
    }

    /// Non-finite τ / gate / keep / mix values must be rejected on both
    /// wire forms: a NaN τ makes every `< tau` comparison false, so decode
    /// pruning would silently never fire. JSON text cannot spell NaN, so
    /// the structured cases are built programmatically.
    #[test]
    fn non_finite_params_rejected_on_both_wire_forms() {
        for bad in [
            "kvzap_mlp:nan",
            "kvzap_mlp:inf",
            "kvzap_linear:-inf",
            "fastkvzip:nan",
            "fastkvzip:-4:inf",
            "h2o:nan",
            "keyformer:0.5:nan",
        ] {
            assert!(PolicySpec::parse(bad).is_err(), "'{bad}' must be rejected");
        }
        let cases = [
            ("kvzap", "tau", f64::NAN),
            ("kvzap", "tau", f64::INFINITY),
            ("fastkvzip", "tau", f64::NAN),
            ("fastkvzip", "gate_tau", f64::NEG_INFINITY),
            ("h2o", "keep_frac", f64::NAN),
            ("keyformer", "mix", f64::NAN),
        ];
        for (kind, field, v) in cases {
            let j = Json::obj(vec![("kind", Json::str(kind)), (field, Json::num(v))]);
            assert!(
                PolicySpec::from_json(&j).is_err(),
                "{kind} with {field} = {v} must be rejected"
            );
        }
    }

    #[test]
    fn build_every_catalog_kind() {
        for spec in sample_specs() {
            let pol = spec.build(16);
            let _ = pol.name();
        }
    }

    #[test]
    fn catalog_covers_every_string_form() {
        // every advertised string form parses (with a sensible parameter)
        for info in CATALOG {
            for form in info.string_forms {
                let with_param = if info.params.is_empty() {
                    (*form).to_string()
                } else {
                    format!("{form}:0.5")
                };
                let spec = PolicySpec::parse(&with_param)
                    .unwrap_or_else(|e| panic!("catalog form '{with_param}': {e}"));
                assert_eq!(spec.kind(), info.kind);
            }
        }
        assert!(catalog_json().as_arr().unwrap().len() == CATALOG.len());
    }
}
