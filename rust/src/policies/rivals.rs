//! Rival pruning policies from the leaderboard the paper compares against.
//!
//! Three presses that KVzap's headline claim is measured against (see
//! PAPERS.md and the `kvzap leaderboard` bench):
//!
//! * [`keyformer`] — Keyformer-style key-token sparsification: rank by a
//!   convex mix of accumulated attention ([`Stat::CumAttn`], persistent
//!   "heavy hitters") and peak attention ([`Stat::MaxAttn`], sharply
//!   attended key tokens), per head.
//! * [`FastKvzip`] — Fast-KVzip-style *gated* eviction: a pair is evicted
//!   only when the MLP surrogate **and** the cheap linear surrogate agree
//!   it is prunable, both at prefill and (via [`PrunePolicy::decode_gate`])
//!   during decoding. Agreement gating trades a little recall for far
//!   fewer faithful-answer regressions on disagreement positions.
//! * [`expected_attention_vnorm`] — ExpectedAttention-style budget press:
//!   forecast attention mass ([`Stat::PlusAttn`]) rescaled by the value
//!   norm ([`Stat::VNorm`]), so a pair's rank reflects the magnitude of
//!   its contribution to the attention output, not just its weight.

use super::{protected, Blend, BudgetPolicy, Granularity, PrefillView, PrunePolicy, Stat};
use crate::kvcache::PagedKvCache;
use crate::runtime::kernels::QuantBits;

/// Keyformer-style key-token press: per-head budget over
/// `(1 - mix) * cum_attn + mix * max_attn`.
pub fn keyformer(keep_frac: f64, mix: f64, window: usize) -> BudgetPolicy {
    BudgetPolicy {
        label: format!("keyformer_mix{mix}"),
        stat: Stat::CumAttn,
        keep_frac,
        granularity: Granularity::PerHead,
        window,
        invert: false,
        sinks: 0,
        needs_oracle: false,
        blend: Some((Stat::MaxAttn, Blend::Mix(mix))),
    }
}

/// ExpectedAttention-style press: per-head budget over
/// `plus_attn * vnorm` (predicted attention weight times value magnitude).
pub fn expected_attention_vnorm(keep_frac: f64, window: usize) -> BudgetPolicy {
    BudgetPolicy {
        label: "expected_attn_vnorm".into(),
        stat: Stat::PlusAttn,
        keep_frac,
        granularity: Granularity::PerHead,
        window,
        invert: false,
        sinks: 0,
        needs_oracle: false,
        blend: Some((Stat::VNorm, Blend::Product)),
    }
}

/// Fast-KVzip-style gated threshold press, decode-capable.
///
/// A pair survives prefill if it is window-protected, its MLP surrogate
/// score clears `tau`, *or* its linear surrogate score clears `gate_tau`
/// (eviction needs both surrogates to agree the pair is prunable). During
/// decoding the same rule applies through the engine's gated
/// [`super::ScoreBuffer`] margin: evict iff
/// `mlp < tau && lin < gate_tau` once the pair ages out of the window.
pub struct FastKvzip {
    /// Primary (MLP surrogate) eviction threshold.
    pub tau: f32,
    /// Agreement threshold on the linear surrogate.
    pub gate_tau: f32,
    /// Demotion floor τ_floor ≤ τ on the primary score: evictable pairs
    /// with `mlp ∈ [floor, τ)` demote to the quantized side tier instead
    /// of dropping. `None` = drop-only.
    pub floor: Option<f32>,
    /// Code width of the side tier (only meaningful with a floor).
    pub bits: QuantBits,
    /// Sliding-window size (positions this recent are never evicted).
    pub window: usize,
}

impl PrunePolicy for FastKvzip {
    fn name(&self) -> String {
        let mut n = format!("fastkvzip_tau{}_gate{}", self.tau, self.gate_tau);
        if let Some(fl) = self.floor {
            n.push_str(&format!("_floor{fl}"));
            if self.bits != QuantBits::Int8 {
                n.push_str(&format!("_{}", self.bits.name()));
            }
        }
        n
    }

    fn prefill_prune(&self, view: &PrefillView, prompt_len: usize, cache: &mut PagedKvCache) {
        for l in 0..cache.layers {
            for h in 0..cache.heads {
                let mlp = view.row(Stat::ScoreMlp, l, h);
                let lin = view.row(Stat::ScoreLin, l, h);
                match self.floor {
                    None => cache.retain(l, h, prompt_len, |p| {
                        protected(p, prompt_len, self.window)
                            || mlp[p] >= self.tau
                            || lin[p] >= self.gate_tau
                    }),
                    Some(floor) => {
                        for p in 0..prompt_len {
                            if protected(p, prompt_len, self.window)
                                || mlp[p] >= self.tau
                                || lin[p] >= self.gate_tau
                            {
                                continue;
                            }
                            if mlp[p] >= floor && cache.demote(l, h, p) {
                                continue;
                            }
                            cache.evict(l, h, p);
                        }
                    }
                }
            }
        }
    }

    fn decode_threshold(&self) -> Option<f32> {
        Some(self.tau)
    }

    fn decode_stat(&self) -> Stat {
        Stat::ScoreMlp
    }

    fn decode_gate(&self) -> Option<(Stat, f32)> {
        Some((Stat::ScoreLin, self.gate_tau))
    }

    fn decode_floor(&self) -> Option<f32> {
        self.floor
    }

    fn tier_bits(&self) -> QuantBits {
        self.bits
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Tensor;

    /// View where mlp = position, lin = t - 1 - position (they disagree).
    fn opposed_view(tensors: &(Tensor, Tensor)) -> PrefillView {
        PrefillView {
            b: 0,
            score_lin: &tensors.1,
            score_mlp: &tensors.0,
            max_attn: &tensors.0,
            plus_attn: &tensors.0,
            cum_attn: &tensors.1,
            win_attn: &tensors.0,
            vnorm: &tensors.1,
            knorm: &tensors.0,
            oracle_s: None,
            oracle_s_plus: None,
        }
    }

    fn opposed_tensors(t: usize) -> (Tensor, Tensor) {
        let up: Vec<f32> = (0..t).map(|p| p as f32).collect();
        let down: Vec<f32> = (0..t).map(|p| (t - 1 - p) as f32).collect();
        (
            Tensor::new(up, vec![1, 1, 1, t]).unwrap(),
            Tensor::new(down, vec![1, 1, 1, t]).unwrap(),
        )
    }

    #[test]
    fn fastkvzip_evicts_only_when_both_surrogates_agree() {
        let tensors = opposed_tensors(64);
        let view = opposed_view(&tensors);
        let mut cache = PagedKvCache::new(1, 1, 64);
        cache.fill(48);
        // mlp = p, lin = 63 - p: with tau = 30 and gate = 30, eviction
        // needs p < 30 && 63 - p < 30, i.e. 33 < p < 30 — impossible.
        FastKvzip { tau: 30.0, gate_tau: 30.0, floor: None, bits: QuantBits::Int8, window: 4 }
            .prefill_prune(&view, 48, &mut cache);
        for p in 0..48 {
            assert!(cache.is_kept(0, 0, p), "pos {p} wrongly evicted");
        }
        // raise the gate so the low-mlp prefix loses its second vote
        let mut cache = PagedKvCache::new(1, 1, 64);
        cache.fill(48);
        FastKvzip { tau: 30.0, gate_tau: 1000.0, floor: None, bits: QuantBits::Int8, window: 4 }
            .prefill_prune(&view, 48, &mut cache);
        assert!(!cache.is_kept(0, 0, 10)); // mlp 10 < 30, lin 53 < 1000
        assert!(cache.is_kept(0, 0, 35)); // mlp 35 >= 30
        assert!(cache.is_kept(0, 0, 46)); // window-protected
    }

    #[test]
    fn keyformer_mix_interpolates_between_cum_and_max_attn() {
        let tensors = opposed_tensors(32);
        let view = opposed_view(&tensors);
        // cum_attn descends, max_attn ascends. mix = 0 ranks purely by
        // cum_attn (early positions win); mix = 1 purely by max_attn.
        let mut early = PagedKvCache::new(1, 1, 32);
        early.fill(32);
        keyformer(0.25, 0.0, 0).prefill_prune(&view, 32, &mut early);
        assert!(early.is_kept(0, 0, 0) && !early.is_kept(0, 0, 31));

        let mut late = PagedKvCache::new(1, 1, 32);
        late.fill(32);
        keyformer(0.25, 1.0, 0).prefill_prune(&view, 32, &mut late);
        assert!(!late.is_kept(0, 0, 0) && late.is_kept(0, 0, 31));
    }

    #[test]
    fn expected_attention_vnorm_ranks_by_product() {
        // plus_attn = p, vnorm = t - 1 - p: product peaks mid-sequence.
        let tensors = opposed_tensors(32);
        let view = opposed_view(&tensors);
        let mut cache = PagedKvCache::new(1, 1, 32);
        cache.fill(32);
        expected_attention_vnorm(0.25, 0).prefill_prune(&view, 32, &mut cache);
        assert!(cache.is_kept(0, 0, 15) && cache.is_kept(0, 0, 16)); // peak
        assert!(!cache.is_kept(0, 0, 0) && !cache.is_kept(0, 0, 31)); // ends
    }
}
