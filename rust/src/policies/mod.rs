//! KV cache pruning policies — KVzap and every baseline from Figure 1.
//!
//! A policy consumes the per-position statistics the prefill artifact
//! produces (surrogate scores, oracle scores, cumulative / windowed
//! attention, norms — see model.PREFILL_OUTPUTS) and decides which KV
//! pairs to evict from the [`PagedKvCache`]. Two families:
//!
//! * **Threshold policies** (KVzap, paper §3.3): evict pairs whose
//!   predicted log s+ falls below τ, keep a sliding window of the `w` most
//!   recent tokens, and keep pruning *during decoding* via the
//!   [`ScoreBuffer`] (Algorithm 1's delayed eviction).
//! * **Budget policies** (KVzip, H2O, SnapKV, ...): keep a fixed fraction
//!   of pairs by score rank — per head, per layer (AdaKV), or global
//!   (KVzip). These match the paper's fixed-budget comparisons and the
//!   Fig. 5 (right) threshold-vs-top-k ablation.

pub mod rivals;
pub mod score_buffer;
pub mod spec;

pub use rivals::{expected_attention_vnorm, keyformer, FastKvzip};
pub use score_buffer::ScoreBuffer;
pub use spec::{PolicySpec, Surrogate};

use crate::kvcache::PagedKvCache;
use crate::runtime::kernels::QuantBits;
use crate::runtime::Tensor;
use crate::util::rng::Rng;

/// Host-side view of one sequence's prefill statistics.
///
/// Every tensor is `[L, B, H, t_max]`; `b` selects the sequence.
pub struct PrefillView<'a> {
    pub b: usize,
    pub score_lin: &'a Tensor,
    pub score_mlp: &'a Tensor,
    pub max_attn: &'a Tensor,
    pub plus_attn: &'a Tensor,
    pub cum_attn: &'a Tensor,
    pub win_attn: &'a Tensor,
    pub vnorm: &'a Tensor,
    pub knorm: &'a Tensor,
    /// KVzip oracle scores `[L, 1, H, T]` — present only when the policy
    /// declared `needs_oracle()` (they cost a second, doubled-length pass).
    pub oracle_s: Option<&'a Tensor>,
    pub oracle_s_plus: Option<&'a Tensor>,
}

impl<'a> PrefillView<'a> {
    pub fn row(&self, which: Stat, l: usize, h: usize) -> &'a [f32] {
        // Oracle tensors are fetched per sequence (batch dim 1), while the
        // prefill stats are slot-batched: index them differently.
        let (t, b) = match which {
            Stat::ScoreLin => (self.score_lin, self.b),
            Stat::ScoreMlp => (self.score_mlp, self.b),
            Stat::MaxAttn => (self.max_attn, self.b),
            Stat::PlusAttn => (self.plus_attn, self.b),
            Stat::CumAttn => (self.cum_attn, self.b),
            Stat::WinAttn => (self.win_attn, self.b),
            Stat::VNorm => (self.vnorm, self.b),
            Stat::KNorm => (self.knorm, self.b),
            Stat::OracleS => (self.oracle_s.expect("oracle stats not fetched"), 0),
            Stat::OracleSPlus => {
                (self.oracle_s_plus.expect("oracle stats not fetched"), 0)
            }
        };
        t.row(&[l, b, h])
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stat {
    ScoreLin,
    ScoreMlp,
    MaxAttn,
    PlusAttn,
    CumAttn,
    WinAttn,
    VNorm,
    KNorm,
    OracleS,
    OracleSPlus,
}

/// Decode-step scores for threshold policies: predicted log s+ per (l, h).
pub struct DecodeScores<'a> {
    /// `[L, H]` for this sequence.
    pub scores: &'a [f32],
    pub heads: usize,
}

impl<'a> DecodeScores<'a> {
    pub fn at(&self, l: usize, h: usize) -> f32 {
        self.scores[l * self.heads + h]
    }
}

pub trait PrunePolicy: Send + Sync {
    fn name(&self) -> String;

    /// Apply prefill-time pruning for positions [0, prompt_len).
    fn prefill_prune(&self, view: &PrefillView, prompt_len: usize, cache: &mut PagedKvCache);

    /// Threshold for decode-time pruning (None = no decode pruning, like
    /// the budget baselines / KVzip itself, paper Criterion 2).
    fn decode_threshold(&self) -> Option<f32> {
        None
    }

    /// Which surrogate drives decode-time scores.
    fn decode_stat(&self) -> Stat {
        Stat::ScoreMlp
    }

    /// Secondary decode-time gate: `Some((stat, gate_tau))` makes decode
    /// eviction require *both* the primary score below `decode_threshold`
    /// and the gate stat below `gate_tau` (Fast-KVzip-style agreement
    /// gating). Only the per-step surrogate outputs ([`Stat::ScoreLin`] /
    /// [`Stat::ScoreMlp`]) are available at decode time.
    fn decode_gate(&self) -> Option<(Stat, f32)> {
        None
    }

    /// Demotion floor τ_floor for the quantized side tier. When set,
    /// positions whose score lands in `[floor, decode_threshold)` are
    /// *demoted* (quantized into the side pool, rehydratable) instead of
    /// dropped — only scores below the floor are truly evicted. `None`
    /// (the default) disables the tier: pure drop-at-τ behaviour.
    fn decode_floor(&self) -> Option<f32> {
        None
    }

    /// Code width of the quantized side tier this policy demotes into.
    /// Only consulted when [`PrunePolicy::decode_floor`] is set; narrower
    /// widths trade side-pool bytes for round-trip error. The engine sizes
    /// each sequence's [`crate::kvcache::TierConfig`] from this.
    fn tier_bits(&self) -> QuantBits {
        QuantBits::Int8
    }

    /// Whether the KVzip oracle double-pass must be run for this policy.
    fn needs_oracle(&self) -> bool {
        false
    }
}

/// Sliding-window size shared by all policies (paper w, scaled — see
/// config.py). Positions in [prompt_len - w, prompt_len) are always kept at
/// prefill; during decode the window slides via the ScoreBuffer.
pub fn protected(pos: usize, prompt_len: usize, window: usize) -> bool {
    pos + window >= prompt_len
}

// ---------------------------------------------------------------------------
// Full cache (no pruning)

pub struct NoPress;

impl PrunePolicy for NoPress {
    fn name(&self) -> String {
        "full".into()
    }
    fn prefill_prune(&self, _: &PrefillView, _: usize, _: &mut PagedKvCache) {}
}

// ---------------------------------------------------------------------------
// KVzap (the paper's method): thresholding + sliding window, decode-capable

pub struct KVzap {
    pub mlp: bool,
    pub tau: f32,
    /// Demotion floor τ_floor ≤ τ: scores in `[floor, τ)` demote to the
    /// quantized side tier instead of dropping. `None` = drop-only.
    pub floor: Option<f32>,
    /// Code width of the side tier demoted entries land in (int8 default;
    /// int4/int2 shrink the bytes axis at higher round-trip error).
    pub bits: QuantBits,
    pub window: usize,
}

impl KVzap {
    pub fn linear(tau: f32, window: usize) -> Self {
        KVzap { mlp: false, tau, floor: None, bits: QuantBits::Int8, window }
    }
    pub fn mlp(tau: f32, window: usize) -> Self {
        KVzap { mlp: true, tau, floor: None, bits: QuantBits::Int8, window }
    }
    /// Set (or clear) the demotion floor — builder-style.
    pub fn with_floor(mut self, floor: Option<f32>) -> Self {
        self.floor = floor;
        self
    }
    /// Set the side-tier code width — builder-style.
    pub fn with_bits(mut self, bits: QuantBits) -> Self {
        self.bits = bits;
        self
    }
}

impl PrunePolicy for KVzap {
    fn name(&self) -> String {
        let mut n = format!("kvzap_{}_tau{}", if self.mlp { "mlp" } else { "linear" }, self.tau);
        if let Some(fl) = self.floor {
            n.push_str(&format!("_floor{fl}"));
            if self.bits != QuantBits::Int8 {
                n.push_str(&format!("_{}", self.bits.name()));
            }
        }
        n
    }

    fn prefill_prune(&self, view: &PrefillView, prompt_len: usize, cache: &mut PagedKvCache) {
        let stat = if self.mlp { Stat::ScoreMlp } else { Stat::ScoreLin };
        for l in 0..cache.layers {
            for h in 0..cache.heads {
                let scores = view.row(stat, l, h);
                match self.floor {
                    // drop-only: the original single-threshold retain path
                    None => cache.retain(l, h, prompt_len, |p| {
                        protected(p, prompt_len, self.window) || scores[p] >= self.tau
                    }),
                    // tiered: [floor, τ) demotes (falling back to evict
                    // when the tier is disabled or the side pool is full),
                    // below the floor drops outright
                    Some(floor) => {
                        for p in 0..prompt_len {
                            if protected(p, prompt_len, self.window) || scores[p] >= self.tau {
                                continue;
                            }
                            if scores[p] >= floor && cache.demote(l, h, p) {
                                continue;
                            }
                            cache.evict(l, h, p);
                        }
                    }
                }
            }
        }
    }

    fn decode_threshold(&self) -> Option<f32> {
        Some(self.tau)
    }

    fn decode_stat(&self) -> Stat {
        if self.mlp {
            Stat::ScoreMlp
        } else {
            Stat::ScoreLin
        }
    }

    fn decode_floor(&self) -> Option<f32> {
        self.floor
    }

    fn tier_bits(&self) -> QuantBits {
        self.bits
    }
}

// ---------------------------------------------------------------------------
// Budget-based scoring policies (KVzip oracle + the baseline zoo)

/// How a budget is allocated across heads/layers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Granularity {
    /// Fixed share per head (SnapKV / H2O style).
    PerHead,
    /// Budget pooled within a layer, heads compete (AdaKV).
    PerLayer,
    /// One global pool across layers and heads (KVzip §3.1).
    Global,
}

/// How a secondary statistic is folded into a [`BudgetPolicy`] score.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Blend {
    /// Convex mix: `(1 - f) * base + f * other` (Keyformer's key-token
    /// score blends accumulated and peak attention).
    Mix(f64),
    /// Multiplicative rescale: `base * other` (ExpectedAttention's
    /// value-norm weighting — attention mass times output magnitude).
    Product,
}

/// Generic score-rank budget policy: keep the `keep_frac` highest-scoring
/// pairs at `granularity`, always keeping the protected window.
pub struct BudgetPolicy {
    pub label: String,
    pub stat: Stat,
    /// Fraction of prompt KV pairs to keep (0, 1].
    pub keep_frac: f64,
    pub granularity: Granularity,
    pub window: usize,
    /// Negate scores (keep the *lowest*, e.g. Knorm keeps small ||k||).
    pub invert: bool,
    /// Always keep the first `sink` tokens (StreamingLLM attention sinks).
    pub sinks: usize,
    pub needs_oracle: bool,
    /// Optional second statistic folded into the base score before
    /// ranking (Keyformer mix, ExpectedAttention value-norm product).
    pub blend: Option<(Stat, Blend)>,
}

impl BudgetPolicy {
    fn score(&self, view: &PrefillView, l: usize, h: usize, p: usize) -> f64 {
        let base = view.row(self.stat, l, h)[p] as f64;
        let v = match self.blend {
            None => base,
            Some((stat, Blend::Mix(f))) => {
                (1.0 - f) * base + f * view.row(stat, l, h)[p] as f64
            }
            Some((stat, Blend::Product)) => base * view.row(stat, l, h)[p] as f64,
        };
        if self.invert {
            -v
        } else {
            v
        }
    }
}

impl PrunePolicy for BudgetPolicy {
    fn name(&self) -> String {
        format!("{}_keep{:.2}", self.label, self.keep_frac)
    }

    fn needs_oracle(&self) -> bool {
        self.needs_oracle
    }

    fn prefill_prune(&self, view: &PrefillView, prompt_len: usize, cache: &mut PagedKvCache) {
        let (layers, heads) = (cache.layers, cache.heads);
        let forced = |p: usize| protected(p, prompt_len, self.window) || p < self.sinks;

        match self.granularity {
            Granularity::PerHead => {
                let budget = ((prompt_len as f64) * self.keep_frac).round() as usize;
                for l in 0..layers {
                    for h in 0..heads {
                        let mut ranked: Vec<(usize, f64)> = (0..prompt_len)
                            .filter(|&p| !forced(p))
                            .map(|p| (p, self.score(view, l, h, p)))
                            .collect();
                        ranked.sort_by(|a, b| b.1.total_cmp(&a.1));
                        let n_forced = (0..prompt_len).filter(|&p| forced(p)).count();
                        let quota = budget.saturating_sub(n_forced);
                        let keep: std::collections::HashSet<usize> =
                            ranked.iter().take(quota).map(|&(p, _)| p).collect();
                        cache.retain(l, h, prompt_len, |p| forced(p) || keep.contains(&p));
                    }
                }
            }
            Granularity::PerLayer | Granularity::Global => {
                let pools: Vec<Vec<(usize, usize)>> = match self.granularity {
                    Granularity::PerLayer => (0..layers)
                        .map(|l| (0..heads).map(|h| (l, h)).collect())
                        .collect(),
                    _ => vec![(0..layers)
                        .flat_map(|l| (0..heads).map(move |h| (l, h)))
                        .collect()],
                };
                for pool in pools {
                    let mut ranked: Vec<(usize, usize, usize, f64)> = vec![];
                    let mut n_forced = 0;
                    for &(l, h) in &pool {
                        for p in 0..prompt_len {
                            if forced(p) {
                                n_forced += 1;
                            } else {
                                ranked.push((l, h, p, self.score(view, l, h, p)));
                            }
                        }
                    }
                    let budget =
                        ((pool.len() * prompt_len) as f64 * self.keep_frac).round() as usize;
                    let quota = budget.saturating_sub(n_forced);
                    ranked.sort_by(|a, b| b.3.total_cmp(&a.3));
                    let keep: std::collections::HashSet<(usize, usize, usize)> =
                        ranked.iter().take(quota).map(|&(l, h, p, _)| (l, h, p)).collect();
                    for &(l, h) in &pool {
                        cache.retain(l, h, prompt_len, |p| {
                            forced(p) || keep.contains(&(l, h, p))
                        });
                    }
                }
            }
        }
    }
}

// Named constructors for the baseline zoo ----------------------------------

pub fn kvzip_oracle(keep_frac: f64, window: usize) -> BudgetPolicy {
    BudgetPolicy {
        label: "kvzip".into(),
        stat: Stat::OracleS,
        keep_frac,
        granularity: Granularity::Global,
        window,
        invert: false,
        sinks: 0,
        needs_oracle: true,
        blend: None,
    }
}

pub fn kvzip_plus_oracle(keep_frac: f64, window: usize) -> BudgetPolicy {
    BudgetPolicy {
        label: "kvzip_plus".into(),
        stat: Stat::OracleSPlus,
        keep_frac,
        granularity: Granularity::Global,
        window,
        invert: false,
        sinks: 0,
        needs_oracle: true,
        blend: None,
    }
}

pub fn h2o(keep_frac: f64, window: usize) -> BudgetPolicy {
    BudgetPolicy {
        label: "h2o".into(),
        stat: Stat::CumAttn,
        keep_frac,
        granularity: Granularity::PerHead,
        window,
        invert: false,
        sinks: 0,
        needs_oracle: false,
        blend: None,
    }
}

pub fn snapkv(keep_frac: f64, window: usize) -> BudgetPolicy {
    BudgetPolicy {
        label: "snapkv".into(),
        stat: Stat::WinAttn,
        keep_frac,
        granularity: Granularity::PerHead,
        window,
        invert: false,
        sinks: 0,
        needs_oracle: false,
        blend: None,
    }
}

pub fn adakv(keep_frac: f64, window: usize) -> BudgetPolicy {
    BudgetPolicy {
        label: "adakv".into(),
        stat: Stat::WinAttn,
        keep_frac,
        granularity: Granularity::PerLayer,
        window,
        invert: false,
        sinks: 0,
        needs_oracle: false,
        blend: None,
    }
}

pub fn tova(keep_frac: f64, window: usize) -> BudgetPolicy {
    BudgetPolicy {
        label: "tova".into(),
        stat: Stat::MaxAttn,
        keep_frac,
        granularity: Granularity::PerHead,
        window,
        invert: false,
        sinks: 0,
        needs_oracle: false,
        blend: None,
    }
}

pub fn observed_attention(keep_frac: f64, window: usize) -> BudgetPolicy {
    BudgetPolicy {
        label: "observed_attn".into(),
        stat: Stat::MaxAttn,
        keep_frac,
        granularity: Granularity::Global,
        window,
        invert: false,
        sinks: 0,
        needs_oracle: false,
        blend: None,
    }
}

pub fn expected_attention(keep_frac: f64, window: usize) -> BudgetPolicy {
    BudgetPolicy {
        label: "expected_attn".into(),
        stat: Stat::PlusAttn,
        keep_frac,
        granularity: Granularity::PerHead,
        window,
        invert: false,
        sinks: 0,
        needs_oracle: false,
        blend: None,
    }
}

pub fn knorm(keep_frac: f64, window: usize) -> BudgetPolicy {
    BudgetPolicy {
        label: "knorm".into(),
        stat: Stat::KNorm,
        keep_frac,
        granularity: Granularity::PerHead,
        window,
        invert: true, // keep the smallest key norms
        sinks: 0,
        needs_oracle: false,
        blend: None,
    }
}

/// Fixed-ratio top-k on KVzap surrogate scores — the Fig. 5 (right)
/// threshold-vs-top-k ablation.
pub fn kvzap_topk(mlp: bool, keep_frac: f64, window: usize, per_layer: bool) -> BudgetPolicy {
    BudgetPolicy {
        label: format!(
            "kvzap_{}_top{}",
            if mlp { "mlp" } else { "linear" },
            if per_layer { "layer" } else { "head" }
        ),
        stat: if mlp { Stat::ScoreMlp } else { Stat::ScoreLin },
        keep_frac,
        granularity: if per_layer { Granularity::PerLayer } else { Granularity::PerHead },
        window,
        invert: false,
        sinks: 0,
        needs_oracle: false,
        blend: None,
    }
}

// ---------------------------------------------------------------------------
// StreamingLLM: sinks + recency (no scores at all)

pub struct StreamingLlm {
    pub keep_frac: f64,
    pub sinks: usize,
}

impl PrunePolicy for StreamingLlm {
    fn name(&self) -> String {
        format!("streaming_llm_keep{:.2}", self.keep_frac)
    }

    fn prefill_prune(&self, _view: &PrefillView, prompt_len: usize, cache: &mut PagedKvCache) {
        let budget = ((prompt_len as f64) * self.keep_frac).round() as usize;
        let recent = budget.saturating_sub(self.sinks).max(1);
        let cut = prompt_len.saturating_sub(recent);
        for l in 0..cache.layers {
            for h in 0..cache.heads {
                cache.retain(l, h, prompt_len, |p| p < self.sinks || p >= cut);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Random eviction (sanity-check lower bound)

pub struct RandomPress {
    pub keep_frac: f64,
    pub seed: u64,
    pub window: usize,
}

impl PrunePolicy for RandomPress {
    fn name(&self) -> String {
        format!("random_keep{:.2}", self.keep_frac)
    }

    fn prefill_prune(&self, _view: &PrefillView, prompt_len: usize, cache: &mut PagedKvCache) {
        let mut rng = Rng::new(self.seed);
        for l in 0..cache.layers {
            for h in 0..cache.heads {
                let keep: Vec<bool> =
                    (0..prompt_len).map(|_| rng.f64() < self.keep_frac).collect();
                cache.retain(l, h, prompt_len, |p| {
                    protected(p, prompt_len, self.window) || keep[p]
                });
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Registry used by the CLI / server / benches

/// Instantiate a policy from the compact string form, e.g.
/// "kvzap_mlp:-4.0", "h2o:0.5", "full" — a thin convenience wrapper over
/// [`PolicySpec::parse`] + [`PolicySpec::build`]. New code should carry a
/// typed [`PolicySpec`] instead of a string; this stays for callers that
/// only ever see CLI/bench flag strings.
pub fn by_name(spec: &str, window: usize) -> Option<Box<dyn PrunePolicy>> {
    PolicySpec::parse(spec).ok().map(|s| s.build(window))
}

/// All accepted string-form policy names, derived from [`spec::CATALOG`]
/// so there is a single source of truth (for bench sweeps; rich
/// client-facing introspection is [`spec::CATALOG`] / `kvzap policies`).
pub fn policy_names() -> Vec<&'static str> {
    spec::CATALOG.iter().flat_map(|info| info.string_forms.iter().copied()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_view(t: &Tensor) -> PrefillView {
        PrefillView {
            b: 0,
            score_lin: t,
            score_mlp: t,
            max_attn: t,
            plus_attn: t,
            cum_attn: t,
            win_attn: t,
            vnorm: t,
            knorm: t,
            oracle_s: Some(t),
            oracle_s_plus: Some(t),
        }
    }

    fn ramp_tensor(l: usize, h: usize, t: usize) -> Tensor {
        // score = position index (later positions score higher)
        let mut data = vec![0.0; l * h * t];
        for li in 0..l {
            for hi in 0..h {
                for p in 0..t {
                    data[(li * h + hi) * t + p] = p as f32;
                }
            }
        }
        Tensor::new(data, vec![l, 1, h, t]).unwrap()
    }

    #[test]
    fn kvzap_threshold_respects_window() {
        let t = ramp_tensor(2, 2, 64);
        let view = fake_view(&t);
        let mut cache = PagedKvCache::new(2, 2, 64);
        cache.fill(50);
        KVzap::mlp(40.0, 8).prefill_prune(&view, 50, &mut cache);
        // scores 0..40 evicted except protected window [42, 50)
        assert!(!cache.is_kept(0, 0, 10));
        assert!(cache.is_kept(0, 0, 45)); // window
        assert!(cache.is_kept(0, 0, 44)); // score 44 >= 40
        assert!(!cache.is_kept(1, 1, 39));
    }

    #[test]
    fn budget_policy_hits_budget() {
        let t = ramp_tensor(2, 2, 64);
        let view = fake_view(&t);
        for gran in [Granularity::PerHead, Granularity::PerLayer, Granularity::Global] {
            let mut cache = PagedKvCache::new(2, 2, 64);
            cache.fill(60);
            let pol = BudgetPolicy {
                label: "test".into(),
                stat: Stat::ScoreMlp,
                keep_frac: 0.5,
                granularity: gran,
                window: 4,
                invert: false,
                sinks: 0,
                needs_oracle: false,
                blend: None,
            };
            pol.prefill_prune(&view, 60, &mut cache);
            let s = cache.stats();
            let frac = s.kept as f64 / s.filled as f64;
            assert!((frac - 0.5).abs() < 0.05, "{gran:?}: kept frac {frac}");
        }
    }

    #[test]
    fn streaming_llm_keeps_sinks_and_recency() {
        let t = ramp_tensor(1, 1, 128);
        let view = fake_view(&t);
        let mut cache = PagedKvCache::new(1, 1, 128);
        cache.fill(100);
        StreamingLlm { keep_frac: 0.3, sinks: 4 }.prefill_prune(&view, 100, &mut cache);
        assert!(cache.is_kept(0, 0, 0) && cache.is_kept(0, 0, 3)); // sinks
        assert!(cache.is_kept(0, 0, 99)); // recent
        assert!(!cache.is_kept(0, 0, 50)); // middle dropped
    }

    #[test]
    fn registry_instantiates_all() {
        let names = policy_names();
        assert!(names.len() >= 21, "catalog lost string forms: {names:?}");
        for name in names {
            let spec = if name == "full" { name.to_string() } else { format!("{name}:0.5") };
            assert!(by_name(&spec, 16).is_some(), "{name}");
        }
        assert!(by_name("nope", 16).is_none());
    }

    #[test]
    fn inverted_budget_keeps_lowest() {
        let t = ramp_tensor(1, 1, 32);
        let view = fake_view(&t);
        let mut cache = PagedKvCache::new(1, 1, 32);
        cache.fill(32);
        knorm(0.25, 0).prefill_prune(&view, 32, &mut cache);
        assert!(cache.is_kept(0, 0, 0)); // smallest score kept
        assert!(!cache.is_kept(0, 0, 31));
    }
}
