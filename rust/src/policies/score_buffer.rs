//! Decode-time delayed eviction (paper Algorithm 1, decoding case).
//!
//! During decoding every new token is provisionally kept; its predicted
//! log s+ enters this buffer. Once a position falls out of the sliding
//! window of the `window` most recent tokens, the deferred decision is
//! applied: evict iff its score is below τ. This is exactly the DMS-style
//! "delayed eviction with a sliding window" the paper adopts (§3.3) — the
//! window also seeds from the tail of the prompt at prefill time so the
//! window semantics are continuous across the phase boundary.

use crate::kvcache::PagedKvCache;

#[derive(Clone)]
pub struct ScoreBuffer {
    window: usize,
    layers: usize,
    heads: usize,
    /// Ring of (position, scores[l*heads+h]) entries, oldest first.
    ring: std::collections::VecDeque<(usize, Vec<f32>)>,
}

impl ScoreBuffer {
    pub fn new(window: usize, layers: usize, heads: usize) -> ScoreBuffer {
        ScoreBuffer { window, layers, heads, ring: Default::default() }
    }

    /// Seed from the prompt tail: positions [prompt_len - window,
    /// prompt_len) with their prefill surrogate scores; `score(l, h, pos)`.
    pub fn seed_from_prefill(
        &mut self,
        prompt_len: usize,
        score: impl Fn(usize, usize, usize) -> f32,
    ) {
        let start = prompt_len.saturating_sub(self.window);
        for pos in start..prompt_len {
            let mut v = Vec::with_capacity(self.layers * self.heads);
            for l in 0..self.layers {
                for h in 0..self.heads {
                    v.push(score(l, h, pos));
                }
            }
            self.ring.push_back((pos, v));
        }
    }

    /// Push the new position's scores; apply the deferred eviction for any
    /// position that just left the window. Returns the number of kept ->
    /// evicted transitions; positions already gone (e.g. pruned at prefill
    /// when the policy window is narrower than this ring) don't count.
    pub fn push_and_evict(
        &mut self,
        pos: usize,
        scores: Vec<f32>,
        tau: f32,
        cache: &mut PagedKvCache,
    ) -> usize {
        self.push_and_evict_tiered(pos, scores, tau, None, cache).0
    }

    /// [`Self::push_and_evict`] with a demotion floor: an exiting position
    /// whose score lands in `[floor, tau)` is *demoted* into the cache's
    /// quantized side tier (when the cache accepts it — tier enabled and
    /// side pool not exhausted; otherwise it falls back to eviction)
    /// instead of dropped. Only scores below the floor drop outright.
    ///
    /// Returns `(evicted, demoted)` where `demoted` lists
    /// `(l, h, pos, score)` for every kept -> demoted transition, so the
    /// engine can mirror each one (score bookkeeping for rehydration, host
    /// snapshot round-trip, backend `kv_demote`).
    pub fn push_and_evict_tiered(
        &mut self,
        pos: usize,
        scores: Vec<f32>,
        tau: f32,
        floor: Option<f32>,
        cache: &mut PagedKvCache,
    ) -> (usize, Vec<(usize, usize, usize, f32)>) {
        debug_assert_eq!(scores.len(), self.layers * self.heads);
        self.ring.push_back((pos, scores));
        let mut evicted = 0;
        let mut demoted = vec![];
        while self.ring.len() > self.window {
            let (old_pos, old_scores) = self.ring.pop_front().unwrap();
            for l in 0..self.layers {
                for h in 0..self.heads {
                    let s = old_scores[l * self.heads + h];
                    if s >= tau {
                        continue;
                    }
                    if let Some(fl) = floor {
                        if s >= fl && cache.demote(l, h, old_pos) {
                            demoted.push((l, h, old_pos, s));
                            continue;
                        }
                    }
                    if cache.evict(l, h, old_pos) {
                        evicted += 1;
                    }
                }
            }
        }
        (evicted, demoted)
    }

    pub fn len(&self) -> usize {
        self.ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delayed_eviction_waits_for_window_exit() {
        let mut cache = PagedKvCache::new(1, 1, 64);
        cache.fill(8);
        let mut buf = ScoreBuffer::new(4, 1, 1);
        // Positions 8..16 decode with low scores; eviction must lag by 4.
        for pos in 8..16 {
            cache.fill(pos + 1);
            let n = buf.push_and_evict(pos, vec![-10.0], -5.0, &mut cache);
            if pos < 12 {
                assert_eq!(n, 0, "still inside window at {pos}");
            } else {
                assert_eq!(n, 1);
                assert!(!cache.is_kept(0, 0, pos - 4));
            }
            assert!(cache.is_kept(0, 0, pos), "current token always kept");
        }
    }

    #[test]
    fn high_scores_survive_window_exit() {
        let mut cache = PagedKvCache::new(1, 1, 64);
        cache.fill(1);
        let mut buf = ScoreBuffer::new(2, 1, 1);
        for pos in 1..8 {
            cache.fill(pos + 1);
            buf.push_and_evict(pos, vec![3.0], -5.0, &mut cache);
        }
        for pos in 0..8 {
            assert!(cache.is_kept(0, 0, pos));
        }
    }

    /// Regression: a position pruned at *prefill* (policy window narrower
    /// than the engine ring, so the ring still carries it) must not bump
    /// the eviction count when its deferred decision fires — the simharness
    /// cache-accounting invariant consumes these counts.
    #[test]
    fn already_evicted_positions_do_not_recount() {
        let mut cache = PagedKvCache::new(1, 1, 64);
        cache.fill(10);
        let mut buf = ScoreBuffer::new(4, 1, 1);
        buf.seed_from_prefill(10, |_, _, _| -9.0); // everything below τ
        // prefill pruning already removed position 6 (pre-pruned prompt)
        assert!(cache.evict(0, 0, 6));
        let kept_before = cache.kept_in_head(0, 0);

        // one decode step pushes position 6 out of the window: its score
        // is below τ but it is already gone — count must stay 0
        cache.fill(11);
        let n = buf.push_and_evict(10, vec![1.0], -5.0, &mut cache);
        assert_eq!(n, 0, "already-evicted position must not be re-counted");
        assert_eq!(cache.kept_in_head(0, 0), kept_before);

        // the next exit (position 7, still kept) counts exactly once
        cache.fill(12);
        let n = buf.push_and_evict(11, vec![1.0], -5.0, &mut cache);
        assert_eq!(n, 1);
        assert!(!cache.is_kept(0, 0, 7));
    }

    /// Tiered window exit sorts each expelled position into its tier:
    /// below the floor drops, `[floor, τ)` demotes, `>= τ` stays kept.
    #[test]
    fn tiered_window_exit_splits_drop_demote_keep() {
        use crate::kvcache::TierConfig;
        use crate::runtime::kernels::QuantBits;
        let tier = TierConfig { d_head: 8, bits: QuantBits::Int8, group: 8 };
        let mut cache = PagedKvCache::new_tiered(1, 1, 64, tier);
        cache.fill(4);
        let (tau, floor) = (-4.0, Some(-8.0));
        let mut buf = ScoreBuffer::new(1, 1, 1);
        // window 1: each push expels the previous position's decision
        let (e, d) = buf.push_and_evict_tiered(0, vec![-10.0], tau, floor, &mut cache);
        assert_eq!((e, d.len()), (0, 0), "first entry still inside the window");
        // expels pos 0 (score -10, below the floor) -> dropped
        let (e, d) = buf.push_and_evict_tiered(1, vec![-6.0], tau, floor, &mut cache);
        assert_eq!((e, d.len()), (1, 0));
        // expels pos 1 (score -6, inside [floor, tau)) -> demoted
        let (e, d) = buf.push_and_evict_tiered(2, vec![-2.0], tau, floor, &mut cache);
        assert_eq!((e, d.len()), (0, 1));
        assert_eq!(d[0], (0, 0, 1, -6.0));
        // expels pos 2 (score -2 >= tau) -> kept
        let (e, d) = buf.push_and_evict_tiered(3, vec![1.0], tau, floor, &mut cache);
        assert_eq!((e, d.len()), (0, 0));
        assert!(!cache.is_kept(0, 0, 0) && !cache.is_demoted(0, 0, 0));
        assert!(cache.is_demoted(0, 0, 1) && !cache.is_kept(0, 0, 1));
        assert!(cache.is_kept(0, 0, 2) && cache.is_kept(0, 0, 3));
        cache.accounting_ok().unwrap();
    }

    #[test]
    fn seed_from_prefill_joins_phases() {
        let mut cache = PagedKvCache::new(1, 1, 64);
        cache.fill(10);
        let mut buf = ScoreBuffer::new(4, 1, 1);
        // prompt tail scores: position 6 low, others high
        buf.seed_from_prefill(10, |_, _, pos| if pos == 6 { -9.0 } else { 1.0 });
        assert_eq!(buf.len(), 4);
        // two decode steps push 6 out of the window -> it gets evicted
        cache.fill(11);
        buf.push_and_evict(10, vec![1.0], -5.0, &mut cache);
        assert!(!cache.is_kept(0, 0, 6));
        assert!(cache.is_kept(0, 0, 7));
    }
}
