//! Serving metrics: latency histograms + throughput/compression counters.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::histogram::Histogram;

#[derive(Default)]
pub struct EngineMetrics {
    /// Prefill latency (µs per batch).
    pub prefill: Mutex<Histogram>,
    /// Oracle (KVzip double-pass) latency (µs) — baseline policies only.
    pub oracle: Mutex<Histogram>,
    /// Per decode step latency (µs).
    pub decode_step: Mutex<Histogram>,
    /// End-to-end request latency (µs), recorded by the batcher.
    pub e2e: Mutex<Histogram>,
    pub requests: AtomicU64,
    pub tokens_out: AtomicU64,
    /// Sum of per-request compression ratios ×1e6 (for a cheap mean).
    compression_micro: AtomicU64,
}

impl EngineMetrics {
    pub fn note_request(&self, tokens: usize, compression: f64) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.tokens_out.fetch_add(tokens as u64, Ordering::Relaxed);
        self.compression_micro
            .fetch_add((compression.max(0.0) * 1e6) as u64, Ordering::Relaxed);
    }

    pub fn mean_compression(&self) -> f64 {
        let n = self.requests.load(Ordering::Relaxed);
        if n == 0 {
            0.0
        } else {
            self.compression_micro.load(Ordering::Relaxed) as f64 / 1e6 / n as f64
        }
    }

    pub fn report(&self) -> String {
        format!(
            "requests={} tokens_out={} mean_compression={:.3}\n  prefill {}\n  decode_step {}\n  e2e {}",
            self.requests.load(Ordering::Relaxed),
            self.tokens_out.load(Ordering::Relaxed),
            self.mean_compression(),
            self.prefill.lock().unwrap().summary("us"),
            self.decode_step.lock().unwrap().summary("us"),
            self.e2e.lock().unwrap().summary("us"),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_accounting() {
        let m = EngineMetrics::default();
        m.note_request(10, 0.5);
        m.note_request(20, 0.7);
        assert_eq!(m.requests.load(Ordering::Relaxed), 2);
        assert_eq!(m.tokens_out.load(Ordering::Relaxed), 30);
        assert!((m.mean_compression() - 0.6).abs() < 1e-6);
    }
}
