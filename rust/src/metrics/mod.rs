//! Serving metrics: latency histograms, throughput/compression counters,
//! and host↔device transfer accounting.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::histogram::Histogram;

/// Host↔device transfer accounting, maintained by the `Runtime` facade so
/// both backends are measured identically: each op is charged its
/// *logical contract* bytes (a `kv_fetch_row` is one `[L, H, D]` row, a
/// `kv_write_mask` one slot mask), which is exactly what the reference
/// backend physically moves. The KV-specific counters isolate cache
/// traffic from model I/O (tokens in, logits out) — the device-resident
/// decode path is the difference between `kv_bytes_up/down` staying flat
/// and growing by the full dense cache every step. Caveat: the interim
/// PJRT implementation physically moves more than the contract on two ops
/// (whole-cache shadow sync behind row fetches, per-step mask re-upload —
/// see runtime/pjrt.rs module docs); those extras are not yet counted, so
/// on that backend the counters are a lower bound until the decode
/// artifact grows mask-state/row-gather outputs.
#[derive(Default)]
pub struct TransferCounters {
    /// KV rows + keep-masks scattered into backend-owned group caches.
    pub kv_bytes_up: AtomicU64,
    /// KV rows/slots gathered from group caches back to the host.
    pub kv_bytes_down: AtomicU64,
    /// Per-slot keep-mask update ops (joins + post-eviction refreshes).
    pub mask_uploads: AtomicU64,
    /// All host→device bytes (tokens, caches, masks, …).
    pub bytes_up: AtomicU64,
    /// All device→host bytes (fetched outputs, gathered KV).
    pub bytes_down: AtomicU64,
    /// Resident decode-step executions.
    pub decode_steps: AtomicU64,
    /// Demote ops into the quantized side tier (device-local: these move
    /// no host↔device bytes, so they are counted apart from the bytes_*
    /// totals; the bytes they *store* accrue in `tier_bytes_stored`).
    pub demotes: AtomicU64,
    /// Rehydrate ops out of the quantized side tier (device-local).
    pub rehydrates: AtomicU64,
    /// Cumulative quantized bytes written into side pools by demote ops.
    pub tier_bytes_stored: AtomicU64,
    /// Cumulative quantized bytes freed by rehydrate/drop ops.
    pub tier_bytes_freed: AtomicU64,
    /// Side-tier rows attended *in place* (dequantize-in-register) by the
    /// quantized decode path. Device-local like demotes/rehydrates: these
    /// rows cost compute, not host↔device transfer, so they never touch
    /// the `bytes_*` totals.
    pub quant_attend_rows: AtomicU64,
    /// Quantized payload bytes read by quant-attended rows (rows × the
    /// side tier's per-entry footprint).
    pub quant_attend_bytes: AtomicU64,
}

impl TransferCounters {
    pub fn add_up(&self, bytes: u64) {
        self.bytes_up.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn add_down(&self, bytes: u64) {
        self.bytes_down.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn add_kv_up(&self, bytes: u64) {
        self.kv_bytes_up.fetch_add(bytes, Ordering::Relaxed);
        self.bytes_up.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn add_kv_down(&self, bytes: u64) {
        self.kv_bytes_down.fetch_add(bytes, Ordering::Relaxed);
        self.bytes_down.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Record one demote op storing `bytes` of quantized payload.
    pub fn note_demote(&self, bytes: u64) {
        self.demotes.fetch_add(1, Ordering::Relaxed);
        self.tier_bytes_stored.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Record a fused band demotion of `n` entries storing `bytes` of
    /// payload in total — counter-equivalent to `n` single
    /// [`TransferCounters::note_demote`] calls, so exact-replay models
    /// never see a difference between the fused and per-entry paths.
    pub fn note_demote_band(&self, n: u64, bytes: u64) {
        self.demotes.fetch_add(n, Ordering::Relaxed);
        self.tier_bytes_stored.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Record one rehydrate (or drop) op freeing `bytes` of payload.
    pub fn note_rehydrate(&self, bytes: u64) {
        self.rehydrates.fetch_add(1, Ordering::Relaxed);
        self.tier_bytes_freed.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Record side-tier rows attended in place by a quantized decode step.
    pub fn note_quant_attend(&self, rows: u64, bytes: u64) {
        self.quant_attend_rows.fetch_add(rows, Ordering::Relaxed);
        self.quant_attend_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> TransferSnapshot {
        TransferSnapshot {
            kv_bytes_up: self.kv_bytes_up.load(Ordering::Relaxed),
            kv_bytes_down: self.kv_bytes_down.load(Ordering::Relaxed),
            mask_uploads: self.mask_uploads.load(Ordering::Relaxed),
            bytes_up: self.bytes_up.load(Ordering::Relaxed),
            bytes_down: self.bytes_down.load(Ordering::Relaxed),
            decode_steps: self.decode_steps.load(Ordering::Relaxed),
            demotes: self.demotes.load(Ordering::Relaxed),
            rehydrates: self.rehydrates.load(Ordering::Relaxed),
            tier_bytes_stored: self.tier_bytes_stored.load(Ordering::Relaxed),
            tier_bytes_freed: self.tier_bytes_freed.load(Ordering::Relaxed),
            quant_attend_rows: self.quant_attend_rows.load(Ordering::Relaxed),
            quant_attend_bytes: self.quant_attend_bytes.load(Ordering::Relaxed),
        }
    }
}

/// A consistent-enough point-in-time copy of [`TransferCounters`] (tests
/// diff two snapshots around a region of interest).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TransferSnapshot {
    pub kv_bytes_up: u64,
    pub kv_bytes_down: u64,
    pub mask_uploads: u64,
    pub bytes_up: u64,
    pub bytes_down: u64,
    pub decode_steps: u64,
    pub demotes: u64,
    pub rehydrates: u64,
    pub tier_bytes_stored: u64,
    pub tier_bytes_freed: u64,
    pub quant_attend_rows: u64,
    pub quant_attend_bytes: u64,
}

#[derive(Default)]
pub struct EngineMetrics {
    /// Prefill latency (µs per batch).
    pub prefill: Mutex<Histogram>,
    /// Oracle (KVzip double-pass) latency (µs) — baseline policies only.
    pub oracle: Mutex<Histogram>,
    /// Per decode step latency (µs).
    pub decode_step: Mutex<Histogram>,
    /// KV bytes uploaded per decode step (joins + mask refreshes; zero in
    /// steady state with the resident cache).
    pub step_kv_up: Mutex<Histogram>,
    /// KV bytes fetched per decode step (one decoded row per sequence).
    pub step_kv_down: Mutex<Histogram>,
    /// End-to-end request latency (µs), recorded by the batcher.
    pub e2e: Mutex<Histogram>,
    pub requests: AtomicU64,
    pub tokens_out: AtomicU64,
    /// Prefix-cache hits: admissions that installed a cached prefill
    /// snapshot instead of executing the prefill bucket.
    pub prefix_hits: AtomicU64,
    /// Prefix-cache misses: admissions that ran a fresh prefill with reuse
    /// enabled (a snapshot was captured and inserted for later requests).
    pub prefix_misses: AtomicU64,
    /// Prefix-cache snapshots this engine's inserts evicted to make room
    /// under the shared cache's bytes budget.
    pub prefix_evictions: AtomicU64,
    /// Prefix-cache inserts by this engine that lost a key race (another
    /// shard deposited the snapshot first; ours was discarded).
    pub prefix_insert_races: AtomicU64,
    /// Prefix-cache inserts refused because the snapshot could not fit
    /// the bytes budget even after evicting every cold entry.
    pub prefix_insert_rejects: AtomicU64,
    /// Side-tier rows attended in place (no rehydrate) across all decode
    /// steps — the steady-state *compute* footprint of the demoted tier.
    pub quant_attend_rows: AtomicU64,
    /// Quantized payload bytes read by those in-place attends.
    pub quant_attend_bytes: AtomicU64,
    /// Sum of per-request compression ratios ×1e6 (for a cheap mean).
    compression_micro: AtomicU64,
}

impl EngineMetrics {
    pub fn note_request(&self, tokens: usize, compression: f64) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.tokens_out.fetch_add(tokens as u64, Ordering::Relaxed);
        self.compression_micro
            .fetch_add((compression.max(0.0) * 1e6) as u64, Ordering::Relaxed);
    }

    pub fn mean_compression(&self) -> f64 {
        let n = self.requests.load(Ordering::Relaxed);
        if n == 0 {
            0.0
        } else {
            self.compression_micro.load(Ordering::Relaxed) as f64 / 1e6 / n as f64
        }
    }

    /// Record side-tier rows a decode step attended without rehydration.
    pub fn note_quant_attend(&self, rows: u64, bytes: u64) {
        self.quant_attend_rows.fetch_add(rows, Ordering::Relaxed);
        self.quant_attend_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Record a prefix-cache hit (snapshot installed, prefill skipped).
    pub fn note_prefix_hit(&self) {
        self.prefix_hits.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a prefix-cache miss (fresh prefill, snapshot captured).
    pub fn note_prefix_miss(&self) {
        self.prefix_misses.fetch_add(1, Ordering::Relaxed);
    }

    /// Record what one prefix-cache insert did (eviction/race/reject
    /// attribution for the engine whose admission performed it).
    pub fn note_prefix_insert(&self, evicted: u64, raced: bool, rejected: bool) {
        self.prefix_evictions.fetch_add(evicted, Ordering::Relaxed);
        if raced {
            self.prefix_insert_races.fetch_add(1, Ordering::Relaxed);
        }
        if rejected {
            self.prefix_insert_rejects.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn report(&self) -> String {
        format!(
            "requests={} tokens_out={} mean_compression={:.3} prefix_hits={} prefix_misses={} prefix_evictions={} prefix_insert_races={} prefix_insert_rejects={} quant_attend_rows={} quant_attend_bytes={}\n  prefill {}\n  decode_step {}\n  step_kv_up {}\n  step_kv_down {}\n  e2e {}",
            self.requests.load(Ordering::Relaxed),
            self.tokens_out.load(Ordering::Relaxed),
            self.mean_compression(),
            self.prefix_hits.load(Ordering::Relaxed),
            self.prefix_misses.load(Ordering::Relaxed),
            self.prefix_evictions.load(Ordering::Relaxed),
            self.prefix_insert_races.load(Ordering::Relaxed),
            self.prefix_insert_rejects.load(Ordering::Relaxed),
            self.quant_attend_rows.load(Ordering::Relaxed),
            self.quant_attend_bytes.load(Ordering::Relaxed),
            self.prefill.lock().unwrap().summary("us"),
            self.decode_step.lock().unwrap().summary("us"),
            self.step_kv_up.lock().unwrap().summary("B"),
            self.step_kv_down.lock().unwrap().summary("B"),
            self.e2e.lock().unwrap().summary("us"),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_accounting() {
        let m = EngineMetrics::default();
        m.note_request(10, 0.5);
        m.note_request(20, 0.7);
        assert_eq!(m.requests.load(Ordering::Relaxed), 2);
        assert_eq!(m.tokens_out.load(Ordering::Relaxed), 30);
        assert!((m.mean_compression() - 0.6).abs() < 1e-6);
    }

    #[test]
    fn transfer_counters_roll_up() {
        let t = TransferCounters::default();
        t.add_up(10);
        t.add_down(20);
        t.add_kv_up(100);
        t.add_kv_down(200);
        t.mask_uploads.fetch_add(1, Ordering::Relaxed);
        t.note_quant_attend(7, 70);
        let s = t.snapshot();
        assert_eq!(s.kv_bytes_up, 100);
        assert_eq!(s.kv_bytes_down, 200);
        assert_eq!(s.bytes_up, 110, "kv uploads count toward the total");
        assert_eq!(s.bytes_down, 220);
        assert_eq!(s.mask_uploads, 1);
        assert_eq!(s.quant_attend_rows, 7);
        assert_eq!(s.quant_attend_bytes, 70);
        assert_eq!(s.bytes_up, 110, "quant attends are device-local");
    }
}
