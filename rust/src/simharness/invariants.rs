//! The invariant registry: what must hold after every scheduler step.
//!
//! The driver condenses each step into a [`StepObs`] — per-sequence cache
//! snapshots, the decode group's slot table, and the *predicted vs
//! observed* transfer-counter deltas (the prediction replays the
//! device-resident KV protocol from PR 3: join = full-slot scatter + mask,
//! eviction = one mask refresh, steady state = row fetch only). Every
//! [`Invariant`] in [`registry`] then checks one property; the first
//! failure aborts the run with a [`Violation`] naming the invariant, the
//! step, and the detail — which the CLI turns into a replay line.

use std::collections::{HashMap, HashSet};
use std::fmt;

use crate::coordinator::router::{Dispatch, Rebalance, Skip};

/// One invariant failure: enough to reproduce (`step` within the scenario)
/// and to triage (`invariant` name + detail).
#[derive(Debug, Clone)]
pub struct Violation {
    /// Simulation step at which the invariant fired (== scenario step for
    /// per-step invariants; the post-hoc faithfulness check reports the
    /// scenario's final step).
    pub step: usize,
    /// Registry name of the failed invariant.
    pub invariant: &'static str,
    /// Human-readable specifics.
    pub detail: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "step {}: [{}] {}", self.step, self.invariant, self.detail)
    }
}

/// Transfer-counter movement over one decode step (subset of
/// [`crate::metrics::TransferSnapshot`] the resident-KV contract pins).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct TransferDelta {
    /// KV + mask bytes scattered into the group cache.
    pub kv_bytes_up: u64,
    /// KV bytes fetched back to host (decoded rows).
    pub kv_bytes_down: u64,
    /// Per-slot mask installs (joins + vacates + eviction refreshes).
    pub mask_uploads: u64,
    /// Resident decode executions.
    pub decode_steps: u64,
    /// Demoted side-tier rows attended in place (quantized, device-local
    /// — they never contribute to the byte counters above).
    pub quant_attend_rows: u64,
    /// Quantized bytes those in-place attends read.
    pub quant_attend_bytes: u64,
}

/// Post-step snapshot of one slot-resident sequence's cache accounting.
#[derive(Debug, Clone)]
pub struct SeqCheck {
    /// Request id.
    pub id: u64,
    /// Sequence identity nonce (matches the group slot table).
    pub uid: u64,
    /// Next decode position.
    pub pos: usize,
    /// Filled cache length.
    pub len: usize,
    /// Cache capacity.
    pub t_max: usize,
    /// layers × kv-heads (filled must equal `len * lh`).
    pub lh: usize,
    /// Kept KV pairs per the incremental counters.
    pub kept: usize,
    /// Filled KV pairs.
    pub filled: usize,
    /// Removed fraction.
    pub compression: f64,
    /// Kept KV pairs per the dense mask (`mask_f32` recount).
    pub mask_on: usize,
    /// Kept KV pairs per the per-head counters (`kept_in_head` sum).
    pub head_sum: usize,
    /// For threshold policies: whether the protected window (the last
    /// `w` filled positions) is fully kept in every head. None for
    /// policies without the window guarantee.
    pub window_ok: Option<bool>,
    /// KV pairs currently demoted to the quantized side tier.
    pub demoted: usize,
    /// Side-tier bytes currently charged for those entries.
    pub side_bytes: usize,
    /// Demoted positions the engine's rehydration ledger tracks (must
    /// equal `demoted`, or rebound rehydration silently leaks entries).
    pub tracked_demoted: usize,
    /// Demoted entries inside the protected window (last `w` filled
    /// positions). Must be 0: demotion never targets the window, and the
    /// re-entry backstop rehydrates anything the window grows over.
    pub demoted_in_window: usize,
    /// Full bitset/counter/pool recount ([`accounting_ok`]'s error, if
    /// any) — kept, demoted, resident-block and byte accounting all
    /// balance after every step.
    ///
    /// [`accounting_ok`]: crate::kvcache::PagedKvCache::accounting_ok
    pub accounting_err: Option<String>,
    /// Cumulative side entries this sequence's decode steps attended in
    /// place (quantized, no rehydrate) per the cache telemetry.
    pub quant_attended_rows: usize,
    /// Cumulative quantized bytes those in-place attends read.
    pub quant_attended_bytes: usize,
    /// Side-tier bytes one demoted entry costs at this cache's code width.
    pub tier_bpe: usize,
    /// Tier flow over this step for decode-active sequences:
    /// `(demoted_before, demotions, rehydrations)`. `None` when the
    /// sequence did not decode this step.
    pub step_flow: Option<(usize, usize, usize)>,
}

/// Post-prefill budget accounting for one newly-admitted budget policy.
#[derive(Debug, Clone)]
pub struct BudgetCheck {
    /// Request id.
    pub id: u64,
    /// Policy display name.
    pub policy: String,
    /// Requested keep fraction.
    pub keep_frac: f64,
    /// Achieved keep fraction right after prefill pruning.
    pub kept_frac: f64,
    /// Tolerance: window protection + rank ties ((w + 2) / n + 0.05).
    pub slack: f64,
}

/// Everything the harness observed around one scheduler step.
#[derive(Debug, Clone)]
pub struct StepObs {
    /// Simulation step index.
    pub step: usize,
    /// Post-decode snapshots of every slot-resident sequence.
    pub seqs: Vec<SeqCheck>,
    /// Budget checks for sequences admitted this step.
    pub budgets: Vec<BudgetCheck>,
    /// Every uid the scheduler has ever held a sequence for, up to and
    /// including this step. Slot-table entries may lag reaping (a finished
    /// sequence keeps its slot until a later step vacates it), so the
    /// conservation check is against this set, not just the live set.
    pub known_uids: Vec<u64>,
    /// The decode group's slot table after the step (0 = vacant).
    pub residents: Vec<u64>,
    /// The decode group's slot capacity after the step.
    pub capacity: usize,
    /// Predicted transfer-counter movement for this step.
    pub expected: TransferDelta,
    /// Observed transfer-counter movement for this step.
    pub actual: TransferDelta,
}

/// One checkable property over a [`StepObs`].
pub trait Invariant {
    /// Stable registry name (printed in violations).
    fn name(&self) -> &'static str;
    /// Err(detail) when the invariant fails.
    fn check(&self, obs: &StepObs) -> Result<(), String>;
}

/// Slot-table conservation: resident uids are distinct, fit the capacity,
/// and every one names a sequence the scheduler still holds.
struct SlotConservation;

impl Invariant for SlotConservation {
    fn name(&self) -> &'static str {
        "slot-conservation"
    }

    fn check(&self, obs: &StepObs) -> Result<(), String> {
        if obs.residents.len() != obs.capacity {
            return Err(format!(
                "slot table has {} entries but capacity is {}",
                obs.residents.len(),
                obs.capacity
            ));
        }
        let occupied: Vec<u64> =
            obs.residents.iter().copied().filter(|&u| u != 0).collect();
        for (i, u) in occupied.iter().enumerate() {
            if occupied[..i].contains(u) {
                return Err(format!("uid {u} occupies two slots"));
            }
            if !obs.known_uids.contains(u) {
                return Err(format!("slot holds uid {u}, which no scheduled sequence ever had"));
            }
        }
        Ok(())
    }
}

/// Per-sequence cache accounting balances: the incremental counters, the
/// per-head counters and the dense mask all agree, and the aggregate
/// stats are internally consistent.
struct CacheAccounting;

impl Invariant for CacheAccounting {
    fn name(&self) -> &'static str {
        "cache-accounting"
    }

    fn check(&self, obs: &StepObs) -> Result<(), String> {
        for s in &obs.seqs {
            if s.mask_on != s.kept {
                return Err(format!(
                    "seq {}: mask recount {} != kept counter {}",
                    s.id, s.mask_on, s.kept
                ));
            }
            if s.head_sum != s.kept {
                return Err(format!(
                    "seq {}: per-head sum {} != kept counter {}",
                    s.id, s.head_sum, s.kept
                ));
            }
            if s.kept > s.filled {
                return Err(format!("seq {}: kept {} > filled {}", s.id, s.kept, s.filled));
            }
            if s.filled != s.len * s.lh {
                return Err(format!(
                    "seq {}: filled {} != len {} x heads {}",
                    s.id, s.filled, s.len, s.lh
                ));
            }
            if s.len != s.pos.min(s.t_max) {
                return Err(format!(
                    "seq {}: cache len {} != min(pos {}, t_max {})",
                    s.id, s.len, s.pos, s.t_max
                ));
            }
            if !(0.0..=1.0).contains(&s.compression) {
                return Err(format!("seq {}: compression {} outside [0, 1]", s.id, s.compression));
            }
        }
        Ok(())
    }
}

/// Transfer accounting matches the row-only steady-state contract: the
/// observed counter deltas equal the protocol replay's prediction.
struct TransferAccounting;

impl Invariant for TransferAccounting {
    fn name(&self) -> &'static str {
        "transfer-accounting"
    }

    fn check(&self, obs: &StepObs) -> Result<(), String> {
        if obs.expected != obs.actual {
            return Err(format!(
                "expected {:?}, observed {:?} (joins/evictions/steady-state replay disagrees \
                 with the engine's actual KV traffic)",
                obs.expected, obs.actual
            ));
        }
        Ok(())
    }
}

/// Threshold policies never evict the sliding window of the `w` most
/// recent filled positions.
struct WindowProtection;

impl Invariant for WindowProtection {
    fn name(&self) -> &'static str {
        "window-protection"
    }

    fn check(&self, obs: &StepObs) -> Result<(), String> {
        for s in &obs.seqs {
            if s.window_ok == Some(false) {
                return Err(format!(
                    "seq {}: a position inside the protected window was evicted (len {})",
                    s.id, s.len
                ));
            }
        }
        Ok(())
    }
}

/// The quantized side tier stays conserved: the cache's own recount
/// balances, the engine's rehydration ledger tracks exactly the demoted
/// set, tier membership is disjoint (kept + demoted ≤ filled), no demoted
/// entry sits inside the protected window, the quant-attend telemetry is
/// internally consistent, and per-step tier flow balances — rehydration
/// counters only move on promotion (demoted_before + demotions ==
/// demoted_after + rehydrations), never as a side effect of a quantized
/// in-place attend. (That attends charge no resident transfer bytes is
/// pinned by [`TransferAccounting`]: the predicted byte deltas exclude
/// quant-attended rows entirely.)
struct TierConservation;

impl Invariant for TierConservation {
    fn name(&self) -> &'static str {
        "tier-conservation"
    }

    fn check(&self, obs: &StepObs) -> Result<(), String> {
        for s in &obs.seqs {
            if let Some(e) = &s.accounting_err {
                return Err(format!("seq {}: cache accounting broken: {e}", s.id));
            }
            if s.tracked_demoted != s.demoted {
                return Err(format!(
                    "seq {}: engine ledger tracks {} demoted entries but the cache holds {}",
                    s.id, s.tracked_demoted, s.demoted
                ));
            }
            if s.kept + s.demoted > s.filled {
                return Err(format!(
                    "seq {}: kept {} + demoted {} > filled {}",
                    s.id, s.kept, s.demoted, s.filled
                ));
            }
            if s.demoted == 0 && s.side_bytes != 0 {
                return Err(format!(
                    "seq {}: {} side bytes charged with nothing demoted",
                    s.id, s.side_bytes
                ));
            }
            if s.demoted_in_window > 0 {
                return Err(format!(
                    "seq {}: {} demoted entries inside the protected window \
                     (re-entry backstop failed to rehydrate)",
                    s.id, s.demoted_in_window
                ));
            }
            if s.quant_attended_bytes != s.quant_attended_rows * s.tier_bpe {
                return Err(format!(
                    "seq {}: {} quant-attended bytes != {} rows x {} bytes/entry",
                    s.id, s.quant_attended_bytes, s.quant_attended_rows, s.tier_bpe
                ));
            }
            if let Some((before, dem, reh)) = s.step_flow {
                if before + dem != s.demoted + reh {
                    return Err(format!(
                        "seq {}: tier flow broken: {before} demoted before + {dem} \
                         demotions != {} demoted after + {reh} rehydrations \
                         (rehydration counters may only move on promotion)",
                        s.id, s.demoted
                    ));
                }
            }
        }
        Ok(())
    }
}

/// Budget policies land on their keep fraction (± window slack) at
/// prefill time.
struct BudgetRespect;

impl Invariant for BudgetRespect {
    fn name(&self) -> &'static str {
        "budget-respect"
    }

    fn check(&self, obs: &StepObs) -> Result<(), String> {
        for b in &obs.budgets {
            if (b.kept_frac - b.keep_frac).abs() > b.slack {
                return Err(format!(
                    "seq {} ({}): kept {:.3} vs budget {:.3} (slack {:.3})",
                    b.id, b.policy, b.kept_frac, b.keep_frac, b.slack
                ));
            }
        }
        Ok(())
    }
}

/// The full registry, in check order.
pub fn registry() -> Vec<Box<dyn Invariant>> {
    vec![
        Box::new(SlotConservation),
        Box::new(CacheAccounting),
        Box::new(TierConservation),
        Box::new(TransferAccounting),
        Box::new(WindowProtection),
        Box::new(BudgetRespect),
    ]
}

// ---------------------------------------------------------------------------
// Router-layer invariants (sharded runs only). These operate on router
// observations rather than [`StepObs`], so they are standalone checks the
// sharded driver calls once per step; violations carry the names
// "placement-stability", "tenant-fairness" and "prefix-accounting". The
// fourth router-layer property — shard-count output invariance — is
// metamorphic (it compares whole runs, not steps) and lives in
// [`crate::simharness::driver::shard_traces_match`] /
// [`crate::simharness::driver::reuse_traces_match`].

/// One prefix-cache admission observation: the hit flag the scheduler
/// reported vs the hit the harness's own replay of the cache protocol
/// (a key hits iff an earlier admission inserted it) predicted.
#[derive(Debug, Clone)]
pub struct PrefixEvent {
    /// Request id of the admission.
    pub id: u64,
    /// Hit flag the scheduler recorded for this admission.
    pub observed_hit: bool,
    /// Hit the harness's protocol replay predicted.
    pub predicted_hit: bool,
}

/// Placement stability: between two observations of the placement table,
/// every moved key must be explained by a chain of recorded
/// [`Rebalance`]s (a placement that changes with no recorded cause is a
/// routing defect — or the injected `PhantomMisroute`). Keys may appear
/// (first placements) but never vanish.
pub fn check_placement_stability(
    prev: &HashMap<u64, usize>,
    cur: &HashMap<u64, usize>,
    new_rebalances: &[Rebalance],
) -> Result<(), String> {
    for (k, &was) in prev {
        let Some(&now) = cur.get(k) else {
            return Err(format!("placement for key {k:#018x} vanished from the table"));
        };
        // walk this key's recorded moves; they must chain from `was`
        let mut at = was;
        for r in new_rebalances.iter().filter(|r| r.key_hash == *k) {
            if r.from != at {
                return Err(format!(
                    "rebalance log for key {k:#018x} does not chain: record moves \
                     {} -> {} ({}) but the key was on shard {at}",
                    r.from, r.to, r.cause
                ));
            }
            at = r.to;
        }
        if at != now {
            return Err(format!(
                "placement for key {k:#018x} moved {was} -> {now} but the recorded \
                 rebalances only explain {was} -> {at}"
            ));
        }
    }
    Ok(())
}

/// Tenant fairness bounds for one pump: (a) round-robin — no tenant
/// dispatches twice in the same round; (b) no silent starvation — a
/// tenant still backlogged after the pump must have a recorded [`Skip`]
/// naming the cause that blocked it.
pub fn check_tenant_fairness(
    dispatches: &[Dispatch],
    skips: &[Skip],
    queued: &[String],
) -> Result<(), String> {
    let mut seen: HashSet<(u64, &str)> = HashSet::new();
    for d in dispatches {
        if !seen.insert((d.round, d.tenant.as_str())) {
            return Err(format!(
                "tenant '{}' dispatched twice in pump round {}",
                d.tenant, d.round
            ));
        }
    }
    for t in queued {
        if !skips.iter().any(|s| &s.tenant == t) {
            return Err(format!(
                "tenant '{t}' is still backlogged after the pump with no recorded skip cause"
            ));
        }
    }
    Ok(())
}

/// Prefix-hit accounting for one step: every admission's hit flag must
/// match the harness's protocol replay, and the engines' hit/miss counter
/// movement must equal the flags — a counter that moves without a
/// matching admission (the injected `PhantomPrefixHit`) or an admission
/// whose flag contradicts the replay is an accounting defect.
///
/// `bounded` relaxes the replay comparison to one-sided: under a finite
/// prefix-cache budget the harness replay (which never evicts) predicts
/// hits for keys the real cache may have evicted or refused, so a
/// predicted-hit/observed-miss disagreement is legitimate there. An
/// observed hit the replay cannot explain is a defect in either mode —
/// eviction only ever removes keys, it cannot invent them. The
/// counter-movement equality is budget-independent and stays exact.
pub fn check_prefix_accounting(
    events: &[PrefixEvent],
    hits_delta: u64,
    misses_delta: u64,
    bounded: bool,
) -> Result<(), String> {
    for e in events {
        if e.observed_hit && !e.predicted_hit {
            return Err(format!(
                "request {}: scheduler reported a prefix hit but the cache-protocol \
                 replay never saw that key inserted",
                e.id
            ));
        }
        if !bounded && !e.observed_hit && e.predicted_hit {
            return Err(format!(
                "request {}: scheduler reported prefix hit=false but the cache-protocol \
                 replay predicts hit=true (unbounded cache: nothing may evict)",
                e.id
            ));
        }
    }
    let flag_hits = events.iter().filter(|e| e.observed_hit).count() as u64;
    let flag_misses = events.len() as u64 - flag_hits;
    if hits_delta != flag_hits || misses_delta != flag_misses {
        return Err(format!(
            "prefix counters moved by {hits_delta} hits / {misses_delta} misses but the \
             step's admissions account for {flag_hits} / {flag_misses} \
             (a hit was counted without a snapshot install, or vice versa)"
        ));
    }
    Ok(())
}

/// One KV-pool observation for one shard at one step: what the pool's own
/// counter says is charged, the budget it was configured with, and an
/// independent recount of the same quantity summed over the shard's live
/// sequences (resident blocks plus demoted side bytes for a unified pool;
/// side bytes alone for a side-only pool). Built by the driver from
/// [`crate::coordinator::Engine::kv_pools`] and per-sequence
/// [`crate::kvcache::PagedKvCache::charged_bytes`].
#[derive(Debug, Clone)]
pub struct PoolCheck {
    /// Shard index (0 in solo runs).
    pub shard: usize,
    /// Which pool this observes: "unified" or "side".
    pub kind: &'static str,
    /// `pool.used()` — bytes the pool believes are charged right now.
    pub pool_used: usize,
    /// `pool.total()` — the configured budget.
    pub budget: usize,
    /// Independent recount over the shard's live sequences.
    pub recount: usize,
    /// `pool.over_released()` — always 0 in a correct system.
    pub over_released: usize,
}

/// Pool-budget invariant for one shard at one step: the pool never
/// over-releases, never charges past its configured budget, and its
/// counter agrees with an independent per-sequence recount (a leak —
/// bytes charged for a sequence the engine no longer tracks — or a
/// phantom credit both surface as a counter/recount split).
pub fn check_pool_budget(p: &PoolCheck) -> Result<(), String> {
    if p.over_released > 0 {
        return Err(format!(
            "shard {} {} pool over-released {} bytes (double-free upstream)",
            p.shard, p.kind, p.over_released
        ));
    }
    if p.pool_used > p.budget {
        return Err(format!(
            "shard {} {} pool charges {} bytes against a budget of {}",
            p.shard, p.kind, p.pool_used, p.budget
        ));
    }
    if p.pool_used != p.recount {
        return Err(format!(
            "shard {} {} pool says {} bytes charged but live sequences account for {} \
             (leak or phantom credit)",
            p.shard, p.kind, p.pool_used, p.recount
        ));
    }
    Ok(())
}
