//! Deterministic multi-client simulation harness with invariant checking.
//!
//! The paper's claim — pruning "with negligible accuracy loss" — must hold
//! under *serving* conditions: adversarial interleavings of join / leave /
//! cancel / evict that no hand-written test enumerates. This module is a
//! seeded scenario fuzzer over the full request path:
//!
//! ```text
//! v2 request parse (server) → SchedCore (continuous batcher core)
//!     → Engine sessions (prefill / shared decode_step) → policies
//!     → PagedKvCache → backend KvHandle (device-resident KV)
//! ```
//!
//! * [`ScenarioSpec::generate`] derives a whole episode from one seed:
//!   clients with staggered joins, bucket-crossing prompt lengths from the
//!   workload generators, threshold/budget policy mixes, mid-decode
//!   cancels and disconnects.
//! * [`run_scenario`] drives it one discrete step at a time and checks the
//!   invariant [`registry`] after every step: slot conservation, cache
//!   accounting balance, the row-only transfer contract (including the
//!   quant-attend counters — every live side entry is attended in place,
//!   charging zero transfer bytes), tier-flow conservation, window
//!   protection, budget respect — then metamorphic faithfulness (solo
//!   replay) at the end.
//! * [`thread_traces_match`] re-runs a scenario at different thread counts
//!   and requires bit-identical traces (the determinism rule every
//!   backend must satisfy — docs/TESTING.md).
//! * [`simulate`] adds the shrink pass: a violation is minimized via
//!   [`crate::util::propcheck::minimize`] and reported with a single
//!   replay line (`kvzap simulate --seed S --steps K ...`).
//!
//! Every run is bitwise reproducible at a fixed seed and thread count;
//! scenarios run hermetically on the reference backend (tier-1 rule).

pub mod driver;
pub mod invariants;
pub mod scenario;

pub use driver::{
    replay_line, replay_opts, run_scenario, shrink_spec, simulate, thread_traces_match,
    ClientOutcome, Fault, SimFailure, SimOptions, SimReport, SimSummary, SimTrace,
};
pub use invariants::{registry, StepObs, TransferDelta, Violation};
pub use scenario::{ClientScript, ScenarioSpec};
