//! Deterministic multi-client simulation harness with invariant checking.
//!
//! The paper's claim — pruning "with negligible accuracy loss" — must hold
//! under *serving* conditions: adversarial interleavings of join / leave /
//! cancel / evict that no hand-written test enumerates. This module is a
//! seeded scenario fuzzer over the full request path:
//!
//! ```text
//! v2 request parse (server) → SchedCore (continuous batcher core)
//!     → Engine sessions (prefill / shared decode_step) → policies
//!     → PagedKvCache → backend KvHandle (device-resident KV)
//! ```
//!
//! * [`ScenarioSpec::generate`] derives a whole episode from one seed:
//!   clients with staggered joins, bucket-crossing prompt lengths from the
//!   workload generators, threshold/budget policy mixes, mid-decode
//!   cancels and disconnects.
//! * [`run_scenario`] drives it one discrete step at a time and checks the
//!   invariant [`registry`] after every step: slot conservation, cache
//!   accounting balance, the row-only transfer contract (including the
//!   quant-attend counters — every live side entry is attended in place,
//!   charging zero transfer bytes), tier-flow conservation, window
//!   protection, budget respect — then metamorphic faithfulness (solo
//!   replay) at the end.
//! * [`thread_traces_match`] re-runs a scenario at different thread counts
//!   and requires bit-identical traces (the determinism rule every
//!   backend must satisfy — docs/TESTING.md).
//! * `--shards N` runs the same episode through the router/shard layer
//!   ([`crate::coordinator::ShardPool`]) and adds its invariants —
//!   placement-stability, tenant-fairness, prefix-accounting — plus the
//!   shard-invariance metamorphic family: [`shard_traces_match`]
//!   (outputs identical at any shard count) and [`reuse_traces_match`]
//!   (outputs identical with the prefix cache on and off).
//! * `--kv-budget` / `--side-budget` attach per-engine admission pools
//!   and check the pool-budget invariant every step: charged bytes never
//!   exceed the budget, never over-release, and always equal an
//!   independent recount over live sequences. `--prefix-budget` bounds
//!   the shared prefix cache the same way (evictions under pressure,
//!   one-sided hit accounting).
//! * [`simulate`] adds the shrink pass: a violation is minimized via
//!   [`crate::util::propcheck::minimize`] and reported with a single
//!   replay line (`kvzap simulate --seed S --steps K ...`).
//!
//! Every run is bitwise reproducible at a fixed seed and thread count;
//! scenarios run hermetically on the reference backend (tier-1 rule).

pub mod driver;
pub mod invariants;
pub mod scenario;

pub use driver::{
    replay_line, replay_opts, reuse_traces_match, run_scenario, shard_traces_match,
    shrink_spec, simulate, thread_traces_match, ClientOutcome, Fault, SimFailure,
    SimOptions, SimReport, SimSummary, SimTrace,
};
pub use invariants::{
    check_placement_stability, check_pool_budget, check_prefix_accounting,
    check_tenant_fairness, registry, PoolCheck, PrefixEvent, StepObs, TransferDelta,
    Violation,
};
pub use scenario::{ClientScript, ScenarioSpec};
