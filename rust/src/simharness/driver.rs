//! The deterministic discrete-step simulator.
//!
//! [`run_scenario`] replays one [`ScenarioSpec`] against a fresh engine on
//! the reference backend, driving the same [`SchedCore`] the production
//! batcher thread runs — but one observable phase at a time (submit/cancel
//! intake → admission → pre-decode observation → shared decode step →
//! invariant checks → event drain → reap). Requests enter through the
//! server's v2 parse path ([`crate::server::parse_request`]), so the
//! protocol surface (string and structured policy forms, sampling fields,
//! ids) is exercised on every run.
//!
//! After every step the [`super::invariants::registry`] checks the
//! [`StepObs`]; the first violation stops the run. When the scenario
//! completes, each client's token stream is optionally replayed solo
//! (metamorphic faithfulness: co-tenants must never change a sequence's
//! tokens), and [`thread_traces_match`] re-runs whole scenarios at
//! different `KVZAP_THREADS` settings to pin bitwise thread invariance.
//! [`simulate`] wraps a run with the shrink pass
//! ([`crate::util::propcheck::minimize`] over [`shrink_spec`]) so a
//! failure is reported as a minimal scenario plus a one-line replay.
//!
//! With `--shards N` (or prefix reuse, or a router-layer fault) the run
//! goes through [`run_pool`] instead: N engines behind a [`ShardPool`],
//! the same per-shard checks, plus the router-layer invariants
//! (placement-stability, tenant-fairness, prefix-accounting) and the
//! shard-invariance metamorphic family ([`shard_traces_match`] /
//! [`reuse_traces_match`]).

use std::collections::{HashMap, HashSet};
use std::sync::atomic::Ordering;
use std::sync::mpsc::{self, Receiver, TryRecvError};
use std::sync::Arc;

use crate::coordinator::{
    BatcherConfig, Engine, Request, RouterConfig, SchedCore, SeqEvent, Sequence, ShardPool,
    StepEvent,
};
use crate::kvcache::{BlockPool, KvPools};
use crate::metrics::TransferSnapshot;
use crate::policies::PolicySpec;
use crate::runtime::{ParallelConfig, Runtime};
use crate::server::{self, ParsedRequest};
use crate::util::propcheck;

use super::invariants::{
    check_placement_stability, check_pool_budget, check_prefix_accounting,
    check_tenant_fairness, registry, BudgetCheck, PoolCheck, PrefixEvent, SeqCheck, StepObs,
    TransferDelta, Violation,
};
use super::scenario::ScenarioSpec;

/// How to run a scenario (orthogonal to the scenario itself).
#[derive(Debug, Clone)]
pub struct SimOptions {
    /// Reference-backend thread count: None = environment default,
    /// Some(1) = the scalar oracle path, Some(n) = blocked parallel.
    pub threads: Option<usize>,
    /// Replay every client solo after the run and require identical token
    /// streams (metamorphic faithfulness).
    pub check_solo: bool,
    /// Test-only mutation switch: inject an accounting bug so the
    /// invariant registry can prove it catches one.
    pub fault: Option<Fault>,
    /// Cache capacity for the run's engine.
    pub t_max: usize,
    /// Engine workers behind the router. 1 = the classic single-core
    /// path; >1 routes through a [`ShardPool`] (one engine + resident
    /// cache per shard) and adds the router-layer checks.
    pub shards: usize,
    /// Attach a shared cross-request prefix cache. Forces the pool path
    /// even at one shard so the reuse machinery is always exercised.
    pub prefix_reuse: bool,
    /// Bytes budget for the shared prefix cache (`None` → unbounded).
    /// A finite budget relaxes prefix-hit accounting to one-sided: the
    /// harness's protocol replay never evicts, so it can only rule out
    /// hits the real cache reports for keys no insert ever deposited.
    pub prefix_budget: Option<usize>,
    /// Unified KV admission pool: one bytes budget *per shard engine*,
    /// charged by resident blocks (at f32 width) and demoted side bytes
    /// alike. Adds the pool-budget invariant per shard per step. Use with
    /// `check_solo: false` — solo replays run on the scripted engines, so
    /// their sequences would contend for the already-charged budget.
    pub kv_budget: Option<usize>,
    /// Split-mode side-tier pool: a bytes budget per shard engine charged
    /// by demotions only (residency stays uncharged, so prefill admission
    /// can never fail). Ignored when `kv_budget` is set; same
    /// `check_solo` caveat.
    pub side_budget: Option<usize>,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions {
            threads: None,
            check_solo: true,
            fault: None,
            t_max: 512,
            shards: 1,
            prefix_reuse: false,
            prefix_budget: None,
            kv_budget: None,
            side_budget: None,
        }
    }
}

/// Deliberate accounting bugs for the mutation self-check: each models a
/// class of real defect the registry must catch.
#[derive(Debug, Clone, Copy)]
pub enum Fault {
    /// Perform one hidden KV row fetch at the given step — an unaccounted
    /// transfer, as a backend bug that moves more than the contract would
    /// produce. Caught by the transfer-accounting invariant.
    PhantomRowFetch {
        /// Simulation step at which to inject the rogue fetch.
        step: usize,
    },
    /// Bump the quant-attend counters at the given step without any
    /// backend work — a backend that reports in-place quantized attends
    /// it never served. Caught by the transfer-accounting invariant's
    /// quant fields (predicted rows come from the pre-step demoted sets).
    PhantomQuantAttend {
        /// Simulation step at which to inject the rogue counter bump.
        step: usize,
    },
    /// Count a prefix-cache hit at the given step without any snapshot
    /// install — a scheduler whose hit counter runs ahead of the installs
    /// it claims. Caught by the prefix-accounting check (the step's
    /// counter movement no longer matches its admissions).
    PhantomPrefixHit {
        /// Simulation step at which to inject the rogue hit count.
        step: usize,
    },
    /// Silently move one placement record at the given step without a
    /// recorded [`crate::coordinator::Rebalance`] — a router that forgets
    /// a move. Caught by the placement-stability check. Never fires at a
    /// single shard (every move is a no-op there).
    PhantomMisroute {
        /// Simulation step at which to inject the silent move.
        step: usize,
    },
}

/// What one scripted client ended up with.
#[derive(Debug, Clone, PartialEq)]
pub struct ClientOutcome {
    /// Accepted token ids, in order.
    pub tokens: Vec<i32>,
    /// Concatenated token texts.
    pub text: String,
    /// Whether a final Done event arrived.
    pub done: bool,
    /// Done reason ("stop" | "max_tokens" | "cache_full" | "cancelled").
    pub reason: Option<String>,
    /// Transport/build error, if any.
    pub error: Option<String>,
    /// Reported tokens_out from the Done event.
    pub tokens_out: Option<usize>,
    /// Final compression as raw f64 bits (exact comparison across runs).
    pub compression_bits: Option<u64>,
}

impl ClientOutcome {
    fn new() -> ClientOutcome {
        ClientOutcome {
            tokens: vec![],
            text: String::new(),
            done: false,
            reason: None,
            error: None,
            tokens_out: None,
            compression_bits: None,
        }
    }
}

/// The bit-comparable record of one run: per-client outcomes plus the
/// engine's final transfer counters (taken before any solo replays).
#[derive(Debug, Clone, PartialEq)]
pub struct SimTrace {
    /// One outcome per scripted client, in client order.
    pub clients: Vec<ClientOutcome>,
    /// Transfer counters at the end of the scripted steps.
    pub transfer: TransferSnapshot,
}

/// Result of one [`run_scenario`] call.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// The run's trace (partial if a violation stopped it early).
    pub trace: SimTrace,
    /// First invariant violation, if any.
    pub violation: Option<Violation>,
    /// Steps actually executed.
    pub steps_run: usize,
    /// Whether a configured [`Fault`] actually performed its injection
    /// (false when no fault was configured, or when its step had no KV
    /// group to act on — the caller must not read a clean run as a passed
    /// mutation check in that case).
    pub fault_injected: bool,
    /// Prefix-cache hits summed over all engines (0 without reuse).
    pub prefix_hits: u64,
    /// Prefix-cache misses summed over all engines (0 without reuse).
    pub prefix_misses: u64,
    /// Budget-pressure evictions the shared prefix cache performed
    /// (0 without reuse or without a `prefix_budget`).
    pub prefix_evictions: u64,
    /// Snapshot bytes the shared prefix cache held at the end of the run.
    pub prefix_bytes: u64,
    /// Pressure-driven demotion refusals summed over every sequence the
    /// harness observed alive at a step boundary (sequences that finish
    /// within their admission step can slip under this count).
    pub demote_refusals: u64,
    /// High-water mark of charged bytes, summed over each shard's
    /// byte-denominated KV pool (the unified pool under `kv_budget`, the
    /// side pool under `side_budget`; 0 when neither is set). Probe runs
    /// read this to size a bounding budget for a rerun.
    pub kv_pool_peak: u64,
}

struct ClientState {
    rx: Option<Receiver<SeqEvent>>,
    outcome: ClientOutcome,
    submitted: bool,
}

/// Run one scenario to completion (or first violation). Deterministic:
/// the same spec and options produce the same [`SimTrace`] bit for bit.
pub fn run_scenario(spec: &ScenarioSpec, opts: &SimOptions) -> SimReport {
    let mk_engine = || {
        let pcfg = match opts.threads {
            None => ParallelConfig::from_env(),
            Some(1) => ParallelConfig::scalar(),
            Some(n) => ParallelConfig::with_threads(n),
        };
        Arc::new(Engine::new(Arc::new(Runtime::reference_with_options(opts.t_max, pcfg))))
    };
    // The pool path is needed for real sharding, for prefix reuse, and
    // for the router-layer faults; everything else keeps the untouched
    // single-core path.
    let pooled = opts.shards > 1
        || opts.prefix_reuse
        || matches!(
            opts.fault,
            Some(Fault::PhantomPrefixHit { .. }) | Some(Fault::PhantomMisroute { .. })
        );
    if pooled {
        let engines = (0..opts.shards.max(1)).map(|_| mk_engine()).collect();
        run_pool(engines, spec, opts)
    } else {
        run_on(mk_engine(), spec, opts)
    }
}

fn run_on(engine: Arc<Engine>, spec: &ScenarioSpec, opts: &SimOptions) -> SimReport {
    let (layers, heads, t_max, d_head) = {
        let m = &engine.rt.manifest.model;
        (m.n_layers, m.n_kv_heads, m.t_max, m.d_head)
    };
    let decode_buckets = engine.rt.manifest.buckets.decode_b.clone();
    let window = engine.window();
    let invariants = registry();
    if let Some(pools) = kv_pools_of(opts) {
        engine.set_kv_pools(Some(pools));
    }

    let mut core = SchedCore::new(
        engine.clone(),
        BatcherConfig { max_batch: spec.max_batch, max_wait_us: 0 },
    );
    let mut states: Vec<ClientState> = spec
        .clients
        .iter()
        .map(|_| ClientState { rx: None, outcome: ClientOutcome::new(), submitted: false })
        .collect();
    // id -> parsed request (policy/sampling for checks and solo replays)
    let mut subs: HashMap<u64, ParsedRequest> = HashMap::new();
    // every uid the scheduler ever held (slot entries may lag reaping)
    let mut known_uids: HashSet<u64> = HashSet::new();
    // cumulative (decode_demotions, decode_rehydrations) per uid, for the
    // per-step tier-flow conservation check
    let mut flow_prev: HashMap<u64, (usize, usize)> = HashMap::new();
    // latest cumulative demote-refusal count per uid (caches die with
    // their sequences, so the last step-boundary observation is kept)
    let mut refusals: HashMap<u64, usize> = HashMap::new();

    let mut violation: Option<Violation> = None;
    let mut fault_injected = false;
    let mut steps_run = 0;
    for t in 0..spec.steps {
        steps_run = t + 1;
        // ---- scripted client actions ----------------------------------
        for (i, c) in spec.clients.iter().enumerate() {
            let id = (i + 1) as u64;
            if c.join_step == t && !states[i].submitted {
                states[i].submitted = true;
                let line = c.request_json(id).dump();
                match server::parse_request(&line, "full") {
                    Ok(preq) => {
                        let (tx, rx) = mpsc::channel();
                        core.submit(
                            id,
                            Request {
                                prompt: preq.prompt.clone(),
                                policy: preq.policy.clone(),
                                sp: preq.sp.clone(),
                                stream: true,
                                events: tx,
                            },
                        );
                        states[i].rx = Some(rx);
                        subs.insert(id, preq);
                    }
                    Err(e) => {
                        violation = Some(Violation {
                            step: t,
                            invariant: "protocol",
                            detail: format!("client {id}: request rejected: {e:#}"),
                        });
                    }
                }
            }
            if c.cancel_step == Some(t) {
                core.cancel(id);
            }
            if c.drop_step == Some(t) {
                states[i].rx = None; // simulated disconnect
            }
        }
        if violation.is_some() {
            break;
        }

        // ---- admission + budget observation ---------------------------
        let admitted = core.admit_waiting();
        let mut budgets: Vec<BudgetCheck> = vec![];
        for (id, seq) in core.live() {
            if !admitted.contains(&id) {
                continue;
            }
            let frac = match subs.get(&id).map(|p| &p.policy).and_then(budget_of) {
                Some(f) => f,
                None => continue,
            };
            let st = seq.cache_stats();
            let n = seq.prompt_len().max(1);
            budgets.push(BudgetCheck {
                id,
                policy: subs[&id].policy.to_string(),
                keep_frac: frac,
                kept_frac: st.kept as f64 / st.filled.max(1) as f64,
                slack: (window as f64 + 2.0) / n as f64 + 0.05,
            });
        }
        core.reap_finished();

        // ---- pre-decode protocol replay (transfer prediction) ---------
        let residents_before: Vec<u64> = core
            .group()
            .resident_uids()
            .iter()
            .copied()
            .filter(|&u| u != 0)
            .collect();
        let capacity_before = core.group().capacity();
        let mut active_uids: Vec<u64> = vec![];
        let mut dirty_uids: HashSet<u64> = HashSet::new();
        // demoted counts per active uid before the step: exactly the side
        // entries the quantized decode path will attend in place (the
        // engine's rehydration scan and fresh demotions both run *after*
        // the exec), so these predict the step's quant-attend counters
        let mut demoted_before: HashMap<u64, usize> = HashMap::new();
        let mut q_rows = 0u64;
        let mut q_bytes = 0u64;
        for (_id, seq) in core.live() {
            if seq.position() < t_max {
                active_uids.push(seq.uid());
                if seq.cache().is_dirty() {
                    dirty_uids.insert(seq.uid());
                }
                let demoted = seq.cache_stats().demoted;
                demoted_before.insert(seq.uid(), demoted);
                q_rows += demoted as u64;
                q_bytes += (demoted * seq.cache().tier().bytes_per_entry()) as u64;
            }
        }
        let expected = predict_transfer(
            &active_uids,
            &dirty_uids,
            &residents_before,
            capacity_before,
            &decode_buckets,
            (layers, heads, t_max, d_head),
            (q_rows, q_bytes),
        );
        let before = engine.rt.transfer.snapshot();

        // ---- the shared decode step -----------------------------------
        if let Err(e) = core.decode_once() {
            violation = Some(Violation {
                step: t,
                invariant: "engine-error",
                detail: format!("{e:#}"),
            });
            break;
        }
        match opts.fault {
            Some(Fault::PhantomRowFetch { step }) if step == t => {
                if let Some(h) = core.group().kv_handle() {
                    let mut k = vec![0.0f32; h.row_elems()];
                    let mut v = vec![0.0f32; h.row_elems()];
                    let _ = engine.rt.kv_fetch_row(h, 0, 0, &mut k, &mut v);
                    fault_injected = true;
                }
            }
            Some(Fault::PhantomQuantAttend { step }) if step == t => {
                engine.rt.transfer.note_quant_attend(1, 64);
                fault_injected = true;
            }
            _ => {}
        }
        let after = engine.rt.transfer.snapshot();
        let actual = TransferDelta {
            kv_bytes_up: after.kv_bytes_up - before.kv_bytes_up,
            kv_bytes_down: after.kv_bytes_down - before.kv_bytes_down,
            mask_uploads: after.mask_uploads - before.mask_uploads,
            decode_steps: after.decode_steps - before.decode_steps,
            quant_attend_rows: after.quant_attend_rows - before.quant_attend_rows,
            quant_attend_bytes: after.quant_attend_bytes - before.quant_attend_bytes,
        };

        // ---- invariant checks -----------------------------------------
        let mut seqs: Vec<SeqCheck> = vec![];
        for (id, seq) in core.live() {
            let (pd, pr) = flow_prev.get(&seq.uid()).copied().unwrap_or((0, 0));
            // tier flow for seqs that decoded this step: demoted-before
            // plus the step's demotion/rehydration counter movement
            let step_flow = demoted_before.get(&seq.uid()).map(|&before| {
                (before, seq.decode_demotions - pd, seq.decode_rehydrations - pr)
            });
            flow_prev
                .insert(seq.uid(), (seq.decode_demotions, seq.decode_rehydrations));
            refusals.insert(seq.uid(), seq.cache().demote_refusals());
            seqs.push(seq_check(
                id,
                seq,
                subs.get(&id).map(|p| &p.policy),
                window,
                layers,
                heads,
                step_flow,
            ));
        }
        known_uids.extend(core.live().map(|(_, s)| s.uid()));
        let obs = StepObs {
            step: t,
            seqs,
            budgets,
            known_uids: known_uids.iter().copied().collect(),
            residents: core.group().resident_uids().to_vec(),
            capacity: core.group().capacity(),
            expected,
            actual,
        };
        for inv in &invariants {
            if let Err(detail) = inv.check(&obs) {
                violation = Some(Violation { step: t, invariant: inv.name(), detail });
                break;
            }
        }
        if violation.is_some() {
            break;
        }

        // ---- event drain + reap ---------------------------------------
        core.reap_finished();

        // ---- pool-budget invariant (post-reap: only live sequences may
        // hold charges, so the recount is exact here) -------------------
        for pc in pool_checks(0, &engine, &core) {
            if let Err(detail) = check_pool_budget(&pc) {
                violation = Some(Violation { step: t, invariant: "pool-budget", detail });
                break;
            }
        }
        if violation.is_some() {
            break;
        }
        drain(&mut states);
    }
    drain(&mut states);
    let transfer = engine.rt.transfer.snapshot();

    if violation.is_none() {
        for (i, st) in states.iter().enumerate() {
            if let Some(e) = &st.outcome.error {
                violation = Some(Violation {
                    step: steps_run,
                    invariant: "request-error",
                    detail: format!("client {}: {e}", i + 1),
                });
                break;
            }
        }
    }
    if violation.is_none() && opts.check_solo {
        violation = solo_check(&engine, &subs, &states, steps_run);
    }

    SimReport {
        trace: SimTrace {
            clients: states.into_iter().map(|s| s.outcome).collect(),
            transfer,
        },
        violation,
        steps_run,
        fault_injected,
        prefix_hits: engine.metrics.prefix_hits.load(Ordering::Relaxed),
        prefix_misses: engine.metrics.prefix_misses.load(Ordering::Relaxed),
        prefix_evictions: 0,
        prefix_bytes: 0,
        demote_refusals: refusals.values().map(|&r| r as u64).sum(),
        kv_pool_peak: pool_peak(&engine) as u64,
    }
}

/// The sharded variant of [`run_on`]: N engines behind a [`ShardPool`],
/// stepped in index order so the run stays deterministic at any shard
/// count. Each shard gets the same per-step treatment as the single-core
/// path (admission → observation → decode → registry checks, against its
/// own engine's counters), and the router layer adds three checks per
/// step: tenant fairness over the pump's dispatch/skip records, placement
/// stability over the router's table, and prefix-hit accounting (the
/// harness replays the cache protocol in admission order and demands the
/// schedulers' hit flags and the engines' counters agree with it).
fn run_pool(engines: Vec<Arc<Engine>>, spec: &ScenarioSpec, opts: &SimOptions) -> SimReport {
    let n_shards = engines.len();
    let (layers, heads, t_max, d_head) = {
        let m = &engines[0].rt.manifest.model;
        (m.n_layers, m.n_kv_heads, m.t_max, m.d_head)
    };
    let decode_buckets = engines[0].rt.manifest.buckets.decode_b.clone();
    let window = engines[0].window();
    let invariants = registry();
    for e in &engines {
        // a fresh pool per shard: budgets are per engine, not pooled
        if let Some(pools) = kv_pools_of(opts) {
            e.set_kv_pools(Some(pools));
        }
    }

    let mut pool = ShardPool::new(
        engines,
        BatcherConfig { max_batch: spec.max_batch, max_wait_us: 0 },
        RouterConfig {
            shards: n_shards,
            prefix_reuse: opts.prefix_reuse,
            prefix_budget: opts.prefix_budget,
            ..RouterConfig::default()
        },
    );
    let mut states: Vec<ClientState> = spec
        .clients
        .iter()
        .map(|_| ClientState { rx: None, outcome: ClientOutcome::new(), submitted: false })
        .collect();
    let mut subs: HashMap<u64, ParsedRequest> = HashMap::new();
    // per-shard mirrors of run_on's per-engine bookkeeping: engines have
    // independent uid counters, so uids are only unique within a shard
    let mut known_uids: Vec<HashSet<u64>> = vec![HashSet::new(); n_shards];
    let mut flow_prev: Vec<HashMap<u64, (usize, usize)>> = vec![HashMap::new(); n_shards];
    let mut refusals: Vec<HashMap<u64, usize>> = vec![HashMap::new(); n_shards];
    // harness-side replay of the prefix-cache protocol: keys deposited so
    // far, maintained in the same shard-index admission order the
    // schedulers run in, so predicted hits are exact
    let mut prefix_keys: HashSet<(String, String)> = HashSet::new();
    let mut prev_placements: HashMap<u64, usize> = HashMap::new();
    let mut seen_rebalances = 0usize;
    let (mut prev_hits, mut prev_misses) = (0u64, 0u64);

    let mut violation: Option<Violation> = None;
    let mut fault_injected = false;
    let mut steps_run = 0;
    'steps: for t in 0..spec.steps {
        steps_run = t + 1;
        // ---- scripted client actions ----------------------------------
        for (i, c) in spec.clients.iter().enumerate() {
            let id = (i + 1) as u64;
            if c.join_step == t && !states[i].submitted {
                states[i].submitted = true;
                let line = c.request_json(id).dump();
                match server::parse_request(&line, "full") {
                    Ok(preq) => {
                        let (tx, rx) = mpsc::channel();
                        pool.submit(
                            id,
                            &preq.tenant,
                            Request {
                                prompt: preq.prompt.clone(),
                                policy: preq.policy.clone(),
                                sp: preq.sp.clone(),
                                stream: true,
                                events: tx,
                            },
                        );
                        states[i].rx = Some(rx);
                        subs.insert(id, preq);
                    }
                    Err(e) => {
                        violation = Some(Violation {
                            step: t,
                            invariant: "protocol",
                            detail: format!("client {id}: request rejected: {e:#}"),
                        });
                        break 'steps;
                    }
                }
            }
            if c.cancel_step == Some(t) {
                pool.cancel(id);
            }
            if c.drop_step == Some(t) {
                states[i].rx = None; // simulated disconnect
            }
        }

        // ---- fair-share pump + router-layer checks --------------------
        pool.pump();
        if let Some(Fault::PhantomMisroute { step }) = opts.fault {
            if step == t && pool.router_mut().inject_misroute() {
                fault_injected = true;
            }
        }
        let dispatches = pool.take_dispatches();
        let skips = pool.take_skips();
        let queued = pool.queued_tenants();
        if let Err(detail) = check_tenant_fairness(&dispatches, &skips, &queued) {
            violation = Some(Violation { step: t, invariant: "tenant-fairness", detail });
            break 'steps;
        }
        let new_rebalances = pool.router().rebalances()[seen_rebalances..].to_vec();
        seen_rebalances += new_rebalances.len();
        let cur_placements = pool.router().placements().clone();
        if let Err(detail) =
            check_placement_stability(&prev_placements, &cur_placements, &new_rebalances)
        {
            violation =
                Some(Violation { step: t, invariant: "placement-stability", detail });
            break 'steps;
        }
        prev_placements = cur_placements;
        if let Some(Fault::PhantomPrefixHit { step }) = opts.fault {
            if step == t {
                pool.core(0).engine().metrics.note_prefix_hit();
                fault_injected = true;
            }
        }

        // ---- per shard, in index order --------------------------------
        let mut prefix_events: Vec<PrefixEvent> = vec![];
        for s in 0..n_shards {
            // admission + budget observation
            let admitted = pool.core_mut(s).admit_waiting();
            for (id, hit) in pool.core_mut(s).take_prefix_flags() {
                let predicted_hit = match subs.get(&id) {
                    // insert() is false when the key was already present
                    Some(p) => !prefix_keys.insert((p.prompt.clone(), p.policy.to_string())),
                    None => false,
                };
                prefix_events.push(PrefixEvent { id, observed_hit: hit, predicted_hit });
            }
            let mut budgets: Vec<BudgetCheck> = vec![];
            for (id, seq) in pool.core(s).live() {
                if !admitted.contains(&id) {
                    continue;
                }
                let frac = match subs.get(&id).map(|p| &p.policy).and_then(budget_of) {
                    Some(f) => f,
                    None => continue,
                };
                let st = seq.cache_stats();
                let n = seq.prompt_len().max(1);
                budgets.push(BudgetCheck {
                    id,
                    policy: subs[&id].policy.to_string(),
                    keep_frac: frac,
                    kept_frac: st.kept as f64 / st.filled.max(1) as f64,
                    slack: (window as f64 + 2.0) / n as f64 + 0.05,
                });
            }
            let done = pool.core_mut(s).reap_finished();
            pool.note_finished(&done);

            // pre-decode protocol replay (transfer prediction)
            let core = pool.core(s);
            let residents_before: Vec<u64> = core
                .group()
                .resident_uids()
                .iter()
                .copied()
                .filter(|&u| u != 0)
                .collect();
            let capacity_before = core.group().capacity();
            let mut active_uids: Vec<u64> = vec![];
            let mut dirty_uids: HashSet<u64> = HashSet::new();
            let mut demoted_before: HashMap<u64, usize> = HashMap::new();
            let mut q_rows = 0u64;
            let mut q_bytes = 0u64;
            for (_id, seq) in core.live() {
                if seq.position() < t_max {
                    active_uids.push(seq.uid());
                    if seq.cache().is_dirty() {
                        dirty_uids.insert(seq.uid());
                    }
                    let demoted = seq.cache_stats().demoted;
                    demoted_before.insert(seq.uid(), demoted);
                    q_rows += demoted as u64;
                    q_bytes += (demoted * seq.cache().tier().bytes_per_entry()) as u64;
                }
            }
            let expected = predict_transfer(
                &active_uids,
                &dirty_uids,
                &residents_before,
                capacity_before,
                &decode_buckets,
                (layers, heads, t_max, d_head),
                (q_rows, q_bytes),
            );
            let before = core.engine().rt.transfer.snapshot();

            // the shard's shared decode step
            if let Err(e) = pool.core_mut(s).decode_once() {
                violation = Some(Violation {
                    step: t,
                    invariant: "engine-error",
                    detail: format!("shard {s}: {e:#}"),
                });
                break 'steps;
            }
            if s == 0 {
                match opts.fault {
                    Some(Fault::PhantomRowFetch { step }) if step == t => {
                        if let Some(h) = pool.core(0).group().kv_handle() {
                            let mut k = vec![0.0f32; h.row_elems()];
                            let mut v = vec![0.0f32; h.row_elems()];
                            let _ = pool
                                .core(0)
                                .engine()
                                .rt
                                .kv_fetch_row(h, 0, 0, &mut k, &mut v);
                            fault_injected = true;
                        }
                    }
                    Some(Fault::PhantomQuantAttend { step }) if step == t => {
                        pool.core(0).engine().rt.transfer.note_quant_attend(1, 64);
                        fault_injected = true;
                    }
                    _ => {}
                }
            }
            let after = pool.core(s).engine().rt.transfer.snapshot();
            let actual = TransferDelta {
                kv_bytes_up: after.kv_bytes_up - before.kv_bytes_up,
                kv_bytes_down: after.kv_bytes_down - before.kv_bytes_down,
                mask_uploads: after.mask_uploads - before.mask_uploads,
                decode_steps: after.decode_steps - before.decode_steps,
                quant_attend_rows: after.quant_attend_rows - before.quant_attend_rows,
                quant_attend_bytes: after.quant_attend_bytes - before.quant_attend_bytes,
            };

            // invariant checks against this shard's engine
            let core = pool.core(s);
            let mut seqs: Vec<SeqCheck> = vec![];
            for (id, seq) in core.live() {
                let (pd, pr) = flow_prev[s].get(&seq.uid()).copied().unwrap_or((0, 0));
                let step_flow = demoted_before.get(&seq.uid()).map(|&b| {
                    (b, seq.decode_demotions - pd, seq.decode_rehydrations - pr)
                });
                flow_prev[s]
                    .insert(seq.uid(), (seq.decode_demotions, seq.decode_rehydrations));
                refusals[s].insert(seq.uid(), seq.cache().demote_refusals());
                seqs.push(seq_check(
                    id,
                    seq,
                    subs.get(&id).map(|p| &p.policy),
                    window,
                    layers,
                    heads,
                    step_flow,
                ));
            }
            known_uids[s].extend(core.live().map(|(_, q)| q.uid()));
            let obs = StepObs {
                step: t,
                seqs,
                budgets,
                known_uids: known_uids[s].iter().copied().collect(),
                residents: core.group().resident_uids().to_vec(),
                capacity: core.group().capacity(),
                expected,
                actual,
            };
            for inv in &invariants {
                if let Err(detail) = inv.check(&obs) {
                    violation = Some(Violation { step: t, invariant: inv.name(), detail });
                    break 'steps;
                }
            }
            let done = pool.core_mut(s).reap_finished();
            pool.note_finished(&done);

            // pool-budget invariant (post-reap: only live sequences may
            // hold charges, so the recount is exact here)
            for pc in pool_checks(s, pool.core(s).engine(), pool.core(s)) {
                if let Err(detail) = check_pool_budget(&pc) {
                    violation =
                        Some(Violation { step: t, invariant: "pool-budget", detail });
                    break 'steps;
                }
            }
        }

        // ---- prefix-hit accounting ------------------------------------
        let (hits, misses) = pool_prefix_counts(&pool);
        if let Err(detail) = check_prefix_accounting(
            &prefix_events,
            hits - prev_hits,
            misses - prev_misses,
            opts.prefix_budget.is_some(),
        ) {
            violation = Some(Violation { step: t, invariant: "prefix-accounting", detail });
            break 'steps;
        }
        prev_hits = hits;
        prev_misses = misses;

        drain(&mut states);
    }
    drain(&mut states);
    let transfer = pool_transfer(&pool);
    let (prefix_hits, prefix_misses) = pool_prefix_counts(&pool);

    if violation.is_none() {
        for (i, st) in states.iter().enumerate() {
            if let Some(e) = &st.outcome.error {
                violation = Some(Violation {
                    step: steps_run,
                    invariant: "request-error",
                    detail: format!("client {}: {e}", i + 1),
                });
                break;
            }
        }
    }
    if violation.is_none() && opts.check_solo {
        violation = solo_check(pool.core(0).engine(), &subs, &states, steps_run);
    }

    let (prefix_evictions, prefix_bytes) = pool
        .prefix_cache()
        .map(|pc| {
            let st = pc.stats();
            (st.evictions, st.bytes as u64)
        })
        .unwrap_or((0, 0));
    let kv_pool_peak: u64 =
        (0..pool.shard_count()).map(|s| pool_peak(pool.core(s).engine()) as u64).sum();
    SimReport {
        trace: SimTrace {
            clients: states.into_iter().map(|s| s.outcome).collect(),
            transfer,
        },
        violation,
        steps_run,
        fault_injected,
        prefix_hits,
        prefix_misses,
        prefix_evictions,
        prefix_bytes,
        demote_refusals: refusals
            .iter()
            .flat_map(|m| m.values())
            .map(|&r| r as u64)
            .sum(),
        kv_pool_peak,
    }
}

/// Field-wise sum of every shard's transfer counters: the pool-level
/// trace aggregates what N engines moved, so N-shard totals are
/// comparable across runs even though per-shard residency differs.
fn pool_transfer(pool: &ShardPool) -> TransferSnapshot {
    let mut acc = pool.core(0).engine().rt.transfer.snapshot();
    for s in 1..pool.shard_count() {
        let t = pool.core(s).engine().rt.transfer.snapshot();
        acc.kv_bytes_up += t.kv_bytes_up;
        acc.kv_bytes_down += t.kv_bytes_down;
        acc.mask_uploads += t.mask_uploads;
        acc.bytes_up += t.bytes_up;
        acc.bytes_down += t.bytes_down;
        acc.decode_steps += t.decode_steps;
        acc.demotes += t.demotes;
        acc.rehydrates += t.rehydrates;
        acc.tier_bytes_stored += t.tier_bytes_stored;
        acc.tier_bytes_freed += t.tier_bytes_freed;
        acc.quant_attend_rows += t.quant_attend_rows;
        acc.quant_attend_bytes += t.quant_attend_bytes;
    }
    acc
}

/// The per-engine KV admission pools [`SimOptions`] asks for: a unified
/// byte pool under `kv_budget`, a side-only split pool under
/// `side_budget`, nothing otherwise. Called once per engine so every
/// shard gets its own fresh pool.
fn kv_pools_of(opts: &SimOptions) -> Option<KvPools> {
    if let Some(b) = opts.kv_budget {
        return Some(KvPools::Unified(Arc::new(BlockPool::new(b))));
    }
    opts.side_budget
        .map(|b| KvPools::Split { blocks: None, side: Some(Arc::new(BlockPool::new(b))) })
}

/// Build the step's [`PoolCheck`]s for one shard: each configured pool's
/// own counter vs budget vs an independent recount over the scheduler's
/// live sequences. Empty when the engine carries no pools.
fn pool_checks(shard: usize, engine: &Engine, core: &SchedCore) -> Vec<PoolCheck> {
    let Some(pools) = engine.kv_pools() else { return vec![] };
    let mut out = vec![];
    match pools {
        KvPools::Unified(p) => out.push(PoolCheck {
            shard,
            kind: "unified",
            pool_used: p.used(),
            budget: p.total(),
            recount: core.live().map(|(_, s)| s.cache().charged_bytes()).sum(),
            over_released: p.over_released(),
        }),
        KvPools::Split { blocks, side } => {
            if let Some(bp) = blocks {
                out.push(PoolCheck {
                    shard,
                    kind: "blocks",
                    pool_used: bp.used(),
                    budget: bp.total(),
                    recount: core
                        .live()
                        .map(|(_, s)| s.cache_stats().resident_blocks)
                        .sum(),
                    over_released: bp.over_released(),
                });
            }
            if let Some(sp) = side {
                out.push(PoolCheck {
                    shard,
                    kind: "side",
                    pool_used: sp.used(),
                    budget: sp.total(),
                    recount: core.live().map(|(_, s)| s.cache_stats().side_bytes).sum(),
                    over_released: sp.over_released(),
                });
            }
        }
    }
    out
}

/// Byte-denominated KV-pool high-water mark for one engine (the unified
/// pool, or the split-mode side pool; 0 without pools — the split-mode
/// *blocks* pool is block-denominated and deliberately excluded).
fn pool_peak(engine: &Engine) -> usize {
    match engine.kv_pools() {
        Some(KvPools::Unified(p)) => p.peak(),
        Some(KvPools::Split { side: Some(p), .. }) => p.peak(),
        _ => 0,
    }
}

/// (hits, misses) summed over every shard's engine.
fn pool_prefix_counts(pool: &ShardPool) -> (u64, u64) {
    let mut hits = 0u64;
    let mut misses = 0u64;
    for s in 0..pool.shard_count() {
        let m = &pool.core(s).engine().metrics;
        hits += m.prefix_hits.load(Ordering::Relaxed);
        misses += m.prefix_misses.load(Ordering::Relaxed);
    }
    (hits, misses)
}

/// Which budget the policy promises at prefill (None: not a budget policy
/// with the rank-selection guarantee the harness checks).
fn budget_of(p: &PolicySpec) -> Option<f64> {
    match p {
        PolicySpec::H2o { keep_frac }
        | PolicySpec::SnapKv { keep_frac }
        | PolicySpec::AdaKv { keep_frac }
        | PolicySpec::Knorm { keep_frac }
        | PolicySpec::Keyformer { keep_frac, .. }
        | PolicySpec::ExpectedAttnVnorm { keep_frac }
        | PolicySpec::Kvzip { keep_frac, .. } => Some(*keep_frac),
        _ => None,
    }
}

/// Replay the device-resident KV protocol for one step: who scatters, who
/// refreshes a mask, who is vacated, and what the row-only steady state
/// fetches — producing the exact counter deltas the engine must match.
/// `quant` is the predicted quant-attend movement (rows, bytes): the sum
/// of pre-step demoted sets over active sequences, since the quantized
/// decode path attends every live side entry in place, and a vacated
/// slot's entries must have been purged.
fn predict_transfer(
    active: &[u64],
    dirty: &HashSet<u64>,
    residents: &[u64],
    capacity: usize,
    decode_buckets: &[usize],
    dims: (usize, usize, usize, usize),
    quant: (u64, u64),
) -> TransferDelta {
    let nb = active.len();
    if nb == 0 {
        return TransferDelta::default();
    }
    let (layers, heads, t_max, d_head) = dims;
    let db = match decode_buckets.iter().copied().find(|&b| b >= nb) {
        Some(b) => b,
        None => return TransferDelta::default(), // decode_once will error
    };
    let resident_set: HashSet<u64> = residents.iter().copied().collect();
    let (newcomers, vacates, refreshes) = if capacity != db {
        // bucket change: the group is reset and everyone re-scatters
        (nb, 0, 0)
    } else {
        let newcomers = active.iter().filter(|u| !resident_set.contains(u)).count();
        let vacates = resident_set.iter().filter(|u| !active.contains(u)).count();
        let refreshes = active
            .iter()
            .filter(|u| resident_set.contains(u) && dirty.contains(u))
            .count();
        (newcomers, vacates, refreshes)
    };
    let slot_elems = layers * heads * t_max * d_head;
    let mask_elems = layers * heads * t_max;
    let row_elems = layers * heads * d_head;
    let up_elems =
        newcomers * (2 * slot_elems + mask_elems) + (vacates + refreshes) * mask_elems;
    TransferDelta {
        kv_bytes_up: 4 * up_elems as u64,
        kv_bytes_down: 4 * (nb * 2 * row_elems) as u64,
        mask_uploads: (newcomers + vacates + refreshes) as u64,
        decode_steps: 1,
        quant_attend_rows: quant.0,
        quant_attend_bytes: quant.1,
    }
}

fn seq_check(
    id: u64,
    seq: &Sequence,
    policy: Option<&PolicySpec>,
    window: usize,
    layers: usize,
    heads: usize,
    step_flow: Option<(usize, usize, usize)>,
) -> SeqCheck {
    let cache = seq.cache();
    let st = cache.stats();
    let len = cache.len();
    let mask_on = cache.mask_f32().iter().filter(|&&m| m > 0.0).count();
    let head_sum = (0..layers)
        .flat_map(|l| (0..heads).map(move |h| (l, h)))
        .map(|(l, h)| cache.kept_in_head(l, h))
        .sum();
    let window_ok = match policy {
        Some(PolicySpec::Kvzap { .. }) | Some(PolicySpec::FastKvzip { .. }) => {
            let mut ok = true;
            for p in len.saturating_sub(window)..len {
                for l in 0..layers {
                    for h in 0..heads {
                        if !cache.is_kept(l, h, p) {
                            ok = false;
                        }
                    }
                }
            }
            Some(ok)
        }
        _ => None,
    };
    SeqCheck {
        id,
        uid: seq.uid(),
        pos: seq.position(),
        len,
        t_max: cache.t_max,
        lh: layers * heads,
        kept: st.kept,
        filled: st.filled,
        compression: st.compression(),
        mask_on,
        head_sum,
        window_ok,
        demoted: st.demoted,
        side_bytes: st.side_bytes,
        tracked_demoted: seq.tracked_demoted(),
        demoted_in_window: cache.demoted_at_or_after(len.saturating_sub(window)),
        accounting_err: cache.accounting_ok().err(),
        quant_attended_rows: st.quant_attended_rows,
        quant_attended_bytes: st.quant_attended_bytes,
        tier_bpe: cache.tier().bytes_per_entry(),
        step_flow,
    }
}

fn drain(states: &mut [ClientState]) {
    for st in states.iter_mut() {
        let mut close = false;
        if let Some(rx) = &st.rx {
            loop {
                match rx.try_recv() {
                    Ok(SeqEvent::Token { token, text }) => {
                        st.outcome.tokens.push(token);
                        st.outcome.text.push_str(&text);
                    }
                    Ok(SeqEvent::Done(r)) => {
                        st.outcome.done = true;
                        st.outcome.reason = r.reason.clone();
                        st.outcome.error = r.error.clone();
                        st.outcome.tokens_out = Some(r.tokens_out);
                        st.outcome.compression_bits = Some(r.compression.to_bits());
                        close = true; // exactly one Done per request
                    }
                    Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
                }
            }
        }
        if close {
            st.rx = None;
        }
    }
}

/// Metamorphic faithfulness: every client's interleaved token stream must
/// be (a prefix of, for cancelled/disconnected/unfinished clients) the
/// stream the same request produces decoded solo.
fn solo_check(
    engine: &Engine,
    subs: &HashMap<u64, ParsedRequest>,
    states: &[ClientState],
    step: usize,
) -> Option<Violation> {
    for (i, st) in states.iter().enumerate() {
        let id = (i + 1) as u64;
        let preq = match subs.get(&id) {
            Some(p) => p,
            None => continue, // never submitted
        };
        let out = &st.outcome;
        // Skip errors (reported separately), never-started clients, and
        // zero-token cancels — their empty-prefix comparison is vacuous
        // and a solo replay would cost a full generation for nothing.
        if out.error.is_some()
            || (out.tokens.is_empty()
                && (!out.done || out.reason.as_deref() == Some("cancelled")))
        {
            continue;
        }
        let (solo_tokens, solo_reason, solo_comp) = match solo_replay(engine, id, preq) {
            Ok(v) => v,
            Err(e) => {
                return Some(Violation {
                    step,
                    invariant: "engine-error",
                    detail: format!("solo replay for client {id}: {e:#}"),
                })
            }
        };
        let finished = out.done && out.reason.as_deref() != Some("cancelled");
        let mismatch = if finished {
            if out.tokens != solo_tokens {
                Some(format!(
                    "client {id}: interleaved tokens {:?} != solo {:?}",
                    out.tokens, solo_tokens
                ))
            } else if out.reason.as_deref() != solo_reason.as_deref() {
                Some(format!(
                    "client {id}: done reason {:?} != solo {:?}",
                    out.reason, solo_reason
                ))
            } else if out.compression_bits != Some(solo_comp.to_bits()) {
                Some(format!("client {id}: compression diverged from the solo run"))
            } else {
                None
            }
        } else if out.tokens.len() > solo_tokens.len()
            || out.tokens[..] != solo_tokens[..out.tokens.len()]
        {
            Some(format!(
                "client {id}: partial stream {:?} is not a prefix of solo {:?}",
                out.tokens, solo_tokens
            ))
        } else {
            None
        };
        if let Some(detail) = mismatch {
            return Some(Violation { step, invariant: "metamorphic-faithfulness", detail });
        }
    }
    None
}

fn solo_replay(
    engine: &Engine,
    id: u64,
    preq: &ParsedRequest,
) -> anyhow::Result<(Vec<i32>, Option<String>, f64)> {
    let policy = preq.policy.build(engine.window());
    let mut seq = engine.sequence(1_000_000 + id, &preq.prompt, preq.sp.clone());
    let mut tokens = vec![];
    let events = engine.prefill(&mut seq, policy.as_ref())?;
    collect_tokens(&events, &mut tokens);
    let mut group = engine.decode_group();
    while !seq.is_done() {
        let events = {
            let mut set = vec![&mut seq];
            engine.decode_step(&mut group, &mut set)?
        };
        collect_tokens(&events, &mut tokens);
    }
    let reason = seq.done_reason().map(|d| d.as_str().to_string());
    Ok((tokens, reason, engine.finish(&seq).compression))
}

fn collect_tokens(events: &[StepEvent], out: &mut Vec<i32>) {
    for ev in events {
        if let StepEvent::Token { token, .. } = ev {
            out.push(*token);
        }
    }
}

/// Run `spec` at two thread counts and require bit-identical traces
/// (tokens, reasons, compressions, transfer counters).
pub fn thread_traces_match(spec: &ScenarioSpec, a: usize, b: usize) -> Result<(), String> {
    let base = SimOptions { check_solo: false, ..SimOptions::default() };
    let ra = run_scenario(spec, &SimOptions { threads: Some(a), ..base.clone() });
    if let Some(v) = ra.violation {
        return Err(format!("threads={a}: {v}"));
    }
    let rb = run_scenario(spec, &SimOptions { threads: Some(b), ..base });
    if let Some(v) = rb.violation {
        return Err(format!("threads={b}: {v}"));
    }
    if ra.trace != rb.trace {
        return Err(format!(
            "trace diverged between KVZAP_THREADS={a} and KVZAP_THREADS={b}"
        ));
    }
    Ok(())
}

/// Metamorphic shard invariance: the same scenario must produce
/// bit-identical per-client outcomes at two shard counts (prefix reuse
/// off). Transfer counters are deliberately *not* compared — batch
/// composition and residency churn differ per shard count by design; the
/// claim is about request-visible outputs only. Requires a
/// cancel/disconnect-free spec whose clients all finish within
/// `spec.steps`: queueing delay differs across shard counts, so a partial
/// stream's cut point is schedule-dependent and its comparison vacuous.
pub fn shard_traces_match(spec: &ScenarioSpec, a: usize, b: usize) -> Result<(), String> {
    let base = SimOptions { check_solo: false, ..SimOptions::default() };
    let run = |shards: usize| -> Result<SimTrace, String> {
        let r = run_scenario(spec, &SimOptions { shards, ..base.clone() });
        if let Some(v) = r.violation {
            return Err(format!("shards={shards}: {v}"));
        }
        for (i, c) in r.trace.clients.iter().enumerate() {
            if !c.done {
                return Err(format!(
                    "shards={shards}: client {} did not finish — raise spec.steps so the \
                     comparison sees complete streams",
                    i + 1
                ));
            }
        }
        Ok(r.trace)
    };
    let ta = run(a)?;
    let tb = run(b)?;
    if ta.clients != tb.clients {
        return Err(format!("outputs diverged between shards={a} and shards={b}"));
    }
    Ok(())
}

/// Metamorphic prefix-reuse invariance: at a fixed shard count, outputs
/// with the prefix cache on must be bit-identical to outputs with it off
/// — and the reuse run must actually hit (a zero-hit run proves nothing
/// about the reuse path). Same spec requirements as
/// [`shard_traces_match`].
pub fn reuse_traces_match(spec: &ScenarioSpec, shards: usize) -> Result<(), String> {
    let base = SimOptions { check_solo: false, shards, ..SimOptions::default() };
    let off = run_scenario(spec, &SimOptions { prefix_reuse: false, ..base.clone() });
    if let Some(v) = off.violation {
        return Err(format!("reuse=off: {v}"));
    }
    let on = run_scenario(spec, &SimOptions { prefix_reuse: true, ..base });
    if let Some(v) = on.violation {
        return Err(format!("reuse=on: {v}"));
    }
    for (label, r) in [("off", &off), ("on", &on)] {
        for (i, c) in r.trace.clients.iter().enumerate() {
            if !c.done {
                return Err(format!(
                    "reuse={label}: client {} did not finish — raise spec.steps",
                    i + 1
                ));
            }
        }
    }
    if on.prefix_hits == 0 {
        return Err(
            "reuse run recorded zero prefix hits — the scenario exercises nothing".into()
        );
    }
    if off.trace.clients != on.trace.clients {
        return Err("outputs diverged between prefix reuse off and on".into());
    }
    Ok(())
}

/// Aggregate counts the CLI prints per clean run.
#[derive(Debug, Clone)]
pub struct SimSummary {
    /// Scenario seed.
    pub seed: u64,
    /// Steps executed.
    pub steps: usize,
    /// Scripted clients.
    pub clients: usize,
    /// Clients whose request finished with a normal reason.
    pub completed: usize,
    /// Clients that ended cancelled (script cancels + disconnects).
    pub cancelled: usize,
    /// Tokens streamed across all clients.
    pub tokens_out: usize,
    /// Whether a configured fault actually fired (see
    /// [`SimReport::fault_injected`]). A clean run with a configured but
    /// never-fired fault is NOT a passed mutation check.
    pub fault_injected: bool,
}

/// A failed run: the violation, the original replay line, and the shrunk
/// scenario (as a spec and as replayable JSON).
#[derive(Debug, Clone)]
pub struct SimFailure {
    /// The (first) invariant violation.
    pub violation: Violation,
    /// One-line reproduction command for the original scenario.
    pub replay: String,
    /// Minimized still-failing scenario.
    pub minimized: ScenarioSpec,
    /// `minimized` as JSON for `kvzap simulate --spec-file`.
    pub minimized_json: String,
}

/// The single replay line a violation prints: regenerates and re-runs the
/// originating scenario exactly. Hand-written / shrunk specs (seed 0 or
/// edited clients) replay via their JSON instead — the CLI writes it to
/// SIM_FAILURE.json and prints the `--spec-file` form alongside.
pub fn replay_line(spec: &ScenarioSpec) -> String {
    format!(
        "kvzap simulate --seed {} --steps {} --clients {} --max-batch {}",
        spec.seed,
        spec.steps,
        spec.clients.len(),
        spec.max_batch
    )
}

/// Non-default run options rendered as the CLI flags that reproduce them;
/// appended to [`replay_line`] so the printed command replays the actual
/// configuration, not the defaults.
pub fn replay_opts(opts: &SimOptions) -> String {
    let mut s = String::new();
    if let Some(t) = opts.threads {
        s.push_str(&format!(" --threads {t}"));
    }
    if !opts.check_solo {
        s.push_str(" --no-solo");
    }
    if opts.shards != 1 {
        s.push_str(&format!(" --shards {}", opts.shards));
    }
    if opts.prefix_reuse {
        s.push_str(" --prefix-reuse");
    }
    if let Some(b) = opts.prefix_budget {
        s.push_str(&format!(" --prefix-budget {b}"));
    }
    if let Some(b) = opts.kv_budget {
        s.push_str(&format!(" --kv-budget {b}"));
    }
    if let Some(b) = opts.side_budget {
        s.push_str(&format!(" --side-budget {b}"));
    }
    match opts.fault {
        Some(Fault::PhantomRowFetch { step }) => {
            s.push_str(&format!(" --fault-step {step}"));
        }
        Some(Fault::PhantomQuantAttend { step }) => {
            s.push_str(&format!(" --fault-quant-step {step}"));
        }
        Some(Fault::PhantomPrefixHit { step }) => {
            s.push_str(&format!(" --fault-prefix-step {step}"));
        }
        Some(Fault::PhantomMisroute { step }) => {
            s.push_str(&format!(" --fault-route-step {step}"));
        }
        None => {}
    }
    s
}

/// Shrink candidates for a failing scenario: fewer clients, fewer steps,
/// no cancels/disconnects, shorter generations. Every candidate strictly
/// reduces a measure, so the greedy pass terminates. (Deliberately not
/// built on `propcheck::shrink_vec`, whose second-half candidate equals
/// the input for single-element lists — a still-failing 1-client scenario
/// would then shrink to itself forever.)
pub fn shrink_spec(s: &ScenarioSpec) -> Vec<ScenarioSpec> {
    let mut out = vec![];
    let n = s.clients.len();
    let with_clients = |clients: Vec<super::scenario::ClientScript>| ScenarioSpec {
        clients,
        ..s.clone()
    };
    if n > 1 {
        out.push(with_clients(s.clients[..n / 2].to_vec()));
        out.push(with_clients(s.clients[n / 2..].to_vec()));
        if n <= 8 {
            for i in 0..n {
                let mut c = s.clients.clone();
                c.remove(i);
                out.push(with_clients(c));
            }
        }
    }
    if s.steps > 8 {
        let mut half = s.clone();
        half.steps = s.steps / 2;
        out.push(half);
    }
    if s.clients.iter().any(|c| c.cancel_step.is_some() || c.drop_step.is_some()) {
        let mut calm = s.clone();
        for c in calm.clients.iter_mut() {
            c.cancel_step = None;
            c.drop_step = None;
        }
        out.push(calm);
    }
    if s.clients.iter().any(|c| c.max_new > 4) {
        let mut short = s.clone();
        for c in short.clients.iter_mut() {
            c.max_new = (c.max_new / 2).max(2);
        }
        out.push(short);
    }
    out
}

/// Run a scenario; on a violation, minimize it and return the failure
/// package (replay line + shrunk spec). This is what `kvzap simulate`
/// calls per seed.
pub fn simulate(spec: &ScenarioSpec, opts: &SimOptions) -> Result<SimSummary, Box<SimFailure>> {
    let report = run_scenario(spec, opts);
    match report.violation {
        None => {
            let completed = report
                .trace
                .clients
                .iter()
                .filter(|c| c.done && c.reason.as_deref() != Some("cancelled"))
                .count();
            let cancelled = report
                .trace
                .clients
                .iter()
                .filter(|c| c.reason.as_deref() == Some("cancelled"))
                .count();
            let tokens_out =
                report.trace.clients.iter().map(|c| c.tokens.len()).sum();
            Ok(SimSummary {
                seed: spec.seed,
                steps: report.steps_run,
                clients: spec.clients.len(),
                completed,
                cancelled,
                tokens_out,
                fault_injected: report.fault_injected,
            })
        }
        Some(v) => {
            let msg = v.to_string();
            let fails = |s: &ScenarioSpec| -> Result<(), String> {
                match run_scenario(s, opts).violation {
                    Some(v) => Err(v.to_string()),
                    None => Ok(()),
                }
            };
            let (minimized, _msg) =
                propcheck::minimize(spec.clone(), msg, shrink_spec, fails);
            Err(Box::new(SimFailure {
                violation: v,
                replay: format!("{}{}", replay_line(spec), replay_opts(opts)),
                minimized_json: minimized.to_json().dump(),
                minimized,
            }))
        }
    }
}
