//! Seeded scenario specifications for the simulation harness.
//!
//! A [`ScenarioSpec`] is a fully-deterministic description of one
//! multi-client serving episode: which clients exist, when each joins,
//! what it asks for (prompt from the [`crate::workload`] generators,
//! pruning policy from the [`crate::policies::PolicySpec`] mix, sampling
//! parameters), and which adversarial actions happen when (mid-decode
//! cancel, client disconnect). Everything derives from one `u64` seed, so
//! `kvzap simulate --seed S --steps K` regenerates the exact episode; the
//! JSON round-trip ([`ScenarioSpec::to_json`] / [`ScenarioSpec::from_json`])
//! replays shrunk scenarios that no longer correspond to any seed.

use anyhow::{anyhow, Result};

use crate::policies::{PolicySpec, Surrogate};
use crate::runtime::kernels::QuantBits;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::workload;

/// One scripted client: a single v2-protocol generation request plus the
/// step-indexed actions the harness performs on its behalf.
#[derive(Debug, Clone, PartialEq)]
pub struct ClientScript {
    /// Simulation step at which the request is submitted.
    pub join_step: usize,
    /// Tenant this request bills to ("" is a tenant like any other). The
    /// sharded driver feeds it to the pool's fair-share queues.
    pub tenant: String,
    /// Prompt text (produced by the workload generators).
    pub prompt: String,
    /// Pruning policy for this request.
    pub policy: PolicySpec,
    /// Send the policy as a structured JSON object instead of the compact
    /// string form (both protocol spellings must behave identically).
    pub structured_policy: bool,
    /// Token budget (`max_new`).
    pub max_new: usize,
    /// Greedy decoding; when false the request samples with the paper's
    /// reasoning settings seeded by `seed`.
    pub greedy: bool,
    /// Sampler seed (kept below 2^32 so the JSON number round-trips).
    pub seed: u64,
    /// Stop at the first newline (the task-grammar default).
    pub stop_newline: bool,
    /// Cancel the request at this simulation step (mid-decode when it
    /// lands after admission).
    pub cancel_step: Option<usize>,
    /// Stop reading events at this step — a simulated client disconnect;
    /// the scheduler notices on the next token send and frees the slot.
    pub drop_step: Option<usize>,
}

impl ClientScript {
    /// The v2-protocol request body for this client (always streaming, id
    /// echoed so cancels can address it).
    pub fn request_json(&self, id: u64) -> Json {
        let policy = if self.structured_policy {
            self.policy.to_json()
        } else {
            Json::str(self.policy.to_string())
        };
        Json::obj(vec![
            ("prompt", Json::str(self.prompt.clone())),
            ("policy", policy),
            ("max_new", Json::num(self.max_new as f64)),
            ("greedy", Json::Bool(self.greedy)),
            ("seed", Json::num(self.seed as f64)),
            ("stop_newline", Json::Bool(self.stop_newline)),
            ("stream", Json::Bool(true)),
            ("tenant", Json::str(self.tenant.clone())),
            ("id", Json::num(id as f64)),
        ])
    }

    /// JSON form (for replaying shrunk scenarios from a file).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("join_step", Json::num(self.join_step as f64)),
            ("tenant", Json::str(self.tenant.clone())),
            ("prompt", Json::str(self.prompt.clone())),
            ("policy", self.policy.to_json()),
            ("structured_policy", Json::Bool(self.structured_policy)),
            ("max_new", Json::num(self.max_new as f64)),
            ("greedy", Json::Bool(self.greedy)),
            ("seed", Json::num(self.seed as f64)),
            ("stop_newline", Json::Bool(self.stop_newline)),
            ("cancel_step", opt_num(self.cancel_step)),
            ("drop_step", opt_num(self.drop_step)),
        ])
    }

    /// Parse the [`ClientScript::to_json`] form.
    pub fn from_json(j: &Json) -> Result<ClientScript> {
        let field = |k: &str| j.get(k).ok_or_else(|| anyhow!("client missing '{k}'"));
        Ok(ClientScript {
            join_step: field("join_step")?.as_usize().ok_or_else(|| anyhow!("bad join_step"))?,
            // absent in pre-shard spec files: default tenant
            tenant: j
                .get("tenant")
                .and_then(|v| v.as_str())
                .unwrap_or("")
                .to_string(),
            prompt: field("prompt")?
                .as_str()
                .ok_or_else(|| anyhow!("bad prompt"))?
                .to_string(),
            policy: PolicySpec::from_json(field("policy")?)?,
            structured_policy: field("structured_policy")?.as_bool().unwrap_or(false),
            max_new: field("max_new")?.as_usize().ok_or_else(|| anyhow!("bad max_new"))?,
            greedy: field("greedy")?.as_bool().unwrap_or(true),
            seed: field("seed")?.as_i64().unwrap_or(0) as u64,
            stop_newline: field("stop_newline")?.as_bool().unwrap_or(true),
            cancel_step: opt_usize(j.get("cancel_step")),
            drop_step: opt_usize(j.get("drop_step")),
        })
    }
}

fn opt_num(v: Option<usize>) -> Json {
    match v {
        Some(n) => Json::num(n as f64),
        None => Json::Null,
    }
}

fn opt_usize(v: Option<&Json>) -> Option<usize> {
    match v {
        None | Some(Json::Null) => None,
        Some(j) => j.as_usize(),
    }
}

/// A deterministic multi-client episode (see module docs).
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioSpec {
    /// Seed this spec was generated from (0 for hand-written specs).
    pub seed: u64,
    /// Number of discrete scheduler steps to run.
    pub steps: usize,
    /// Continuous-batcher slot cap (clamped to the largest decode bucket).
    pub max_batch: usize,
    /// The scripted clients, submitted in index order at their join steps.
    pub clients: Vec<ClientScript>,
}

impl ScenarioSpec {
    /// Generate the episode for `seed`: `n_clients` clients with staggered
    /// joins over the first half of the run, prompts drawn from the
    /// ruler/longbench/aime generators at bucket-crossing context lengths,
    /// policies mixed over the threshold and budget families of
    /// [`crate::policies::spec::CATALOG`], and a sprinkle of cancels and
    /// disconnects.
    pub fn generate(seed: u64, steps: usize, n_clients: usize, max_batch: usize) -> ScenarioSpec {
        let mut r = Rng::new(seed);
        let clients =
            (0..n_clients).map(|i| client_script(&mut r.fork(i as u64), steps)).collect();
        ScenarioSpec { seed, steps, max_batch, clients }
    }

    /// A demotion-heavy episode for the tier invariants: every client runs
    /// a two-threshold tiered policy with an aggressive τ and a deep
    /// floor, so the demote band is as wide as possible and long
    /// generations keep the demote → rehydrate (score rebound) and
    /// window-growth churn going. No cancels or disconnects — slot churn
    /// is [`ScenarioSpec::generate`]'s job; this one maximizes side-tier
    /// traffic per step.
    pub fn generate_tiered(
        seed: u64,
        steps: usize,
        n_clients: usize,
        max_batch: usize,
    ) -> ScenarioSpec {
        let mut r = Rng::new(seed);
        let clients = (0..n_clients)
            .map(|i| {
                let r = &mut r.fork(i as u64);
                let target = *r.choice(&TARGET_LENS);
                let subset = *r.choice(workload::RULER_SUBSETS);
                let t = workload::ruler_instance(subset, target, r);
                ClientScript {
                    join_step: r.below((steps / 4).max(1)),
                    tenant: String::new(),
                    prompt: t.prompt,
                    policy: tiered_policy(r),
                    structured_policy: r.below(100) < 30,
                    max_new: r.below(32) + 16,
                    greedy: true,
                    seed: r.below(1 << 31) as u64,
                    stop_newline: false,
                    cancel_step: None,
                    drop_step: None,
                }
            })
            .collect();
        ScenarioSpec { seed, steps, max_batch, clients }
    }

    /// A shared-prefix episode for the router layer: clients are grouped
    /// into prompt *families* (each family one duplicated prompt from
    /// [`crate::workload::prefix_families`], all members the identical
    /// byte string and the identical policy — the prefix cache's reuse
    /// unit) and spread over a few tenants, so one run exercises
    /// consistent-hash placement, fair-share queueing and prefix
    /// hit/miss accounting at once. Members differ only in sampler seed
    /// and token budget; no cancels or disconnects.
    pub fn generate_shared_prefix(
        seed: u64,
        steps: usize,
        n_clients: usize,
        max_batch: usize,
    ) -> ScenarioSpec {
        let mut r = Rng::new(seed);
        let n_families = (n_clients / 2).max(1);
        let fam_r = &mut r.fork(1_000_003);
        let target = *fam_r.choice(&[120usize, 200, 300]);
        let families = workload::prefix_families(fam_r, n_families, 1, target);
        let fam_policies: Vec<PolicySpec> = (0..n_families)
            .map(|i| match fam_r.below(3) {
                0 => PolicySpec::Full,
                1 => PolicySpec::Kvzap {
                    surrogate: Surrogate::Mlp,
                    tau: -4.0,
                    floor: None,
                    bits: QuantBits::Int8,
                },
                _ => tiered_policy(&mut fam_r.fork(i as u64)),
            })
            .collect();
        let n_tenants = n_clients.clamp(1, 3);
        let clients = (0..n_clients)
            .map(|i| {
                let r = &mut r.fork(i as u64);
                let fam = i % n_families;
                ClientScript {
                    join_step: r.below((steps / 3).max(1)),
                    tenant: format!("tenant-{}", i % n_tenants),
                    prompt: families[fam][0].prompt.clone(),
                    policy: fam_policies[fam].clone(),
                    structured_policy: false,
                    max_new: r.below(16) + 6,
                    greedy: true,
                    seed: r.below(1 << 31) as u64,
                    stop_newline: false,
                    cancel_step: None,
                    drop_step: None,
                }
            })
            .collect();
        ScenarioSpec { seed, steps, max_batch, clients }
    }

    /// JSON form (for replaying shrunk scenarios from a file).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("seed", Json::num(self.seed as f64)),
            ("steps", Json::num(self.steps as f64)),
            ("max_batch", Json::num(self.max_batch as f64)),
            ("clients", Json::Arr(self.clients.iter().map(|c| c.to_json()).collect())),
        ])
    }

    /// Parse the [`ScenarioSpec::to_json`] form.
    pub fn from_json(j: &Json) -> Result<ScenarioSpec> {
        let clients = j
            .get("clients")
            .and_then(|c| c.as_arr())
            .ok_or_else(|| anyhow!("scenario missing 'clients' array"))?
            .iter()
            .map(ClientScript::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(ScenarioSpec {
            seed: j.get("seed").and_then(|v| v.as_i64()).unwrap_or(0) as u64,
            steps: j
                .get("steps")
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow!("scenario missing 'steps'"))?,
            max_batch: j.get("max_batch").and_then(|v| v.as_usize()).unwrap_or(4),
            clients,
        })
    }
}

/// Context-length targets chosen to cross the prefill bucket grid
/// (128/256/384/512): admission cost and bucket selection both vary.
const TARGET_LENS: [usize; 5] = [80, 120, 200, 300, 460];

fn client_script(r: &mut Rng, steps: usize) -> ClientScript {
    let join_step = r.below((steps / 2).max(1));
    let target = *r.choice(&TARGET_LENS);
    let (prompt, task_max_new) = match r.below(10) {
        0..=5 => {
            let subset = *r.choice(workload::RULER_SUBSETS);
            let t = workload::ruler_instance(subset, target, r);
            (t.prompt, t.max_new)
        }
        6 | 7 => {
            let subset = *r.choice(workload::LONGBENCH_SUBSETS);
            let t = workload::longbench_instance(subset, target, r);
            (t.prompt, t.max_new)
        }
        _ => {
            let a = workload::aime_instance(r);
            (a.task.prompt, a.task.max_new.min(48))
        }
    };
    let greedy = r.below(100) < 85;
    let max_new = match r.below(4) {
        0 => task_max_new.clamp(2, 48),
        1 => r.below(6) + 2,
        2 => r.below(24) + 4,
        _ => r.below(40) + 8,
    };
    let cancel_step = if r.below(100) < 20 { Some(join_step + 1 + r.below(12)) } else { None };
    let drop_step = if cancel_step.is_none() && r.below(100) < 12 {
        Some(join_step + 2 + r.below(12))
    } else {
        None
    };
    ClientScript {
        join_step,
        tenant: String::new(),
        prompt,
        policy: random_policy(r),
        structured_policy: r.below(100) < 30,
        max_new,
        greedy,
        seed: r.below(1 << 31) as u64,
        stop_newline: greedy && r.below(100) < 80,
        cancel_step,
        drop_step,
    }
}

/// Policy mix: threshold policies (including the decode-evicting tau=100
/// extreme), the budget family, recency/sink and random baselines, the
/// occasional oracle double pass, the rival zoo (keyformer blends, the
/// gated fastkvzip decode path, the value-norm budget press), and the
/// two-threshold tiered forms that exercise demote/rehydrate churn.
fn random_policy(r: &mut Rng) -> PolicySpec {
    match r.below(21) {
        0..=3 => PolicySpec::Kvzap {
            surrogate: Surrogate::Mlp,
            tau: *r.choice(&[-8.0, -4.0, -1.0]),
            floor: None,
            bits: QuantBits::Int8,
        },
        4 => PolicySpec::Kvzap {
            surrogate: Surrogate::Linear,
            tau: *r.choice(&[-6.0, -4.0]),
            floor: None,
            bits: QuantBits::Int8,
        },
        5 => PolicySpec::Kvzap {
            surrogate: Surrogate::Mlp,
            tau: 100.0,
            floor: None,
            bits: QuantBits::Int8,
        },
        6 | 7 => PolicySpec::Full,
        8 => PolicySpec::H2o { keep_frac: *r.choice(&[0.25, 0.5, 0.75]) },
        9 => PolicySpec::SnapKv { keep_frac: *r.choice(&[0.25, 0.5, 0.75]) },
        10 => PolicySpec::AdaKv { keep_frac: *r.choice(&[0.5, 0.75]) },
        11 => PolicySpec::Knorm { keep_frac: *r.choice(&[0.5, 0.75]) },
        12 => PolicySpec::StreamingLlm { keep_frac: 0.5, sinks: 4 },
        13 => PolicySpec::Random { keep_frac: *r.choice(&[0.3, 0.6]), seed: r.below(1000) as u64 },
        14 => PolicySpec::Kvzip { plus: false, keep_frac: 0.5 },
        15 => PolicySpec::KvzapTopk {
            surrogate: Surrogate::Mlp,
            keep_frac: 0.5,
            per_layer: false,
        },
        16 => PolicySpec::Keyformer {
            keep_frac: *r.choice(&[0.25, 0.5, 0.75]),
            mix: *r.choice(&[0.0, 0.5, 1.0]),
        },
        17 => {
            // include the decode-evicting tau=100 extreme so the gated
            // decode path (both surrogates must agree) gets fuzzed too
            let tau = *r.choice(&[-4.0, 100.0]);
            PolicySpec::FastKvzip {
                tau,
                gate_tau: *r.choice(&[tau, -4.0]),
                floor: None,
                bits: QuantBits::Int8,
            }
        }
        18 => PolicySpec::ExpectedAttnVnorm { keep_frac: *r.choice(&[0.5, 0.75]) },
        19 => {
            // tiered KVzap: an aggressive τ with a deep floor maximises
            // the demote band (and decode-time rehydration churn)
            let tau = *r.choice(&[-4.0, -1.0, 100.0]);
            PolicySpec::Kvzap {
                surrogate: Surrogate::Mlp,
                tau,
                floor: Some(*r.choice(&[-10.0, -8.0])),
                bits: *r.choice(&[QuantBits::Int8, QuantBits::Int4]),
            }
        }
        _ => {
            let tau = *r.choice(&[-4.0, 100.0]);
            PolicySpec::FastKvzip {
                tau,
                gate_tau: *r.choice(&[tau, -4.0]),
                floor: Some(-9.0),
                bits: QuantBits::Int8,
            }
        }
    }
}

/// Tiered-only policy mix for [`ScenarioSpec::generate_tiered`]: wide
/// demote bands (τ up to the evict-everything extreme, floors near the
/// bottom of the score range) across both two-threshold families.
fn tiered_policy(r: &mut Rng) -> PolicySpec {
    // every bit width shows up so quant-attend accounting is fuzzed over
    // int8/int4/int2 side tiers, not just the default
    let bits = *r.choice(&[QuantBits::Int8, QuantBits::Int4, QuantBits::Int2]);
    match r.below(3) {
        0 => PolicySpec::Kvzap {
            surrogate: Surrogate::Mlp,
            tau: *r.choice(&[-1.0, 100.0]),
            floor: Some(*r.choice(&[-10.0, -8.0])),
            bits,
        },
        1 => PolicySpec::Kvzap {
            surrogate: Surrogate::Linear,
            tau: *r.choice(&[-2.0, 100.0]),
            floor: Some(-9.0),
            bits,
        },
        _ => {
            let tau = *r.choice(&[-1.0, 100.0]);
            PolicySpec::FastKvzip {
                tau,
                gate_tau: *r.choice(&[tau, -1.0]),
                floor: Some(*r.choice(&[-10.0, -8.0])),
                bits,
            }
        }
    }
}
