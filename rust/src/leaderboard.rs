//! In-repo KVpress-style leaderboard: every cataloged policy × workload
//! suite × compression target, in one sweep.
//!
//! The paper's headline claim is a leaderboard ranking (KVzap vs KVzip,
//! H2O, SnapKV, Keyformer, Fast-KVzip, ExpectedAttention, ...). This
//! module reproduces that comparison in-repo: it walks [`CATALOG`], sweeps
//! each policy kind over the RULER/LongBench/AIME generators and a set of
//! compression targets (τ values for threshold policies, keep-fractions
//! for budget policies, and two-threshold `:floor=` variants for kinds
//! with a demotion band), and emits one `BENCH_leaderboard.json` with
//! accuracy, answer-NLL, compression-ratio, steady-state KV-bytes,
//! side-tier and scoring-overhead columns per (policy, suite) cell. The
//! sweep is CATALOG-driven, so a policy registered in
//! [`crate::policies::spec`] joins the leaderboard with no further wiring
//! — and [`run`] fails loudly if any cataloged kind ends up with zero
//! rows, or if a swept tiered spec never demotes (no silently-skipped
//! policy and no silently-empty demotion band; the CI `--quick` lane
//! relies on both). Alongside the classic compression frontier, [`run`]
//! prints an accuracy-vs-bytes frontier per suite and a dominance report
//! pairing each tiered spec against its drop-at-floor counterpart.
//!
//! Drive it via `kvzap leaderboard [--quick]` or
//! `cargo bench --bench bench_leaderboard`.

use anyhow::{anyhow, Result};

use crate::bench_support::{
    aggregate, default_taus, eval_policy, print_bytes_frontier, print_frontier,
    write_bench_json, KEEP_FRACS,
};
use crate::coordinator::Engine;
use crate::policies::spec::{PolicyInfo, CATALOG};
use crate::workload;

/// Sweep configuration (defaults via [`LeaderboardConfig::new`]).
pub struct LeaderboardConfig {
    /// Smoke mode: one subset per suite, one sample, one target per kind.
    pub quick: bool,
    /// Samples per (policy, subset) cell.
    pub samples: usize,
    /// Prompt context budget (bytes) for the ruler/longbench generators.
    pub ctx: usize,
    /// Base rng seed (forked per subset/sample inside the eval).
    pub seed: u64,
}

impl LeaderboardConfig {
    /// Default configuration for `quick` (CI smoke) or full mode.
    pub fn new(quick: bool) -> LeaderboardConfig {
        LeaderboardConfig {
            quick,
            samples: if quick { 1 } else { 3 },
            ctx: if quick { 160 } else { 248 },
            seed: 0,
        }
    }
}

/// One leaderboard cell: a policy spec evaluated over one suite.
#[derive(Debug, Clone)]
pub struct LeaderboardRow {
    /// Catalog kind tag (`"kvzap"`, `"keyformer"`, ...).
    pub kind: &'static str,
    /// Full policy spec string (kind + swept parameter).
    pub policy: String,
    /// Workload suite (`"ruler"` / `"longbench"` / `"aime"`).
    pub suite: &'static str,
    /// Mean exact-match accuracy across the suite's subsets.
    pub accuracy: f64,
    /// Mean teacher-forced answer NLL (nats/byte, lower = better).
    pub nll: f64,
    /// Mean removed fraction of the KV cache.
    pub compression: f64,
    /// Mean steady-state KV footprint in bytes (resident fp32 blocks +
    /// quantized side tier) — the x-axis of the accuracy-vs-bytes
    /// frontier.
    pub kv_bytes: f64,
    /// Mean KV entries held in the quantized side tier at steady state
    /// (non-zero only for two-threshold `:floor=` specs).
    pub demoted: f64,
    /// Side-tier code width the spec runs at (8/4/2; 8 for drop-only
    /// specs, whose band is empty).
    pub quant_bits: u8,
    /// Mean prefill wall-clock µs per sample.
    pub prefill_us: f64,
    /// Mean decode wall-clock µs per sample.
    pub decode_us: f64,
    /// Mean scoring overhead µs per sample: policy scoring/eviction time
    /// plus the KVzip oracle double pass where the policy needs one.
    pub scoring_us: f64,
}

/// The spec strings swept for one catalog kind: τ values for threshold
/// kinds (first parameter `tau`), keep-fractions for budget kinds. Quick
/// mode picks one mid-sweep target per kind.
///
/// Kinds that accept a `floor` parameter additionally sweep two-threshold
/// `:floor=` variants pairing each τ with the deepest swept τ as the
/// demotion floor — and the plain drop-only spec at that floor always
/// joins the sweep too, so every tiered point has the drop-at-floor
/// counterpart it must dominate on the bytes axis. Kinds that also accept
/// a `bits` parameter sweep every side-tier code width (int8 canonical,
/// `:bits=4`, `:bits=2`) per tiered point, putting the width trade-off
/// (side-pool bytes vs round-trip error) directly on the bytes frontier.
fn specs_for(info: &PolicyInfo, taus: &[f64], quick: bool) -> Vec<String> {
    let form = info.string_forms[0];
    if info.params.is_empty() {
        return vec![form.to_string()];
    }
    let is_threshold = info.params[0].name == "tau";
    let targets: Vec<f64> = if is_threshold {
        if quick {
            vec![taus[taus.len() / 2]]
        } else {
            taus.to_vec()
        }
    } else if quick {
        vec![0.5]
    } else {
        KEEP_FRACS.to_vec()
    };
    let mut specs: Vec<String> = targets.iter().map(|t| format!("{form}:{t}")).collect();
    if is_threshold && info.params.iter().any(|p| p.name == "floor") {
        let floor = taus[0];
        if !targets.contains(&floor) {
            specs.insert(0, format!("{form}:{floor}"));
        }
        let widths: &[&str] = if info.params.iter().any(|p| p.name == "bits") {
            &["", ":bits=4", ":bits=2"]
        } else {
            &[""]
        };
        for t in targets.iter().filter(|&&t| t > floor) {
            for w in widths {
                specs.push(format!("{form}:{t}:floor={floor}{w}"));
            }
        }
    }
    specs
}

/// Side-tier code width carried by a spec string: the trailing `:bits=`
/// segment when present, else the int8 default (also what drop-only specs
/// report — their band is empty, so the width is nominal).
fn spec_quant_bits(spec: &str) -> u8 {
    spec.split_once(":bits=")
        .and_then(|(_, b)| b.parse::<u8>().ok())
        .unwrap_or(8)
}

/// Run the full sweep; one row per (cataloged policy spec, suite).
pub fn sweep(engine: &Engine, cfg: &LeaderboardConfig) -> Result<Vec<LeaderboardRow>> {
    let taus = default_taus(engine);
    let mut rows = vec![];
    for info in CATALOG {
        for spec in specs_for(info, &taus, cfg.quick) {
            for &suite in workload::SUITES {
                let subsets = workload::eval_subsets(suite, cfg.quick);
                eprintln!("  [leaderboard] {spec} x {suite} ({} subsets)", subsets.len());
                let cells =
                    eval_policy(engine, suite, subsets, &spec, cfg.samples, cfg.ctx, cfg.seed)?;
                let (acc, comp, nll) = aggregate(&cells);
                let n = cells.len() as f64;
                let mean = |f: fn(&crate::bench_support::EvalRow) -> f64| {
                    cells.iter().map(f).sum::<f64>() / n
                };
                rows.push(LeaderboardRow {
                    kind: info.kind,
                    policy: spec.clone(),
                    suite,
                    accuracy: acc,
                    nll,
                    compression: comp,
                    kv_bytes: mean(|r| r.kv_bytes),
                    demoted: mean(|r| r.demoted),
                    quant_bits: spec_quant_bits(&spec),
                    prefill_us: mean(|r| r.prefill_us),
                    decode_us: mean(|r| r.decode_us),
                    scoring_us: mean(|r| r.policy_us + r.oracle_us),
                });
            }
        }
    }
    Ok(rows)
}

/// Fail if any cataloged policy kind produced zero rows — a silently
/// skipped policy would otherwise just vanish from the leaderboard.
pub fn assert_coverage(rows: &[LeaderboardRow]) -> Result<()> {
    let missing: Vec<&str> = CATALOG
        .iter()
        .map(|info| info.kind)
        .filter(|kind| !rows.iter().any(|r| r.kind == *kind))
        .collect();
    if missing.is_empty() {
        Ok(())
    } else {
        Err(anyhow!("leaderboard skipped catalog kinds: {missing:?}"))
    }
}

/// Fail if any swept two-threshold `:floor=` spec never parked a single
/// entry in the quantized side tier on any suite — an always-empty
/// demotion band means the tiered plumbing silently degenerated to
/// drop-only (the CI `--quick` lane relies on this firing).
pub fn assert_tiered_coverage(rows: &[LeaderboardRow]) -> Result<()> {
    let mut empty: Vec<&str> = vec![];
    for r in rows.iter().filter(|r| r.policy.contains(":floor=")) {
        if empty.contains(&r.policy.as_str()) {
            continue;
        }
        let demoted_somewhere =
            rows.iter().any(|o| o.policy == r.policy && o.demoted > 0.0);
        if !demoted_somewhere {
            empty.push(&r.policy);
        }
    }
    if empty.is_empty() {
        Ok(())
    } else {
        Err(anyhow!("tiered specs with an always-empty demotion band: {empty:?}"))
    }
}

/// One tiered-vs-drop-only comparison on the accuracy-vs-bytes frontier:
/// the two-threshold spec `form:τ:floor=f[:bits=b]` against the plain
/// drop-only spec `form:f` that retains the same score band (resident, in
/// fp32). The tiered point holds the `[f, τ)` band in quantized side
/// entries instead of fp32 blocks, so it should reach the same accuracy at
/// strictly fewer bytes — [`DominancePair::dominates`] checks exactly
/// that. Every swept code width pairs against the *same* drop-at-floor
/// counterpart, so the report reads as a width ladder: narrower codes buy
/// fewer bytes against the same fp32 baseline at (ideally) no accuracy
/// cost.
#[derive(Debug, Clone)]
pub struct DominancePair {
    /// The two-threshold spec string.
    pub tiered: String,
    /// Side-tier code width of the tiered spec (8/4/2).
    pub quant_bits: u8,
    /// The drop-only spec at τ' = floor (same retained band, all fp32).
    pub drop_at_floor: String,
    /// Mean steady-state bytes for the tiered spec.
    pub tiered_bytes: f64,
    /// Mean steady-state bytes for the drop-only counterpart.
    pub drop_bytes: f64,
    /// Mean accuracy for the tiered spec.
    pub tiered_acc: f64,
    /// Mean accuracy for the drop-only counterpart.
    pub drop_acc: f64,
    /// Mean answer NLL for the tiered spec.
    pub tiered_nll: f64,
    /// Mean answer NLL for the drop-only counterpart.
    pub drop_nll: f64,
}

impl DominancePair {
    /// Strict dominance on the (accuracy ↑, bytes ↓) plane: no accuracy
    /// lost and strictly fewer bytes than keeping the band resident.
    pub fn dominates(&self) -> bool {
        self.tiered_acc >= self.drop_acc && self.tiered_bytes < self.drop_bytes
    }
}

/// Pair every two-threshold row on `suite` with its drop-at-floor
/// counterpart from the same sweep (specs_for always co-schedules it).
pub fn dominance_pairs(rows: &[LeaderboardRow], suite: &str) -> Vec<DominancePair> {
    let mut pairs = vec![];
    for r in rows.iter().filter(|r| r.suite == suite) {
        let Some((base, floor)) = r.policy.split_once(":floor=") else { continue };
        let Some((form, _tau)) = base.rsplit_once(':') else { continue };
        // a trailing ":bits=" segment belongs to the tiered spec, not the
        // floor value — every code width pairs against the same fp32
        // drop-at-floor counterpart
        let floor = floor.split_once(":bits=").map_or(floor, |(f, _)| f);
        let floor_spec = format!("{form}:{floor}");
        if let Some(d) =
            rows.iter().find(|d| d.suite == suite && d.policy == floor_spec)
        {
            pairs.push(DominancePair {
                tiered: r.policy.clone(),
                quant_bits: r.quant_bits,
                drop_at_floor: floor_spec,
                tiered_bytes: r.kv_bytes,
                drop_bytes: d.kv_bytes,
                tiered_acc: r.accuracy,
                drop_acc: d.accuracy,
                tiered_nll: r.nll,
                drop_nll: d.nll,
            });
        }
    }
    pairs
}

fn render_row(r: &LeaderboardRow) -> String {
    format!(
        "{{\"kind\": \"{}\", \"policy\": \"{}\", \"suite\": \"{}\", \"accuracy\": {:.4}, \
         \"nll\": {:.4}, \"compression\": {:.4}, \"kv_bytes\": {:.1}, \"demoted\": {:.2}, \
         \"quant_bits\": {}, \"prefill_us\": {:.1}, \"decode_us\": {:.1}, \"scoring_us\": {:.1}}}",
        r.kind,
        r.policy,
        r.suite,
        r.accuracy,
        r.nll,
        r.compression,
        r.kv_bytes,
        r.demoted,
        r.quant_bits,
        r.prefill_us,
        r.decode_us,
        r.scoring_us
    )
}

/// Sweep, verify catalog + tiered coverage, write
/// `BENCH_leaderboard.json`, and print per-suite frontier tables — the
/// classic compression frontier plus the accuracy-vs-bytes frontier with
/// a tiered-vs-drop-at-floor dominance report. Returns the rows for
/// callers that want to post-process (tests, future report generators).
pub fn run(engine: &Engine, cfg: &LeaderboardConfig) -> Result<Vec<LeaderboardRow>> {
    let rows = sweep(engine, cfg)?;
    assert_coverage(&rows)?;
    assert_tiered_coverage(&rows)?;
    let rendered: Vec<String> = rows.iter().map(render_row).collect();
    write_bench_json("leaderboard", engine.rt.backend_name(), cfg.quick, &rendered)?;
    for &suite in workload::SUITES {
        let points: Vec<(String, f64, f64, f64)> = rows
            .iter()
            .filter(|r| r.suite == suite)
            .map(|r| (r.policy.clone(), r.compression, r.accuracy, r.nll))
            .collect();
        print_frontier(&format!("leaderboard: {suite}"), &points);
        let bytes_points: Vec<(String, f64, f64, f64)> = rows
            .iter()
            .filter(|r| r.suite == suite)
            .map(|r| (r.policy.clone(), r.kv_bytes, r.accuracy, r.nll))
            .collect();
        print_bytes_frontier(
            &format!("leaderboard: {suite} (accuracy vs bytes)"),
            &bytes_points,
        );
        let pairs = dominance_pairs(&rows, suite);
        if !pairs.is_empty() {
            println!("\n== dominance: {suite} (tiered vs drop-at-floor)");
            for p in pairs {
                println!(
                    "{:<40} [int{}] vs {:<20} {:>8.0} vs {:>8.0} bytes, acc {:>5.1}% vs {:>5.1}%, \
                     nll {:.3} vs {:.3} -> {}",
                    p.tiered,
                    p.quant_bits,
                    p.drop_at_floor,
                    p.tiered_bytes,
                    p.drop_bytes,
                    100.0 * p.tiered_acc,
                    100.0 * p.drop_acc,
                    p.tiered_nll,
                    p.drop_nll,
                    if p.dominates() { "DOMINATES" } else { "dominated/mixed" }
                );
            }
        }
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(policy: &str, suite: &'static str, acc: f64, bytes: f64, dem: f64) -> LeaderboardRow {
        LeaderboardRow {
            kind: "kvzap",
            quant_bits: spec_quant_bits(policy),
            policy: policy.into(),
            suite,
            accuracy: acc,
            nll: 1.0,
            compression: 0.5,
            kv_bytes: bytes,
            demoted: dem,
            prefill_us: 0.0,
            decode_us: 0.0,
            scoring_us: 0.0,
        }
    }

    #[test]
    fn specs_cover_every_catalog_kind_and_parse() {
        let taus = vec![-8.0, -6.0, -4.0, -3.0];
        for quick in [true, false] {
            for info in CATALOG {
                let specs = specs_for(info, &taus, quick);
                assert!(!specs.is_empty(), "{}: no specs", info.kind);
                for s in specs {
                    let parsed = crate::policies::PolicySpec::parse(&s)
                        .unwrap_or_else(|e| panic!("{}: '{s}': {e}", info.kind));
                    assert_eq!(parsed.kind(), info.kind, "spec '{s}'");
                }
            }
        }
    }

    #[test]
    fn floor_kinds_sweep_tiered_specs_with_drop_at_floor_counterpart() {
        let taus = vec![-8.0, -6.0, -4.0, -3.0];
        for info in CATALOG {
            let has_floor = info.params.iter().any(|p| p.name == "floor");
            for quick in [true, false] {
                let specs = specs_for(info, &taus, quick);
                let tiered: Vec<&String> =
                    specs.iter().filter(|s| s.contains(":floor=")).collect();
                if !has_floor {
                    assert!(tiered.is_empty(), "{}: unexpected tiered specs", info.kind);
                    continue;
                }
                assert!(!tiered.is_empty(), "{}: no tiered specs swept", info.kind);
                for t in &tiered {
                    // every tiered spec's drop-at-floor counterpart is
                    // co-scheduled so the dominance pair exists in-sweep
                    let (base, floor) = t.split_once(":floor=").unwrap();
                    let (form, _) = base.rsplit_once(':').unwrap();
                    let floor = floor.split_once(":bits=").map_or(floor, |(f, _)| f);
                    let counterpart = format!("{form}:{floor}");
                    assert!(
                        specs.contains(&counterpart),
                        "{}: '{t}' swept without '{counterpart}'",
                        info.kind
                    );
                }
                // bits-capable kinds ladder every tiered point across the
                // swept code widths (int8 canonical has no suffix)
                if info.params.iter().any(|p| p.name == "bits") {
                    for w in [8u8, 4, 2] {
                        assert!(
                            tiered.iter().any(|s| spec_quant_bits(s) == w),
                            "{}: no tiered spec at int{w}",
                            info.kind
                        );
                    }
                    let n_tiered = tiered.len();
                    assert_eq!(n_tiered % 3, 0, "{}: widths unevenly swept", info.kind);
                }
            }
        }
    }

    #[test]
    fn coverage_check_catches_missing_kind() {
        let mut r = row("full", "ruler", 1.0, 0.0, 0.0);
        r.kind = "full";
        let err = assert_coverage(&[r]).unwrap_err().to_string();
        assert!(err.contains("keyformer"), "{err}");
        assert!(err.contains("fastkvzip"), "{err}");
    }

    #[test]
    fn tiered_coverage_check_catches_empty_demotion_band() {
        let ok = vec![
            row("kvzap_mlp:-4:floor=-8", "ruler", 0.5, 100.0, 0.0),
            row("kvzap_mlp:-4:floor=-8", "longbench", 0.5, 100.0, 3.0),
        ];
        assert_tiered_coverage(&ok).unwrap();
        let bad = vec![row("kvzap_mlp:-4:floor=-8", "ruler", 0.5, 100.0, 0.0)];
        let err = assert_tiered_coverage(&bad).unwrap_err().to_string();
        assert!(err.contains("kvzap_mlp:-4:floor=-8"), "{err}");
        // drop-only rows never trip the check
        assert_tiered_coverage(&[row("kvzap_mlp:-4", "ruler", 0.5, 100.0, 0.0)]).unwrap();
    }

    #[test]
    fn dominance_pairs_match_tiered_rows_to_drop_at_floor() {
        let rows = vec![
            row("kvzap_mlp:-4", "ruler", 0.5, 80.0, 0.0),
            row("kvzap_mlp:-8", "ruler", 0.75, 200.0, 0.0),
            row("kvzap_mlp:-4:floor=-8", "ruler", 0.75, 140.0, 6.0),
            // same specs on another suite must not cross-pair
            row("kvzap_mlp:-8", "longbench", 0.9, 999.0, 0.0),
        ];
        let pairs = dominance_pairs(&rows, "ruler");
        assert_eq!(pairs.len(), 1);
        let p = &pairs[0];
        assert_eq!(p.tiered, "kvzap_mlp:-4:floor=-8");
        assert_eq!(p.drop_at_floor, "kvzap_mlp:-8");
        assert_eq!(p.drop_bytes, 200.0);
        assert_eq!(p.quant_bits, 8);
        assert!(p.dominates(), "equal accuracy at fewer bytes dominates");
        // losing accuracy or gaining bytes breaks dominance
        let mut worse = p.clone();
        worse.tiered_acc = 0.5;
        assert!(!worse.dominates());
        let mut heavier = p.clone();
        heavier.tiered_bytes = 200.0;
        assert!(!heavier.dominates());
    }

    #[test]
    fn dominance_pairs_ladder_code_widths_against_one_counterpart() {
        let rows = vec![
            row("kvzap_mlp:-8", "ruler", 0.75, 200.0, 0.0),
            row("kvzap_mlp:-4:floor=-8", "ruler", 0.75, 140.0, 6.0),
            row("kvzap_mlp:-4:floor=-8:bits=4", "ruler", 0.74, 110.0, 6.0),
            row("kvzap_mlp:-4:floor=-8:bits=2", "ruler", 0.70, 95.0, 6.0),
        ];
        let pairs = dominance_pairs(&rows, "ruler");
        assert_eq!(pairs.len(), 3, "every width pairs");
        for p in &pairs {
            // the ":bits=" suffix never leaks into the floor counterpart
            assert_eq!(p.drop_at_floor, "kvzap_mlp:-8", "tiered {}", p.tiered);
            assert_eq!(p.drop_bytes, 200.0);
        }
        let widths: Vec<u8> = pairs.iter().map(|p| p.quant_bits).collect();
        assert_eq!(widths, vec![8, 4, 2]);
    }

    #[test]
    fn rows_render_as_json_objects() {
        let mut r = row("h2o:0.5", "ruler", 0.5, 4096.0, 12.0);
        r.kind = "h2o";
        r.nll = 1.25;
        r.compression = 0.4;
        r.scoring_us = 3.5;
        let j = crate::util::json::Json::parse(&render_row(&r)).unwrap();
        assert_eq!(j.get("kind").and_then(|v| v.as_str()), Some("h2o"));
        assert_eq!(j.get("accuracy").and_then(|v| v.as_f64()), Some(0.5));
        assert_eq!(j.get("kv_bytes").and_then(|v| v.as_f64()), Some(4096.0));
        assert_eq!(j.get("demoted").and_then(|v| v.as_f64()), Some(12.0));
        assert_eq!(j.get("quant_bits").and_then(|v| v.as_f64()), Some(8.0));
        assert_eq!(j.get("scoring_us").and_then(|v| v.as_f64()), Some(3.5));
    }

    #[test]
    fn spec_quant_bits_reads_the_trailing_segment() {
        assert_eq!(spec_quant_bits("kvzap_mlp:-4"), 8);
        assert_eq!(spec_quant_bits("kvzap_mlp:-4:floor=-8"), 8);
        assert_eq!(spec_quant_bits("kvzap_mlp:-4:floor=-8:bits=4"), 4);
        assert_eq!(spec_quant_bits("fastkvzip:-4:floor=-8:bits=2"), 2);
    }
}
