//! In-repo KVpress-style leaderboard: every cataloged policy × workload
//! suite × compression target, in one sweep.
//!
//! The paper's headline claim is a leaderboard ranking (KVzap vs KVzip,
//! H2O, SnapKV, Keyformer, Fast-KVzip, ExpectedAttention, ...). This
//! module reproduces that comparison in-repo: it walks [`CATALOG`], sweeps
//! each policy kind over the RULER/LongBench/AIME generators and a set of
//! compression targets (τ values for threshold policies, keep-fractions
//! for budget policies), and emits one `BENCH_leaderboard.json` with
//! accuracy, answer-NLL, compression-ratio and scoring-overhead columns
//! per (policy, suite) cell. The sweep is CATALOG-driven, so a policy
//! registered in [`crate::policies::spec`] joins the leaderboard with no
//! further wiring — and [`run`] fails loudly if any cataloged kind ends up
//! with zero rows (no silently-skipped policy; the CI `--quick` lane
//! relies on this).
//!
//! Drive it via `kvzap leaderboard [--quick]` or
//! `cargo bench --bench bench_leaderboard`.

use anyhow::{anyhow, Result};

use crate::bench_support::{
    aggregate, default_taus, eval_policy, print_frontier, write_bench_json, KEEP_FRACS,
};
use crate::coordinator::Engine;
use crate::policies::spec::{PolicyInfo, CATALOG};
use crate::workload;

/// Sweep configuration (defaults via [`LeaderboardConfig::new`]).
pub struct LeaderboardConfig {
    /// Smoke mode: one subset per suite, one sample, one target per kind.
    pub quick: bool,
    /// Samples per (policy, subset) cell.
    pub samples: usize,
    /// Prompt context budget (bytes) for the ruler/longbench generators.
    pub ctx: usize,
    /// Base rng seed (forked per subset/sample inside the eval).
    pub seed: u64,
}

impl LeaderboardConfig {
    /// Default configuration for `quick` (CI smoke) or full mode.
    pub fn new(quick: bool) -> LeaderboardConfig {
        LeaderboardConfig {
            quick,
            samples: if quick { 1 } else { 3 },
            ctx: if quick { 160 } else { 248 },
            seed: 0,
        }
    }
}

/// One leaderboard cell: a policy spec evaluated over one suite.
#[derive(Debug, Clone)]
pub struct LeaderboardRow {
    /// Catalog kind tag (`"kvzap"`, `"keyformer"`, ...).
    pub kind: &'static str,
    /// Full policy spec string (kind + swept parameter).
    pub policy: String,
    /// Workload suite (`"ruler"` / `"longbench"` / `"aime"`).
    pub suite: &'static str,
    /// Mean exact-match accuracy across the suite's subsets.
    pub accuracy: f64,
    /// Mean teacher-forced answer NLL (nats/byte, lower = better).
    pub nll: f64,
    /// Mean removed fraction of the KV cache.
    pub compression: f64,
    /// Mean prefill wall-clock µs per sample.
    pub prefill_us: f64,
    /// Mean decode wall-clock µs per sample.
    pub decode_us: f64,
    /// Mean scoring overhead µs per sample: policy scoring/eviction time
    /// plus the KVzip oracle double pass where the policy needs one.
    pub scoring_us: f64,
}

/// The spec strings swept for one catalog kind: τ values for threshold
/// kinds (first parameter `tau`), keep-fractions for budget kinds. Quick
/// mode picks one mid-sweep target per kind.
fn specs_for(info: &PolicyInfo, taus: &[f64], quick: bool) -> Vec<String> {
    let form = info.string_forms[0];
    if info.params.is_empty() {
        return vec![form.to_string()];
    }
    let is_threshold = info.params[0].name == "tau";
    let targets: Vec<f64> = if is_threshold {
        if quick {
            vec![taus[taus.len() / 2]]
        } else {
            taus.to_vec()
        }
    } else if quick {
        vec![0.5]
    } else {
        KEEP_FRACS.to_vec()
    };
    targets.iter().map(|t| format!("{form}:{t}")).collect()
}

/// Run the full sweep; one row per (cataloged policy spec, suite).
pub fn sweep(engine: &Engine, cfg: &LeaderboardConfig) -> Result<Vec<LeaderboardRow>> {
    let taus = default_taus(engine);
    let mut rows = vec![];
    for info in CATALOG {
        for spec in specs_for(info, &taus, cfg.quick) {
            for &suite in workload::SUITES {
                let subsets = workload::eval_subsets(suite, cfg.quick);
                eprintln!("  [leaderboard] {spec} x {suite} ({} subsets)", subsets.len());
                let cells =
                    eval_policy(engine, suite, subsets, &spec, cfg.samples, cfg.ctx, cfg.seed)?;
                let (acc, comp, nll) = aggregate(&cells);
                let n = cells.len() as f64;
                let mean = |f: fn(&crate::bench_support::EvalRow) -> f64| {
                    cells.iter().map(f).sum::<f64>() / n
                };
                rows.push(LeaderboardRow {
                    kind: info.kind,
                    policy: spec.clone(),
                    suite,
                    accuracy: acc,
                    nll,
                    compression: comp,
                    prefill_us: mean(|r| r.prefill_us),
                    decode_us: mean(|r| r.decode_us),
                    scoring_us: mean(|r| r.policy_us + r.oracle_us),
                });
            }
        }
    }
    Ok(rows)
}

/// Fail if any cataloged policy kind produced zero rows — a silently
/// skipped policy would otherwise just vanish from the leaderboard.
pub fn assert_coverage(rows: &[LeaderboardRow]) -> Result<()> {
    let missing: Vec<&str> = CATALOG
        .iter()
        .map(|info| info.kind)
        .filter(|kind| !rows.iter().any(|r| r.kind == *kind))
        .collect();
    if missing.is_empty() {
        Ok(())
    } else {
        Err(anyhow!("leaderboard skipped catalog kinds: {missing:?}"))
    }
}

fn render_row(r: &LeaderboardRow) -> String {
    format!(
        "{{\"kind\": \"{}\", \"policy\": \"{}\", \"suite\": \"{}\", \"accuracy\": {:.4}, \
         \"nll\": {:.4}, \"compression\": {:.4}, \"prefill_us\": {:.1}, \"decode_us\": {:.1}, \
         \"scoring_us\": {:.1}}}",
        r.kind,
        r.policy,
        r.suite,
        r.accuracy,
        r.nll,
        r.compression,
        r.prefill_us,
        r.decode_us,
        r.scoring_us
    )
}

/// Sweep, verify catalog coverage, write `BENCH_leaderboard.json`, and
/// print per-suite frontier tables. Returns the rows for callers that
/// want to post-process (tests, future report generators).
pub fn run(engine: &Engine, cfg: &LeaderboardConfig) -> Result<Vec<LeaderboardRow>> {
    let rows = sweep(engine, cfg)?;
    assert_coverage(&rows)?;
    let rendered: Vec<String> = rows.iter().map(render_row).collect();
    write_bench_json("leaderboard", engine.rt.backend_name(), cfg.quick, &rendered)?;
    for &suite in workload::SUITES {
        let points: Vec<(String, f64, f64, f64)> = rows
            .iter()
            .filter(|r| r.suite == suite)
            .map(|r| (r.policy.clone(), r.compression, r.accuracy, r.nll))
            .collect();
        print_frontier(&format!("leaderboard: {suite}"), &points);
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_cover_every_catalog_kind_and_parse() {
        let taus = vec![-8.0, -6.0, -4.0, -3.0];
        for quick in [true, false] {
            for info in CATALOG {
                let specs = specs_for(info, &taus, quick);
                assert!(!specs.is_empty(), "{}: no specs", info.kind);
                for s in specs {
                    let parsed = crate::policies::PolicySpec::parse(&s)
                        .unwrap_or_else(|e| panic!("{}: '{s}': {e}", info.kind));
                    assert_eq!(parsed.kind(), info.kind, "spec '{s}'");
                }
            }
        }
    }

    #[test]
    fn coverage_check_catches_missing_kind() {
        let row = LeaderboardRow {
            kind: "full",
            policy: "full".into(),
            suite: "ruler",
            accuracy: 1.0,
            nll: 0.0,
            compression: 0.0,
            prefill_us: 0.0,
            decode_us: 0.0,
            scoring_us: 0.0,
        };
        let err = assert_coverage(&[row]).unwrap_err().to_string();
        assert!(err.contains("keyformer"), "{err}");
        assert!(err.contains("fastkvzip"), "{err}");
    }

    #[test]
    fn rows_render_as_json_objects() {
        let row = LeaderboardRow {
            kind: "h2o",
            policy: "h2o:0.5".into(),
            suite: "ruler",
            accuracy: 0.5,
            nll: 1.25,
            compression: 0.4,
            prefill_us: 100.0,
            decode_us: 200.0,
            scoring_us: 3.5,
        };
        let j = crate::util::json::Json::parse(&render_row(&row)).unwrap();
        assert_eq!(j.get("kind").and_then(|v| v.as_str()), Some("h2o"));
        assert_eq!(j.get("accuracy").and_then(|v| v.as_f64()), Some(0.5));
        assert_eq!(j.get("scoring_us").and_then(|v| v.as_f64()), Some(3.5));
    }
}
