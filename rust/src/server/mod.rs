//! JSON-lines TCP serving frontend.
//!
//! Protocol: one JSON object per line.
//!   request : {"prompt": str, "policy": str, "max_new": int,
//!              "greedy": bool?, "temperature": f?, "top_k": int?,
//!              "top_p": f?, "seed": int?}
//!   response: {"text": str, "compression": f, "tokens_out": int,
//!              "e2e_us": int, "error": str?}
//!   special : {"cmd": "metrics"} -> metrics report; {"cmd": "shutdown"}
//!
//! Connections are handled by a small thread-per-connection frontend; all
//! generation funnels through the shared [`Batcher`] so concurrent clients
//! get batched together (the continuous-batching path).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};

use anyhow::{Context, Result};

use crate::coordinator::{Batcher, Engine, Request, SamplingParams};
use crate::util::json::Json;

pub struct ServerConfig {
    pub addr: String,
    pub default_policy: String,
    pub max_batch: usize,
    pub max_wait_us: u64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:7712".into(),
            default_policy: "kvzap_mlp:-4".into(),
            max_batch: 4,
            max_wait_us: 2_000,
        }
    }
}

pub fn parse_request(line: &str, default_policy: &str) -> Result<(String, String, SamplingParams)> {
    let j = Json::parse(line).map_err(|e| anyhow::anyhow!("bad json: {e}"))?;
    let prompt = j
        .get("prompt")
        .and_then(|p| p.as_str())
        .context("missing 'prompt'")?
        .to_string();
    let policy = j
        .get("policy")
        .and_then(|p| p.as_str())
        .unwrap_or(default_policy)
        .to_string();
    let max_new = j.get("max_new").and_then(|v| v.as_usize()).unwrap_or(32);
    let greedy = j.get("greedy").and_then(|v| v.as_bool()).unwrap_or(true);
    let mut sp = if greedy {
        SamplingParams::greedy(max_new)
    } else {
        SamplingParams::reasoning(max_new, j.get("seed").and_then(|v| v.as_i64()).unwrap_or(0) as u64)
    };
    if let Some(t) = j.get("temperature").and_then(|v| v.as_f64()) {
        sp.temperature = t as f32;
    }
    if let Some(k) = j.get("top_k").and_then(|v| v.as_usize()) {
        sp.top_k = k;
    }
    if let Some(p) = j.get("top_p").and_then(|v| v.as_f64()) {
        sp.top_p = p as f32;
    }
    Ok((prompt, policy, sp))
}

pub fn response_json(r: &crate::coordinator::Response) -> String {
    let mut pairs = vec![
        ("text", Json::str(r.text.clone())),
        ("compression", Json::num(r.compression)),
        ("tokens_out", Json::num(r.tokens_out as f64)),
        ("e2e_us", Json::num(r.e2e_us as f64)),
    ];
    if let Some(e) = &r.error {
        pairs.push(("error", Json::str(e.clone())));
    }
    Json::obj(pairs).dump()
}

pub struct Server {
    pub engine: Arc<Engine>,
    batcher: Arc<Batcher>,
    cfg: ServerConfig,
    stop: Arc<AtomicBool>,
}

impl Server {
    pub fn new(engine: Arc<Engine>, cfg: ServerConfig) -> Server {
        let batcher = Arc::new(Batcher::start(
            engine.clone(),
            crate::coordinator::BatcherConfig {
                max_batch: cfg.max_batch,
                max_wait_us: cfg.max_wait_us,
            },
        ));
        Server { engine, batcher, cfg, stop: Arc::new(AtomicBool::new(false)) }
    }

    /// Blocking accept loop. Returns when a client sends {"cmd":"shutdown"}.
    pub fn serve(&self) -> Result<()> {
        let listener = TcpListener::bind(&self.cfg.addr)
            .with_context(|| format!("bind {}", self.cfg.addr))?;
        listener.set_nonblocking(true)?;
        eprintln!("[kvzap] serving on {}", self.cfg.addr);
        let mut handles = vec![];
        while !self.stop.load(Ordering::Relaxed) {
            match listener.accept() {
                Ok((stream, _)) => {
                    let batcher = self.batcher.clone();
                    let engine = self.engine.clone();
                    let stop = self.stop.clone();
                    let default_policy = self.cfg.default_policy.clone();
                    handles.push(std::thread::spawn(move || {
                        let _ = handle_conn(stream, batcher, engine, stop, default_policy);
                    }));
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Err(e) => return Err(e.into()),
            }
        }
        for h in handles {
            let _ = h.join();
        }
        Ok(())
    }
}

fn handle_conn(
    stream: TcpStream,
    batcher: Arc<Batcher>,
    engine: Arc<Engine>,
    stop: Arc<AtomicBool>,
    default_policy: String,
) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        if let Ok(j) = Json::parse(&line) {
            match j.get("cmd").and_then(|c| c.as_str()) {
                Some("metrics") => {
                    let rep = Json::obj(vec![("metrics", Json::str(engine.metrics.report()))]);
                    writeln!(writer, "{}", rep.dump())?;
                    continue;
                }
                Some("shutdown") => {
                    stop.store(true, Ordering::Relaxed);
                    writeln!(writer, "{}", Json::obj(vec![("ok", Json::Bool(true))]).dump())?;
                    return Ok(());
                }
                _ => {}
            }
        }
        match parse_request(&line, &default_policy) {
            Ok((prompt, policy, sp)) => {
                let (tx, rx) = mpsc::channel();
                batcher.submit(Request { prompt, policy, sp, resp: tx })?;
                let resp = rx.recv()?;
                writeln!(writer, "{}", response_json(&resp))?;
            }
            Err(e) => {
                let err = Json::obj(vec![("error", Json::str(format!("{e:#}")))]);
                writeln!(writer, "{}", err.dump())?;
            }
        }
    }
    Ok(())
}

/// Minimal blocking client (used by examples and integration tests).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    pub fn request(&mut self, body: &Json) -> Result<Json> {
        writeln!(self.writer, "{}", body.dump())?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Json::parse(&line).map_err(|e| anyhow::anyhow!("bad response: {e}"))
    }

    pub fn shutdown(&mut self) -> Result<()> {
        writeln!(self.writer, "{}", Json::obj(vec![("cmd", Json::str("shutdown"))]).dump())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_request_defaults() {
        let (p, pol, sp) =
            parse_request(r#"{"prompt": "hi", "max_new": 7}"#, "kvzap_mlp:-4").unwrap();
        assert_eq!(p, "hi");
        assert_eq!(pol, "kvzap_mlp:-4");
        assert_eq!(sp.max_new, 7);
        assert!(sp.greedy);
    }

    #[test]
    fn parse_request_sampling_overrides() {
        let (_, _, sp) = parse_request(
            r#"{"prompt":"x","greedy":false,"temperature":0.8,"top_k":5,"top_p":0.9,"seed":3}"#,
            "full",
        )
        .unwrap();
        assert!(!sp.greedy);
        assert!((sp.temperature - 0.8).abs() < 1e-6);
        assert_eq!(sp.top_k, 5);
    }

    #[test]
    fn parse_request_rejects_missing_prompt() {
        assert!(parse_request(r#"{"max_new": 2}"#, "full").is_err());
    }
}
