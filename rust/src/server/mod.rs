//! JSON-lines TCP serving frontend (protocol v2).
//!
//! One JSON object per line, in both directions.
//!
//! Generation request:
//!   {"prompt": str,                      required
//!    "policy": str | object,             "kvzap_mlp:-4" or
//!                                        {"kind": "kvzap", "surrogate":
//!                                         "mlp", "tau": -4.0} — see
//!                                        {"cmd": "policies"}
//!    "max_new": int, "greedy": bool?, "temperature": f?, "top_k": int?,
//!    "top_p": f?, "seed": int?, "stop_newline": bool?,
//!    "stream": bool?,                    default false
//!    "id": str | num?}                   echoed in events; auto-assigned
//!                                        when absent
//!
//! Non-streaming response (back-compatible with protocol v1):
//!   {"text": str, "compression": f, "tokens_out": int, "e2e_us": int,
//!    "id"?: as sent, "error"?: str}
//!
//! Streaming (`"stream": true`): one line per accepted token, then a
//! final done line — tokens from concurrent requests interleave, keyed by
//! id:
//!   {"event": "token", "id": ..., "token": int, "text": str}
//!   {"event": "done", "id": ..., "text": str, "compression": f,
//!    "tokens_out": int, "e2e_us": int,
//!    "reason": "stop"|"max_tokens"|"cache_full"|"cancelled", "error"?: str}
//!
//! Commands:
//!   {"cmd": "metrics"}            -> {"metrics": str}
//!   {"cmd": "stats"}              -> {"stats": {...}} structured counters:
//!                                    requests/tokens_out/mean_compression
//!                                    plus host<->device transfer accounting
//!                                    (kv_bytes_up, kv_bytes_down,
//!                                    mask_uploads, bytes_up, bytes_down,
//!                                    decode_steps, backend) — the
//!                                    device-resident KV cache shows up
//!                                    here as kv_bytes staying flat while
//!                                    decode_steps grows
//!   {"cmd": "policies"}           -> {"policies": [catalog...]}
//!   {"cmd": "cancel", "id": ...}  -> {"ok": bool}; the cancelled stream
//!                                    receives its done line with reason
//!                                    "cancelled" and its slot is freed
//!                                    mid-decode
//!   {"cmd": "shutdown"}           -> {"ok": true}; stops the server
//!
//! Connections are handled by a small thread-per-connection frontend; all
//! generation funnels through the shared [`Batcher`], whose continuous
//! scheduler lets requests join a running decode group whenever a slot
//! frees (each request keeps its own sampling params and policy).
//!
//! The per-connection protocol loop (`serve_lines`) is generic over the
//! transport (any `BufRead` in, any `Write` out): the TCP frontend wraps a
//! socket, and [`headless`] runs the same loop over in-process channels —
//! no ports, no threads beyond the connection's own — which is what the
//! error-path tests and tools that embed the server use.

pub mod headless;

pub use headless::{HeadlessClient, HeadlessServer};

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};

use anyhow::{Context, Result};

use crate::coordinator::{
    Batcher, BatcherConfig, Engine, PrefixCache, Request, Router, RouterConfig,
    SamplingParams, SeqEvent,
};
use crate::policies::{spec, PolicySpec};
use crate::util::json::Json;

pub struct ServerConfig {
    pub addr: String,
    pub default_policy: String,
    pub max_batch: usize,
    pub max_wait_us: u64,
    /// Engine workers the frontend should run. Purely a builder hint —
    /// `Server::new_sharded` / `HeadlessServer::new_sharded` take the
    /// actual engines and use their count; `main` reads this to decide how
    /// many to construct.
    pub shards: usize,
    /// Share a cross-request prefix cache across all shards' batchers.
    pub prefix_reuse: bool,
    /// Bytes budget for the shared prefix cache (`None` = unbounded).
    /// Under a finite budget cold snapshots are LRU-evicted on insert;
    /// the churn shows up in the `stats` command as `prefix_evictions` /
    /// `prefix_insert_rejects` next to the live `prefix_bytes` gauge.
    pub prefix_budget: Option<usize>,
    /// Per-tenant in-flight cap across the shard set (mirrors
    /// [`crate::coordinator::router::RouterConfig`]'s `tenant_inflight`
    /// on the deterministic pool path). A submit beyond the cap blocks
    /// the submitting connection's thread until one of that tenant's
    /// requests finishes — backpressure lands on the flooding tenant
    /// while other tenants' connections dispatch unimpeded.
    pub tenant_inflight: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:7712".into(),
            default_policy: "kvzap_mlp:-4".into(),
            max_batch: 4,
            max_wait_us: 2_000,
            shards: 1,
            prefix_reuse: false,
            prefix_budget: None,
            tenant_inflight: 8,
        }
    }
}

/// A fully-parsed generation request.
pub struct ParsedRequest {
    pub prompt: String,
    pub policy: PolicySpec,
    pub sp: SamplingParams,
    pub stream: bool,
    /// Client-chosen id (string or number), echoed in responses/events.
    pub id: Option<Json>,
    /// Tenant the request bills to ("" when absent — a tenant like any
    /// other). Both paths enforce per-tenant fair-share on it: the
    /// deterministic pool with round-robin queues, the threaded frontend
    /// with [`ShardSet`]'s blocking in-flight gate.
    pub tenant: String,
}

pub fn parse_request(line: &str, default_policy: &str) -> Result<ParsedRequest> {
    let j = Json::parse(line).map_err(|e| anyhow::anyhow!("bad json: {e}"))?;
    parse_request_json(&j, default_policy)
}

pub fn parse_request_json(j: &Json, default_policy: &str) -> Result<ParsedRequest> {
    let prompt = j
        .get("prompt")
        .and_then(|p| p.as_str())
        .context("missing 'prompt'")?
        .to_string();
    let policy = match j.get("policy") {
        Some(p) => PolicySpec::from_json(p).map_err(|e| anyhow::anyhow!("bad 'policy': {e:#}"))?,
        None => PolicySpec::parse(default_policy)
            .map_err(|e| anyhow::anyhow!("bad default policy: {e:#}"))?,
    };
    let sp = SamplingParams::from_json(j);
    let stream = j.get("stream").and_then(|v| v.as_bool()).unwrap_or(false);
    let id = j.get("id").cloned();
    if let Some(idj) = &id {
        if !matches!(idj, Json::Str(_) | Json::Num(_)) {
            anyhow::bail!("'id' must be a string or a number");
        }
    }
    let tenant = match j.get("tenant") {
        None => String::new(),
        Some(t) => t.as_str().context("'tenant' must be a string")?.to_string(),
    };
    Ok(ParsedRequest { prompt, policy, sp, stream, id, tenant })
}

/// Non-streaming response body — the exact protocol-v1 shape, plus the
/// request id when (and only when) the client supplied one.
pub fn response_json(r: &crate::coordinator::Response) -> String {
    response_json_with_id(r, None)
}

pub fn response_json_with_id(r: &crate::coordinator::Response, id: Option<&Json>) -> String {
    let mut pairs = vec![
        ("text", Json::str(r.text.clone())),
        ("compression", Json::num(r.compression)),
        ("tokens_out", Json::num(r.tokens_out as f64)),
        ("e2e_us", Json::num(r.e2e_us as f64)),
    ];
    if let Some(id) = id {
        pairs.push(("id", id.clone()));
    }
    if let Some(e) = &r.error {
        pairs.push(("error", Json::str(e.clone())));
    }
    Json::obj(pairs).dump()
}

/// Structured engine/runtime counters for {"cmd": "stats"}.
pub fn stats_json(engine: &Engine) -> Json {
    let t = engine.rt.transfer.snapshot();
    let m = &engine.metrics;
    Json::obj(vec![
        ("backend", Json::str(engine.rt.backend_name())),
        (
            "requests",
            Json::num(m.requests.load(std::sync::atomic::Ordering::Relaxed) as f64),
        ),
        (
            "tokens_out",
            Json::num(m.tokens_out.load(std::sync::atomic::Ordering::Relaxed) as f64),
        ),
        ("mean_compression", Json::num(m.mean_compression())),
        (
            "prefix_hits",
            Json::num(m.prefix_hits.load(std::sync::atomic::Ordering::Relaxed) as f64),
        ),
        (
            "prefix_misses",
            Json::num(m.prefix_misses.load(std::sync::atomic::Ordering::Relaxed) as f64),
        ),
        // prefix-cache churn attributed to this shard's inserts (the
        // live bytes/entries gauges are cache-wide and ride at the set
        // level — see `stats_json_set` — so they never double-count)
        (
            "prefix_evictions",
            Json::num(m.prefix_evictions.load(std::sync::atomic::Ordering::Relaxed) as f64),
        ),
        (
            "prefix_insert_races",
            Json::num(m.prefix_insert_races.load(std::sync::atomic::Ordering::Relaxed) as f64),
        ),
        (
            "prefix_insert_rejects",
            Json::num(m.prefix_insert_rejects.load(std::sync::atomic::Ordering::Relaxed) as f64),
        ),
        ("decode_steps", Json::num(t.decode_steps as f64)),
        ("kv_bytes_up", Json::num(t.kv_bytes_up as f64)),
        ("kv_bytes_down", Json::num(t.kv_bytes_down as f64)),
        ("mask_uploads", Json::num(t.mask_uploads as f64)),
        ("bytes_up", Json::num(t.bytes_up as f64)),
        ("bytes_down", Json::num(t.bytes_down as f64)),
        // quantized side-tier activity (device-local, so disjoint from
        // the bytes_up/bytes_down transfer totals above)
        ("demotes", Json::num(t.demotes as f64)),
        ("rehydrates", Json::num(t.rehydrates as f64)),
        ("tier_bytes_stored", Json::num(t.tier_bytes_stored as f64)),
        ("tier_bytes_freed", Json::num(t.tier_bytes_freed as f64)),
    ])
}

/// Aggregated stats across a server's shards, for {"cmd": "stats"}: every
/// counter field is summed, `mean_compression` is request-weighted, and
/// the untouched per-shard bodies ride along under "shard" (index order)
/// so load imbalance stays visible. For a single shard the top-level
/// fields equal the lone shard entry's.
pub fn stats_json_sharded(engines: &[Arc<Engine>]) -> Json {
    let per: Vec<Json> = engines.iter().map(|e| stats_json(e)).collect();
    let keys: Vec<String> = per[0].as_obj().unwrap().keys().cloned().collect();
    let mut pairs: Vec<(&str, Json)> = vec![];
    for k in &keys {
        match k.as_str() {
            "backend" => {
                pairs.push(("backend", per[0].get("backend").cloned().unwrap_or(Json::Null)));
            }
            "mean_compression" => {
                let total: u64 = engines
                    .iter()
                    .map(|e| e.metrics.requests.load(Ordering::Relaxed))
                    .sum();
                let mean = if total == 0 {
                    0.0
                } else {
                    engines
                        .iter()
                        .map(|e| {
                            let n = e.metrics.requests.load(Ordering::Relaxed) as f64;
                            e.metrics.mean_compression() * n
                        })
                        .sum::<f64>()
                        / total as f64
                };
                pairs.push(("mean_compression", Json::num(mean)));
            }
            _ => {
                let sum: f64 = per
                    .iter()
                    .map(|p| p.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0))
                    .sum();
                pairs.push((k.as_str(), Json::num(sum)));
            }
        }
    }
    pairs.push(("shard", Json::Arr(per)));
    Json::obj(pairs)
}

/// [`stats_json_sharded`] plus the shard set's cache-wide gauges: the
/// shared prefix cache's live `prefix_bytes` / `prefix_entries`. Gauges
/// are set once at the set level — never summed per shard — because the
/// cache is one object shared by every batcher.
pub fn stats_json_set(shards: &ShardSet) -> Json {
    let mut j = stats_json_sharded(shards.engines());
    if let (Some(pc), Json::Obj(m)) = (shards.prefix_cache(), &mut j) {
        let s = pc.stats();
        m.insert("prefix_bytes".into(), Json::num(s.bytes as f64));
        m.insert("prefix_entries".into(), Json::num(s.entries as f64));
    }
    j
}

/// Per-tenant admission slots behind [`ShardSet`]'s fair-share gate.
#[derive(Default)]
struct TenantSlots {
    /// Dispatched-but-unfinished requests billed to this tenant.
    count: usize,
    /// High-water mark of `count` (never exceeds the configured cap —
    /// the regression tests pin this invariant).
    peak: usize,
}

/// Shard-aware dispatch state shared by every connection of a server: one
/// continuous [`Batcher`] per shard (all sharing one [`PrefixCache`] when
/// reuse is on) behind a [`Router`], with per-shard outstanding-request
/// counters the router reads as its load vector. The threaded frontends
/// do placement and load spill here, and enforce the same per-tenant
/// in-flight cap the deterministic [`crate::coordinator::ShardPool`]
/// (sim path) enforces with its round-robin queues: a tenant past its
/// cap blocks *its own* submitting connection until one of its requests
/// finishes, so a flooding tenant backpressures itself while every other
/// tenant's connections keep dispatching.
pub struct ShardSet {
    engines: Vec<Arc<Engine>>,
    batchers: Vec<Arc<Batcher>>,
    router: Mutex<Router>,
    outstanding: Vec<AtomicUsize>,
    /// Fallback client-visible ids (clients that sent no "id"): a
    /// set-global counter, since per-batcher ids collide across shards.
    next_auto: AtomicU64,
    /// The shared cross-shard prefix cache, kept for its live gauges
    /// (`None` when prefix reuse is off).
    prefix: Option<Arc<PrefixCache>>,
    /// Fair-share gate: per-tenant in-flight slots under `tenant_cap`.
    tenant_cap: usize,
    tenants: Mutex<HashMap<String, TenantSlots>>,
    tenant_freed: Condvar,
    /// Submits that had to wait on the gate (observability: nonzero means
    /// a tenant hit its cap at least once).
    throttle_waits: AtomicU64,
}

impl ShardSet {
    /// One batcher per engine; each engine should own its runtime (its
    /// own resident cache).
    pub fn new(engines: Vec<Arc<Engine>>, cfg: &ServerConfig) -> Arc<ShardSet> {
        assert!(!engines.is_empty(), "shard set needs at least one engine");
        let prefix =
            cfg.prefix_reuse.then(|| Arc::new(PrefixCache::with_budget(cfg.prefix_budget)));
        let bcfg = BatcherConfig { max_batch: cfg.max_batch, max_wait_us: cfg.max_wait_us };
        let batchers = engines
            .iter()
            .map(|e| {
                Arc::new(Batcher::start_with_prefix(e.clone(), bcfg.clone(), prefix.clone()))
            })
            .collect();
        let router = Mutex::new(Router::new(&RouterConfig {
            shards: engines.len(),
            prefix_reuse: cfg.prefix_reuse,
            ..RouterConfig::default()
        }));
        let outstanding = (0..engines.len()).map(|_| AtomicUsize::new(0)).collect();
        Arc::new(ShardSet {
            engines,
            batchers,
            router,
            outstanding,
            next_auto: AtomicU64::new(1),
            prefix,
            tenant_cap: cfg.tenant_inflight.max(1),
            tenants: Mutex::new(HashMap::new()),
            tenant_freed: Condvar::new(),
            throttle_waits: AtomicU64::new(0),
        })
    }

    /// Number of shards.
    pub fn shard_count(&self) -> usize {
        self.engines.len()
    }

    /// Every shard's engine, in shard order.
    pub fn engines(&self) -> &[Arc<Engine>] {
        &self.engines
    }

    /// Shard `s`'s engine.
    pub fn engine(&self, s: usize) -> &Arc<Engine> {
        &self.engines[s]
    }

    /// The shared prefix cache, when reuse is enabled.
    pub fn prefix_cache(&self) -> Option<&Arc<PrefixCache>> {
        self.prefix.as_ref()
    }

    /// Acquire one of `tenant`'s in-flight slots, blocking the calling
    /// connection thread while the tenant sits at its cap. The wait ends
    /// when [`ShardSet::finished`] releases one of the tenant's slots —
    /// other tenants' submits never wait on this tenant's backlog.
    fn acquire_tenant(&self, tenant: &str) {
        let mut map = self.tenants.lock().unwrap();
        if map.get(tenant).is_some_and(|s| s.count >= self.tenant_cap) {
            self.throttle_waits.fetch_add(1, Ordering::Relaxed);
            while map.get(tenant).is_some_and(|s| s.count >= self.tenant_cap) {
                map = self.tenant_freed.wait(map).unwrap();
            }
        }
        let slots = map.entry(tenant.to_string()).or_default();
        slots.count += 1;
        slots.peak = slots.peak.max(slots.count);
    }

    /// Route by prompt (consistent hash + load spill) and submit to the
    /// placed shard's batcher, after taking one of `tenant`'s fair-share
    /// slots (blocks while the tenant is at its in-flight cap). Returns
    /// (shard, batcher id).
    pub fn submit(&self, tenant: &str, req: Request) -> Result<(usize, u64)> {
        self.acquire_tenant(tenant);
        let loads: Vec<usize> =
            self.outstanding.iter().map(|o| o.load(Ordering::Relaxed)).collect();
        let shard = self.router.lock().unwrap().place(&req.prompt, &loads);
        self.outstanding[shard].fetch_add(1, Ordering::Relaxed);
        match self.batchers[shard].submit(req) {
            Ok(bid) => Ok((shard, bid)),
            Err(e) => {
                self.finished(shard, tenant);
                Err(e)
            }
        }
    }

    /// Release `shard`'s outstanding charge and `tenant`'s in-flight slot
    /// for one finished request (wakes submits parked at the cap).
    pub fn finished(&self, shard: usize, tenant: &str) {
        let _ = self.outstanding[shard].fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |v| Some(v.saturating_sub(1)),
        );
        let mut map = self.tenants.lock().unwrap();
        if let Some(slots) = map.get_mut(tenant) {
            slots.count = slots.count.saturating_sub(1);
        }
        drop(map);
        self.tenant_freed.notify_all();
    }

    /// High-water mark of `tenant`'s concurrently in-flight requests —
    /// by construction never above the configured `tenant_inflight` cap.
    pub fn tenant_peak_inflight(&self, tenant: &str) -> usize {
        self.tenants.lock().unwrap().get(tenant).map_or(0, |s| s.peak)
    }

    /// Times a submit had to wait because its tenant sat at the cap.
    pub fn throttle_waits(&self) -> u64 {
        self.throttle_waits.load(Ordering::Relaxed)
    }

    /// Cancel a dispatched request on its shard.
    pub fn cancel(&self, shard: usize, bid: u64) -> Result<()> {
        self.batchers[shard].cancel(bid)
    }

    fn next_auto_id(&self) -> u64 {
        self.next_auto.fetch_add(1, Ordering::Relaxed)
    }
}

fn done_event_json(r: &crate::coordinator::Response, id: &Json) -> Json {
    let mut pairs = vec![
        ("event", Json::str("done")),
        ("id", id.clone()),
        ("text", Json::str(r.text.clone())),
        ("compression", Json::num(r.compression)),
        ("tokens_out", Json::num(r.tokens_out as f64)),
        ("e2e_us", Json::num(r.e2e_us as f64)),
    ];
    if let Some(reason) = &r.reason {
        pairs.push(("reason", Json::str(reason.clone())));
    }
    if let Some(e) = &r.error {
        pairs.push(("error", Json::str(e.clone())));
    }
    Json::obj(pairs)
}

fn write_line<W: Write>(writer: &Arc<Mutex<W>>, j: &Json) -> std::io::Result<()> {
    let mut w = writer.lock().unwrap();
    writeln!(w, "{}", j.dump())
}

pub struct Server {
    /// Shard 0's engine, kept for embedders that poke metrics directly.
    pub engine: Arc<Engine>,
    shards: Arc<ShardSet>,
    cfg: ServerConfig,
    stop: Arc<AtomicBool>,
}

impl Server {
    pub fn new(engine: Arc<Engine>, cfg: ServerConfig) -> Server {
        Server::new_sharded(vec![engine], cfg)
    }

    /// A server over N engine workers (one batcher + resident cache
    /// each); requests are placed by prompt via the consistent-hash
    /// router with load spill.
    pub fn new_sharded(engines: Vec<Arc<Engine>>, cfg: ServerConfig) -> Server {
        let shards = ShardSet::new(engines, &cfg);
        Server {
            engine: shards.engine(0).clone(),
            shards,
            cfg,
            stop: Arc::new(AtomicBool::new(false)),
        }
    }

    /// Blocking accept loop. Returns when a client sends {"cmd":"shutdown"}
    /// (the shutdown handler wakes the blocking accept with a loopback
    /// connection — no polling). Finished connection threads are reaped on
    /// every accept instead of accumulating.
    pub fn serve(&self) -> Result<()> {
        let listener = TcpListener::bind(&self.cfg.addr)
            .with_context(|| format!("bind {}", self.cfg.addr))?;
        eprintln!("[kvzap] serving on {}", self.cfg.addr);
        let mut handles: Vec<std::thread::JoinHandle<()>> = vec![];
        while !self.stop.load(Ordering::Relaxed) {
            let (stream, _) = match listener.accept() {
                Ok(s) => s,
                Err(e) => {
                    if self.stop.load(Ordering::Relaxed) {
                        break;
                    }
                    return Err(e.into());
                }
            };
            if self.stop.load(Ordering::Relaxed) {
                break; // woken by the shutdown handler
            }
            handles.retain(|h| !h.is_finished());
            let shards = self.shards.clone();
            let stop = self.stop.clone();
            let addr = self.cfg.addr.clone();
            let default_policy = self.cfg.default_policy.clone();
            handles.push(std::thread::spawn(move || {
                let _ = handle_conn(stream, shards, stop, addr, default_policy);
            }));
        }
        // Join only finished connection threads: a client idling on an
        // open connection must not block shutdown (its thread parks in a
        // blocking read and exits when the process or the peer does).
        for h in handles {
            if h.is_finished() {
                let _ = h.join();
            }
        }
        Ok(())
    }
}

fn handle_conn(
    stream: TcpStream,
    shards: Arc<ShardSet>,
    stop: Arc<AtomicBool>,
    addr: String,
    default_policy: String,
) -> Result<()> {
    let writer = Arc::new(Mutex::new(stream.try_clone()?));
    let reader = BufReader::new(stream);
    // the shutdown handler wakes the blocking accept with a loopback
    // connection — no polling
    let wake = move || {
        let _ = TcpStream::connect(&addr);
    };
    serve_lines(reader, writer, shards, stop, wake, &default_policy)
}

/// One connection's protocol-v2 loop over an arbitrary transport: read
/// JSON lines from `reader`, write response/event lines through the shared
/// `writer` (streaming pump threads interleave on it). Returns when the
/// reader reaches EOF, errors, or a `{"cmd": "shutdown"}` arrives (which
/// also sets `stop` and calls `wake` so a blocking accept loop can exit).
pub(crate) fn serve_lines<R, W>(
    reader: R,
    writer: Arc<Mutex<W>>,
    shards: Arc<ShardSet>,
    stop: Arc<AtomicBool>,
    wake: impl Fn(),
    default_policy: &str,
) -> Result<()>
where
    R: BufRead,
    W: Write + Send + 'static,
{
    // client-visible id -> (shard, batcher id), for {"cmd": "cancel"};
    // entries are removed when their request completes, so the map stays
    // bounded by the number of in-flight requests
    let ids: Arc<Mutex<HashMap<String, (usize, u64)>>> = Arc::new(Mutex::new(HashMap::new()));
    let mut pumps: Vec<std::thread::JoinHandle<()>> = vec![];
    let mut result: Result<()> = Ok(());
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(e) => {
                result = Err(e.into());
                break;
            }
        };
        if line.trim().is_empty() {
            continue;
        }
        let j = match Json::parse(&line) {
            Ok(j) => j,
            Err(e) => {
                let msg = Json::str(format!("bad json: {e}"));
                write_line(&writer, &Json::obj(vec![("error", msg)]))?;
                continue;
            }
        };
        match j.get("cmd").and_then(|c| c.as_str()) {
            Some("metrics") => {
                let report = if shards.shard_count() == 1 {
                    shards.engine(0).metrics.report()
                } else {
                    (0..shards.shard_count())
                        .map(|s| format!("shard {s}: {}", shards.engine(s).metrics.report()))
                        .collect::<Vec<_>>()
                        .join("\n")
                };
                write_line(&writer, &Json::obj(vec![("metrics", Json::str(report))]))?;
                continue;
            }
            Some("stats") => {
                write_line(&writer, &Json::obj(vec![("stats", stats_json_set(&shards))]))?;
                continue;
            }
            Some("policies") => {
                write_line(&writer, &Json::obj(vec![("policies", spec::catalog_json())]))?;
                continue;
            }
            Some("cancel") => {
                let ok = j
                    .get("id")
                    .map(|idj| idj.dump())
                    .and_then(|key| ids.lock().unwrap().get(&key).copied())
                    .map(|(shard, bid)| shards.cancel(shard, bid).is_ok())
                    .unwrap_or(false);
                let mut pairs = vec![("ok", Json::Bool(ok))];
                if !ok {
                    pairs.push(("error", Json::str("unknown request id")));
                }
                write_line(&writer, &Json::obj(pairs))?;
                continue;
            }
            Some("shutdown") => {
                stop.store(true, Ordering::Relaxed);
                write_line(&writer, &Json::obj(vec![("ok", Json::Bool(true))]))?;
                wake();
                break;
            }
            Some(other) => {
                write_line(
                    &writer,
                    &Json::obj(vec![("error", Json::str(format!("unknown cmd '{other}'")))]),
                )?;
                continue;
            }
            None => {}
        }
        match parse_request_json(&j, default_policy) {
            Ok(preq) => {
                // Reject prompts beyond the largest prefill bucket with a
                // structured error instead of silently truncating (the
                // tokenizer is byte-level, so tokens = bytes + BOS).
                let max_prompt = shards.engine(0).max_prompt();
                if preq.prompt.len() + 1 > max_prompt {
                    let mut pairs = vec![(
                        "error",
                        Json::str(format!(
                            "prompt too long: {} tokens (incl. BOS) exceeds the \
                             max prefill bucket of {max_prompt}",
                            preq.prompt.len() + 1
                        )),
                    )];
                    if let Some(idj) = &preq.id {
                        pairs.push(("id", idj.clone()));
                    }
                    write_line(&writer, &Json::obj(pairs))?;
                    continue;
                }
                let (tx, rx) = mpsc::channel();
                let client_id = preq.id.clone();
                let stream_flag = preq.stream;
                let tenant = preq.tenant.clone();
                match shards.submit(&tenant, Request {
                    prompt: preq.prompt,
                    policy: preq.policy,
                    sp: preq.sp,
                    stream: stream_flag,
                    events: tx,
                }) {
                    Ok((shard, _bid)) => {
                        // default ids come from the set-global counter, not
                        // the per-shard batcher id (those collide across
                        // shards and would alias cancel targets)
                        let id_json = client_id
                            .clone()
                            .unwrap_or_else(|| Json::num(shards.next_auto_id() as f64));
                        let id_key = id_json.dump();
                        ids.lock().unwrap().insert(id_key.clone(), (shard, _bid));
                        if stream_flag {
                            let w = writer.clone();
                            let ids = ids.clone();
                            let set = shards.clone();
                            pumps.push(std::thread::spawn(move || {
                                pump_stream(rx, w, id_json);
                                set.finished(shard, &tenant);
                                ids.lock().unwrap().remove(&id_key);
                            }));
                        } else {
                            // block for the final response (v1 behavior)
                            let resp = loop {
                                match rx.recv() {
                                    Ok(SeqEvent::Done(r)) => break r,
                                    Ok(SeqEvent::Token { .. }) => continue,
                                    Err(_) => {
                                        shards.finished(shard, &tenant);
                                        ids.lock().unwrap().remove(&id_key);
                                        anyhow::bail!("batcher dropped the request")
                                    }
                                }
                            };
                            shards.finished(shard, &tenant);
                            ids.lock().unwrap().remove(&id_key);
                            let body = response_json_with_id(&resp, client_id.as_ref());
                            let mut w = writer.lock().unwrap();
                            writeln!(w, "{body}")?;
                        }
                    }
                    Err(e) => {
                        write_line(
                            &writer,
                            &Json::obj(vec![("error", Json::str(format!("{e:#}")))]),
                        )?;
                    }
                }
            }
            Err(e) => {
                write_line(&writer, &Json::obj(vec![("error", Json::str(format!("{e:#}")))]))?;
            }
        }
    }
    for p in pumps {
        let _ = p.join();
    }
    result
}

/// Forward one streaming request's events to the shared connection writer.
fn pump_stream<W: Write>(rx: mpsc::Receiver<SeqEvent>, writer: Arc<Mutex<W>>, id: Json) {
    for ev in rx.iter() {
        match ev {
            SeqEvent::Token { token, text } => {
                let line = Json::obj(vec![
                    ("event", Json::str("token")),
                    ("id", id.clone()),
                    ("token", Json::num(token as f64)),
                    ("text", Json::str(text)),
                ]);
                if write_line(&writer, &line).is_err() {
                    return;
                }
            }
            SeqEvent::Done(r) => {
                let _ = write_line(&writer, &done_event_json(&r, &id));
                return;
            }
        }
    }
}

/// Minimal blocking client (used by examples and integration tests).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    /// Send a request line without waiting for the reply (streaming use).
    pub fn send(&mut self, body: &Json) -> Result<()> {
        writeln!(self.writer, "{}", body.dump())?;
        Ok(())
    }

    /// Read the next protocol line as JSON.
    pub fn read_event(&mut self) -> Result<Json> {
        let mut line = String::new();
        loop {
            line.clear();
            let n = self.reader.read_line(&mut line)?;
            if n == 0 {
                anyhow::bail!("connection closed");
            }
            if !line.trim().is_empty() {
                break;
            }
        }
        Json::parse(&line).map_err(|e| anyhow::anyhow!("bad response: {e}"))
    }

    /// Blocking request/response (non-streaming bodies).
    pub fn request(&mut self, body: &Json) -> Result<Json> {
        self.send(body)?;
        self.read_event()
    }

    /// Stream a request to completion: `on_token` runs per token text
    /// fragment; returns the final `"done"` event. Lines that are not
    /// events for this stream (e.g. command acks) are skipped.
    pub fn stream(&mut self, body: &Json, mut on_token: impl FnMut(&str)) -> Result<Json> {
        self.send(body)?;
        loop {
            let ev = self.read_event()?;
            match ev.get("event").and_then(|e| e.as_str()) {
                Some("token") => {
                    if let Some(t) = ev.get("text").and_then(|t| t.as_str()) {
                        on_token(t);
                    }
                }
                Some("done") => return Ok(ev),
                _ => {}
            }
        }
    }

    /// Cancel an in-flight request by its id (the ack line arrives
    /// interleaved with any open stream on this connection).
    pub fn cancel(&mut self, id: &Json) -> Result<()> {
        self.send(&Json::obj(vec![("cmd", Json::str("cancel")), ("id", id.clone())]))
    }

    pub fn shutdown(&mut self) -> Result<()> {
        self.send(&Json::obj(vec![("cmd", Json::str("shutdown"))]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_request_defaults() {
        let preq = parse_request(r#"{"prompt": "hi", "max_new": 7}"#, "kvzap_mlp:-4").unwrap();
        assert_eq!(preq.prompt, "hi");
        assert_eq!(preq.policy, PolicySpec::parse("kvzap_mlp:-4").unwrap());
        assert_eq!(preq.sp.max_new, 7);
        assert!(preq.sp.greedy);
        assert!(!preq.stream);
        assert!(preq.id.is_none());
    }

    #[test]
    fn parse_request_sampling_overrides() {
        let preq = parse_request(
            r#"{"prompt":"x","greedy":false,"temperature":0.8,"top_k":5,"top_p":0.9,"seed":3}"#,
            "full",
        )
        .unwrap();
        assert!(!preq.sp.greedy);
        assert!((preq.sp.temperature - 0.8).abs() < 1e-6);
        assert_eq!(preq.sp.top_k, 5);
        assert_eq!(preq.sp.seed, 3);
    }

    #[test]
    fn parse_request_rejects_missing_prompt() {
        assert!(parse_request(r#"{"max_new": 2}"#, "full").is_err());
    }

    #[test]
    fn parse_request_string_and_structured_policy_agree() {
        let a = parse_request(r#"{"prompt":"x","policy":"kvzap_mlp:-4"}"#, "full").unwrap();
        let b = parse_request(
            r#"{"prompt":"x","policy":{"kind":"kvzap","surrogate":"mlp","tau":-4.0}}"#,
            "full",
        )
        .unwrap();
        assert_eq!(a.policy, b.policy);
        let a = parse_request(r#"{"prompt":"x","policy":"streaming_llm:0.3:8"}"#, "full").unwrap();
        let b = parse_request(
            r#"{"prompt":"x","policy":{"kind":"streaming_llm","keep_frac":0.3,"sinks":8}}"#,
            "full",
        )
        .unwrap();
        assert_eq!(a.policy, b.policy);
    }

    #[test]
    fn parse_request_rejects_bad_policy() {
        assert!(parse_request(r#"{"prompt":"x","policy":"kvzap_mlp:"}"#, "full").is_err());
        assert!(parse_request(r#"{"prompt":"x","policy":{"kind":"nope"}}"#, "full").is_err());
        assert!(parse_request(r#"{"prompt":"x","policy":[1]}"#, "full").is_err());
        assert!(parse_request(r#"{"prompt":"x","id":[1]}"#, "full").is_err());
    }

    #[test]
    fn parse_request_stream_and_id() {
        let preq =
            parse_request(r#"{"prompt":"x","stream":true,"id":"req-1"}"#, "full").unwrap();
        assert!(preq.stream);
        assert_eq!(preq.id, Some(Json::str("req-1")));
    }

    #[test]
    fn response_shape_is_v1_compatible_without_id() {
        let r = crate::coordinator::Response {
            text: "ok".into(),
            compression: 0.5,
            tokens_out: 2,
            e2e_us: 10,
            error: None,
            reason: Some("stop".into()),
        };
        let j = Json::parse(&response_json(&r)).unwrap();
        let keys: Vec<&str> =
            j.as_obj().unwrap().keys().map(|k| k.as_str()).collect();
        assert_eq!(keys, vec!["compression", "e2e_us", "text", "tokens_out"]);
    }
}
