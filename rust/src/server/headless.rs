//! In-process (headless) transport for the v2 protocol.
//!
//! Runs the exact per-connection loop the TCP frontend runs
//! (`super::serve_lines`) over in-memory channels instead of a socket:
//! no ports, no listener, no OS networking. Each [`HeadlessClient`] is one
//! "connection" — a thread running the protocol loop, fed request lines
//! through a channel and answering with parsed JSON lines. All generation
//! still funnels through the shard set's continuous batchers, so routing,
//! batching, streaming, cancellation and error handling behave exactly as
//! they do over TCP.
//!
//! This is what the server error-path tests and the simulation tooling
//! use: hermetic, deterministic setup/teardown, and no port allocation.

use std::collections::VecDeque;
use std::io::{BufReader, Read, Write};
use std::sync::atomic::AtomicBool;
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, Result};

use super::{serve_lines, ServerConfig, ShardSet};
use crate::coordinator::Engine;
use crate::util::json::Json;

/// `Read` over a byte channel; EOF when the sending side is dropped.
struct ChanReader {
    rx: Receiver<Vec<u8>>,
    buf: VecDeque<u8>,
}

impl Read for ChanReader {
    fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
        while self.buf.is_empty() {
            match self.rx.recv() {
                Ok(bytes) => self.buf.extend(bytes),
                Err(_) => return Ok(0), // client dropped: EOF
            }
        }
        let n = out.len().min(self.buf.len());
        for (slot, b) in out.iter_mut().zip(self.buf.drain(..n)) {
            *slot = b;
        }
        Ok(n)
    }
}

/// `Write` that forwards each complete line to a string channel.
struct ChanWriter {
    tx: Sender<String>,
    buf: Vec<u8>,
}

impl Write for ChanWriter {
    fn write(&mut self, data: &[u8]) -> std::io::Result<usize> {
        self.buf.extend_from_slice(data);
        while let Some(p) = self.buf.iter().position(|&b| b == b'\n') {
            let line: Vec<u8> = self.buf.drain(..=p).collect();
            let s = String::from_utf8_lossy(&line[..line.len() - 1]).into_owned();
            if !s.trim().is_empty() {
                let _ = self.tx.send(s);
            }
        }
        Ok(data.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

/// The headless server: the shard set (engines + batchers) a set of
/// [`HeadlessClient`] connections funnel into. `cfg.addr` is unused (there
/// is no socket); the other [`ServerConfig`] fields mean what they mean
/// for the TCP frontend.
pub struct HeadlessServer {
    shards: Arc<ShardSet>,
    default_policy: String,
    stop: Arc<AtomicBool>,
}

impl HeadlessServer {
    /// Start a single-shard batcher; connections attach via
    /// [`HeadlessServer::connect`].
    pub fn new(engine: Arc<Engine>, cfg: ServerConfig) -> HeadlessServer {
        HeadlessServer::new_sharded(vec![engine], cfg)
    }

    /// Start one batcher per engine behind the router; requests placed by
    /// consistent hash with load spill, exactly like the TCP frontend.
    pub fn new_sharded(engines: Vec<Arc<Engine>>, cfg: ServerConfig) -> HeadlessServer {
        let shards = ShardSet::new(engines, &cfg);
        HeadlessServer {
            shards,
            default_policy: cfg.default_policy,
            stop: Arc::new(AtomicBool::new(false)),
        }
    }

    /// Every shard's engine, in shard order (for tests that cross-check
    /// the aggregated `stats` command against per-shard counters).
    pub fn engines(&self) -> &[Arc<Engine>] {
        self.shards.engines()
    }

    /// The dispatch state behind every connection (fair-share gate
    /// observability: per-tenant peaks and throttle waits).
    pub fn shard_set(&self) -> &Arc<ShardSet> {
        &self.shards
    }

    /// Open one in-process protocol connection (its own loop thread).
    pub fn connect(&self) -> HeadlessClient {
        let (line_tx, line_rx) = mpsc::channel::<Vec<u8>>();
        let (resp_tx, resp_rx) = mpsc::channel::<String>();
        let reader = BufReader::new(ChanReader { rx: line_rx, buf: VecDeque::new() });
        let writer = Arc::new(Mutex::new(ChanWriter { tx: resp_tx, buf: vec![] }));
        let shards = self.shards.clone();
        let stop = self.stop.clone();
        let default_policy = self.default_policy.clone();
        let handle = std::thread::spawn(move || {
            let _ = serve_lines(reader, writer, shards, stop, || {}, &default_policy);
        });
        HeadlessClient { tx: line_tx, rx: resp_rx, handle: Some(handle) }
    }
}

/// One in-process protocol connection (see [`HeadlessServer::connect`]).
/// Dropping the client closes the connection (EOF) and joins its loop
/// thread.
pub struct HeadlessClient {
    tx: Sender<Vec<u8>>,
    rx: Receiver<String>,
    handle: Option<JoinHandle<()>>,
}

impl HeadlessClient {
    /// Send one protocol line (the newline is appended here).
    pub fn send_line(&self, line: &str) -> Result<()> {
        let mut bytes = line.as_bytes().to_vec();
        bytes.push(b'\n');
        self.tx.send(bytes).map_err(|_| anyhow!("headless connection closed"))
    }

    /// Read the next protocol line as JSON, waiting up to `timeout`.
    pub fn recv(&self, timeout: Duration) -> Result<Json> {
        let line = self
            .rx
            .recv_timeout(timeout)
            .map_err(|e| anyhow!("no response line: {e:?}"))?;
        Json::parse(&line).map_err(|e| anyhow!("bad response line: {e}"))
    }

    /// Blocking request/response for lines that produce exactly one reply
    /// (commands, non-streaming generations, error paths).
    pub fn request(&self, line: &str) -> Result<Json> {
        self.send_line(line)?;
        self.recv(Duration::from_secs(120))
    }
}

impl Drop for HeadlessClient {
    fn drop(&mut self) {
        // Closing tx EOFs the reader, so the loop thread exits after any
        // in-flight request of this connection completes.
        let (dummy, _) = mpsc::channel();
        drop(std::mem::replace(&mut self.tx, dummy));
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}
