//! Shared harness for the benchmark binaries (criterion is unavailable
//! offline — DESIGN.md §7): evaluation sweeps, CSV output, timing loops.
//!
//! Every `cargo bench` target regenerates one paper table/figure by running
//! policy sweeps over the workload generators and writing a CSV into
//! results/ plus a human-readable table on stdout (DESIGN.md §5 maps
//! each target to its table/figure).

use std::io::Write;
use std::path::PathBuf;
use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::{Engine, SamplingParams};
use crate::policies;
use crate::util::rng::Rng;
use crate::workload;

/// Parse `--key value` bench arguments (cargo bench passes extra args after
/// `--`).
pub struct BenchArgs {
    kv: std::collections::HashMap<String, String>,
}

impl BenchArgs {
    pub fn parse() -> BenchArgs {
        let mut kv = std::collections::HashMap::new();
        let args: Vec<String> = std::env::args().collect();
        let mut i = 0;
        while i < args.len() {
            if let Some(k) = args[i].strip_prefix("--") {
                if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                    kv.insert(k.to_string(), args[i + 1].clone());
                    i += 1;
                } else {
                    kv.insert(k.to_string(), "true".into());
                }
            }
            i += 1;
        }
        BenchArgs { kv }
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.kv.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// The parsed value of `--key`, or None when the flag is absent or
    /// unparsable (for flags whose absence means "pick a default" rather
    /// than a fixed number).
    pub fn usize_opt(&self, key: &str) -> Option<usize> {
        self.kv.get(key).and_then(|v| v.parse().ok())
    }

    pub fn flag(&self, key: &str) -> bool {
        self.kv.get(key).map(|v| v == "true").unwrap_or(false)
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.kv.get(key).cloned().unwrap_or_else(|| default.into())
    }
}

/// Locate results/ next to artifacts/.
pub fn results_dir() -> PathBuf {
    let mut d = crate::artifacts_dir();
    d.pop();
    let r = d.join("results");
    let _ = std::fs::create_dir_all(&r);
    r
}

/// Engine over the best available backend: PJRT artifacts when compiled
/// with `--features pjrt` and built, the hermetic reference backend
/// otherwise — so every bench target runs from a fresh checkout.
pub fn load_engine() -> Result<Arc<Engine>> {
    let rt = crate::runtime::Runtime::auto()?;
    eprintln!("[kvzap] backend: {}", rt.backend_desc());
    Ok(Arc::new(Engine::new(Arc::new(rt))))
}

/// Walk up from cwd to the repo root (marked by ROADMAP.md) so bench
/// artifacts land in the same place no matter which directory cargo runs
/// the target from.
pub fn repo_root() -> PathBuf {
    let mut d = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        if d.join("ROADMAP.md").exists() {
            return d;
        }
        if !d.pop() {
            return ".".into();
        }
    }
}

/// Write one `BENCH_<name>.json` perf-trajectory seed at the repo root:
/// `{"bench": name, "backend": ..., "quick": ..., "rows": [...]}` where
/// each row is a pre-rendered JSON object (all `BENCH_*.json` files share
/// this shape — see docs/BENCHMARKS.md).
pub fn write_bench_json(name: &str, backend: &str, quick: bool, rows: &[String]) -> Result<()> {
    let body = format!(
        "{{\"bench\": \"{}\", \"backend\": \"{}\", \"quick\": {}, \"rows\": [{}]}}\n",
        name,
        backend,
        quick,
        rows.join(", ")
    );
    let path = repo_root().join(format!("BENCH_{name}.json"));
    std::fs::write(&path, body)?;
    eprintln!("  wrote {}", path.display());
    Ok(())
}

/// Threshold sweep for KVzap policies, derived from the oracle log-score
/// quantiles recorded in the manifest (the paper sweeps τ per model).
pub fn default_taus(engine: &Engine) -> Vec<f64> {
    let q = &engine.rt.manifest.threshold_quantiles;
    let picks = ["0.3", "0.5", "0.7", "0.8"];
    let mut taus: Vec<f64> =
        picks.iter().filter_map(|k| q.get(*k).copied()).collect();
    if taus.is_empty() {
        taus = vec![-8.0, -6.0, -4.0, -3.0];
    }
    taus
}

pub const KEEP_FRACS: &[f64] = &[0.8, 0.6, 0.4, 0.25];

#[derive(Debug, Clone)]
pub struct EvalRow {
    pub policy: String,
    pub subset: String,
    pub n: usize,
    pub accuracy: f64,
    /// Teacher-forced answer NLL (nats/byte): the smooth quality metric
    /// reported alongside exact match (lower = better).
    pub nll: f64,
    pub compression: f64,
    /// Mean steady-state KV footprint in bytes after prefill pruning:
    /// resident fp32 blocks plus the quantized side tier (the x-axis of
    /// the accuracy-vs-bytes frontier).
    pub kv_bytes: f64,
    /// Mean KV entries parked in the quantized side tier at steady state.
    pub demoted: f64,
    /// Mean side-tier entries rehydrated before answer scoring (0 under
    /// the default quant-attend re-score path, which never rehydrates).
    pub rehydrated: f64,
    /// Mean demoted rows attended from their quantized form per
    /// teacher-forcing step during answer scoring.
    pub quant_attended: f64,
    pub prefill_us: f64,
    pub decode_us: f64,
    pub policy_us: f64,
    pub oracle_us: f64,
}

/// Evaluate one policy spec over one suite; returns one row per subset.
pub fn eval_policy(
    engine: &Engine,
    suite: &str,
    subsets: &[&str],
    spec: &str,
    samples: usize,
    ctx: usize,
    seed: u64,
) -> Result<Vec<EvalRow>> {
    let policy = policies::PolicySpec::parse(spec)
        .map_err(|e| anyhow::anyhow!("bad policy '{spec}': {e:#}"))?
        .build(engine.window());
    let mut rows = vec![];
    for subset in subsets {
        let mut rng = Rng::new(seed ^ fxhash(subset));
        let mut ok = 0usize;
        let mut comp = 0.0;
        let mut nll_sum = 0.0;
        let (mut bytes, mut dem, mut reh, mut qat) = (0.0, 0.0, 0.0, 0.0);
        let (mut pf, mut dc, mut pol, mut orc) = (0.0, 0.0, 0.0, 0.0);
        for i in 0..samples {
            let mut r = rng.fork(i as u64);
            let (task, is_aime) = match suite {
                "ruler" => (workload::ruler_instance(subset, ctx, &mut r), false),
                "longbench" => (workload::longbench_instance(subset, ctx, &mut r), false),
                "aime" => (workload::aime_instance(&mut r).task, true),
                _ => anyhow::bail!("unknown suite {suite}"),
            };
            let sp = SamplingParams::greedy(task.max_new);
            let res = engine.generate(&task.prompt, policy.as_ref(), &sp)?;
            let correct = if is_aime {
                workload::generators::parse_aime_answer(&res.text).as_deref()
                    == Some(task.answer.as_str())
            } else {
                task.score(&res.text)
            };
            let score =
                engine.score_answer_full(&task.prompt, &task.answer, policy.as_ref())?;
            nll_sum += score.nll;
            bytes += score.kv_bytes as f64;
            dem += score.demoted as f64;
            reh += score.rehydrated as f64;
            qat += score.quant_attended as f64;
            ok += correct as usize;
            comp += res.compression;
            pf += res.prefill_us as f64;
            dc += res.decode_us as f64;
            pol += res.policy_us as f64;
            orc += res.oracle_us as f64;
        }
        let n = samples as f64;
        rows.push(EvalRow {
            policy: spec.to_string(),
            subset: subset.to_string(),
            n: samples,
            accuracy: ok as f64 / n,
            nll: nll_sum / n,
            compression: comp / n,
            kv_bytes: bytes / n,
            demoted: dem / n,
            rehydrated: reh / n,
            quant_attended: qat / n,
            prefill_us: pf / n,
            decode_us: dc / n,
            policy_us: pol / n,
            oracle_us: orc / n,
        });
    }
    Ok(rows)
}

fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x100000001b3);
    }
    h
}

/// Mean (accuracy, compression, nll) across subsets (a figure's point).
pub fn aggregate(rows: &[EvalRow]) -> (f64, f64, f64) {
    let n = rows.len() as f64;
    (
        rows.iter().map(|r| r.accuracy).sum::<f64>() / n,
        rows.iter().map(|r| r.compression).sum::<f64>() / n,
        rows.iter().map(|r| r.nll).sum::<f64>() / n,
    )
}

pub fn write_csv(path: &PathBuf, header: &str, lines: &[String]) -> Result<()> {
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{header}")?;
    for l in lines {
        writeln!(f, "{l}")?;
    }
    eprintln!("  wrote {}", path.display());
    Ok(())
}

/// Simple timing loop: median of `iters` runs after `warmup` (µs).
pub fn time_us(warmup: usize, iters: usize, mut f: impl FnMut()) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = std::time::Instant::now();
        f();
        samples.push(t0.elapsed().as_micros() as f64);
    }
    samples.sort_by(|a, b| a.total_cmp(b));
    samples[samples.len() / 2]
}

/// Print a paper-style frontier table: policy -> (compression, accuracy,
/// answer-NLL).
pub fn print_frontier(title: &str, points: &[(String, f64, f64, f64)]) {
    println!("\n== {title}");
    println!(
        "{:<32} {:>12} {:>10} {:>8} {:>10}",
        "policy", "compression", "factor", "acc %", "ans NLL"
    );
    let mut sorted = points.to_vec();
    sorted.sort_by(|a, b| a.1.total_cmp(&b.1));
    for (name, comp, acc, nll) in sorted {
        println!(
            "{:<32} {:>11.3} {:>9} {:>7.1} {:>10.3}",
            name,
            comp,
            format!("{:.2}x", compression_factor(comp)),
            100.0 * acc,
            nll
        );
    }
}

/// Compression factor for a mean removed fraction, with the same
/// convention as [`crate::kvcache::CacheStats::factor`]: a fully-pruned
/// cache is infinitely compressed (`inf`), never clamped to a finite
/// value that would under-report the most aggressive settings.
pub fn compression_factor(compression: f64) -> f64 {
    if compression >= 1.0 {
        f64::INFINITY
    } else {
        1.0 / (1.0 - compression)
    }
}

/// Print the accuracy-vs-bytes frontier: policy -> (steady-state KV
/// bytes, accuracy, answer-NLL), cheapest first. Bytes are the idle
/// footprint after prefill pruning — resident fp32 blocks plus the
/// quantized side tier — so a demotion policy and its drop-only
/// counterpart land on comparable x positions.
pub fn print_bytes_frontier(title: &str, points: &[(String, f64, f64, f64)]) {
    println!("\n== {title}");
    println!(
        "{:<40} {:>10} {:>8} {:>10}",
        "policy", "kv bytes", "acc %", "ans NLL"
    );
    let mut sorted = points.to_vec();
    sorted.sort_by(|a, b| a.1.total_cmp(&b.1));
    for (name, bytes, acc, nll) in sorted {
        println!("{:<40} {:>10.0} {:>8.1} {:>10.3}", name, bytes, 100.0 * acc, nll);
    }
}
