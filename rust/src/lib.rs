//! # kvzap — fast, adaptive and faithful KV cache pruning
//!
//! Reproduction of *KVzap* (Jégou & Jeblick, 2026) as a three-layer
//! rust + JAX + Pallas serving stack:
//!
//! * **L1/L2** (build-time python): Pallas attention/scorer kernels inside a
//!   GQA transformer, AOT-lowered to HLO-text artifacts (`make artifacts`).
//! * **L3** (this crate): a vLLM-router-shaped serving coordinator — request
//!   router, continuous batcher, paged KV cache manager with per-head
//!   variable lengths, prefill/decode scheduler — with KV cache pruning as a
//!   first-class feature ([`policies`]).
//!
//! Python never runs on the request path: the [`runtime`] module loads the
//! artifacts once and executes them via PJRT.

pub mod analysis;
pub mod bench_support;
pub mod coordinator;
pub mod kvcache;
pub mod metrics;
pub mod policies;
pub mod runtime;
pub mod server;
pub mod util;
pub mod workload;

/// Default artifacts directory, overridable via `KVZAP_ARTIFACTS`.
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(d) = std::env::var("KVZAP_ARTIFACTS") {
        return d.into();
    }
    // Walk up from cwd until an artifacts/manifest.json is found (so tests,
    // benches and examples work from any directory in the repo).
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return "artifacts".into();
        }
    }
}
