//! # kvzap — fast, adaptive and faithful KV cache pruning
//!
//! Reproduction of *KVzap* (Jégou & Jeblick, 2026) as a serving stack with
//! KV cache pruning as a first-class feature: a vLLM-router-shaped
//! coordinator (request router, continuous batcher, paged KV cache manager
//! with per-head variable lengths, prefill/decode scheduler) over a
//! **pluggable execution backend** ([`runtime::Backend`]).
//!
//! ## Two backends, one engine
//!
//! * **reference** (default) — [`runtime::reference`]: a hermetic pure-Rust
//!   CPU port of the model semantics (GQA attention + RoPE + RMSNorm, the
//!   paper's per-position prefill statistics, the KVzip oracle double pass,
//!   masked decode) over a deterministic in-code weight set. No artifacts,
//!   no python, no native dependencies: `cargo build && cargo test` run the
//!   full engine → policy → cache path from a fresh checkout, which is how
//!   CI regression-gates the stack.
//! * **pjrt** (`--features pjrt`) — [`runtime::pjrt`]: loads the AOT
//!   HLO-text artifacts built by the python compile pipeline
//!   (`make artifacts`: Pallas kernels → JAX model → HLO text) and executes
//!   them via the PJRT CPU client. Python never runs on the request path.
//!
//! [`runtime::Runtime::auto`] picks PJRT when compiled in and artifacts
//! exist, the reference backend otherwise, so the CLI, server and benches
//! work out of the box and transparently upgrade.
//!
//! Layering:
//!
//! * **L1/L2** (build-time python, optional): Pallas attention/scorer
//!   kernels inside a GQA transformer, AOT-lowered to HLO-text artifacts.
//! * **L3** (this crate): the serving coordinator — [`coordinator`],
//!   [`kvcache`], [`policies`], [`server`] — plus the [`runtime`] backends
//!   and the [`simharness`] scenario fuzzer that gates them (see
//!   docs/TESTING.md).

pub mod analysis;
pub mod bench_support;
pub mod coordinator;
pub mod kvcache;
pub mod leaderboard;
pub mod metrics;
pub mod policies;
pub mod runtime;
pub mod server;
pub mod simharness;
pub mod util;
pub mod workload;

/// Default artifacts directory, overridable via `KVZAP_ARTIFACTS`.
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(d) = std::env::var("KVZAP_ARTIFACTS") {
        return d.into();
    }
    // Walk up from cwd until an artifacts/manifest.json is found (so tests,
    // benches and examples work from any directory in the repo).
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return "artifacts".into();
        }
    }
}
