//! Hermetic pure-Rust reference backend.
//!
//! A faithful CPU port of the L2 model semantics the PJRT artifacts encode
//! (python/compile/model.py over the python/compile/kernels/ref.py kernel
//! oracles): causal GQA attention with RoPE + RMSNorm, the paper's
//! per-position prefill statistics (score_lin / score_mlp surrogates,
//! max/plus/cum/win attention, k/v norms, Eqs. 1 and 3), the KVzip
//! repeated-prompt oracle double pass, and a masked decode step that honors
//! the eviction mask — everything `coordinator::Engine` needs, with **no
//! artifacts, no python and no native dependencies**.
//!
//! The weight set is tiny, deterministic and generated in-code (not
//! trained): byte-code embeddings from the repo PRNG plus a hand-designed
//! salience circuit. Layout of the `d_model = 48` residual stream:
//!
//! * dims 0..16 — a random ±0.25 identity code per byte,
//! * dim 16/17  — a binary salience flag (digits, uppercase, BOS) and its
//!   complement (so every embedding has equal norm and RMSNorm is uniform),
//! * dim 18     — a constant channel driving content-independent queries,
//! * dims 19..35 — the retrieval subspace attention writes into.
//!
//! Queries read the constant channel, keys read the salience flag (both on
//! the slowest RoPE frequency, so scores are distance-insensitive), values carry
//! the byte code, and the output projection routes the attended code mix
//! into the retrieval subspace that the unembedding reads. The surrogate
//! heads read the salience flag directly. Net behavior: attention
//! concentrates on salient positions (needle digits, keys, BOS sink),
//! surrogate scores are ≈ +2 for salient and ≈ −6 for filler KV pairs, so
//! KVzap thresholds in between prune the filler without perturbing the
//! output logits — compression > 0 with full-cache-faithful generation,
//! which is exactly the paper's claim the integration tests exercise. The
//! MLP path of the transformer is identity (SwiGLU weights zero) and is
//! elided.
//!
//! Anything numeric here is mirrored 1:1 by the tuning prototype that set
//! the gain constants; change the constants together with the margins
//! documented on the integration tests.
//!
//! ## Compute core: scalar vs blocked-parallel
//!
//! The forward passes are organized as **work units with a fixed-order
//! merge** and driven by [`super::parallel::WorkerPool`]:
//!
//! * prefill — units are `(kv head, query group, query row-block)` for
//!   attention plus `(kv head, query group, position-block)` for the
//!   Eq. 3 value-norm table; per-position statistics accumulate into
//!   per-unit partials that are merged serially in a fixed order, so the
//!   emitted bits never depend on the thread count.
//! * resident/legacy decode — units are group slots (each slot's cache
//!   rows and scratch are disjoint `split_at_mut` views).
//!
//! [`super::parallel::ParallelConfig::threads`] `== 1` selects the
//! *scalar path* (the original naive kernels, run inline); `> 1` selects
//! the cache-blocked transposed-layout kernels in [`super::kernels`].
//! Both paths share the same unit decomposition, merge order and
//! [`super::kernels::fast_exp`], and the blocked kernels preserve
//! per-output reduction order — which is why the integration suite can
//! assert the two paths (and any thread count) are **bitwise identical**.

#![allow(clippy::needless_range_loop)]

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use super::backend::{Arg, Backend, Buffer, BufferRepr, KvHandle, QuantAttendStat};
use super::kernels::{self, fast_exp, SimdLevel};
use super::manifest::{ArtifactMeta, Buckets, IoSpec, Manifest, ModelDims, SpecialTokens};
use super::parallel::{ParallelConfig, WorkerPool};
use super::tensor::Tensor;
use crate::util::rng::Rng;

// ---------------------------------------------------------------- dimensions

const V: usize = 256;
const DM: usize = 48; // d_model
const L: usize = 2; // layers (identical weights per layer)
const HQ: usize = 4; // query heads
const HKV: usize = 2; // kv heads
const GRP: usize = HQ / HKV;
const D: usize = 8; // head dim
const HALF: usize = D / 2;
const DSUR: usize = 8; // surrogate MLP hidden width
/// Default cache capacity; [`ReferenceBackend::with_t_max`] overrides it
/// (the decode cost model scales with t_max, which the decode bench sweeps).
const T_MAX: usize = 512;
const D_INT: usize = 64; // reported for the flops table; FFN is identity
pub const WINDOW: usize = 16;
pub const OBS_WINDOW: usize = 32;
const ROPE_THETA: f32 = 10_000.0;
const RMS_EPS: f32 = 1e-5;

// residual-stream layout
const NCODE: usize = 16;
const SAL: usize = 16;
const ANTI: usize = 17;
const CONST: usize = 18;
const RETR0: usize = 19;

// gains (tuned with the mirrored prototype; see module docs)
const G_SAL: f32 = 1.0;
const G_MU: f32 = 1.0;
const Q_GAIN: f32 = 1.0;
const K_GAIN: f32 = 2.0;
const G_V: f32 = 0.3;
const G_O: f32 = 0.25;
const B_OUT: f32 = 4.0;
const SUR_BIAS: f32 = -6.0;
const SUR_GAIN: f32 = 8.0;
const PRIOR_NL: f32 = -2.0;
const PRIOR_SPECIAL: f32 = -4.0;
const WEIGHT_SEED: u64 = 0x4B56_5A50;

const PREFILL_T: [usize; 4] = [128, 256, 384, 512];
const PREFILL_B: [usize; 2] = [1, 4];
const DECODE_B: [usize; 3] = [1, 4, 8];
const KVZIP_T: [usize; 3] = [256, 384, 512];

// ------------------------------------------------------------------- weights

struct RefWeights {
    emb: Vec<f32>,   // [V, DM]
    wq: Vec<f32>,    // [DM, HQ*D]
    wk: Vec<f32>,    // [DM, HKV*D]
    wv: Vec<f32>,    // [DM, HKV*D]
    wo: Vec<f32>,    // [HQ*D, DM]
    w_out: Vec<f32>, // [DM, V]
    w_sl: Vec<f32>,  // [DM, HKV]
    b_sl: Vec<f32>,  // [HKV]
    w1: Vec<f32>,    // [DM, DSUR]
    b1: Vec<f32>,    // [DSUR]
    w2: Vec<f32>,    // [DSUR, HKV]
    b2: Vec<f32>,    // [HKV]
}

fn gelu(x: f32) -> f32 {
    // tanh approximation (jax.nn.gelu default) — the semantics the
    // surrogate_mlp kernel oracle uses.
    let c = (2.0f32 / std::f32::consts::PI).sqrt();
    0.5 * x * (1.0 + (c * (x + 0.044715 * x * x * x)).tanh())
}

fn is_salient(b: usize) -> bool {
    (48..=57).contains(&b) || (65..=90).contains(&b) || b == 1
}

fn gen_weights() -> RefWeights {
    let mut rng = Rng::new(WEIGHT_SEED);
    let mut code = vec![0.0f32; V * NCODE];
    for b in 0..V {
        for i in 0..NCODE {
            code[b * NCODE + i] = if rng.below(2) == 1 { 0.25 } else { -0.25 };
        }
    }
    // Structured value projections: kv head h carries code dims
    // [h*D, h*D + D) verbatim, so the attended value mix is an exact
    // attention-weighted code average (no cross-code noise on retrieval).
    let mut proj = vec![0.0f32; HKV * NCODE * D];
    for h in 0..HKV {
        for j in 0..D {
            proj[(h * NCODE + h * D + j) * D + j] = 1.0;
        }
    }

    let mut emb = vec![0.0f32; V * DM];
    for b in 0..V {
        for i in 0..NCODE {
            emb[b * DM + i] = code[b * NCODE + i];
        }
        let s = if is_salient(b) { 1.0 } else { 0.0 };
        emb[b * DM + SAL] = s * G_SAL;
        emb[b * DM + ANTI] = (1.0 - s) * G_SAL;
        emb[b * DM + CONST] = G_MU;
    }

    let mut wq = vec![0.0f32; DM * HQ * D];
    for qh in 0..HQ {
        // slowest RoPE frequency pair (component 3 of 0..4) so attention
        // scores are almost distance-independent across the context
        wq[CONST * (HQ * D) + qh * D + 3] = Q_GAIN;
    }
    let mut wk = vec![0.0f32; DM * HKV * D];
    for h in 0..HKV {
        wk[SAL * (HKV * D) + h * D + 3] = K_GAIN;
    }
    let mut wv = vec![0.0f32; DM * HKV * D];
    for h in 0..HKV {
        for i in 0..NCODE {
            for j in 0..D {
                wv[i * (HKV * D) + h * D + j] = G_V * proj[(h * NCODE + i) * D + j];
            }
        }
    }
    let mut wo = vec![0.0f32; HQ * D * DM];
    for qh in 0..HQ {
        let h = qh / GRP;
        for j in 0..D {
            for i in 0..NCODE {
                wo[(qh * D + j) * DM + RETR0 + i] = G_O * proj[(h * NCODE + i) * D + j];
            }
        }
    }

    let mut w_out = vec![0.0f32; DM * V];
    for b in 0..V {
        for i in 0..NCODE {
            w_out[(RETR0 + i) * V + b] = B_OUT * code[b * NCODE + i];
        }
        if b == b'\n' as usize {
            w_out[CONST * V + b] = PRIOR_NL;
        } else if b < 4 {
            w_out[CONST * V + b] = PRIOR_SPECIAL;
        }
    }

    let mut w_sl = vec![0.0f32; DM * HKV];
    for h in 0..HKV {
        w_sl[SAL * HKV + h] = SUR_GAIN;
    }
    let b_sl = vec![SUR_BIAS; HKV];
    let mut w1 = vec![0.0f32; DM * DSUR];
    w1[SAL * DSUR] = 1.0;
    let b1 = vec![0.0f32; DSUR];
    let mut w2 = vec![0.0f32; DSUR * HKV];
    let g1 = gelu(G_SAL);
    for h in 0..HKV {
        w2[h] = SUR_GAIN * G_SAL / g1;
    }
    let b2 = vec![SUR_BIAS; HKV];

    RefWeights { emb, wq, wk, wv, wo, w_out, w_sl, b_sl, w1, b1, w2, b2 }
}

// --------------------------------------------------------------- math helpers

fn rmsnorm_row(x: &[f32], out: &mut [f32]) {
    let mut ms = 0.0f32;
    for &v in x {
        ms += v * v;
    }
    ms = ms / x.len() as f32 + RMS_EPS;
    let s = 1.0 / ms.sqrt();
    for (o, &v) in out.iter_mut().zip(x) {
        *o = v * s;
    }
}

fn rope_angles(pos: f32) -> ([f32; HALF], [f32; HALF]) {
    let mut cos = [0.0f32; HALF];
    let mut sin = [0.0f32; HALF];
    for i in 0..HALF {
        let freq = ROPE_THETA.powf(-(i as f32) / HALF as f32);
        let ang = pos * freq;
        cos[i] = ang.cos();
        sin[i] = ang.sin();
    }
    (cos, sin)
}

/// Split-half RoPE rotation of one head vector [D], in place.
fn apply_rope(x: &mut [f32], cos: &[f32; HALF], sin: &[f32; HALF]) {
    for i in 0..HALF {
        let (x1, x2) = (x[i], x[i + HALF]);
        x[i] = x1 * cos[i] - x2 * sin[i];
        x[i + HALF] = x1 * sin[i] + x2 * cos[i];
    }
}

fn norm(xs: &[f32]) -> f32 {
    let mut s = 0.0f32;
    for &v in xs {
        s += v * v;
    }
    s.sqrt()
}

/// ||v_head @ wo_slice(qh)|| — the Eq. 3 value-norm factor for one
/// (query-head, position) pair. `vh` is the kv head's value vector [D].
fn vnorm_one(w: &RefWeights, qh: usize, vh: &[f32]) -> f32 {
    let mut contrib = [0.0f32; DM];
    for d in 0..D {
        let vv = vh[d];
        if vv == 0.0 {
            continue;
        }
        let wrow = &w.wo[(qh * D + d) * DM..(qh * D + d) * DM + DM];
        for e in 0..DM {
            contrib[e] += vv * wrow[e];
        }
    }
    norm(&contrib)
}

// ------------------------------------------------------------ prefill forward

/// Everything one prefill pass produces for one sequence of length `n`.
struct PrefillOut {
    logits: Vec<f32>, // [V]
    k: Vec<f32>,      // [L, HKV, n, D]
    v: Vec<f32>,      // [L, HKV, n, D]
    /// [L, HKV, n] each, in PREFILL_OUTPUTS stat order.
    score_lin: Vec<f32>,
    score_mlp: Vec<f32>,
    max_attn: Vec<f32>,
    plus_attn: Vec<f32>,
    cum_attn: Vec<f32>,
    win_attn: Vec<f32>,
    vnorm: Vec<f32>,
    knorm: Vec<f32>,
}

/// Kernel selection + worker pool, threaded from the backend into the
/// prefill/decode drivers (`cfg.threads == 1` ⇒ scalar path, inline).
/// `simd` is the host-resolved level for the blocked kernels; the scalar
/// naive path never consults it (the backend forces `Scalar` when
/// `threads == 1`, keeping the semantic oracle untouched).
struct ParCtx<'a> {
    cfg: ParallelConfig,
    pool: &'a WorkerPool,
    simd: SimdLevel,
}

/// Per-unit partial statistics of one `(kv, g, row-block)` attention unit:
/// everything the original inner loop accumulated across queries, reduced
/// over this unit's rows only. Arrays cover positions `s < len` (the last
/// query row of the unit attends that far); the serial fixed-order merge
/// folds them into the `[L, H, n]` outputs.
struct UnitStats {
    len: usize,
    maxp: Vec<f32>,
    maxn: Vec<f32>,
    cum: Vec<f32>,
    win: Vec<f32>,
}

impl UnitStats {
    fn new(len: usize) -> UnitStats {
        UnitStats {
            len,
            maxp: vec![0.0; len],
            maxn: vec![0.0; len],
            cum: vec![0.0; len],
            win: vec![0.0; len],
        }
    }
}

/// Carve `buf` into consecutive disjoint mutable chunks (one per work
/// unit), each behind a `Mutex<Option<..>>` cell a pool worker can take.
fn carve<'a>(
    mut buf: &'a mut [f32],
    sizes: impl Iterator<Item = usize>,
) -> Vec<Mutex<Option<&'a mut [f32]>>> {
    let mut out = Vec::new();
    for sz in sizes {
        let (head, tail) = buf.split_at_mut(sz);
        out.push(Mutex::new(Some(head)));
        buf = tail;
    }
    out
}

/// One attention work unit: queries `j0..j1` of query head `kv*GRP + g`.
/// Computes softmax rows, the attention output rows (disjoint per unit)
/// and the unit's partial statistics. The score kernel is the only
/// scalar/blocked divergence (`kt` panel vs strided dot) and both sum the
/// head dim in ascending order, so the unit's output bits are identical on
/// either path.
#[allow(clippy::too_many_arguments)]
fn attn_unit(
    w: &RefWeights,
    kv: usize,
    g: usize,
    j0: usize,
    j1: usize,
    n: usize,
    qk_buf: &[f32],
    kbuf: &[f32],
    kt: Option<&[f32]>,
    vbuf: &[f32],
    hnorm_inv: &[f32],
    stats_from: usize,
    win_from: usize,
    simd: SimdLevel,
    rows: &mut [f32],
    st: &mut UnitStats,
) {
    let qh = kv * GRP + g;
    let mut row = vec![0.0f32; j1];
    for j in j0..j1 {
        let jp1 = j + 1;
        let q = &qk_buf[j * HQ * D + qh * D..j * HQ * D + qh * D + D];
        match kt {
            Some(kt) => kernels::scores_from_kt_level(
                q,
                &kt[kv * D * n..(kv + 1) * D * n],
                n,
                D,
                jp1,
                &mut row,
                simd,
            ),
            None => {
                for s in 0..jp1 {
                    let k = &kbuf[s * HKV * D + kv * D..s * HKV * D + kv * D + D];
                    row[s] = kernels::dot(q, k, D);
                }
            }
        }
        let mut m = f32::NEG_INFINITY;
        for &sc in &row[..jp1] {
            if sc > m {
                m = sc;
            }
        }
        kernels::fast_exp_sub_rows(&mut row[..jp1], m, simd);
        let mut sum = 0.0f32;
        for &e in &row[..jp1] {
            sum += e;
        }
        let inv = 1.0 / sum;
        for r in &mut row[..jp1] {
            *r *= inv;
        }
        let orow = &mut rows[(j - j0) * D..(j - j0) * D + D];
        for s in 0..jp1 {
            let a = row[s];
            let vrow = &vbuf[s * HKV * D + kv * D..s * HKV * D + kv * D + D];
            for d in 0..D {
                orow[d] += a * vrow[d];
            }
        }
        if j >= stats_from {
            for s in 0..jp1 {
                if row[s] > st.maxp[s] {
                    st.maxp[s] = row[s];
                }
            }
            let hi = hnorm_inv[j];
            for s in 0..jp1 {
                let an = row[s] * hi;
                if an > st.maxn[s] {
                    st.maxn[s] = an;
                }
            }
            for s in 0..jp1 {
                st.cum[s] += row[s];
            }
        }
        if j >= win_from {
            for s in 0..jp1 {
                st.win[s] += row[s];
            }
        }
    }
}

/// Causal GQA prefill with statistics over `toks` (true content only —
/// bucket padding is the caller's concern). `stats_from` restricts the
/// max/maxn statistics to queries >= stats_from (the KVzip oracle pass).
/// See the module docs for the scalar/blocked work-unit structure.
fn prefill_one(w: &RefWeights, toks: &[i32], stats_from: usize, par: &ParCtx) -> PrefillOut {
    let n = toks.len();
    let win_from = n.saturating_sub(OBS_WINDOW);
    let lhn = L * HKV * n;
    let mut out = PrefillOut {
        logits: vec![0.0; V],
        k: vec![0.0; lhn * D],
        v: vec![0.0; lhn * D],
        score_lin: vec![0.0; lhn],
        score_mlp: vec![0.0; lhn],
        max_attn: vec![0.0; lhn],
        plus_attn: vec![0.0; lhn],
        cum_attn: vec![0.0; lhn],
        win_attn: vec![0.0; lhn],
        vnorm: vec![0.0; lhn],
        knorm: vec![0.0; lhn],
    };

    // embed
    let mut h = vec![0.0f32; n * DM];
    for j in 0..n {
        let b = toks[j].clamp(0, V as i32 - 1) as usize;
        h[j * DM..j * DM + DM].copy_from_slice(&w.emb[b * DM..b * DM + DM]);
    }

    let blocked = par.cfg.threads > 1;
    let br = par.cfg.block_rows.max(1);
    let njb = n.div_ceil(br);
    let simd = par.simd;
    // threads == 1 is the scalar path: naive kernels, inline execution.
    // The blocked path dispatches on the resolved SIMD level (scalar
    // blocked when the host has no vector ISA or KVZAP_SIMD=scalar);
    // every level is bitwise identical (see kernels::matmul_block_rows_level).
    let mm = |x: &[f32], w: &[f32], rows: usize, a: usize, b: usize, out: &mut [f32]| {
        if blocked {
            kernels::matmul_block_rows_level(x, w, 0..rows, a, b, out, simd)
        } else {
            kernels::matmul(x, w, rows, a, b, out)
        }
    };

    let mut x = vec![0.0f32; n * DM];
    let mut qk_buf = vec![0.0f32; n * HQ * D]; // reused for q then o
    let mut kbuf = vec![0.0f32; n * HKV * D];
    let mut vbuf = vec![0.0f32; n * HKV * D];
    let mut tmp = vec![0.0f32; n * DSUR.max(HKV)];
    let mut hnorm_inv = vec![0.0f32; n];
    let mut maxn = vec![0.0f32; HKV * GRP * n];
    let mut vng = vec![0.0f32; HKV * GRP * n];
    let mut attn_out = vec![0.0f32; HQ * n * D];

    for l in 0..L {
        let sbase = l * HKV * n;
        // surrogate scores from the layer *input* hidden states
        mm(&h, &w.w_sl, n, DM, HKV, &mut tmp[..n * HKV]);
        for j in 0..n {
            for hh in 0..HKV {
                out.score_lin[sbase + hh * n + j] = tmp[j * HKV + hh] + w.b_sl[hh];
            }
        }
        {
            let mut z = vec![0.0f32; n * DSUR];
            mm(&h, &w.w1, n, DM, DSUR, &mut z);
            for j in 0..n {
                for m in 0..DSUR {
                    z[j * DSUR + m] = gelu(z[j * DSUR + m] + w.b1[m]);
                }
            }
            mm(&z, &w.w2, n, DSUR, HKV, &mut tmp[..n * HKV]);
            for j in 0..n {
                for hh in 0..HKV {
                    out.score_mlp[sbase + hh * n + j] = tmp[j * HKV + hh] + w.b2[hh];
                }
            }
        }
        for j in 0..n {
            hnorm_inv[j] = 1.0 / norm(&h[j * DM..j * DM + DM]).max(1e-6);
        }

        // projections + RoPE
        for j in 0..n {
            rmsnorm_row(&h[j * DM..j * DM + DM], &mut x[j * DM..j * DM + DM]);
        }
        mm(&x, &w.wq, n, DM, HQ * D, &mut qk_buf);
        mm(&x, &w.wk, n, DM, HKV * D, &mut kbuf);
        mm(&x, &w.wv, n, DM, HKV * D, &mut vbuf);
        let scale = 1.0 / (D as f32).sqrt();
        for j in 0..n {
            let (cos, sin) = rope_angles(j as f32);
            for qh in 0..HQ {
                let q = &mut qk_buf[j * HQ * D + qh * D..j * HQ * D + qh * D + D];
                apply_rope(q, &cos, &sin);
                for d in 0..D {
                    q[d] *= scale;
                }
            }
            for kv in 0..HKV {
                apply_rope(
                    &mut kbuf[j * HKV * D + kv * D..j * HKV * D + kv * D + D],
                    &cos,
                    &sin,
                );
            }
        }

        // attention + statistics as parallel work units: attention units
        // are (kv, g, query row-block), value-norm units are (kv, g,
        // position-block); outputs are disjoint carved slices and the
        // statistics land in per-unit partials
        attn_out.fill(0.0);
        maxn.fill(0.0);
        let kt: Option<Vec<f32>> = if blocked {
            // transposed [D, n] key panels per kv head for the blocked
            // score kernel (contiguous position lanes)
            let mut buf = vec![0.0f32; HKV * D * n];
            for kv in 0..HKV {
                let panel = &mut buf[kv * D * n..(kv + 1) * D * n];
                kernels::pack_kt(&kbuf, kv * D, HKV * D, n, D, panel);
            }
            Some(buf)
        } else {
            None
        };
        let n_units = HKV * GRP * njb;
        let stats_cells: Vec<Mutex<Option<UnitStats>>> =
            (0..n_units).map(|_| Mutex::new(None)).collect();
        {
            let block_of = |u: usize| {
                let j0 = (u % njb) * br;
                (j0, (j0 + br).min(n))
            };
            let attn_slices = carve(
                &mut attn_out,
                (0..n_units).map(|u| {
                    let (j0, j1) = block_of(u);
                    (j1 - j0) * D
                }),
            );
            let vng_slices = carve(
                &mut vng,
                (0..n_units).map(|u| {
                    let (s0, s1) = block_of(u);
                    s1 - s0
                }),
            );
            let kt_ref = kt.as_deref();
            let (qk, kb, vb, hn) = (&qk_buf, &kbuf, &vbuf, &hnorm_inv);
            let worker = |u: usize| {
                if u < n_units {
                    let kv = u / (GRP * njb);
                    let g = (u / njb) % GRP;
                    let (j0, j1) = block_of(u);
                    let rows = attn_slices[u].lock().unwrap().take().unwrap();
                    let mut st = UnitStats::new(j1);
                    attn_unit(
                        w,
                        kv,
                        g,
                        j0,
                        j1,
                        n,
                        qk,
                        kb,
                        kt_ref,
                        vb,
                        hn,
                        stats_from,
                        win_from,
                        simd,
                        rows,
                        &mut st,
                    );
                    *stats_cells[u].lock().unwrap() = Some(st);
                } else {
                    let v = u - n_units;
                    let kv = v / (GRP * njb);
                    let g = (v / njb) % GRP;
                    let (s0, s1) = block_of(v);
                    let chunk = vng_slices[v].lock().unwrap().take().unwrap();
                    for (i, s) in (s0..s1).enumerate() {
                        let vrow = &vb[s * HKV * D + kv * D..s * HKV * D + kv * D + D];
                        chunk[i] = vnorm_one(w, kv * GRP + g, vrow);
                    }
                }
            };
            par.pool.run(2 * n_units, &worker);
        }
        // fixed-order serial merge of the unit partials (g asc, row-block
        // asc per kv head): this order — never the thread schedule —
        // defines the floating-point grouping of the statistics
        for kv in 0..HKV {
            for g in 0..GRP {
                let gbase = (kv * GRP + g) * n;
                for jb in 0..njb {
                    let cell = (kv * GRP + g) * njb + jb;
                    let st = stats_cells[cell].lock().unwrap().take().unwrap();
                    let mi0 = sbase + kv * n;
                    for s in 0..st.len {
                        if st.maxp[s] > out.max_attn[mi0 + s] {
                            out.max_attn[mi0 + s] = st.maxp[s];
                        }
                        out.cum_attn[mi0 + s] += st.cum[s];
                        out.win_attn[mi0 + s] += st.win[s];
                        if st.maxn[s] > maxn[gbase + s] {
                            maxn[gbase + s] = st.maxn[s];
                        }
                    }
                }
            }
            for s in 0..n {
                let mut plus = 0.0f32;
                let mut vn = 0.0f32;
                for g in 0..GRP {
                    let gi = (kv * GRP + g) * n + s;
                    plus = plus.max(maxn[gi] * vng[gi]);
                    vn = vn.max(vng[gi]);
                }
                out.plus_attn[sbase + kv * n + s] = plus;
                out.vnorm[sbase + kv * n + s] = vn;
                out.knorm[sbase + kv * n + s] =
                    norm(&kbuf[s * HKV * D + kv * D..s * HKV * D + kv * D + D]);
                let kvi = (l * HKV + kv) * n * D + s * D;
                out.k[kvi..kvi + D]
                    .copy_from_slice(&kbuf[s * HKV * D + kv * D..s * HKV * D + kv * D + D]);
                out.v[kvi..kvi + D]
                    .copy_from_slice(&vbuf[s * HKV * D + kv * D..s * HKV * D + kv * D + D]);
            }
        }

        // residual: h += concat(attn_out) @ wo  (reuse x as the concat buf)
        for j in 0..n {
            for qh in 0..HQ {
                for d in 0..D {
                    x[j * HQ * D + qh * D + d] = attn_out[qh * n * D + j * D + d];
                }
            }
        }
        let mut delta = vec![0.0f32; n * DM];
        mm(&x[..n * HQ * D], &w.wo, n, HQ * D, DM, &mut delta);
        for i in 0..n * DM {
            h[i] += delta[i];
        }
        // (the FFN is identity in the reference model — SwiGLU weights zero)
    }

    // final norm + unembedding at the last position
    let mut hf = vec![0.0f32; DM];
    rmsnorm_row(&h[(n - 1) * DM..n * DM], &mut hf);
    for i in 0..DM {
        let hv = hf[i];
        if hv == 0.0 {
            continue;
        }
        let wrow = &w.w_out[i * V..i * V + V];
        for b in 0..V {
            out.logits[b] += hv * wrow[b];
        }
    }
    out
}

// ------------------------------------------------------------- decode forward

struct DecodeScratch {
    logits: Vec<f32>,    // [B, V]
    score_lin: Vec<f32>, // [L, B, HKV]
    score_mlp: Vec<f32>,
    vnorm: Vec<f32>,
    attn_row: Vec<f32>, // [L, B, HKV, T_MAX + 1]
}

/// One decode slot's disjoint mutable views into the group cache and
/// scratch — the unit of work the parallel decode driver hands a thread.
/// Cache/mask/attn-row chunks are ordered `(layer, kv head)`; the
/// surrogate chunks are ordered by layer. Built with `split`/`chunks_mut`,
/// so concurrent slots never alias.
struct SlotViews<'a> {
    kc: Vec<&'a mut [f32]>,        // L*HKV × [t_max * D]
    vc: Vec<&'a mut [f32]>,        // L*HKV × [t_max * D]
    mask: Vec<&'a [f32]>,          // L*HKV × [t_max]
    logits: &'a mut [f32],         // [V]
    score_lin: Vec<&'a mut [f32]>, // L × [HKV]
    score_mlp: Vec<&'a mut [f32]>, // L × [HKV]
    vnorm: Vec<&'a mut [f32]>,     // L × [HKV]
    attn_row: Vec<&'a mut [f32]>,  // L*HKV × [t_max + 1]
}

/// Split a `[L, B, inner, chunk]`-shaped flat buffer into per-slot chunk
/// lists (each list ordered `(l, inner)`-major), so slots can be decoded
/// concurrently without aliasing.
fn carve_slots_mut(buf: &mut [f32], b: usize, inner: usize, chunk: usize) -> Vec<Vec<&mut [f32]>> {
    let mut out: Vec<Vec<&mut [f32]>> = (0..b).map(|_| Vec::new()).collect();
    for (i, c) in buf.chunks_mut(chunk).enumerate() {
        out[(i / inner) % b].push(c);
    }
    out
}

/// Immutable sibling of [`carve_slots_mut`].
fn carve_slots_ref(buf: &[f32], b: usize, inner: usize, chunk: usize) -> Vec<Vec<&[f32]>> {
    let mut out: Vec<Vec<&[f32]>> = (0..b).map(|_| Vec::new()).collect();
    for (i, c) in buf.chunks(chunk).enumerate() {
        out[(i / inner) % b].push(c);
    }
    out
}

/// One slot's attendable demoted-tier rows for a quant-attend decode
/// step: per `(layer, kv head)` lists (indexed `l * HKV + head`) of
/// quantized entries sorted ascending by position — the deterministic
/// append order of the quant-attend softmax.
#[derive(Default)]
struct SlotSide {
    rows: Vec<Vec<SideRow>>,
}

/// One quantized (K, V) pair attendable without rehydration.
struct SideRow {
    pos: usize,
    k: kernels::QuantRow,
    v: kernels::QuantRow,
    bits: kernels::QuantBits,
    group: usize,
    bytes: usize,
}

impl SlotSide {
    /// Total entries / side-pool bytes this slot attends per step.
    fn stat(&self) -> QuantAttendStat {
        let rows = self.rows.iter().map(|r| r.len()).sum();
        let bytes = self.rows.iter().flatten().map(|e| e.bytes).sum();
        QuantAttendStat { rows, bytes }
    }
}

/// One masked decode step for one batch slot, against that slot's views of
/// the dense padded cache. Mirrors kernels/ref.py::decode_attention_ref:
/// row `pos` of the cache is written *after* attending (the new KV
/// participates via a virtual appended row, exactly the static-shape
/// S = t_max + 1 trick the decode artifact uses).
///
/// With `side` present, each `(l, kv)` additionally attends that list's
/// quantized demoted rows, dequantized in-register inside the score and
/// value loops (`kernels::score_from_quant` / `axpy_from_quant`) and
/// appended to the softmax after the virtual row. With `side` `None` (or
/// all-empty) the step is bitwise identical to the pre-quant-attend path.
fn decode_slot(
    w: &RefWeights,
    t_max: usize,
    token: i32,
    pos: usize,
    side: Option<&SlotSide>,
    sv: &mut SlotViews,
) {
    let b = token.clamp(0, V as i32 - 1) as usize;
    let pos = pos.min(t_max - 1);
    let mut h = [0.0f32; DM];
    h.copy_from_slice(&w.emb[b * DM..b * DM + DM]);
    let (cos, sin) = rope_angles(pos as f32);
    let scale = 1.0 / (D as f32).sqrt();
    let mut x = [0.0f32; DM];
    let max_side = side
        .map(|s| s.rows.iter().map(|r| r.len()).max().unwrap_or(0))
        .unwrap_or(0);
    let mut row = vec![0.0f32; t_max + 1 + max_side];
    let mut keep = vec![0usize; t_max + 1];

    for l in 0..L {
        // surrogate scores from the layer input
        for hh in 0..HKV {
            let mut lin = w.b_sl[hh];
            for i in 0..DM {
                lin += h[i] * w.w_sl[i * HKV + hh];
            }
            sv.score_lin[l][hh] = lin;
        }
        {
            let mut z = [0.0f32; DSUR];
            for m in 0..DSUR {
                let mut acc = w.b1[m];
                for i in 0..DM {
                    acc += h[i] * w.w1[i * DSUR + m];
                }
                z[m] = gelu(acc);
            }
            for hh in 0..HKV {
                let mut mlp = w.b2[hh];
                for m in 0..DSUR {
                    mlp += z[m] * w.w2[m * HKV + hh];
                }
                sv.score_mlp[l][hh] = mlp;
            }
        }

        rmsnorm_row(&h, &mut x);
        let mut q = [0.0f32; HQ * D];
        let mut kn = [0.0f32; HKV * D];
        let mut vn = [0.0f32; HKV * D];
        for i in 0..DM {
            let xv = x[i];
            if xv == 0.0 {
                continue;
            }
            for j in 0..HQ * D {
                q[j] += xv * w.wq[i * HQ * D + j];
            }
            for j in 0..HKV * D {
                kn[j] += xv * w.wk[i * HKV * D + j];
                vn[j] += xv * w.wv[i * HKV * D + j];
            }
        }
        for qh in 0..HQ {
            apply_rope(&mut q[qh * D..qh * D + D], &cos, &sin);
            for d in 0..D {
                q[qh * D + d] *= scale;
            }
        }
        for kv in 0..HKV {
            apply_rope(&mut kn[kv * D..kv * D + D], &cos, &sin);
        }

        let mut attn_out = [0.0f32; HQ * D];
        for kv in 0..HKV {
            let lh = l * HKV + kv;
            let kc = &mut *sv.kc[lh];
            let vc = &mut *sv.vc[lh];
            let mask = sv.mask[lh];
            let ar = &mut *sv.attn_row[lh];
            let srows: &[SideRow] = side.map(|s| s.rows[lh].as_slice()).unwrap_or(&[]);
            // attendable positions: masked cache rows + the appended new KV
            let mut nkeep = 0;
            for s in 0..t_max {
                if mask[s] > 0.0 {
                    keep[nkeep] = s;
                    nkeep += 1;
                }
            }
            keep[nkeep] = t_max; // virtual appended row
            nkeep += 1;
            let total = nkeep + srows.len();
            for g in 0..GRP {
                let qh = kv * GRP + g;
                let qv = &q[qh * D..qh * D + D];
                let mut m = f32::NEG_INFINITY;
                for (i, &s) in keep[..nkeep].iter().enumerate() {
                    let sc = if s == t_max {
                        kernels::dot(qv, &kn[kv * D..kv * D + D], D)
                    } else {
                        kernels::dot(qv, &kc[s * D..s * D + D], D)
                    };
                    row[i] = sc;
                    if sc > m {
                        m = sc;
                    }
                }
                // demoted rows join the softmax after the virtual row,
                // scored straight off their codes (no rehydration)
                for (i, e) in srows.iter().enumerate() {
                    let sc = kernels::score_from_quant(qv, &e.k, e.group, e.bits, D);
                    row[nkeep + i] = sc;
                    if sc > m {
                        m = sc;
                    }
                }
                let mut sum = 0.0f32;
                for r in &mut row[..total] {
                    let e = fast_exp(*r - m);
                    *r = e;
                    sum += e;
                }
                let inv = 1.0 / sum;
                for (i, &s) in keep[..nkeep].iter().enumerate() {
                    let a = row[i] * inv;
                    let vrow = if s == t_max {
                        &vn[kv * D..kv * D + D]
                    } else {
                        &vc[s * D..s * D + D]
                    };
                    for d in 0..D {
                        attn_out[qh * D + d] += a * vrow[d];
                    }
                    ar[s] += a;
                }
                for (i, e) in srows.iter().enumerate() {
                    let a = row[nkeep + i] * inv;
                    kernels::axpy_from_quant(
                        a,
                        &e.v,
                        e.group,
                        e.bits,
                        D,
                        &mut attn_out[qh * D..qh * D + D],
                    );
                    ar[e.pos] += a;
                }
            }
            // vnorm statistic for the new KV pair
            let mut vmax = 0.0f32;
            for g in 0..GRP {
                vmax = vmax.max(vnorm_one(w, kv * GRP + g, &vn[kv * D..kv * D + D]));
            }
            sv.vnorm[l][kv] = vmax;
            // write the new KV into its true cache slot
            kc[pos * D..pos * D + D].copy_from_slice(&kn[kv * D..kv * D + D]);
            vc[pos * D..pos * D + D].copy_from_slice(&vn[kv * D..kv * D + D]);
        }
        for qh in 0..HQ {
            for d in 0..D {
                let ov = attn_out[qh * D + d];
                if ov == 0.0 {
                    continue;
                }
                for e in 0..DM {
                    h[e] += ov * w.wo[(qh * D + d) * DM + e];
                }
            }
        }
    }

    let hin = h;
    rmsnorm_row(&hin, &mut h);
    for i in 0..DM {
        let hv = h[i];
        if hv == 0.0 {
            continue;
        }
        for b in 0..V {
            sv.logits[b] += hv * w.w_out[i * V + b];
        }
    }
}

// ----------------------------------------------------------- backend plumbing

/// One backend-owned decode-group cache: k/v `[L, B, H, t_max, D]` plus
/// keep-mask `[L, B, H, t_max]`, mutated in place by the resident decode
/// path (no per-step cloning — the group layout is identical to what the
/// decode artifact consumes, so `decode_slot` runs directly on it).
struct RefKvGroup {
    batch: usize,
    t_max: usize,
    k: Vec<f32>,
    v: Vec<f32>,
    mask: Vec<f32>,
}

/// One demoted entry in the backend's quantized side pool: the groupwise
/// codes for the K and V `[D]` rows of a single `(slot, l, head, pos)`.
struct SideEntry {
    k: kernels::QuantRow,
    v: kernels::QuantRow,
    bits: kernels::QuantBits,
    group: usize,
    bytes: usize,
}

/// Side-pool key: (kv handle id, slot, layer, head, pos).
type SideKey = (u64, usize, usize, usize, usize);

pub struct ReferenceBackend {
    w: RefWeights,
    t_max: usize,
    cfg: ParallelConfig,
    /// Host-resolved SIMD level for the blocked kernels (forced to
    /// `Scalar` when `cfg.threads == 1` so the naive oracle never
    /// vectorizes, whatever `KVZAP_SIMD` says).
    simd: SimdLevel,
    pool: WorkerPool,
    kv: Mutex<HashMap<u64, Arc<Mutex<RefKvGroup>>>>,
    /// Quantized demoted-tier payloads (see [`Backend::kv_demote`]).
    /// Entries die with their handle (kv_free) or slot reuse (kv_scatter).
    side: Mutex<HashMap<SideKey, SideEntry>>,
    next_kv: AtomicU64,
}

impl ReferenceBackend {
    /// Default capacity, parallelism from the environment
    /// ([`ParallelConfig::from_env`]: auto threads unless `KVZAP_THREADS`
    /// pins them).
    pub fn new() -> ReferenceBackend {
        Self::with_options(T_MAX, ParallelConfig::from_env())
    }

    /// A reference backend with a non-default cache capacity (the decode
    /// bench sweeps t_max; the model semantics are unchanged).
    pub fn with_t_max(t_max: usize) -> ReferenceBackend {
        Self::with_options(t_max, ParallelConfig::from_env())
    }

    /// Full control over capacity and the parallel/blocked compute path —
    /// `cfg.threads == 1` is the scalar reference path, anything larger
    /// runs the blocked kernels over a persistent worker pool. Outputs are
    /// bitwise identical across configs with equal `block_rows`.
    pub fn with_options(t_max: usize, cfg: ParallelConfig) -> ReferenceBackend {
        assert!(t_max >= *PREFILL_T.iter().max().unwrap(), "t_max below the prefill buckets");
        let simd = if cfg.threads > 1 { cfg.simd.resolve() } else { SimdLevel::Scalar };
        ReferenceBackend {
            w: gen_weights(),
            t_max,
            cfg,
            simd,
            pool: WorkerPool::new(&cfg),
            kv: Mutex::new(HashMap::new()),
            side: Mutex::new(HashMap::new()),
            next_kv: AtomicU64::new(1),
        }
    }

    /// The active parallel configuration.
    pub fn parallel_config(&self) -> ParallelConfig {
        self.cfg
    }

    /// The host-resolved SIMD level the blocked kernels run at.
    pub fn simd_level(&self) -> SimdLevel {
        self.simd
    }

    fn par(&self) -> ParCtx<'_> {
        ParCtx { cfg: self.cfg, pool: &self.pool, simd: self.simd }
    }

    /// Decode every slot of one group step, in parallel across slots when
    /// the config allows (slots are disjoint carved views; per-slot math
    /// is identical either way, so thread count never changes the bits).
    #[allow(clippy::too_many_arguments)]
    fn decode_group_run(
        &self,
        b: usize,
        tokens: &[i32],
        pos: &[i32],
        kc: &mut [f32],
        vc: &mut [f32],
        mask: &[f32],
        side: Option<&[SlotSide]>,
        scratch: &mut DecodeScratch,
    ) {
        let t_max = self.t_max;
        let kviews = carve_slots_mut(kc, b, HKV, t_max * D);
        let vviews = carve_slots_mut(vc, b, HKV, t_max * D);
        let mviews = carve_slots_ref(mask, b, HKV, t_max);
        let lviews = carve_slots_mut(&mut scratch.logits, b, 1, V);
        let slviews = carve_slots_mut(&mut scratch.score_lin, b, 1, HKV);
        let smviews = carve_slots_mut(&mut scratch.score_mlp, b, 1, HKV);
        let vnviews = carve_slots_mut(&mut scratch.vnorm, b, 1, HKV);
        let arviews = carve_slots_mut(&mut scratch.attn_row, b, HKV, t_max + 1);
        let mut slots: Vec<SlotViews> = kviews
            .into_iter()
            .zip(vviews)
            .zip(mviews)
            .zip(lviews)
            .zip(slviews)
            .zip(smviews)
            .zip(vnviews)
            .zip(arviews)
            .map(|(((((((kc, vc), mask), mut l), sl), sm), vn), ar)| SlotViews {
                kc,
                vc,
                mask,
                logits: l.pop().expect("one logits chunk per slot"),
                score_lin: sl,
                score_mlp: sm,
                vnorm: vn,
                attn_row: ar,
            })
            .collect();
        let side_of = |s: usize| side.and_then(|sides| sides.get(s));
        if self.cfg.threads > 1 && b > 1 {
            let work: Vec<Mutex<Option<SlotViews>>> =
                slots.into_iter().map(|s| Mutex::new(Some(s))).collect();
            let w = &self.w;
            self.pool.run(b, &|s| {
                let mut sv = work[s].lock().unwrap().take().unwrap();
                decode_slot(w, t_max, tokens[s], pos[s].max(0) as usize, side_of(s), &mut sv);
            });
        } else {
            for (s, sv) in slots.iter_mut().enumerate() {
                decode_slot(&self.w, t_max, tokens[s], pos[s].max(0) as usize, side_of(s), sv);
            }
        }
    }

    fn group(&self, h: &KvHandle) -> Result<Arc<Mutex<RefKvGroup>>> {
        self.kv
            .lock()
            .unwrap()
            .get(&h.id)
            .cloned()
            .ok_or_else(|| anyhow!("kv handle {} unknown (freed?)", h.id))
    }

    fn exec_prefill(&self, meta: &ArtifactMeta, data: &[Arg]) -> Result<Vec<Buffer>> {
        let t_max = self.t_max;
        let (b, t) = (meta.batch, meta.t);
        let tokens = arg_i32(data, 0, b * t)?;
        let lens = arg_i32(data, 1, b)?;
        let mut logits = vec![0.0f32; b * V];
        let mut kcache = vec![0.0f32; L * b * HKV * t_max * D];
        let mut vcache = vec![0.0f32; L * b * HKV * t_max * D];
        let mut stats: Vec<Vec<f32>> = (0..8).map(|_| vec![0.0f32; L * b * HKV * t_max]).collect();
        for s in 0..b {
            let n = (lens[s].max(1) as usize).min(t).min(t_max);
            let one = prefill_one(&self.w, &tokens[s * t..s * t + n], 0, &self.par());
            logits[s * V..s * V + V].copy_from_slice(&one.logits);
            let srcs = [
                &one.score_lin,
                &one.score_mlp,
                &one.max_attn,
                &one.plus_attn,
                &one.cum_attn,
                &one.win_attn,
                &one.vnorm,
                &one.knorm,
            ];
            for l in 0..L {
                for kv in 0..HKV {
                    let src = (l * HKV + kv) * n;
                    for (st, out) in srcs.iter().zip(stats.iter_mut()) {
                        let dst = ((l * b + s) * HKV + kv) * t_max;
                        out[dst..dst + n].copy_from_slice(&st[src..src + n]);
                    }
                    let cdst = (((l * b + s) * HKV + kv) * t_max) * D;
                    kcache[cdst..cdst + n * D].copy_from_slice(&one.k[src * D..(src + n) * D]);
                    vcache[cdst..cdst + n * D].copy_from_slice(&one.v[src * D..(src + n) * D]);
                }
            }
        }
        let mut outs = vec![
            host(logits, vec![b, V])?,
            host(kcache, vec![L, b, HKV, t_max, D])?,
            host(vcache, vec![L, b, HKV, t_max, D])?,
        ];
        for st in stats {
            outs.push(host(st, vec![L, b, HKV, t_max])?);
        }
        Ok(outs)
    }

    fn decode_scratch(&self, b: usize) -> DecodeScratch {
        DecodeScratch {
            logits: vec![0.0; b * V],
            score_lin: vec![0.0; L * b * HKV],
            score_mlp: vec![0.0; L * b * HKV],
            vnorm: vec![0.0; L * b * HKV],
            attn_row: vec![0.0; L * b * HKV * (self.t_max + 1)],
        }
    }

    /// Legacy buffer-threading decode (`rt.exec` on a decode artifact):
    /// inputs are immutable buffers, so the caches are cloned per step.
    /// The resident path ([`Self::exec_decode_resident`]) mutates the
    /// backend-owned group in place instead and is what the engine uses.
    fn exec_decode(&self, meta: &ArtifactMeta, data: &[Arg]) -> Result<Vec<Buffer>> {
        let t_max = self.t_max;
        let b = meta.batch;
        let tokens = arg_i32(data, 0, b)?;
        let pos = arg_i32(data, 1, b)?;
        let kc_in = arg_buf(data, 2)?;
        let vc_in = arg_buf(data, 3)?;
        let mask = arg_buf(data, 4)?;
        let cache_len = L * b * HKV * t_max * D;
        if kc_in.data.len() != cache_len || vc_in.data.len() != cache_len {
            return Err(anyhow!("decode_b{b}: cache buffer has wrong size"));
        }
        if mask.data.len() != L * b * HKV * t_max {
            return Err(anyhow!("decode_b{b}: mask buffer has wrong size"));
        }
        let mut kc = kc_in.data.clone();
        let mut vc = vc_in.data.clone();
        let mut scratch = self.decode_scratch(b);
        self.decode_group_run(b, tokens, pos, &mut kc, &mut vc, &mask.data, None, &mut scratch);
        Ok(vec![
            host(scratch.logits, vec![b, V])?,
            host(kc, vec![L, b, HKV, t_max, D])?,
            host(vc, vec![L, b, HKV, t_max, D])?,
            host(scratch.score_lin, vec![L, b, HKV])?,
            host(scratch.score_mlp, vec![L, b, HKV])?,
            host(scratch.vnorm, vec![L, b, HKV])?,
            host(scratch.attn_row, vec![L, b, HKV, t_max + 1])?,
        ])
    }

    /// Shared body of the resident decode paths: validate, run the group
    /// step (optionally quant-attending `side`), flip the decoded rows'
    /// mask bits, package outputs.
    fn decode_resident_inner(
        &self,
        meta: &ArtifactMeta,
        tokens: &[i32],
        pos: &[i32],
        h: &KvHandle,
        side: Option<&[SlotSide]>,
    ) -> Result<Vec<Buffer>> {
        let t_max = self.t_max;
        let b = meta.batch;
        if meta.kind != "decode" {
            return Err(anyhow!("exec_decode_resident on non-decode artifact {}", meta.name));
        }
        if tokens.len() != b || pos.len() != b || h.batch != b {
            return Err(anyhow!(
                "exec_decode_resident: batch mismatch (artifact {b}, tokens {}, handle {})",
                tokens.len(),
                h.batch
            ));
        }
        let g = self.group(h)?;
        let mut g = g.lock().unwrap();
        let mut scratch = self.decode_scratch(b);
        let RefKvGroup { k, v, mask, .. } = &mut *g;
        self.decode_group_run(b, tokens, pos, k, v, mask, side, &mut scratch);
        // the decoded row is attendable from the next step on (mirrors
        // PagedKvCache::fill — joins overwrite vacant-slot leftovers)
        for s in 0..b {
            let p = (pos[s].max(0) as usize).min(t_max - 1);
            for l in 0..L {
                for hh in 0..HKV {
                    mask[((l * b + s) * HKV + hh) * t_max + p] = 1.0;
                }
            }
        }
        Ok(vec![
            host(scratch.logits, vec![b, V])?,
            host(scratch.score_lin, vec![L, b, HKV])?,
            host(scratch.score_mlp, vec![L, b, HKV])?,
            host(scratch.vnorm, vec![L, b, HKV])?,
            host(scratch.attn_row, vec![L, b, HKV, t_max + 1])?,
        ])
    }

    fn exec_kvzip(&self, meta: &ArtifactMeta, data: &[Arg]) -> Result<Vec<Buffer>> {
        let t = meta.t;
        let tokens = arg_i32(data, 0, t)?;
        let lens = arg_i32(data, 1, 1)?;
        let n = (lens[0].max(1) as usize).min(t);
        // repeated-prompt double pass: [prompt; prompt], stats from queries
        // of the repeat only (paper §3.1)
        let mut tok2 = Vec::with_capacity(2 * n);
        tok2.extend_from_slice(&tokens[..n]);
        tok2.extend_from_slice(&tokens[..n]);
        let one = prefill_one(&self.w, &tok2, n, &self.par());
        let mut s = vec![0.0f32; L * HKV * t];
        let mut sp = vec![0.0f32; L * HKV * t];
        for l in 0..L {
            for kv in 0..HKV {
                let src = (l * HKV + kv) * 2 * n;
                let dst = (l * HKV + kv) * t;
                s[dst..dst + n].copy_from_slice(&one.max_attn[src..src + n]);
                sp[dst..dst + n].copy_from_slice(&one.plus_attn[src..src + n]);
            }
        }
        Ok(vec![host(s, vec![L, 1, HKV, t])?, host(sp, vec![L, 1, HKV, t])?])
    }
}

impl Default for ReferenceBackend {
    fn default() -> Self {
        Self::new()
    }
}

fn host(data: Vec<f32>, shape: Vec<usize>) -> Result<Buffer> {
    Ok(Buffer(BufferRepr::HostF32(Tensor::new(data, shape)?)))
}

fn arg_i32<'a>(data: &'a [Arg], i: usize, want: usize) -> Result<&'a [i32]> {
    match data.get(i) {
        Some(Arg::I32(v, _)) if v.len() == want => Ok(v),
        Some(Arg::I32(v, _)) => Err(anyhow!("input {i}: expected {want} i32s, got {}", v.len())),
        _ => Err(anyhow!("input {i}: expected i32 data")),
    }
}

fn arg_buf<'a>(data: &'a [Arg], i: usize) -> Result<&'a Tensor> {
    match data.get(i) {
        Some(Arg::Buf(b)) => b.host_f32(),
        _ => Err(anyhow!("input {i}: expected a buffer")),
    }
}

impl Backend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn describe(&self) -> String {
        if self.cfg.threads > 1 {
            format!(
                "reference (blocked, threads={}, block_rows={}, simd={})",
                self.cfg.threads,
                self.cfg.block_rows,
                self.simd.tag()
            )
        } else {
            "reference (scalar)".to_string()
        }
    }

    fn exec(&self, meta: &ArtifactMeta, data: &[Arg]) -> Result<Vec<Buffer>> {
        match meta.kind.as_str() {
            "prefill" => self.exec_prefill(meta, data),
            "decode" => self.exec_decode(meta, data),
            "kvzip_score" => self.exec_kvzip(meta, data),
            k => Err(anyhow!("reference backend: unknown artifact kind '{k}'")),
        }
    }

    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<Buffer> {
        host(data.to_vec(), dims.to_vec())
    }

    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<Buffer> {
        Ok(Buffer(BufferRepr::HostI32(data.to_vec(), dims.to_vec())))
    }

    fn fetch_f32(&self, buf: &Buffer, shape: &[usize]) -> Result<Tensor> {
        let t = buf.host_f32()?;
        if t.data.len() != shape.iter().product::<usize>() {
            return Err(anyhow!("fetch_f32: buffer len {} != shape {shape:?}", t.data.len()));
        }
        Tensor::new(t.data.clone(), shape.to_vec())
    }

    // ---- backend-owned KV cache -----------------------------------------

    fn kv_alloc(
        &self,
        layers: usize,
        batch: usize,
        heads: usize,
        t_max: usize,
        d_head: usize,
    ) -> Result<KvHandle> {
        if (layers, heads, d_head) != (L, HKV, D) || t_max != self.t_max {
            return Err(anyhow!(
                "kv_alloc: dims [{layers}, {batch}, {heads}, {t_max}, {d_head}] do not match \
                 the reference model [{L}, _, {HKV}, {}, {D}]",
                self.t_max
            ));
        }
        let id = self.next_kv.fetch_add(1, Ordering::Relaxed);
        let elems = layers * batch * heads * t_max * d_head;
        self.kv.lock().unwrap().insert(
            id,
            Arc::new(Mutex::new(RefKvGroup {
                batch,
                t_max,
                k: vec![0.0; elems],
                v: vec![0.0; elems],
                mask: vec![0.0; layers * batch * heads * t_max],
            })),
        );
        Ok(KvHandle { id, layers, batch, heads, t_max, d_head })
    }

    fn kv_free(&self, h: &KvHandle) {
        self.kv.lock().unwrap().remove(&h.id);
        self.side.lock().unwrap().retain(|key, _| key.0 != h.id);
    }

    fn kv_scatter(&self, h: &KvHandle, slot: usize, k: &[f32], v: &[f32]) -> Result<()> {
        if k.len() != h.slot_elems() || v.len() != h.slot_elems() {
            return Err(anyhow!("kv_scatter: rows have {} elems, want {}", k.len(), h.slot_elems()));
        }
        // a scatter re-seats the slot: any demoted payload left by the
        // previous occupant is stale (the joining sequence re-demotes its
        // own entries after the scatter)
        self.side.lock().unwrap().retain(|key, _| !(key.0 == h.id && key.1 == slot));
        let g = self.group(h)?;
        let mut g = g.lock().unwrap();
        check_slot(&g, h, slot)?;
        let chunk = h.t_max * h.d_head;
        for l in 0..h.layers {
            for hh in 0..h.heads {
                let src = (l * h.heads + hh) * chunk;
                let dst = ((l * g.batch + slot) * h.heads + hh) * chunk;
                g.k[dst..dst + chunk].copy_from_slice(&k[src..src + chunk]);
                g.v[dst..dst + chunk].copy_from_slice(&v[src..src + chunk]);
            }
        }
        Ok(())
    }

    fn kv_write_mask(&self, h: &KvHandle, slot: usize, mask: &[f32]) -> Result<()> {
        if mask.len() != h.mask_elems() {
            return Err(anyhow!("kv_write_mask: {} elems, want {}", mask.len(), h.mask_elems()));
        }
        let g = self.group(h)?;
        let mut g = g.lock().unwrap();
        check_slot(&g, h, slot)?;
        for l in 0..h.layers {
            for hh in 0..h.heads {
                let src = (l * h.heads + hh) * h.t_max;
                let dst = ((l * g.batch + slot) * h.heads + hh) * h.t_max;
                g.mask[dst..dst + h.t_max].copy_from_slice(&mask[src..src + h.t_max]);
            }
        }
        Ok(())
    }

    fn kv_fetch_row(
        &self,
        h: &KvHandle,
        slot: usize,
        pos: usize,
        k_row: &mut [f32],
        v_row: &mut [f32],
    ) -> Result<()> {
        if k_row.len() != h.row_elems() || v_row.len() != h.row_elems() {
            return Err(anyhow!("kv_fetch_row: {} elems, want {}", k_row.len(), h.row_elems()));
        }
        if pos >= h.t_max {
            return Err(anyhow!("kv_fetch_row: pos {pos} >= t_max {}", h.t_max));
        }
        let g = self.group(h)?;
        let g = g.lock().unwrap();
        check_slot(&g, h, slot)?;
        let d = h.d_head;
        for l in 0..h.layers {
            for hh in 0..h.heads {
                let src = (((l * g.batch + slot) * h.heads + hh) * h.t_max + pos) * d;
                let dst = (l * h.heads + hh) * d;
                k_row[dst..dst + d].copy_from_slice(&g.k[src..src + d]);
                v_row[dst..dst + d].copy_from_slice(&g.v[src..src + d]);
            }
        }
        Ok(())
    }

    fn kv_gather(&self, h: &KvHandle, slot: usize, k: &mut [f32], v: &mut [f32]) -> Result<()> {
        if k.len() != h.slot_elems() || v.len() != h.slot_elems() {
            return Err(anyhow!("kv_gather: {} elems, want {}", k.len(), h.slot_elems()));
        }
        let g = self.group(h)?;
        let g = g.lock().unwrap();
        check_slot(&g, h, slot)?;
        let chunk = h.t_max * h.d_head;
        for l in 0..h.layers {
            for hh in 0..h.heads {
                let src = ((l * g.batch + slot) * h.heads + hh) * chunk;
                let dst = (l * h.heads + hh) * chunk;
                k[dst..dst + chunk].copy_from_slice(&g.k[src..src + chunk]);
                v[dst..dst + chunk].copy_from_slice(&g.v[src..src + chunk]);
            }
        }
        Ok(())
    }

    fn exec_decode_resident(
        &self,
        meta: &ArtifactMeta,
        tokens: &[i32],
        pos: &[i32],
        h: &KvHandle,
    ) -> Result<Vec<Buffer>> {
        self.decode_resident_inner(meta, tokens, pos, h, None)
    }

    fn exec_decode_resident_quant(
        &self,
        meta: &ArtifactMeta,
        tokens: &[i32],
        pos: &[i32],
        h: &KvHandle,
    ) -> Result<(Vec<Buffer>, Vec<QuantAttendStat>)> {
        let b = meta.batch;
        // snapshot the attendable side entries per slot (cloned out of the
        // side map so no lock is held across the worker pool), grouped per
        // (layer, kv head) and sorted by position — a deterministic order
        // independent of map iteration
        let mut sides: Vec<SlotSide> = (0..b)
            .map(|_| SlotSide { rows: (0..L * HKV).map(|_| Vec::new()).collect() })
            .collect();
        {
            let side = self.side.lock().unwrap();
            for (&(id, slot, l, head, pos), e) in side.iter() {
                if id == h.id && slot < b {
                    sides[slot].rows[l * HKV + head].push(SideRow {
                        pos,
                        k: e.k.clone(),
                        v: e.v.clone(),
                        bits: e.bits,
                        group: e.group,
                        bytes: e.bytes,
                    });
                }
            }
        }
        for s in &mut sides {
            for list in &mut s.rows {
                list.sort_by_key(|e| e.pos);
            }
        }
        let stats: Vec<QuantAttendStat> = sides.iter().map(|s| s.stat()).collect();
        let outs = self.decode_resident_inner(meta, tokens, pos, h, Some(&sides))?;
        Ok((outs, stats))
    }

    fn kv_drop_slot(&self, h: &KvHandle, slot: usize) -> Result<usize> {
        let mut n = 0;
        self.side.lock().unwrap().retain(|key, _| {
            let hit = key.0 == h.id && key.1 == slot;
            n += hit as usize;
            !hit
        });
        Ok(n)
    }

    // ---- demoted (quantized) KV tier -------------------------------------

    fn kv_demote(
        &self,
        h: &KvHandle,
        slot: usize,
        l: usize,
        head: usize,
        pos: usize,
        bits: kernels::QuantBits,
        group: usize,
    ) -> Result<usize> {
        check_lhp(h, l, head, pos)?;
        let g = self.group(h)?;
        let mut g = g.lock().unwrap();
        check_slot(&g, h, slot)?;
        let d = h.d_head;
        let base = (((l * g.batch + slot) * h.heads + head) * h.t_max + pos) * d;
        let kq = kernels::quantize_row(&g.k[base..base + d], group, bits);
        let vq = kernels::quantize_row(&g.v[base..base + d], group, bits);
        // leave the lossy round-trip in the resident rows so host-side
        // snapshot round-trips and a later rehydrate agree bit-for-bit
        kernels::dequantize_row(&kq, group, bits, &mut g.k[base..base + d]);
        kernels::dequantize_row(&vq, group, bits, &mut g.v[base..base + d]);
        let bytes = 2 * kernels::quant_row_bytes(d, group, bits);
        self.side
            .lock()
            .unwrap()
            .insert((h.id, slot, l, head, pos), SideEntry { k: kq, v: vq, bits, group, bytes });
        Ok(bytes)
    }

    /// Fused band demotion: one group lock + one side-map lock for the
    /// whole band, instead of a lock pair per entry. Encoding semantics
    /// are identical to [`Backend::kv_demote`] per entry (lossy
    /// round-trip left in the resident rows).
    fn kv_demote_band(
        &self,
        h: &KvHandle,
        slot: usize,
        band: &[(usize, usize, usize)],
        bits: kernels::QuantBits,
        group: usize,
    ) -> Result<usize> {
        let g = self.group(h)?;
        let mut g = g.lock().unwrap();
        check_slot(&g, h, slot)?;
        let d = h.d_head;
        let mut side = self.side.lock().unwrap();
        let mut total = 0;
        for &(l, head, pos) in band {
            check_lhp(h, l, head, pos)?;
            let base = (((l * g.batch + slot) * h.heads + head) * h.t_max + pos) * d;
            let kq = kernels::quantize_row(&g.k[base..base + d], group, bits);
            let vq = kernels::quantize_row(&g.v[base..base + d], group, bits);
            kernels::dequantize_row(&kq, group, bits, &mut g.k[base..base + d]);
            kernels::dequantize_row(&vq, group, bits, &mut g.v[base..base + d]);
            let bytes = 2 * kernels::quant_row_bytes(d, group, bits);
            side.insert((h.id, slot, l, head, pos), SideEntry { k: kq, v: vq, bits, group, bytes });
            total += bytes;
        }
        Ok(total)
    }

    fn kv_rehydrate(
        &self,
        h: &KvHandle,
        slot: usize,
        l: usize,
        head: usize,
        pos: usize,
    ) -> Result<usize> {
        check_lhp(h, l, head, pos)?;
        let e = self
            .side
            .lock()
            .unwrap()
            .remove(&(h.id, slot, l, head, pos))
            .ok_or_else(|| anyhow!("kv_rehydrate: no demoted entry at ({slot},{l},{head},{pos})"))?;
        let g = self.group(h)?;
        let mut g = g.lock().unwrap();
        check_slot(&g, h, slot)?;
        let d = h.d_head;
        let base = (((l * g.batch + slot) * h.heads + head) * h.t_max + pos) * d;
        kernels::dequantize_row(&e.k, e.group, e.bits, &mut g.k[base..base + d]);
        kernels::dequantize_row(&e.v, e.group, e.bits, &mut g.v[base..base + d]);
        Ok(e.bytes)
    }

    fn kv_drop_demoted(
        &self,
        h: &KvHandle,
        slot: usize,
        l: usize,
        head: usize,
        pos: usize,
    ) -> Result<usize> {
        Ok(self
            .side
            .lock()
            .unwrap()
            .remove(&(h.id, slot, l, head, pos))
            .map(|e| e.bytes)
            .unwrap_or(0))
    }
}

fn check_lhp(h: &KvHandle, l: usize, head: usize, pos: usize) -> Result<()> {
    if l >= h.layers || head >= h.heads || pos >= h.t_max {
        return Err(anyhow!(
            "demoted-tier op out of range: ({l},{head},{pos}) vs [{},{},{}]",
            h.layers,
            h.heads,
            h.t_max
        ));
    }
    Ok(())
}

fn check_slot(g: &RefKvGroup, h: &KvHandle, slot: usize) -> Result<()> {
    debug_assert_eq!(g.t_max, h.t_max);
    if slot >= g.batch {
        return Err(anyhow!("slot {slot} out of range (batch {})", g.batch));
    }
    Ok(())
}

// ------------------------------------------------------------------- manifest

fn io(name: &str, shape: Vec<usize>, dtype: &str) -> IoSpec {
    IoSpec { name: name.into(), shape, dtype: dtype.into() }
}

/// The in-code manifest: same bucket grid and artifact contract as
/// python/compile/aot.py emits, so every coordinator path (bucket
/// resolution, output indexing, benches) is exercised identically on both
/// backends.
pub fn reference_manifest() -> Manifest {
    reference_manifest_with(T_MAX)
}

/// Bucket grid for a given capacity: the fixed seed buckets plus
/// power-of-two extensions up to `t_max`, so long-context sweeps (the
/// prefill bench) can prefill `t_max`-sized prompts in one pass. Applied
/// to the kvzip oracle grid too, preserving the engine invariant
/// `max_prompt() <= max(kvzip_t)` — every admitted prompt stays
/// oracle-scorable.
fn extend_ts(seed: &[usize], t_max: usize) -> Vec<usize> {
    let mut ts = seed.to_vec();
    let mut t = 1024;
    while t <= t_max {
        ts.push(t);
        t *= 2;
    }
    ts
}

/// The reference manifest with a non-default cache capacity (pair with
/// [`ReferenceBackend::with_t_max`]).
pub fn reference_manifest_with(t_max: usize) -> Manifest {
    let mut artifacts = std::collections::HashMap::new();
    let prefill_t = extend_ts(&PREFILL_T, t_max);
    let kvzip_t = extend_ts(&KVZIP_T, t_max);
    let stat_outputs = |b: usize| -> Vec<IoSpec> {
        let mut outs = vec![
            io("logits", vec![b, V], "f32"),
            io("kcache", vec![L, b, HKV, t_max, D], "f32"),
            io("vcache", vec![L, b, HKV, t_max, D], "f32"),
        ];
        for name in
            ["score_lin", "score_mlp", "max_attn", "plus_attn", "cum_attn", "win_attn", "vnorm", "knorm"]
        {
            outs.push(io(name, vec![L, b, HKV, t_max], "f32"));
        }
        outs
    };
    for &b in &PREFILL_B {
        for &t in &prefill_t {
            let name = format!("prefill_b{b}_t{t}");
            artifacts.insert(
                name.clone(),
                ArtifactMeta {
                    name: name.clone(),
                    file: format!("{name}.builtin"),
                    kind: "prefill".into(),
                    batch: b,
                    t,
                    inputs: vec![io("tokens", vec![b, t], "i32"), io("true_len", vec![b], "i32")],
                    outputs: stat_outputs(b),
                },
            );
        }
    }
    for &b in &DECODE_B {
        let name = format!("decode_b{b}");
        artifacts.insert(
            name.clone(),
            ArtifactMeta {
                name: name.clone(),
                file: format!("{name}.builtin"),
                kind: "decode".into(),
                batch: b,
                t: t_max,
                inputs: vec![
                    io("tokens", vec![b], "i32"),
                    io("pos", vec![b], "i32"),
                    io("kcache", vec![L, b, HKV, t_max, D], "f32"),
                    io("vcache", vec![L, b, HKV, t_max, D], "f32"),
                    io("mask", vec![L, b, HKV, t_max], "f32"),
                ],
                outputs: vec![
                    io("logits", vec![b, V], "f32"),
                    io("kcache", vec![L, b, HKV, t_max, D], "f32"),
                    io("vcache", vec![L, b, HKV, t_max, D], "f32"),
                    io("score_lin", vec![L, b, HKV], "f32"),
                    io("score_mlp", vec![L, b, HKV], "f32"),
                    io("vnorm", vec![L, b, HKV], "f32"),
                    io("attn_row", vec![L, b, HKV, t_max + 1], "f32"),
                ],
            },
        );
    }
    for &t in &kvzip_t {
        let name = format!("kvzip_score_t{t}");
        artifacts.insert(
            name.clone(),
            ArtifactMeta {
                name: name.clone(),
                file: format!("{name}.builtin"),
                kind: "kvzip_score".into(),
                batch: 1,
                t,
                inputs: vec![io("tokens", vec![1, t], "i32"), io("true_len", vec![1], "i32")],
                outputs: vec![
                    io("s", vec![L, 1, HKV, t], "f32"),
                    io("s_plus", vec![L, 1, HKV, t], "f32"),
                ],
            },
        );
    }

    let mut threshold_quantiles = std::collections::BTreeMap::new();
    // Oracle log-score quantile substitutes for the bench tau sweeps: the
    // reference surrogate is bimodal at {-6, +2}, so the sweep brackets it.
    for (q, tau) in [("0.3", -7.0), ("0.5", -4.0), ("0.7", -1.0), ("0.8", 0.5)] {
        threshold_quantiles.insert(q.to_string(), tau);
    }

    Manifest {
        model: ModelDims {
            vocab: V,
            d_model: DM,
            n_layers: L,
            n_q_heads: HQ,
            n_kv_heads: HKV,
            d_head: D,
            d_int: D_INT,
            d_surrogate: DSUR,
            t_max,
        },
        special: SpecialTokens { pad: 0, bos: 1, eos: 2, sep: 3 },
        window: WINDOW,
        obs_window: OBS_WINDOW,
        buckets: Buckets {
            prefill_t,
            prefill_b: PREFILL_B.to_vec(),
            decode_b: DECODE_B.to_vec(),
            kvzip_t,
        },
        artifacts,
        weights: vec![],
        threshold_quantiles,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exec(
        be: &ReferenceBackend,
        man: &Manifest,
        name: &str,
        data: &[Arg],
    ) -> Vec<Buffer> {
        be.exec(man.artifacts.get(name).unwrap(), data).unwrap()
    }

    #[test]
    fn weights_are_deterministic() {
        let a = gen_weights();
        let b = gen_weights();
        assert_eq!(a.emb, b.emb);
        assert_eq!(a.w_out, b.w_out);
    }

    fn scalar_prefill(w: &RefWeights, toks: &[i32], stats_from: usize) -> PrefillOut {
        let cfg = ParallelConfig::scalar();
        let pool = WorkerPool::new(&cfg);
        prefill_one(w, toks, stats_from, &ParCtx { cfg, pool: &pool, simd: SimdLevel::Scalar })
    }

    #[test]
    fn surrogate_scores_are_salience_bimodal() {
        let w = gen_weights();
        // "a1" -> filler then digit
        let one = scalar_prefill(&w, &[1, b'a' as i32, b'1' as i32], 0);
        // layer 0, head 0: positions BOS(salient), 'a'(filler), '1'(salient)
        let lin = &one.score_lin[0..3];
        assert!((lin[0] - (SUR_BIAS + SUR_GAIN * G_SAL)).abs() < 1e-4, "{lin:?}");
        assert!((lin[1] - SUR_BIAS).abs() < 1e-4, "{lin:?}");
        assert!((lin[2] - (SUR_BIAS + SUR_GAIN * G_SAL)).abs() < 1e-4, "{lin:?}");
        let mlp = &one.score_mlp[0..3];
        assert!((mlp[1] - SUR_BIAS).abs() < 1e-3, "{mlp:?}");
        assert!((mlp[2] - (SUR_BIAS + SUR_GAIN * G_SAL)).abs() < 1e-3, "{mlp:?}");
    }

    #[test]
    fn prefill_exec_shapes_and_determinism() {
        let be = ReferenceBackend::new();
        let man = reference_manifest();
        let t = 128;
        let mut toks = vec![0i32; t];
        for (i, b) in "AB = 123. hello".bytes().enumerate() {
            toks[i + 1] = b as i32;
        }
        toks[0] = 1;
        let lens = [16i32];
        let outs = exec(&be, &man, "prefill_b1_t128", &[
            Arg::I32(&toks, &[1, t]),
            Arg::I32(&lens, &[1]),
        ]);
        assert_eq!(outs.len(), 11);
        let logits = be.fetch_f32(&outs[0], &[1, V]).unwrap();
        let outs2 = exec(&be, &man, "prefill_b1_t128", &[
            Arg::I32(&toks, &[1, t]),
            Arg::I32(&lens, &[1]),
        ]);
        let logits2 = be.fetch_f32(&outs2[0], &[1, V]).unwrap();
        assert_eq!(logits.data, logits2.data);
        // stats are zero beyond true_len
        let ml = be.fetch_f32(&outs[5], &[L, 1, HKV, T_MAX]).unwrap();
        assert_eq!(ml.at(&[0, 0, 0, 20]), 0.0);
        assert!(ml.at(&[0, 0, 0, 0]) > 0.0, "BOS must be attended");
    }

    #[test]
    fn decode_writes_kv_and_respects_mask() {
        let be = ReferenceBackend::new();
        let man = reference_manifest();
        let t = 128;
        let mut toks = vec![0i32; t];
        toks[0] = 1;
        for (i, b) in "XY = 77.".bytes().enumerate() {
            toks[i + 1] = b as i32;
        }
        let n = 9usize;
        let lens = [n as i32];
        let outs = exec(&be, &man, "prefill_b1_t128", &[
            Arg::I32(&toks, &[1, t]),
            Arg::I32(&lens, &[1]),
        ]);
        let mut mask = vec![0.0f32; L * HKV * T_MAX];
        for l in 0..L {
            for h in 0..HKV {
                for p in 0..n {
                    mask[(l * HKV + h) * T_MAX + p] = 1.0;
                }
            }
        }
        let mask_buf = be.upload_f32(&mask, &[L, 1, HKV, T_MAX]).unwrap();
        let tok = [b'7' as i32];
        let pos = [n as i32];
        let douts = exec(&be, &man, "decode_b1", &[
            Arg::I32(&tok, &[1]),
            Arg::I32(&pos, &[1]),
            Arg::Buf(&outs[1]),
            Arg::Buf(&outs[2]),
            Arg::Buf(&mask_buf),
        ]);
        assert_eq!(douts.len(), 7);
        // new KV written at row `pos` of the returned cache
        let kc = douts[1].host_f32().unwrap();
        let base = n * D; // [l=0, b=0, h=0, pos=n, :]
        assert!(kc.data[base..base + D].iter().any(|&v| v != 0.0));
        // masking everything out changes the logits (only the appended row
        // remains attendable)
        let zeros = vec![0.0f32; mask.len()];
        let zero_buf = be.upload_f32(&zeros, &[L, 1, HKV, T_MAX]).unwrap();
        let douts2 = exec(&be, &man, "decode_b1", &[
            Arg::I32(&tok, &[1]),
            Arg::I32(&pos, &[1]),
            Arg::Buf(&outs[1]),
            Arg::Buf(&outs[2]),
            Arg::Buf(&zero_buf),
        ]);
        let l1 = be.fetch_f32(&douts[0], &[1, V]).unwrap();
        let l2 = be.fetch_f32(&douts2[0], &[1, V]).unwrap();
        assert_ne!(l1.data, l2.data);
    }

    /// The resident decode path must be bit-identical to the legacy
    /// buffer-threading exec: same logits, same surrogate scores, same new
    /// KV row — and the implicit mask fill must reproduce the host-side
    /// mask update across a second step.
    #[test]
    fn resident_decode_matches_legacy_exec_bitwise() {
        let be = ReferenceBackend::new();
        let man = reference_manifest();
        let t = 128;
        let mut toks = vec![0i32; t];
        toks[0] = 1;
        for (i, b) in "KQ = 41. pad pad".bytes().enumerate() {
            toks[i + 1] = b as i32;
        }
        let n = 17usize;
        let lens = [n as i32];
        let outs = exec(&be, &man, "prefill_b1_t128", &[
            Arg::I32(&toks, &[1, t]),
            Arg::I32(&lens, &[1]),
        ]);
        let kc0 = outs[1].host_f32().unwrap().data.clone();
        let vc0 = outs[2].host_f32().unwrap().data.clone();
        let mut mask = vec![0.0f32; L * HKV * T_MAX];
        for l in 0..L {
            for h in 0..HKV {
                for p in 0..n {
                    mask[(l * HKV + h) * T_MAX + p] = 1.0;
                }
            }
        }
        let dec = man.artifacts.get("decode_b1").unwrap();
        let steps = [(b'4' as i32, n), (b'1' as i32, n + 1)];

        // legacy: thread buffers, update the mask by hand between steps
        let mut legacy_logits = vec![];
        let mut legacy_kc = kc0.clone();
        let mut legacy_sl = vec![];
        {
            let mut kc = be.upload_f32(&kc0, &[L, 1, HKV, T_MAX, D]).unwrap();
            let mut vc = be.upload_f32(&vc0, &[L, 1, HKV, T_MAX, D]).unwrap();
            let mut m = mask.clone();
            for (i, &(tok, pos)) in steps.iter().enumerate() {
                if i > 0 {
                    for l in 0..L {
                        for h in 0..HKV {
                            m[(l * HKV + h) * T_MAX + pos - 1] = 1.0;
                        }
                    }
                }
                let mb = be.upload_f32(&m, &[L, 1, HKV, T_MAX]).unwrap();
                let douts = be
                    .exec(dec, &[
                        Arg::I32(&[tok], &[1]),
                        Arg::I32(&[pos as i32], &[1]),
                        Arg::Buf(&kc),
                        Arg::Buf(&vc),
                        Arg::Buf(&mb),
                    ])
                    .unwrap();
                legacy_logits.push(douts[0].host_f32().unwrap().data.clone());
                legacy_sl.push(douts[3].host_f32().unwrap().data.clone());
                legacy_kc = douts[1].host_f32().unwrap().data.clone();
                let mut it = douts.into_iter();
                let _ = it.next(); // logits (already cloned)
                kc = it.next().unwrap();
                vc = it.next().unwrap();
            }
        }

        // resident: scatter once, step twice — no mask traffic after join
        let h = be.kv_alloc(L, 1, HKV, T_MAX, D).unwrap();
        be.kv_scatter(&h, 0, &kc0, &vc0).unwrap();
        be.kv_write_mask(&h, 0, &mask).unwrap();
        for (i, &(tok, pos)) in steps.iter().enumerate() {
            let routs = be
                .exec_decode_resident(dec, &[tok], &[pos as i32], &h)
                .unwrap();
            assert_eq!(
                routs[0].host_f32().unwrap().data,
                legacy_logits[i],
                "step {i}: resident logits must match the legacy path bit-for-bit"
            );
            assert_eq!(
                routs[1].host_f32().unwrap().data,
                legacy_sl[i],
                "step {i}: resident score_lin must match"
            );
        }
        // the in-place rows equal the legacy returned cache rows
        let mut k_row = vec![0.0f32; L * HKV * D];
        let mut v_row = vec![0.0f32; L * HKV * D];
        for &(_, pos) in &steps {
            be.kv_fetch_row(&h, 0, pos, &mut k_row, &mut v_row).unwrap();
            for l in 0..L {
                for hh in 0..HKV {
                    let g = ((l * HKV + hh) * T_MAX + pos) * D;
                    let r = (l * HKV + hh) * D;
                    assert_eq!(&k_row[r..r + D], &legacy_kc[g..g + D]);
                }
            }
        }
        // gather returns the full slot including the prefill rows
        let mut kg = vec![0.0f32; h.slot_elems()];
        let mut vg = vec![0.0f32; h.slot_elems()];
        be.kv_gather(&h, 0, &mut kg, &mut vg).unwrap();
        assert_eq!(kg[..n * D], kc0[..n * D]);
        be.kv_free(&h);
        assert!(be.kv_scatter(&h, 0, &kc0, &vc0).is_err(), "freed handle rejected");
    }

    /// Quant-attended decode: demoted rows contribute to attention
    /// straight from their codes, no `kv_rehydrate`. With an empty side
    /// tier the quant path is bitwise the plain resident path; with a
    /// demoted band it matches the rehydrate-everything decode to the
    /// ≤1e-3 property bound (identical dequantized values, different
    /// softmax summation order) and reports the attended rows/bytes.
    #[test]
    fn quant_attend_matches_rehydrated_decode() {
        let be = ReferenceBackend::new();
        let man = reference_manifest();
        let t = 128;
        let mut toks = vec![0i32; t];
        toks[0] = 1;
        for (i, b) in "Zt = 905. filler filler".bytes().enumerate() {
            toks[i + 1] = b as i32;
        }
        let n = 24usize;
        let lens = [n as i32];
        let outs = exec(&be, &man, "prefill_b1_t128", &[
            Arg::I32(&toks, &[1, t]),
            Arg::I32(&lens, &[1]),
        ]);
        let kc0 = outs[1].host_f32().unwrap().data.clone();
        let vc0 = outs[2].host_f32().unwrap().data.clone();
        let dec = man.artifacts.get("decode_b1").unwrap();
        let band: Vec<usize> = (2..7).collect();
        let bits = kernels::QuantBits::Int8;

        let mk = |demote_band: bool, rehydrate_back: bool, mask_band: bool| -> KvHandle {
            let h = be.kv_alloc(L, 1, HKV, T_MAX, D).unwrap();
            be.kv_scatter(&h, 0, &kc0, &vc0).unwrap();
            if demote_band {
                for l in 0..L {
                    for hh in 0..HKV {
                        for &p in &band {
                            be.kv_demote(&h, 0, l, hh, p, bits, 8).unwrap();
                            if rehydrate_back {
                                be.kv_rehydrate(&h, 0, l, hh, p).unwrap();
                            }
                        }
                    }
                }
            }
            let mut mask = vec![0.0f32; L * HKV * T_MAX];
            for l in 0..L {
                for hh in 0..HKV {
                    for p in 0..n {
                        if mask_band || !band.contains(&p) {
                            mask[(l * HKV + hh) * T_MAX + p] = 1.0;
                        }
                    }
                }
            }
            be.kv_write_mask(&h, 0, &mask).unwrap();
            h
        };

        // A: band demoted + masked out → quant-attended from the side tier
        let ha = mk(true, false, false);
        // B: band demoted then rehydrated (same lossy values), fully masked
        let hb = mk(true, true, true);
        let tok = [b'9' as i32];
        let pos = [n as i32];
        let (aouts, stats) = be.exec_decode_resident_quant(dec, &tok, &pos, &ha).unwrap();
        assert_eq!(stats.len(), 1);
        assert_eq!(stats[0].rows, L * HKV * band.len());
        assert!(stats[0].bytes > 0);
        let bouts = be.exec_decode_resident(dec, &tok, &pos, &hb).unwrap();
        let la = aouts[0].host_f32().unwrap().data.clone();
        let lb = bouts[0].host_f32().unwrap().data.clone();
        assert_ne!(la, lb, "summation order differs — bitwise equality would be suspicious");
        for (a, b) in la.iter().zip(&lb) {
            assert!(
                (a - b).abs() <= 1e-3 * a.abs().max(1.0),
                "quant-attend vs rehydrate-everything drifted: {a} vs {b}"
            );
        }

        // with an empty side tier the quant path is bitwise the plain path
        let hc = mk(false, false, true);
        let hd = mk(false, false, true);
        let (couts, cstats) = be.exec_decode_resident_quant(dec, &tok, &pos, &hc).unwrap();
        let douts = be.exec_decode_resident(dec, &tok, &pos, &hd).unwrap();
        assert_eq!(cstats[0], QuantAttendStat { rows: 0, bytes: 0 });
        assert_eq!(
            couts[0].host_f32().unwrap().data,
            douts[0].host_f32().unwrap().data,
            "no side entries ⇒ quant path must be bitwise identical"
        );

        // kv_drop_slot purges a vacated slot's side entries
        assert_eq!(be.kv_drop_slot(&ha, 0).unwrap(), L * HKV * band.len());
        let (_, s2) = be.exec_decode_resident_quant(dec, &tok, &pos, &ha).unwrap();
        assert_eq!(s2[0].rows, 0, "dropped slot must not quant-attend");
        for h in [ha, hb, hc, hd] {
            be.kv_free(&h);
        }
    }

    #[test]
    fn kvzip_oracle_scores_cover_prompt_only() {
        let be = ReferenceBackend::new();
        let man = reference_manifest();
        let t = 256;
        let mut toks = vec![0i32; t];
        toks[0] = 1;
        for (i, b) in "needle 42 in here".bytes().enumerate() {
            toks[i + 1] = b as i32;
        }
        let n = 18usize;
        let lens = [n as i32];
        let outs = exec(&be, &man, "kvzip_score_t256", &[
            Arg::I32(&toks, &[1, t]),
            Arg::I32(&lens, &[1]),
        ]);
        let s = be.fetch_f32(&outs[0], &[L, 1, HKV, t]).unwrap();
        assert!(s.row(&[0, 0, 0])[..n].iter().any(|&v| v > 0.0));
        assert!(s.row(&[0, 0, 0])[n..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn manifest_contract_matches_engine_expectations() {
        let man = reference_manifest();
        assert_eq!(man.prefill_bucket(200, 1).as_deref(), Some("prefill_b1_t256"));
        assert_eq!(man.decode_bucket(3).as_deref(), Some("decode_b4"));
        assert!(man.kvzip_bucket(513).is_none());
        let pf = man.artifacts.get("prefill_b4_t512").unwrap();
        assert_eq!(pf.output_index("knorm").unwrap(), 10);
        let dec = man.artifacts.get("decode_b8").unwrap();
        assert_eq!(dec.inputs.len(), 5);
        assert_eq!(dec.output_index("score_mlp").unwrap(), 4);
        // resident-decode indexing skips the cache outputs
        assert_eq!(dec.resident_output_index("logits").unwrap(), 0);
        assert_eq!(dec.resident_output_index("score_lin").unwrap(), 1);
        assert_eq!(dec.resident_output_index("score_mlp").unwrap(), 2);
        assert!(dec.resident_output_index("kcache").is_err());
    }

    #[test]
    fn t_max_parameterization_scales_shapes() {
        let man = reference_manifest_with(2048);
        assert_eq!(man.model.t_max, 2048);
        let dec = man.artifacts.get("decode_b4").unwrap();
        assert_eq!(dec.inputs[2].shape, vec![L, 4, HKV, 2048, D]);
        let be = ReferenceBackend::with_t_max(2048);
        let h = be.kv_alloc(L, 1, HKV, 2048, D).unwrap();
        assert_eq!(h.slot_elems(), L * HKV * 2048 * D);
        be.kv_free(&h);
        assert!(be.kv_alloc(L, 1, HKV, 512, D).is_err(), "t_max mismatch rejected");
    }
}
