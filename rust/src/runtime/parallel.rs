//! Worker-pool parallel driver for the reference backend.
//!
//! The hermetic backend is the tier-1 workhorse: every test and bench runs
//! on it, so its throughput gates every sweep. This module provides the
//! two pieces the compute core needs to scale on host CPUs **without any
//! new dependencies**:
//!
//! * [`ParallelConfig`] — thread count + kernel block sizes, auto-detected
//!   from [`std::thread::available_parallelism`] and overridable via the
//!   `KVZAP_THREADS` / `KVZAP_BLOCK_ROWS` environment variables. Threaded
//!   through `Runtime::reference*` constructors so the engine, batcher,
//!   server and benches all pick it up.
//! * [`WorkerPool`] — a persistent pool of `threads - 1` workers plus the
//!   submitting thread, exposing one operation: [`WorkerPool::run`], a
//!   parallel-for over `n` independent work items. Items are claimed from
//!   an atomic counter so imbalanced units (attention row-blocks grow with
//!   the query index) self-balance.
//!
//! ## Determinism contract
//!
//! `run(n, f)` promises nothing about *which* thread executes an item —
//! callers must only submit items whose outputs are disjoint and whose
//! per-item computation is independent of thread assignment. The reference
//! backend's prefill/decode drivers are built so every floating-point
//! reduction happens either inside one item or in a fixed-order serial
//! merge afterwards; that is what makes `threads ∈ {1, 2, 8}` produce
//! bitwise-identical artifacts (see the equivalence tests in
//! `tests/integration.rs`).
//!
//! The pool runs one job at a time (submissions serialize on an internal
//! lock); a panicking item is caught on the worker and re-raised on the
//! submitting thread once the job drains.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::thread::JoinHandle;

use super::kernels::SimdMode;

/// Default query-row block size for the blocked prefill kernels (rows per
/// attention work unit, and the boundary grid for the fixed-order stat
/// merge). Changing it changes the (deterministic) summation grouping of
/// the per-position statistics; thread count never does.
pub const DEFAULT_BLOCK_ROWS: usize = 64;

/// Parallel execution configuration for the reference backend.
///
/// `threads == 1` selects the *scalar path*: the untuned naive kernels
/// run inline on the calling thread, kept as the bitwise-equivalence
/// oracle for the blocked kernels. `threads > 1` selects the blocked
/// kernels (transposed-layout scores, panel matmul) plus the worker pool.
/// Note the scalar path shares this PR's `fast_exp` and block-grid stat
/// merge — it is bitwise identical to the *parallel* path at equal
/// `block_rows`, not to the pre-rewrite backend (whose outputs differ by
/// ~1e-5 relative; see the module docs in `runtime/reference.rs`).
///
/// # Examples
///
/// ```
/// use kvzap::runtime::ParallelConfig;
///
/// let scalar = ParallelConfig::scalar();
/// assert_eq!(scalar.threads, 1);
///
/// let four = ParallelConfig::with_threads(4);
/// assert_eq!(four.threads, 4);
/// assert_eq!(four.block_rows, ParallelConfig::auto().block_rows);
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ParallelConfig {
    /// Total threads used per execution (submitter included). `1` = the
    /// scalar reference path, no pool.
    pub threads: usize,
    /// Query rows per attention work unit (also the stat-merge grid).
    pub block_rows: usize,
    /// Requested SIMD mode for the blocked kernels (the `KVZAP_SIMD`
    /// override). Resolved to a host-supported level at backend
    /// construction; the `threads == 1` naive path ignores it entirely,
    /// so the semantic oracle stays scalar no matter what is requested.
    pub simd: SimdMode,
}

impl ParallelConfig {
    /// The scalar reference path: one thread, naive kernels.
    pub fn scalar() -> ParallelConfig {
        ParallelConfig {
            threads: 1,
            block_rows: DEFAULT_BLOCK_ROWS,
            simd: SimdMode::Scalar,
        }
    }

    /// Blocked + parallel with an explicit thread count (0 means auto).
    /// SIMD defaults to `auto` (best available level, scalar fallback).
    pub fn with_threads(threads: usize) -> ParallelConfig {
        let t = if threads == 0 { detected_parallelism() } else { threads };
        ParallelConfig {
            threads: t.max(1),
            block_rows: DEFAULT_BLOCK_ROWS,
            simd: SimdMode::Auto,
        }
    }

    /// Auto-detected parallelism (`std::thread::available_parallelism`).
    pub fn auto() -> ParallelConfig {
        ParallelConfig::with_threads(0)
    }

    /// Same config with an explicit SIMD mode (builder style).
    pub fn with_simd(mut self, simd: SimdMode) -> ParallelConfig {
        self.simd = simd;
        self
    }

    /// [`ParallelConfig::auto`] with `KVZAP_THREADS` / `KVZAP_BLOCK_ROWS`
    /// / `KVZAP_SIMD` environment overrides — what `Runtime::reference()`
    /// uses, so CI can pin the whole tier-1 suite to any path.
    pub fn from_env() -> ParallelConfig {
        let mut cfg = match std::env::var("KVZAP_THREADS").ok().and_then(|v| v.parse().ok()) {
            Some(0) | None => ParallelConfig::auto(),
            Some(t) => ParallelConfig::with_threads(t),
        };
        if let Some(br) = std::env::var("KVZAP_BLOCK_ROWS").ok().and_then(|v| v.parse().ok()) {
            if br > 0 {
                cfg.block_rows = br;
            }
        }
        if let Ok(s) = std::env::var("KVZAP_SIMD") {
            match SimdMode::parse(&s) {
                Some(m) => cfg.simd = m,
                None => eprintln!(
                    "[kvzap] ignoring unknown KVZAP_SIMD='{s}' (want auto|avx2|neon|scalar)"
                ),
            }
        }
        cfg
    }
}

fn detected_parallelism() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

// ------------------------------------------------------------------ the pool

/// Lifetime-erased borrow of the job closure. Valid strictly between job
/// submission and the submitter observing `remaining == 0 && active == 0`
/// (the submitter does not return before that, so workers never outlive
/// the real borrow despite the `'static` lie).
#[derive(Clone, Copy)]
struct RawTask(&'static (dyn Fn(usize) + Sync));

struct PoolState {
    /// Monotone job id; workers adopt a job at most once.
    epoch: u64,
    task: Option<RawTask>,
    n: usize,
    /// Items not yet finished executing.
    remaining: usize,
    /// Workers currently inside the claim loop of the live job.
    active: usize,
    panicked: bool,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    /// Workers park here for the next job.
    work_cv: Condvar,
    /// The submitter parks here for job completion.
    done_cv: Condvar,
    /// Next unclaimed item index of the live job.
    next: AtomicUsize,
}

/// A persistent worker pool executing one parallel-for job at a time.
/// Construction is cheap for `threads <= 1` (no threads are spawned and
/// [`WorkerPool::run`] degenerates to an inline loop).
pub struct WorkerPool {
    shared: std::sync::Arc<PoolShared>,
    /// Serializes submissions (one job at a time).
    submit: Mutex<()>,
    workers: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// A pool for `cfg.threads` total threads (spawns `threads - 1`
    /// workers; the submitting thread participates in every job).
    pub fn new(cfg: &ParallelConfig) -> WorkerPool {
        let shared = std::sync::Arc::new(PoolShared {
            state: Mutex::new(PoolState {
                epoch: 0,
                task: None,
                n: 0,
                remaining: 0,
                active: 0,
                panicked: false,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            next: AtomicUsize::new(0),
        });
        let workers = (1..cfg.threads)
            .map(|i| {
                let sh = shared.clone();
                std::thread::Builder::new()
                    .name(format!("kvzap-ref-{i}"))
                    .spawn(move || worker_loop(&sh))
                    .expect("spawn reference-backend worker")
            })
            .collect();
        WorkerPool { shared, submit: Mutex::new(()), workers }
    }

    /// Number of threads that execute a job (workers + submitter).
    pub fn threads(&self) -> usize {
        self.workers.len() + 1
    }

    /// Execute `f(0) .. f(n-1)` across the pool and the calling thread,
    /// returning when all items completed. Items must have disjoint
    /// outputs; claim order is unspecified. With no workers (or `n <= 1`)
    /// the items run inline in index order.
    pub fn run(&self, n: usize, f: &(dyn Fn(usize) + Sync)) {
        if self.workers.is_empty() || n <= 1 {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let _job = self.submit.lock().unwrap();
        {
            let mut st = self.shared.state.lock().unwrap();
            // erase the borrow lifetime; `run` does not return before every
            // claimed item finished, which bounds all worker accesses
            let static_f: &'static (dyn Fn(usize) + Sync) =
                unsafe { std::mem::transmute(f) };
            st.task = Some(RawTask(static_f));
            st.epoch += 1;
            st.n = n;
            st.remaining = n;
            st.panicked = false;
            self.shared.next.store(0, Ordering::SeqCst);
            self.shared.work_cv.notify_all();
        }
        // the submitter works too
        claim_items(&self.shared, f, n);
        let panicked;
        {
            let mut st = self.shared.state.lock().unwrap();
            while st.remaining > 0 || st.active > 0 {
                st = self.shared.done_cv.wait(st).unwrap();
            }
            st.task = None;
            panicked = st.panicked;
        }
        if panicked {
            panic!("a reference-backend worker item panicked");
        }
    }
}

/// Claim-and-execute loop shared by workers and the submitter. Each
/// executed item decrements `remaining`; the caller that finishes the last
/// item wakes the submitter.
fn claim_items(shared: &PoolShared, f: &(dyn Fn(usize) + Sync), n: usize) {
    loop {
        let i = shared.next.fetch_add(1, Ordering::Relaxed);
        if i >= n {
            return;
        }
        let ok = catch_unwind(AssertUnwindSafe(|| f(i))).is_ok();
        let mut st = shared.state.lock().unwrap();
        if !ok {
            st.panicked = true;
        }
        st.remaining -= 1;
        if st.remaining == 0 && st.active == 0 {
            shared.done_cv.notify_all();
        }
    }
}

fn worker_loop(shared: &PoolShared) {
    let mut seen_epoch = 0u64;
    loop {
        let (task, n) = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.task.is_some() && st.epoch != seen_epoch {
                    seen_epoch = st.epoch;
                    st.active += 1;
                    break;
                }
                st = shared.work_cv.wait(st).unwrap();
            }
            (st.task.expect("live job after adoption"), st.n)
        };
        claim_items(shared, task.0, n);
        let mut st = shared.state.lock().unwrap();
        st.active -= 1;
        if st.remaining == 0 && st.active == 0 {
            shared.done_cv.notify_all();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn inline_when_single_threaded() {
        let pool = WorkerPool::new(&ParallelConfig::scalar());
        assert_eq!(pool.threads(), 1);
        let hits = AtomicUsize::new(0);
        pool.run(17, &|_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 17);
    }

    #[test]
    fn runs_every_item_exactly_once_across_threads() {
        let pool = WorkerPool::new(&ParallelConfig::with_threads(4));
        assert_eq!(pool.threads(), 4);
        for round in 0..50 {
            let n = 1 + (round % 97);
            let marks: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            pool.run(n, &|i| {
                marks[i].fetch_add(1, Ordering::Relaxed);
            });
            for (i, m) in marks.iter().enumerate() {
                assert_eq!(m.load(Ordering::Relaxed), 1, "item {i} of round {round}");
            }
        }
    }

    #[test]
    fn disjoint_writes_land() {
        let pool = WorkerPool::new(&ParallelConfig::with_threads(3));
        let out: Vec<Mutex<usize>> = (0..256).map(|_| Mutex::new(0)).collect();
        pool.run(256, &|i| {
            *out[i].lock().unwrap() = i * i;
        });
        for (i, o) in out.iter().enumerate() {
            assert_eq!(*o.lock().unwrap(), i * i);
        }
    }

    #[test]
    fn env_and_explicit_configs() {
        assert_eq!(ParallelConfig::scalar().threads, 1);
        assert!(ParallelConfig::auto().threads >= 1);
        assert_eq!(ParallelConfig::with_threads(8).threads, 8);
        assert_eq!(ParallelConfig::with_threads(0).threads, ParallelConfig::auto().threads);
        assert_eq!(ParallelConfig::scalar().simd, SimdMode::Scalar);
        assert_eq!(ParallelConfig::auto().simd, SimdMode::Auto);
        assert_eq!(
            ParallelConfig::auto().with_simd(SimdMode::Scalar).simd,
            SimdMode::Scalar
        );
    }

    #[test]
    fn pool_survives_item_panic() {
        let pool = WorkerPool::new(&ParallelConfig::with_threads(2));
        let res = catch_unwind(AssertUnwindSafe(|| {
            pool.run(8, &|i| {
                if i == 3 {
                    panic!("boom");
                }
            });
        }));
        assert!(res.is_err(), "panic must propagate to the submitter");
        // the pool still works afterwards
        let hits = AtomicUsize::new(0);
        pool.run(8, &|_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 8);
    }
}
