//! Runtime: artifact execution behind a pluggable [`Backend`].
//!
//! The coordinator talks to a [`Runtime`] facade — bucket resolution via
//! the [`Manifest`], `artifact()` handles with per-name caching, `exec()`
//! over backend-opaque [`Buffer`]s — and never sees which backend runs the
//! math:
//!
//! * **reference** (default, hermetic): [`reference::ReferenceBackend`], a
//!   pure-Rust CPU port of the model semantics with a deterministic
//!   in-code weight set. No artifacts, no python, no native deps — this is
//!   what CI and `cargo test` exercise.
//! * **pjrt** (`--features pjrt`): [`pjrt::PjrtBackend`] loads the AOT
//!   HLO-text artifacts produced by `make artifacts` and executes them via
//!   the PJRT CPU client with weights resident on device.
//!
//! [`Runtime::auto`] picks pjrt when the feature is compiled in *and*
//! artifacts exist, otherwise the reference backend — so every binary
//! (CLI, server, benches) runs out of the box and transparently upgrades
//! when artifacts are built.

pub mod backend;
pub mod kernels;
pub mod manifest;
pub mod parallel;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod reference;
pub mod tensor;

use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

pub use backend::{Arg, Backend, Buffer, KvHandle};
pub use manifest::{ArtifactMeta, Manifest};
pub use parallel::ParallelConfig;
pub use tensor::Tensor;

use crate::metrics::TransferCounters;

/// A resolved artifact handle: the manifest metadata the engine indexes
/// outputs by. Compilation state (for backends that compile) lives in the
/// backend, keyed by `meta.name`.
pub struct Executable {
    pub meta: ArtifactMeta,
}

pub struct Runtime {
    pub manifest: Manifest,
    backend: Box<dyn Backend>,
    exes: Mutex<HashMap<String, Arc<Executable>>>,
    /// Host↔device transfer accounting; every upload/fetch/KV op below
    /// rolls its byte count in here (see `metrics::TransferCounters`).
    pub transfer: TransferCounters,
}

impl Runtime {
    /// The hermetic pure-Rust reference runtime (no artifacts needed).
    pub fn reference() -> Runtime {
        Runtime {
            manifest: reference::reference_manifest(),
            backend: Box::new(reference::ReferenceBackend::new()),
            exes: Mutex::new(HashMap::new()),
            transfer: TransferCounters::default(),
        }
    }

    /// The reference runtime with a non-default cache capacity — the
    /// decode bench sweeps `t_max` to measure how transfer volume scales.
    pub fn reference_with_t_max(t_max: usize) -> Runtime {
        Self::reference_with_options(t_max, ParallelConfig::from_env())
    }

    /// The reference runtime with explicit capacity *and* parallelism —
    /// the prefill bench sweeps thread counts and block sizes through
    /// here, and the equivalence tests pin both paths.
    ///
    /// ```
    /// use kvzap::runtime::{ParallelConfig, Runtime};
    ///
    /// let scalar = Runtime::reference_with_options(512, ParallelConfig::scalar());
    /// let parallel = Runtime::reference_with_options(512, ParallelConfig::with_threads(2));
    /// assert_eq!(scalar.manifest.model.t_max, parallel.manifest.model.t_max);
    /// ```
    pub fn reference_with_options(t_max: usize, cfg: ParallelConfig) -> Runtime {
        Runtime {
            manifest: reference::reference_manifest_with(t_max),
            backend: Box::new(reference::ReferenceBackend::with_options(t_max, cfg)),
            exes: Mutex::new(HashMap::new()),
            transfer: TransferCounters::default(),
        }
    }

    /// Load the PJRT runtime from an artifacts directory.
    #[cfg(feature = "pjrt")]
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let backend = pjrt::PjrtBackend::load(&dir, &manifest)?;
        Ok(Runtime {
            manifest,
            backend: Box::new(backend),
            exes: Mutex::new(HashMap::new()),
            transfer: TransferCounters::default(),
        })
    }

    /// Best available backend: PJRT when compiled in and artifacts exist,
    /// the hermetic reference backend otherwise.
    pub fn auto() -> Result<Runtime> {
        #[cfg(feature = "pjrt")]
        {
            let dir = crate::artifacts_dir();
            if dir.join("manifest.json").exists() {
                return Runtime::load(dir);
            }
        }
        Ok(Runtime::reference())
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Human-readable backend description (name + compute-path details,
    /// e.g. `reference (blocked, threads=8, block_rows=64)`).
    pub fn backend_desc(&self) -> String {
        self.backend.describe()
    }

    /// Resolve an artifact by bucket name (cached).
    pub fn artifact(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.exes.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let meta = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?
            .clone();
        let entry = Arc::new(Executable { meta });
        self.exes.lock().unwrap().insert(name.to_string(), entry.clone());
        Ok(entry)
    }

    /// Execute an artifact: `data` args in manifest input order. Returns
    /// one buffer per manifest output.
    pub fn exec(&self, exe: &Executable, data: &[Arg]) -> Result<Vec<Buffer>> {
        if data.len() != exe.meta.inputs.len() {
            return Err(anyhow!(
                "artifact {} expects {} data inputs, got {}",
                exe.meta.name,
                exe.meta.inputs.len(),
                data.len()
            ));
        }
        self.backend.exec(&exe.meta, data)
    }

    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<Buffer> {
        self.transfer.add_up(4 * data.len() as u64);
        self.backend.upload_f32(data, dims)
    }

    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<Buffer> {
        self.transfer.add_up(4 * data.len() as u64);
        self.backend.upload_i32(data, dims)
    }

    /// Fetch an output buffer to the host as an f32 tensor.
    pub fn fetch_f32(&self, buf: &Buffer, shape: &[usize]) -> Result<Tensor> {
        self.transfer.add_down(4 * shape.iter().product::<usize>() as u64);
        self.backend.fetch_f32(buf, shape)
    }

    // ---- backend-owned KV cache (see backend.rs module docs) ------------

    /// Allocate a zeroed decode-group KV cache on the backend.
    pub fn kv_alloc(&self, batch: usize) -> Result<KvHandle> {
        let m = &self.manifest.model;
        self.backend.kv_alloc(m.n_layers, batch, m.n_kv_heads, m.t_max, m.d_head)
    }

    pub fn kv_free(&self, h: &KvHandle) {
        self.backend.kv_free(h);
    }

    /// Scatter one sequence's `[L, H, t_max, D]` KV rows into `slot`.
    pub fn kv_scatter(&self, h: &KvHandle, slot: usize, k: &[f32], v: &[f32]) -> Result<()> {
        self.transfer.add_kv_up(4 * (k.len() + v.len()) as u64);
        self.backend.kv_scatter(h, slot, k, v)
    }

    /// Install `slot`'s keep-mask (`[L, H, t_max]`).
    pub fn kv_write_mask(&self, h: &KvHandle, slot: usize, mask: &[f32]) -> Result<()> {
        self.transfer.mask_uploads.fetch_add(1, Ordering::Relaxed);
        self.transfer.add_kv_up(4 * mask.len() as u64);
        self.backend.kv_write_mask(h, slot, mask)
    }

    /// Fetch the decoded `[L, H, D]` row at `pos` of `slot` to the host.
    pub fn kv_fetch_row(
        &self,
        h: &KvHandle,
        slot: usize,
        pos: usize,
        k_row: &mut [f32],
        v_row: &mut [f32],
    ) -> Result<()> {
        self.transfer.add_kv_down(4 * (k_row.len() + v_row.len()) as u64);
        self.backend.kv_fetch_row(h, slot, pos, k_row, v_row)
    }

    /// Fetch `slot`'s full `[L, H, t_max, D]` KV rows to the host.
    pub fn kv_gather(&self, h: &KvHandle, slot: usize, k: &mut [f32], v: &mut [f32]) -> Result<()> {
        self.transfer.add_kv_down(4 * (k.len() + v.len()) as u64);
        self.backend.kv_gather(h, slot, k, v)
    }

    /// Demote `(l, head, pos)` of `slot` into the backend's quantized side
    /// tier (see `Backend::kv_demote`). Device-local — no transfer bytes
    /// are charged; the stored payload size rolls into the tier counters.
    #[allow(clippy::too_many_arguments)]
    pub fn kv_demote(
        &self,
        h: &KvHandle,
        slot: usize,
        l: usize,
        head: usize,
        pos: usize,
        bits: kernels::QuantBits,
        group: usize,
    ) -> Result<usize> {
        let bytes = self.backend.kv_demote(h, slot, l, head, pos, bits, group)?;
        self.transfer.note_demote(bytes as u64);
        Ok(bytes)
    }

    /// Demote a band of entries of `slot` in one backend call (see
    /// `Backend::kv_demote_band`). Device-local like the per-entry op;
    /// the band's payload bytes roll into the demote tier counters.
    pub fn kv_demote_band(
        &self,
        h: &KvHandle,
        slot: usize,
        band: &[(usize, usize, usize)],
        bits: kernels::QuantBits,
        group: usize,
    ) -> Result<usize> {
        let bytes = self.backend.kv_demote_band(h, slot, band, bits, group)?;
        if !band.is_empty() {
            self.transfer.note_demote_band(band.len() as u64, bytes as u64);
        }
        Ok(bytes)
    }

    /// Rehydrate a demoted entry back into the resident rows of `slot`
    /// (see `Backend::kv_rehydrate`). Device-local.
    pub fn kv_rehydrate(
        &self,
        h: &KvHandle,
        slot: usize,
        l: usize,
        head: usize,
        pos: usize,
    ) -> Result<usize> {
        let bytes = self.backend.kv_rehydrate(h, slot, l, head, pos)?;
        self.transfer.note_rehydrate(bytes as u64);
        Ok(bytes)
    }

    /// One decode step over the resident group `h`. Returns the artifact
    /// outputs minus the resident `kcache`/`vcache` — index with
    /// [`ArtifactMeta::resident_output_index`].
    pub fn exec_decode_resident(
        &self,
        exe: &Executable,
        tokens: &[i32],
        pos: &[i32],
        h: &KvHandle,
    ) -> Result<Vec<Buffer>> {
        self.transfer.add_up(4 * (tokens.len() + pos.len()) as u64);
        self.transfer.decode_steps.fetch_add(1, Ordering::Relaxed);
        self.backend.exec_decode_resident(&exe.meta, tokens, pos, h)
    }

    /// Quantized-attend decode step: like [`Runtime::exec_decode_resident`]
    /// but demoted side entries contribute to attention in place (see
    /// `Backend::exec_decode_resident_quant`). Charges the same upload
    /// bytes as the plain step — quant-attended rows are device-local and
    /// roll into the `quant_attend_*` counters, never `bytes_*`.
    pub fn exec_decode_resident_quant(
        &self,
        exe: &Executable,
        tokens: &[i32],
        pos: &[i32],
        h: &KvHandle,
    ) -> Result<(Vec<Buffer>, Vec<backend::QuantAttendStat>)> {
        self.transfer.add_up(4 * (tokens.len() + pos.len()) as u64);
        self.transfer.decode_steps.fetch_add(1, Ordering::Relaxed);
        let (outs, stats) = self.backend.exec_decode_resident_quant(&exe.meta, tokens, pos, h)?;
        let rows: u64 = stats.iter().map(|s| s.rows as u64).sum();
        let bytes: u64 = stats.iter().map(|s| s.bytes as u64).sum();
        if rows > 0 || bytes > 0 {
            self.transfer.note_quant_attend(rows, bytes);
        }
        Ok((outs, stats))
    }

    /// Purge every demoted side entry belonging to `slot` (vacate path —
    /// a freed slot must never quant-attend stale payloads). Device-local;
    /// returns the number of entries purged. Per-entry byte accounting
    /// stays with the engine's ledger, which drops entries it tracks via
    /// [`Runtime::kv_demote`]'s recorded sizes.
    pub fn kv_drop_slot(&self, h: &KvHandle, slot: usize) -> Result<usize> {
        self.backend.kv_drop_slot(h, slot)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_runtime_resolves_and_executes() {
        let rt = Runtime::reference();
        assert_eq!(rt.backend_name(), "reference");
        let name = rt.manifest.prefill_bucket(50, 1).unwrap();
        let art = rt.artifact(&name).unwrap();
        let again = rt.artifact(&name).unwrap();
        assert!(Arc::ptr_eq(&art, &again), "artifact handles are cached");
        let t = art.meta.t;
        let mut toks = vec![0i32; t];
        toks[0] = 1;
        let lens = [1i32];
        let outs = rt.exec(&art, &[Arg::I32(&toks, &[1, t]), Arg::I32(&lens, &[1])]).unwrap();
        assert_eq!(outs.len(), art.meta.outputs.len());
        let li = art.meta.output_index("logits").unwrap();
        let logits = rt.fetch_f32(&outs[li], &art.meta.outputs[li].shape).unwrap();
        assert_eq!(logits.shape, vec![1, 256]);
    }

    #[test]
    fn exec_arity_checked() {
        let rt = Runtime::reference();
        let art = rt.artifact("decode_b1").unwrap();
        let toks = [0i32];
        assert!(rt.exec(&art, &[Arg::I32(&toks, &[1])]).is_err());
    }

    #[test]
    fn unknown_artifact_rejected() {
        let rt = Runtime::reference();
        assert!(rt.artifact("prefill_b9_t9").is_err());
    }
}
