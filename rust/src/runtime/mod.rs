//! PJRT runtime: load AOT HLO-text artifacts and execute them on the CPU
//! client with the weights resident on device.
//!
//! Wiring (from /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute_b`.
//! HLO *text* is the interchange format — jax ≥ 0.5 emits protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids. Our vendored xla crate is patched with
//! `untuple_result = true`, so each artifact output arrives as its own
//! device buffer: the KV cache produced by prefill (or a decode step) is
//! fed straight back into the next decode step with zero host traffic.

pub mod manifest;
pub mod tensor;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Context, Result};
use xla::{HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

pub use manifest::{ArtifactMeta, Manifest};
pub use tensor::Tensor;

/// An argument to an artifact execution.
pub enum Arg<'a> {
    F32(&'a [f32], &'a [usize]),
    I32(&'a [i32], &'a [usize]),
    /// A device buffer from a previous execution (e.g. the KV cache).
    Buf(&'a PjRtBuffer),
}

pub struct Executable {
    pub meta: ArtifactMeta,
    exe: PjRtLoadedExecutable,
}

pub struct Runtime {
    client: PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
    /// Weight tensors resident on device, in manifest order; appended to
    /// every execute call after the data inputs.
    weights: Vec<PjRtBuffer>,
    exes: Mutex<HashMap<String, Arc<Executable>>>,
}

impl Runtime {
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;

        let blob = std::fs::read(dir.join("weights.bin"))
            .with_context(|| "reading weights.bin (run `make artifacts`)")?;
        let mut weights = Vec::with_capacity(manifest.weights.len());
        for w in &manifest.weights {
            let slice = blob
                .get(w.offset..w.offset + w.bytes)
                .ok_or_else(|| anyhow!("weights.bin too short for {}", w.name))?;
            let data: Vec<f32> = slice
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            let buf = client
                .buffer_from_host_buffer(&data, &w.shape, None)
                .map_err(|e| anyhow!("upload weight {}: {e:?}", w.name))?;
            weights.push(buf);
        }

        Ok(Runtime { client, manifest, dir, weights, exes: Mutex::new(HashMap::new()) })
    }

    pub fn client(&self) -> &PjRtClient {
        &self.client
    }

    /// Compile-on-demand with caching; artifacts are keyed by bucket name.
    pub fn artifact(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.exes.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let meta = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?
            .clone();
        let path = self.dir.join(&meta.file);
        let proto = HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow!("parse {}: {e:?}", meta.file))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e:?}", meta.file))?;
        let entry = Arc::new(Executable { meta, exe });
        self.exes.lock().unwrap().insert(name.to_string(), entry.clone());
        Ok(entry)
    }

    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload f32: {e:?}"))
    }

    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload i32: {e:?}"))
    }

    /// Execute an artifact: `data` args in manifest input order; the weight
    /// buffers are appended automatically. Returns one device buffer per
    /// manifest output (untupled).
    pub fn exec(&self, exe: &Executable, data: &[Arg]) -> Result<Vec<PjRtBuffer>> {
        if data.len() != exe.meta.inputs.len() {
            return Err(anyhow!(
                "artifact {} expects {} data inputs, got {}",
                exe.meta.name,
                exe.meta.inputs.len(),
                data.len()
            ));
        }
        let mut owned: Vec<PjRtBuffer> = vec![];
        for (arg, spec) in data.iter().zip(&exe.meta.inputs) {
            match arg {
                Arg::F32(v, dims) => {
                    debug_assert_eq!(&spec.shape, *dims, "{} shape", spec.name);
                    owned.push(self.upload_f32(v, dims)?);
                }
                Arg::I32(v, dims) => {
                    debug_assert_eq!(&spec.shape, *dims, "{} shape", spec.name);
                    owned.push(self.upload_i32(v, dims)?);
                }
                Arg::Buf(_) => {}
            }
        }
        let mut refs: Vec<&PjRtBuffer> = Vec::with_capacity(data.len() + self.weights.len());
        let mut oi = 0;
        for arg in data {
            match arg {
                Arg::Buf(b) => refs.push(b),
                _ => {
                    refs.push(&owned[oi]);
                    oi += 1;
                }
            }
        }
        refs.extend(self.weights.iter());
        let mut outs = exe
            .exe
            .execute_b(&refs)
            .map_err(|e| anyhow!("execute {}: {e:?}", exe.meta.name))?;
        let replica = outs
            .pop()
            .ok_or_else(|| anyhow!("no replica outputs from {}", exe.meta.name))?;
        if replica.len() != exe.meta.outputs.len() {
            return Err(anyhow!(
                "artifact {}: {} outputs returned, manifest says {} — \
                 was the xla crate patched with untuple_result?",
                exe.meta.name,
                replica.len(),
                exe.meta.outputs.len()
            ));
        }
        Ok(replica)
    }

    /// Fetch an output buffer to the host as an f32 tensor.
    pub fn fetch_f32(&self, buf: &PjRtBuffer, shape: &[usize]) -> Result<Tensor> {
        let lit: Literal = buf.to_literal_sync().map_err(|e| anyhow!("fetch: {e:?}"))?;
        let data = lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
        Tensor::new(data, shape.to_vec())
    }
}
