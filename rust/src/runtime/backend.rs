//! The execution-backend abstraction.
//!
//! A [`Backend`] executes manifest artifacts (prefill / decode /
//! kvzip_score) over opaque device [`Buffer`]s. Two implementations:
//!
//! * [`crate::runtime::reference`] — pure-Rust CPU reference (hermetic,
//!   default): the model forward runs in-process from a deterministic
//!   in-code weight set; buffers are host tensors.
//! * [`crate::runtime::pjrt`] (`--features pjrt`) — loads AOT HLO-text
//!   artifacts and executes them on the PJRT CPU client; buffers are
//!   device-resident `PjRtBuffer`s, so the KV cache never touches the host
//!   between decode steps.
//!
//! The trait is object-safe: the engine, batcher, server and benches hold a
//! `Runtime` facade over `Box<dyn Backend>` and are generic over backends
//! without generics infecting their signatures.

use anyhow::{anyhow, Result};

use super::manifest::ArtifactMeta;
use super::tensor::Tensor;

/// An argument to an artifact execution.
pub enum Arg<'a> {
    F32(&'a [f32], &'a [usize]),
    I32(&'a [i32], &'a [usize]),
    /// A buffer from a previous execution (e.g. the KV cache).
    Buf(&'a Buffer),
}

/// Backend-owned value: host tensor for the reference backend, device
/// buffer for PJRT. Opaque to the coordinator — it only threads buffers
/// from one exec into the next and fetches f32 outputs it needs on host.
pub struct Buffer(pub(crate) BufferRepr);

pub(crate) enum BufferRepr {
    HostF32(Tensor),
    HostI32(Vec<i32>, Vec<usize>),
    #[cfg(feature = "pjrt")]
    Pjrt(xla::PjRtBuffer),
}

impl Buffer {
    pub(crate) fn host_f32(&self) -> Result<&Tensor> {
        match &self.0 {
            BufferRepr::HostF32(t) => Ok(t),
            BufferRepr::HostI32(..) => Err(anyhow!("expected f32 buffer, got i32")),
            #[cfg(feature = "pjrt")]
            BufferRepr::Pjrt(_) => Err(anyhow!("expected host buffer, got device buffer")),
        }
    }
}

/// An execution backend: runs artifacts, moves data on/off the "device".
pub trait Backend: Send + Sync {
    /// Short backend identifier ("reference" / "pjrt").
    fn name(&self) -> &'static str;

    /// Execute one artifact. `data` holds the artifact's data inputs in
    /// manifest input order (weights, if any, are the backend's concern).
    /// Returns one buffer per manifest output.
    fn exec(&self, meta: &ArtifactMeta, data: &[Arg]) -> Result<Vec<Buffer>>;

    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<Buffer>;

    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<Buffer>;

    /// Fetch an output buffer to the host as an f32 tensor.
    fn fetch_f32(&self, buf: &Buffer, shape: &[usize]) -> Result<Tensor>;
}
