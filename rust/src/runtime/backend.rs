//! The execution-backend abstraction.
//!
//! A [`Backend`] executes manifest artifacts (prefill / decode /
//! kvzip_score) over opaque device [`Buffer`]s, and — since the
//! device-resident KV refactor — *owns* the decode-group KV cache between
//! steps behind a [`KvHandle`]. Two implementations:
//!
//! * [`crate::runtime::reference`] — pure-Rust CPU reference (hermetic,
//!   default): the model forward runs in-process from a deterministic
//!   in-code weight set; buffers are host tensors and the group cache is a
//!   flat in-place-mutated allocation.
//! * [`crate::runtime::pjrt`] (`--features pjrt`) — loads AOT HLO-text
//!   artifacts and executes them on the PJRT CPU client; buffers are
//!   device-resident `PjRtBuffer`s, and the group KV cache is threaded
//!   from one decode execution into the next without touching the host.
//!
//! ## KV-handle lifecycle
//!
//! The per-token host↔device round-trip of the original engine (re-upload
//! the dense `[L, B, H, t_max, d_head]` caches plus keep-mask every decode
//! step, fetch them back after) is replaced by backend-owned state:
//!
//! 1. **alloc** — [`Backend::kv_alloc`] reserves a zeroed group cache
//!    (`k`/`v` of `[L, B, H, t_max, d_head]` plus a `[L, B, H, t_max]`
//!    keep-mask) and returns an opaque [`KvHandle`].
//! 2. **scatter** — when a sequence joins a slot,
//!    [`Backend::kv_scatter`] writes its host `[L, H, t_max, d_head]` KV
//!    rows into that slot, and [`Backend::kv_write_mask`] installs its
//!    keep-mask. This is the only full-slot upload a sequence ever pays.
//! 3. **step** — [`Backend::exec_decode_resident`] runs one decode step
//!    *in place*: the new KV row for each slot is written into the
//!    resident cache at its position, and that position is marked
//!    attendable in the slot's mask (mirroring `PagedKvCache::fill`), so
//!    steady-state decode uploads nothing but the token/pos scalars.
//!    Cache outputs are *not* returned; see
//!    [`crate::runtime::manifest::ArtifactMeta::resident_output_index`].
//! 4. **mask-update** — [`Backend::kv_write_mask`] re-uploads one slot's
//!    mask only when the coordinator's `PagedKvCache` reports evictions
//!    (its dirty flag); a no-eviction policy performs zero mask updates
//!    after the join.
//! 5. **gather** — [`Backend::kv_fetch_row`] copies the one decoded
//!    `[L, H, d_head]` row per step back to the sequence's host snapshot
//!    (keeping join/leave free of bulk syncs), and [`Backend::kv_gather`]
//!    fetches a whole slot on demand (snapshots, debugging).
//!
//! The trait is object-safe: the engine, batcher, server and benches hold a
//! `Runtime` facade over `Box<dyn Backend>` and are generic over backends
//! without generics infecting their signatures. Transfer byte-accounting
//! lives in the facade (`Runtime`), not in the backends.

#![warn(missing_docs)]

use anyhow::{anyhow, Result};

use super::kernels::QuantBits;
use super::manifest::ArtifactMeta;
use super::tensor::Tensor;

/// An argument to an artifact execution, in manifest input order.
///
/// ```
/// use kvzap::runtime::{Arg, Runtime};
///
/// let rt = Runtime::reference();
/// let pf = rt.artifact("prefill_b1_t128").unwrap();
/// let toks = [1i32; 128];
/// let lens = [1i32];
/// let outs = rt
///     .exec(&pf, &[Arg::I32(&toks, &[1, 128]), Arg::I32(&lens, &[1])])
///     .unwrap();
/// assert_eq!(outs.len(), pf.meta.outputs.len());
/// ```
pub enum Arg<'a> {
    /// Host f32 data with its shape (uploaded by the backend as needed).
    F32(&'a [f32], &'a [usize]),
    /// Host i32 data with its shape (token ids, positions, lengths).
    I32(&'a [i32], &'a [usize]),
    /// A buffer from a previous execution (e.g. the KV cache).
    Buf(&'a Buffer),
}

/// Backend-owned value: host tensor for the reference backend, device
/// buffer for PJRT. Opaque to the coordinator — it only threads buffers
/// from one exec into the next and fetches f32 outputs it needs on host.
pub struct Buffer(pub(crate) BufferRepr);

pub(crate) enum BufferRepr {
    HostF32(Tensor),
    HostI32(Vec<i32>, Vec<usize>),
    #[cfg(feature = "pjrt")]
    Pjrt(xla::PjRtBuffer),
}

impl Buffer {
    pub(crate) fn host_f32(&self) -> Result<&Tensor> {
        match &self.0 {
            BufferRepr::HostF32(t) => Ok(t),
            BufferRepr::HostI32(..) => Err(anyhow!("expected f32 buffer, got i32")),
            #[cfg(feature = "pjrt")]
            BufferRepr::Pjrt(_) => Err(anyhow!("expected host buffer, got device buffer")),
        }
    }
}

/// Opaque handle to a backend-owned decode-group KV cache (k/v of
/// `[layers, batch, heads, t_max, d_head]` plus a `[layers, batch, heads,
/// t_max]` keep-mask). Created by [`Backend::kv_alloc`]; the dims are
/// recorded so callers and backends can size and validate transfers.
/// Not `Clone`: the owner (the engine's `DecodeGroup`) frees it via
/// [`Backend::kv_free`].
///
/// ```no_run
/// use kvzap::runtime::Runtime;
///
/// let rt = Runtime::reference();
/// let h = rt.kv_alloc(4).unwrap();          // 4-slot decode group
/// assert_eq!(h.batch, 4);
/// let mut k = vec![0.0f32; h.slot_elems()]; // one slot's K rows
/// let mut v = vec![0.0f32; h.slot_elems()];
/// rt.kv_gather(&h, 0, &mut k, &mut v).unwrap();
/// rt.kv_free(&h);
/// ```
#[derive(Debug)]
pub struct KvHandle {
    pub(crate) id: u64,
    /// Model layer count `L` of the cached rows.
    pub layers: usize,
    /// Group slot capacity `B` (the decode bucket batch size).
    pub batch: usize,
    /// KV head count `H` per layer.
    pub heads: usize,
    /// Cache row capacity per head (positions).
    pub t_max: usize,
    /// Head dimension `D` of each row.
    pub d_head: usize,
}

impl KvHandle {
    /// f32 element count of one slot's k (or v) rows: `[L, H, t_max, D]`.
    pub fn slot_elems(&self) -> usize {
        self.layers * self.heads * self.t_max * self.d_head
    }

    /// f32 element count of one slot's keep-mask: `[L, H, t_max]`.
    pub fn mask_elems(&self) -> usize {
        self.layers * self.heads * self.t_max
    }

    /// f32 element count of one decoded row in one slot: `[L, H, D]`.
    pub fn row_elems(&self) -> usize {
        self.layers * self.heads * self.d_head
    }
}

/// An execution backend: runs artifacts, moves data on/off the "device",
/// and owns decode-group KV caches between steps (see module docs for the
/// handle lifecycle).
pub trait Backend: Send + Sync {
    /// Short backend identifier ("reference" / "pjrt").
    fn name(&self) -> &'static str;

    /// Human-readable description of the backend's execution mode; the
    /// default is just [`Backend::name`]. The reference backend reports
    /// its parallel configuration here.
    fn describe(&self) -> String {
        self.name().to_string()
    }

    /// Execute one artifact. `data` holds the artifact's data inputs in
    /// manifest input order (weights, if any, are the backend's concern).
    /// Returns one buffer per manifest output.
    fn exec(&self, meta: &ArtifactMeta, data: &[Arg]) -> Result<Vec<Buffer>>;

    /// Upload host f32 data of shape `dims` into a backend [`Buffer`].
    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<Buffer>;

    /// Upload host i32 data of shape `dims` into a backend [`Buffer`].
    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<Buffer>;

    /// Fetch an output buffer to the host as an f32 tensor.
    fn fetch_f32(&self, buf: &Buffer, shape: &[usize]) -> Result<Tensor>;

    // ---- backend-owned KV cache (device-resident decode) ----------------

    /// Allocate a zeroed group KV cache (k, v, keep-mask) for `batch`
    /// decode slots.
    fn kv_alloc(
        &self,
        layers: usize,
        batch: usize,
        heads: usize,
        t_max: usize,
        d_head: usize,
    ) -> Result<KvHandle>;

    /// Release a group cache. Unknown/already-freed handles are a no-op.
    fn kv_free(&self, h: &KvHandle);

    /// Write one sequence's KV rows into slot `slot` (join). `k`/`v` are
    /// host `[L, H, t_max, D]` f32 rows.
    fn kv_scatter(&self, h: &KvHandle, slot: usize, k: &[f32], v: &[f32]) -> Result<()>;

    /// Install slot `slot`'s keep-mask (`[L, H, t_max]` f32, 1.0 =
    /// attendable). Called on join and after evictions; steady-state
    /// decode never calls it (the backend marks the decoded position
    /// attendable itself).
    fn kv_write_mask(&self, h: &KvHandle, slot: usize, mask: &[f32]) -> Result<()>;

    /// Copy the decoded KV row at `pos` of slot `slot` to the host:
    /// `k_row`/`v_row` are `[L, H, D]` f32. This is the only per-step KV
    /// transfer of the resident decode path.
    fn kv_fetch_row(
        &self,
        h: &KvHandle,
        slot: usize,
        pos: usize,
        k_row: &mut [f32],
        v_row: &mut [f32],
    ) -> Result<()>;

    /// Fetch slot `slot`'s full KV rows back to the host (`[L, H, t_max,
    /// D]` each) — snapshot/leave path, not used during steady decode.
    fn kv_gather(&self, h: &KvHandle, slot: usize, k: &mut [f32], v: &mut [f32]) -> Result<()>;

    /// One decode step over the resident group cache `h`: `tokens`/`pos`
    /// are `[batch]`. The new KV row of every slot is written in place at
    /// its `pos` and that position becomes attendable in the slot's mask.
    /// Returns the decode artifact's outputs in manifest order *minus* the
    /// `kcache`/`vcache` entries (which stay resident) — index them with
    /// [`ArtifactMeta::resident_output_index`].
    fn exec_decode_resident(
        &self,
        meta: &ArtifactMeta,
        tokens: &[i32],
        pos: &[i32],
        h: &KvHandle,
    ) -> Result<Vec<Buffer>>;

    /// [`Backend::exec_decode_resident`], but every slot additionally
    /// attends its demoted side-tier entries *in quantized form*: codes
    /// are dequantized in-register inside the score/value loops and the
    /// rows join the softmax after the appended new-KV row, so demoted
    /// positions contribute to attention with **zero** `kv_rehydrate`
    /// calls and zero transfer bytes. Returns the same outputs plus one
    /// [`QuantAttendStat`] per slot (rows/bytes attended this step).
    ///
    /// The default delegates to the plain resident step with zero stats —
    /// correct for backends without a quantized tier (nothing is ever
    /// demoted there, so there is nothing to attend), and for tier-capable
    /// backends that have not implemented fused quantized compute yet
    /// (their engines keep rehydrating for correctness).
    fn exec_decode_resident_quant(
        &self,
        meta: &ArtifactMeta,
        tokens: &[i32],
        pos: &[i32],
        h: &KvHandle,
    ) -> Result<(Vec<Buffer>, Vec<QuantAttendStat>)> {
        let outs = self.exec_decode_resident(meta, tokens, pos, h)?;
        Ok((outs, vec![QuantAttendStat::default(); h.batch]))
    }

    // ---- demoted (quantized) KV tier -------------------------------------

    /// Demote position `pos` of `(l, head)` in `slot` into the backend's
    /// quantized side pool: the resident `[D]` K/V rows are encoded
    /// groupwise (`bits` codes, `group` channels per scale/zero pair —
    /// see `runtime::kernels::quantize_row`) and the resident rows are
    /// replaced by their lossy round-trip, so a later
    /// [`Backend::kv_rehydrate`] (or a host-side re-scatter of a
    /// round-tripped snapshot) reproduces the same values bit-for-bit.
    /// Both ops are device-local: no host↔device bytes move. Returns the
    /// side-pool bytes the entry occupies. Backends without a quantized
    /// tier report an error (the engine only demotes when the policy asks
    /// for it, so drop-only serving works everywhere).
    fn kv_demote(
        &self,
        _h: &KvHandle,
        _slot: usize,
        _l: usize,
        _head: usize,
        _pos: usize,
        _bits: QuantBits,
        _group: usize,
    ) -> Result<usize> {
        Err(anyhow!("backend '{}' does not support the demoted KV tier", self.name()))
    }

    /// Demote a whole band of `(l, head, pos)` entries of `slot` in one
    /// call — the batched sibling of [`Backend::kv_demote`]. The engine
    /// uses it when a joining sequence re-installs its side tier after a
    /// scatter, and when the answer scorer parks a prefill's demoted band
    /// so it can score from quantized form without rehydrating. Returns
    /// the total side-pool bytes the band occupies. The default loops the
    /// per-entry op; tier-capable backends can fuse the encode and
    /// bookkeeping under one lock.
    fn kv_demote_band(
        &self,
        h: &KvHandle,
        slot: usize,
        band: &[(usize, usize, usize)],
        bits: QuantBits,
        group: usize,
    ) -> Result<usize> {
        let mut bytes = 0;
        for &(l, head, pos) in band {
            bytes += self.kv_demote(h, slot, l, head, pos, bits, group)?;
        }
        Ok(bytes)
    }

    /// Rehydrate a previously demoted entry: decode the side-pool payload
    /// back into the resident K/V rows at `(l, head, pos)` of `slot` and
    /// drop the side-pool entry. Returns the side-pool bytes freed.
    fn kv_rehydrate(
        &self,
        _h: &KvHandle,
        _slot: usize,
        _l: usize,
        _head: usize,
        _pos: usize,
    ) -> Result<usize> {
        Err(anyhow!("backend '{}' does not support the demoted KV tier", self.name()))
    }

    /// Drop a demoted entry without rehydrating it (sequence left the
    /// group or the entry fell below the hard floor). Unknown entries are
    /// a no-op so slot-reuse cleanup can be unconditional. Returns the
    /// side-pool bytes freed (0 if absent).
    fn kv_drop_demoted(
        &self,
        _h: &KvHandle,
        _slot: usize,
        _l: usize,
        _head: usize,
        _pos: usize,
    ) -> Result<usize> {
        Ok(0)
    }

    /// Drop **every** demoted entry parked under `slot` — the vacate-path
    /// bulk sibling of [`Backend::kv_drop_demoted`]. The engine calls it
    /// when a sequence leaves its decode slot, so a stale occupant's side
    /// entries can never be quant-attended by (or counted against) the
    /// next occupant. Returns the number of entries purged; no-op (0) on
    /// backends without a quantized tier.
    fn kv_drop_slot(&self, _h: &KvHandle, _slot: usize) -> Result<usize> {
        Ok(0)
    }
}

/// Per-slot accounting of one quant-attended decode step: how many
/// demoted side-tier entries joined the softmax and how many side-pool
/// bytes they occupy. Device-local compute — never charged as transfer.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct QuantAttendStat {
    /// Side entries attended this step (one per demoted `(l, head, pos)`).
    pub rows: usize,
    /// Side-pool bytes backing those entries.
    pub bytes: usize,
}
