//! A minimal host-side dense f32 tensor (row-major) for artifact outputs.

use anyhow::{anyhow, Result};

#[derive(Debug, Clone)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(data: Vec<f32>, shape: Vec<usize>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(anyhow!("shape {:?} != data len {}", shape, data.len()));
        }
        Ok(Tensor { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Row-major strides.
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1; self.shape.len()];
        for i in (0..self.shape.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.shape[i + 1];
        }
        s
    }

    pub fn at(&self, idx: &[usize]) -> f32 {
        debug_assert_eq!(idx.len(), self.shape.len());
        let s = self.strides();
        let off: usize = idx.iter().zip(&s).map(|(i, st)| i * st).sum();
        self.data[off]
    }

    /// Contiguous slice for a prefix index (e.g. [layer, batch, head] of a
    /// 4-D tensor -> the trailing row).
    pub fn row(&self, prefix: &[usize]) -> &[f32] {
        let s = self.strides();
        let off: usize = prefix.iter().zip(&s).map(|(i, st)| i * st).sum();
        let len: usize = self.shape[prefix.len()..].iter().product();
        &self.data[off..off + len]
    }

    pub fn argmax(&self) -> usize {
        let mut best = 0;
        for (i, &v) in self.data.iter().enumerate() {
            if v > self.data[best] {
                best = i;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing() {
        let t = Tensor::new((0..24).map(|x| x as f32).collect(), vec![2, 3, 4]).unwrap();
        assert_eq!(t.at(&[1, 2, 3]), 23.0);
        assert_eq!(t.row(&[0, 1]), &[4.0, 5.0, 6.0, 7.0]);
        assert_eq!(t.strides(), vec![12, 4, 1]);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(Tensor::new(vec![0.0; 5], vec![2, 3]).is_err());
    }
}
