//! PJRT execution backend (`--features pjrt`): load AOT HLO-text artifacts
//! and execute them on the CPU client with the weights resident on device.
//!
//! Wiring (from /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute_b`.
//! HLO *text* is the interchange format — jax ≥ 0.5 emits protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids. Our vendored xla crate is patched with
//! `untuple_result = true`, so each artifact output arrives as its own
//! device buffer: the KV cache produced by prefill (or a decode step) is
//! fed straight back into the next decode step with zero host traffic.
//!
//! Building with this feature requires the vendored `xla` crate — see the
//! commented dependency in rust/Cargo.toml.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, Context, Result};
use xla::{HloModuleProto, Literal, PjRtBuffer, PjRtClient, XlaComputation};

use super::backend::{Arg, Backend, Buffer, BufferRepr};
use super::manifest::{ArtifactMeta, Manifest};
use super::tensor::Tensor;

pub struct PjrtBackend {
    client: PjRtClient,
    dir: PathBuf,
    /// Weight tensors resident on device, in manifest order; appended to
    /// every execute call after the data inputs.
    weights: Vec<PjRtBuffer>,
    exes: Mutex<HashMap<String, xla::PjRtLoadedExecutable>>,
}

impl PjrtBackend {
    /// Load weights.bin onto the device; artifacts compile on demand.
    pub fn load(dir: impl AsRef<Path>, manifest: &Manifest) -> Result<PjrtBackend> {
        let dir = dir.as_ref().to_path_buf();
        let client = PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;

        let blob = std::fs::read(dir.join("weights.bin"))
            .with_context(|| "reading weights.bin (run `make artifacts`)")?;
        let mut weights = Vec::with_capacity(manifest.weights.len());
        for w in &manifest.weights {
            let slice = blob
                .get(w.offset..w.offset + w.bytes)
                .ok_or_else(|| anyhow!("weights.bin too short for {}", w.name))?;
            let data: Vec<f32> = slice
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            let buf = client
                .buffer_from_host_buffer(&data, &w.shape, None)
                .map_err(|e| anyhow!("upload weight {}: {e:?}", w.name))?;
            weights.push(buf);
        }
        Ok(PjrtBackend { client, dir, weights, exes: Mutex::new(HashMap::new()) })
    }

    /// Compile-on-demand with caching, keyed by artifact name.
    fn compile(&self, meta: &ArtifactMeta) -> Result<()> {
        if self.exes.lock().unwrap().contains_key(&meta.name) {
            return Ok(());
        }
        let path = self.dir.join(&meta.file);
        let proto =
            HloModuleProto::from_text_file(path.to_str().ok_or_else(|| anyhow!("bad path"))?)
                .map_err(|e| anyhow!("parse {}: {e:?}", meta.file))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e:?}", meta.file))?;
        self.exes.lock().unwrap().insert(meta.name.clone(), exe);
        Ok(())
    }
}

fn device<'a>(buf: &'a Buffer, ctx: &str) -> Result<&'a PjRtBuffer> {
    match &buf.0 {
        BufferRepr::Pjrt(b) => Ok(b),
        _ => Err(anyhow!("{ctx}: expected a device buffer (mixed backends?)")),
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn exec(&self, meta: &ArtifactMeta, data: &[Arg]) -> Result<Vec<Buffer>> {
        self.compile(meta)?;
        let mut owned: Vec<PjRtBuffer> = vec![];
        for (arg, spec) in data.iter().zip(&meta.inputs) {
            match arg {
                Arg::F32(v, dims) => {
                    debug_assert_eq!(&spec.shape, *dims, "{} shape", spec.name);
                    owned.push(
                        self.client
                            .buffer_from_host_buffer(v, dims, None)
                            .map_err(|e| anyhow!("upload f32: {e:?}"))?,
                    );
                }
                Arg::I32(v, dims) => {
                    debug_assert_eq!(&spec.shape, *dims, "{} shape", spec.name);
                    owned.push(
                        self.client
                            .buffer_from_host_buffer(v, dims, None)
                            .map_err(|e| anyhow!("upload i32: {e:?}"))?,
                    );
                }
                Arg::Buf(_) => {}
            }
        }
        let mut refs: Vec<&PjRtBuffer> = Vec::with_capacity(data.len() + self.weights.len());
        let mut oi = 0;
        for arg in data {
            match arg {
                Arg::Buf(b) => refs.push(device(b, &meta.name)?),
                _ => {
                    refs.push(&owned[oi]);
                    oi += 1;
                }
            }
        }
        refs.extend(self.weights.iter());
        let exes = self.exes.lock().unwrap();
        let exe = exes.get(&meta.name).expect("compiled above");
        let mut outs = exe
            .execute_b(&refs)
            .map_err(|e| anyhow!("execute {}: {e:?}", meta.name))?;
        let replica = outs
            .pop()
            .ok_or_else(|| anyhow!("no replica outputs from {}", meta.name))?;
        if replica.len() != meta.outputs.len() {
            return Err(anyhow!(
                "artifact {}: {} outputs returned, manifest says {} — \
                 was the xla crate patched with untuple_result?",
                meta.name,
                replica.len(),
                meta.outputs.len()
            ));
        }
        Ok(replica.into_iter().map(|b| Buffer(BufferRepr::Pjrt(b))).collect())
    }

    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<Buffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map(|b| Buffer(BufferRepr::Pjrt(b)))
            .map_err(|e| anyhow!("upload f32: {e:?}"))
    }

    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<Buffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map(|b| Buffer(BufferRepr::Pjrt(b)))
            .map_err(|e| anyhow!("upload i32: {e:?}"))
    }

    fn fetch_f32(&self, buf: &Buffer, shape: &[usize]) -> Result<Tensor> {
        let lit: Literal =
            device(buf, "fetch")?.to_literal_sync().map_err(|e| anyhow!("fetch: {e:?}"))?;
        let data = lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
        Tensor::new(data, shape.to_vec())
    }
}
