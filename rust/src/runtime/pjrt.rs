//! PJRT execution backend (`--features pjrt`): load AOT HLO-text artifacts
//! and execute them on the CPU client with the weights resident on device.
//!
//! Wiring (from /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute_b`.
//! HLO *text* is the interchange format — jax ≥ 0.5 emits protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids. Our vendored xla crate is patched with
//! `untuple_result = true`, so each artifact output arrives as its own
//! device buffer: the KV cache produced by prefill (or a decode step) is
//! fed straight back into the next decode step with zero host traffic.
//!
//! Building with this feature requires the vendored `xla` crate — see the
//! commented dependency in rust/Cargo.toml.
//!
//! ## Device-resident decode groups
//!
//! The KV-handle ops keep a decode group's `kcache`/`vcache` as
//! `PjRtBuffer`s threaded from one decode execution into the next: the
//! artifact's cache *outputs* become the next step's cache *inputs*, so
//! steady-state decode moves no KV bytes over the host boundary. Host
//! shadows back the buffers for scatter/gather (PJRT has no partial-update
//! API, so a join re-uploads the group after syncing decoded rows back).
//! Two artifact-shaped costs remain until the decode artifact grows
//! dedicated outputs: the keep-mask is a plain input re-uploaded each step
//! from its host shadow, and row fetches sync the whole cache to the
//! shadows (once per step — a freshness flag dedups the per-sequence
//! calls). NOTE: the `Runtime` facade counts *logical contract* bytes
//! (one row per `kv_fetch_row`, nothing for the in-exec mask upload), so
//! on this backend the counters under-report the interim physical traffic
//! until the artifact revision (mask-state + row-gather outputs) lands —
//! see the doc on `metrics::TransferCounters`.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{anyhow, Context, Result};
use xla::{HloModuleProto, Literal, PjRtBuffer, PjRtClient, XlaComputation};

use super::backend::{Arg, Backend, Buffer, BufferRepr, KvHandle};
use super::manifest::{ArtifactMeta, Manifest};
use super::tensor::Tensor;

/// One decode group: device-resident k/v plus host shadows and the
/// keep-mask shadow (see module docs).
struct PjrtKvGroup {
    /// Device caches; `None` until the first resident step uploads the
    /// shadows (and after any scatter invalidates them).
    dk: Option<PjRtBuffer>,
    dv: Option<PjRtBuffer>,
    /// Host shadows `[L, B, H, t_max, D]`: authoritative whenever the
    /// device buffers are `None`.
    hk: Vec<f32>,
    hv: Vec<f32>,
    /// True while the shadows match the device buffers (or the device
    /// buffers are absent). Cleared by each resident exec; lets the
    /// per-sequence row fetches of one step share a single device sync.
    host_fresh: bool,
    /// Keep-mask host shadow `[L, B, H, t_max]`.
    mask: Vec<f32>,
}

pub struct PjrtBackend {
    client: PjRtClient,
    dir: PathBuf,
    /// Weight tensors resident on device, in manifest order; appended to
    /// every execute call after the data inputs.
    weights: Vec<PjRtBuffer>,
    exes: Mutex<HashMap<String, xla::PjRtLoadedExecutable>>,
    kv: Mutex<HashMap<u64, PjrtKvGroup>>,
    next_kv: AtomicU64,
}

impl PjrtBackend {
    /// Load weights.bin onto the device; artifacts compile on demand.
    pub fn load(dir: impl AsRef<Path>, manifest: &Manifest) -> Result<PjrtBackend> {
        let dir = dir.as_ref().to_path_buf();
        let client = PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;

        let blob = std::fs::read(dir.join("weights.bin"))
            .with_context(|| "reading weights.bin (run `make artifacts`)")?;
        let mut weights = Vec::with_capacity(manifest.weights.len());
        for w in &manifest.weights {
            let slice = blob
                .get(w.offset..w.offset + w.bytes)
                .ok_or_else(|| anyhow!("weights.bin too short for {}", w.name))?;
            let data: Vec<f32> = slice
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            let buf = client
                .buffer_from_host_buffer(&data, &w.shape, None)
                .map_err(|e| anyhow!("upload weight {}: {e:?}", w.name))?;
            weights.push(buf);
        }
        Ok(PjrtBackend {
            client,
            dir,
            weights,
            exes: Mutex::new(HashMap::new()),
            kv: Mutex::new(HashMap::new()),
            next_kv: AtomicU64::new(1),
        })
    }

    /// Refresh the host shadows from the device buffers if they are stale
    /// (one device round-trip shared by all of a step's row fetches).
    fn refresh_shadows(g: &mut PjrtKvGroup) -> Result<()> {
        if g.host_fresh {
            return Ok(());
        }
        if let (Some(dk), Some(dv)) = (&g.dk, &g.dv) {
            let lk: Literal = dk.to_literal_sync().map_err(|e| anyhow!("kv fetch k: {e:?}"))?;
            let lv: Literal = dv.to_literal_sync().map_err(|e| anyhow!("kv fetch v: {e:?}"))?;
            g.hk = lk.to_vec::<f32>().map_err(|e| anyhow!("kv to_vec k: {e:?}"))?;
            g.hv = lv.to_vec::<f32>().map_err(|e| anyhow!("kv to_vec v: {e:?}"))?;
        }
        g.host_fresh = true;
        Ok(())
    }

    /// Pull the device caches back into the host shadows (so a scatter can
    /// read-modify-write without losing decoded rows), leaving the group
    /// host-authoritative.
    fn kv_sync_to_host(g: &mut PjrtKvGroup) -> Result<()> {
        Self::refresh_shadows(g)?;
        g.dk = None;
        g.dv = None;
        Ok(())
    }

    /// Compile-on-demand with caching, keyed by artifact name.
    fn compile(&self, meta: &ArtifactMeta) -> Result<()> {
        if self.exes.lock().unwrap().contains_key(&meta.name) {
            return Ok(());
        }
        let path = self.dir.join(&meta.file);
        let proto =
            HloModuleProto::from_text_file(path.to_str().ok_or_else(|| anyhow!("bad path"))?)
                .map_err(|e| anyhow!("parse {}: {e:?}", meta.file))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e:?}", meta.file))?;
        self.exes.lock().unwrap().insert(meta.name.clone(), exe);
        Ok(())
    }
}

fn device<'a>(buf: &'a Buffer, ctx: &str) -> Result<&'a PjRtBuffer> {
    match &buf.0 {
        BufferRepr::Pjrt(b) => Ok(b),
        _ => Err(anyhow!("{ctx}: expected a device buffer (mixed backends?)")),
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn exec(&self, meta: &ArtifactMeta, data: &[Arg]) -> Result<Vec<Buffer>> {
        self.compile(meta)?;
        let mut owned: Vec<PjRtBuffer> = vec![];
        for (arg, spec) in data.iter().zip(&meta.inputs) {
            match arg {
                Arg::F32(v, dims) => {
                    debug_assert_eq!(&spec.shape, *dims, "{} shape", spec.name);
                    owned.push(
                        self.client
                            .buffer_from_host_buffer(v, dims, None)
                            .map_err(|e| anyhow!("upload f32: {e:?}"))?,
                    );
                }
                Arg::I32(v, dims) => {
                    debug_assert_eq!(&spec.shape, *dims, "{} shape", spec.name);
                    owned.push(
                        self.client
                            .buffer_from_host_buffer(v, dims, None)
                            .map_err(|e| anyhow!("upload i32: {e:?}"))?,
                    );
                }
                Arg::Buf(_) => {}
            }
        }
        let mut refs: Vec<&PjRtBuffer> = Vec::with_capacity(data.len() + self.weights.len());
        let mut oi = 0;
        for arg in data {
            match arg {
                Arg::Buf(b) => refs.push(device(b, &meta.name)?),
                _ => {
                    refs.push(&owned[oi]);
                    oi += 1;
                }
            }
        }
        refs.extend(self.weights.iter());
        let exes = self.exes.lock().unwrap();
        let exe = exes.get(&meta.name).expect("compiled above");
        let mut outs = exe
            .execute_b(&refs)
            .map_err(|e| anyhow!("execute {}: {e:?}", meta.name))?;
        let replica = outs
            .pop()
            .ok_or_else(|| anyhow!("no replica outputs from {}", meta.name))?;
        if replica.len() != meta.outputs.len() {
            return Err(anyhow!(
                "artifact {}: {} outputs returned, manifest says {} — \
                 was the xla crate patched with untuple_result?",
                meta.name,
                replica.len(),
                meta.outputs.len()
            ));
        }
        Ok(replica.into_iter().map(|b| Buffer(BufferRepr::Pjrt(b))).collect())
    }

    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<Buffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map(|b| Buffer(BufferRepr::Pjrt(b)))
            .map_err(|e| anyhow!("upload f32: {e:?}"))
    }

    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<Buffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map(|b| Buffer(BufferRepr::Pjrt(b)))
            .map_err(|e| anyhow!("upload i32: {e:?}"))
    }

    fn fetch_f32(&self, buf: &Buffer, shape: &[usize]) -> Result<Tensor> {
        let lit: Literal =
            device(buf, "fetch")?.to_literal_sync().map_err(|e| anyhow!("fetch: {e:?}"))?;
        let data = lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
        Tensor::new(data, shape.to_vec())
    }

    // ---- backend-owned KV cache -----------------------------------------

    fn kv_alloc(
        &self,
        layers: usize,
        batch: usize,
        heads: usize,
        t_max: usize,
        d_head: usize,
    ) -> Result<KvHandle> {
        let id = self.next_kv.fetch_add(1, Ordering::Relaxed);
        let elems = layers * batch * heads * t_max * d_head;
        self.kv.lock().unwrap().insert(
            id,
            PjrtKvGroup {
                dk: None,
                dv: None,
                hk: vec![0.0; elems],
                hv: vec![0.0; elems],
                host_fresh: true,
                mask: vec![0.0; layers * batch * heads * t_max],
            },
        );
        Ok(KvHandle { id, layers, batch, heads, t_max, d_head })
    }

    fn kv_free(&self, h: &KvHandle) {
        self.kv.lock().unwrap().remove(&h.id);
    }

    fn kv_scatter(&self, h: &KvHandle, slot: usize, k: &[f32], v: &[f32]) -> Result<()> {
        if k.len() != h.slot_elems() || v.len() != h.slot_elems() {
            return Err(anyhow!("kv_scatter: rows have {} elems, want {}", k.len(), h.slot_elems()));
        }
        let mut kv = self.kv.lock().unwrap();
        let g = kv.get_mut(&h.id).ok_or_else(|| anyhow!("kv handle {} unknown", h.id))?;
        Self::kv_sync_to_host(g)?;
        let chunk = h.t_max * h.d_head;
        for l in 0..h.layers {
            for hh in 0..h.heads {
                let src = (l * h.heads + hh) * chunk;
                let dst = ((l * h.batch + slot) * h.heads + hh) * chunk;
                g.hk[dst..dst + chunk].copy_from_slice(&k[src..src + chunk]);
                g.hv[dst..dst + chunk].copy_from_slice(&v[src..src + chunk]);
            }
        }
        Ok(())
    }

    fn kv_write_mask(&self, h: &KvHandle, slot: usize, mask: &[f32]) -> Result<()> {
        if mask.len() != h.mask_elems() {
            return Err(anyhow!("kv_write_mask: {} elems, want {}", mask.len(), h.mask_elems()));
        }
        let mut kv = self.kv.lock().unwrap();
        let g = kv.get_mut(&h.id).ok_or_else(|| anyhow!("kv handle {} unknown", h.id))?;
        for l in 0..h.layers {
            for hh in 0..h.heads {
                let src = (l * h.heads + hh) * h.t_max;
                let dst = ((l * h.batch + slot) * h.heads + hh) * h.t_max;
                g.mask[dst..dst + h.t_max].copy_from_slice(&mask[src..src + h.t_max]);
            }
        }
        Ok(())
    }

    fn kv_fetch_row(
        &self,
        h: &KvHandle,
        slot: usize,
        pos: usize,
        k_row: &mut [f32],
        v_row: &mut [f32],
    ) -> Result<()> {
        let mut kv = self.kv.lock().unwrap();
        let g = kv.get_mut(&h.id).ok_or_else(|| anyhow!("kv handle {} unknown", h.id))?;
        // No row-slice fetch in the PJRT API: refresh the shadows (one sync
        // shared by every row fetch of this step — the device copy stays
        // authoritative) and slice from them.
        Self::refresh_shadows(g)?;
        let d = h.d_head;
        for l in 0..h.layers {
            for hh in 0..h.heads {
                let src = (((l * h.batch + slot) * h.heads + hh) * h.t_max + pos) * d;
                let dst = (l * h.heads + hh) * d;
                k_row[dst..dst + d].copy_from_slice(&g.hk[src..src + d]);
                v_row[dst..dst + d].copy_from_slice(&g.hv[src..src + d]);
            }
        }
        Ok(())
    }

    fn kv_gather(&self, h: &KvHandle, slot: usize, k: &mut [f32], v: &mut [f32]) -> Result<()> {
        let mut kv = self.kv.lock().unwrap();
        let g = kv.get_mut(&h.id).ok_or_else(|| anyhow!("kv handle {} unknown", h.id))?;
        Self::refresh_shadows(g)?;
        let chunk = h.t_max * h.d_head;
        for l in 0..h.layers {
            for hh in 0..h.heads {
                let src = ((l * h.batch + slot) * h.heads + hh) * chunk;
                let dst = (l * h.heads + hh) * chunk;
                k[dst..dst + chunk].copy_from_slice(&g.hk[src..src + chunk]);
                v[dst..dst + chunk].copy_from_slice(&g.hv[src..src + chunk]);
            }
        }
        Ok(())
    }

    fn exec_decode_resident(
        &self,
        meta: &ArtifactMeta,
        tokens: &[i32],
        pos: &[i32],
        h: &KvHandle,
    ) -> Result<Vec<Buffer>> {
        self.compile(meta)?;
        let b = meta.batch;
        let mut kv = self.kv.lock().unwrap();
        let g = kv.get_mut(&h.id).ok_or_else(|| anyhow!("kv handle {} unknown", h.id))?;
        // (re)materialize the device caches from the shadows if a scatter
        // invalidated them (or this is the first step)
        if g.dk.is_none() {
            let dims = [h.layers, h.batch, h.heads, h.t_max, h.d_head];
            g.dk = Some(
                self.client
                    .buffer_from_host_buffer(&g.hk, &dims, None)
                    .map_err(|e| anyhow!("kv upload k: {e:?}"))?,
            );
            g.dv = Some(
                self.client
                    .buffer_from_host_buffer(&g.hv, &dims, None)
                    .map_err(|e| anyhow!("kv upload v: {e:?}"))?,
            );
        }
        let tok_buf = self
            .client
            .buffer_from_host_buffer(tokens, &[b], None)
            .map_err(|e| anyhow!("upload tokens: {e:?}"))?;
        let pos_buf = self
            .client
            .buffer_from_host_buffer(pos, &[b], None)
            .map_err(|e| anyhow!("upload pos: {e:?}"))?;
        let mask_buf = self
            .client
            .buffer_from_host_buffer(&g.mask, &[h.layers, h.batch, h.heads, h.t_max], None)
            .map_err(|e| anyhow!("upload mask: {e:?}"))?;
        let mut refs: Vec<&PjRtBuffer> = vec![
            &tok_buf,
            &pos_buf,
            g.dk.as_ref().unwrap(),
            g.dv.as_ref().unwrap(),
            &mask_buf,
        ];
        refs.extend(self.weights.iter());
        let mut outs = {
            let exes = self.exes.lock().unwrap();
            let exe = exes.get(&meta.name).expect("compiled above");
            exe.execute_b(&refs).map_err(|e| anyhow!("execute {}: {e:?}", meta.name))?
        };
        let replica = outs
            .pop()
            .ok_or_else(|| anyhow!("no replica outputs from {}", meta.name))?;
        if replica.len() != meta.outputs.len() {
            return Err(anyhow!(
                "artifact {}: {} outputs returned, manifest says {}",
                meta.name,
                replica.len(),
                meta.outputs.len()
            ));
        }
        // cache outputs stay resident (they are next step's inputs); the
        // rest go back to the caller in resident output order
        let mut rest = vec![];
        for (spec, buf) in meta.outputs.iter().zip(replica.into_iter()) {
            match spec.name.as_str() {
                "kcache" => g.dk = Some(buf),
                "vcache" => g.dv = Some(buf),
                _ => rest.push(Buffer(BufferRepr::Pjrt(buf))),
            }
        }
        // the step rewrote the device caches: shadows are stale until the
        // next refresh (shared by this step's row fetches)
        g.host_fresh = false;
        // the decoded rows are attendable from the next step on (mirrors
        // PagedKvCache::fill)
        for s in 0..b {
            let p = (pos[s].max(0) as usize).min(h.t_max - 1);
            for l in 0..h.layers {
                for hh in 0..h.heads {
                    g.mask[((l * h.batch + s) * h.heads + hh) * h.t_max + p] = 1.0;
                }
            }
        }
        Ok(rest)
    }
}
