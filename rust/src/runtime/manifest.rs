//! artifacts/manifest.json — the contract between the python compile path
//! and the rust runtime (written by python/compile/aot.py).

use std::collections::{BTreeMap, HashMap};
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct ModelDims {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_q_heads: usize,
    pub n_kv_heads: usize,
    pub d_head: usize,
    pub d_int: usize,
    pub d_surrogate: usize,
    pub t_max: usize,
}

impl ModelDims {
    pub fn group(&self) -> usize {
        self.n_q_heads / self.n_kv_heads
    }
}

#[derive(Debug, Clone)]
pub struct SpecialTokens {
    pub pad: u8,
    pub bos: u8,
    pub eos: u8,
    pub sep: u8,
}

#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "i32"
}

impl IoSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub kind: String, // prefill | decode | kvzip_score
    pub batch: usize,
    pub t: usize,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

impl ArtifactMeta {
    pub fn output_index(&self, name: &str) -> Result<usize> {
        self.outputs
            .iter()
            .position(|o| o.name == name)
            .ok_or_else(|| anyhow!("artifact {} has no output '{name}'", self.name))
    }

    /// Output index in a *resident* decode result
    /// ([`crate::runtime::Backend::exec_decode_resident`]): manifest
    /// output order with the `kcache`/`vcache` entries removed, since those
    /// stay backend-resident and are never returned.
    pub fn resident_output_index(&self, name: &str) -> Result<usize> {
        if name == "kcache" || name == "vcache" {
            return Err(anyhow!(
                "artifact {}: '{name}' stays backend-resident (use kv_fetch_row/kv_gather)",
                self.name
            ));
        }
        let mut idx = 0;
        for o in &self.outputs {
            if o.name == name {
                return Ok(idx);
            }
            if o.name != "kcache" && o.name != "vcache" {
                idx += 1;
            }
        }
        Err(anyhow!("artifact {} has no output '{name}'", self.name))
    }
}

#[derive(Debug, Clone)]
pub struct WeightEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub bytes: usize,
}

#[derive(Debug, Clone)]
pub struct Buckets {
    pub prefill_t: Vec<usize>,
    pub prefill_b: Vec<usize>,
    pub decode_b: Vec<usize>,
    pub kvzip_t: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub model: ModelDims,
    pub special: SpecialTokens,
    pub window: usize,
    pub obs_window: usize,
    pub buckets: Buckets,
    pub artifacts: HashMap<String, ArtifactMeta>,
    pub weights: Vec<WeightEntry>,
    /// Oracle log-score quantiles — the default threshold sweep for benches.
    pub threshold_quantiles: BTreeMap<String, f64>,
}

fn io_specs(v: &Json) -> Result<Vec<IoSpec>> {
    v.as_arr()
        .ok_or_else(|| anyhow!("io spec not an array"))?
        .iter()
        .map(|e| {
            Ok(IoSpec {
                name: e.req("name").map_err(|e| anyhow!(e))?.as_str().unwrap().to_string(),
                shape: e
                    .req("shape")
                    .map_err(|e| anyhow!(e))?
                    .usize_vec()
                    .ok_or_else(|| anyhow!("bad shape"))?,
                dtype: e.req("dtype").map_err(|e| anyhow!(e))?.as_str().unwrap().to_string(),
            })
        })
        .collect()
}

fn req_usize(v: &Json, key: &str) -> Result<usize> {
    v.req(key)
        .map_err(|e| anyhow!(e))?
        .as_usize()
        .ok_or_else(|| anyhow!("{key} not a number"))
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading manifest in {dir:?} (run `make artifacts`)"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;

        let m = j.req("model").map_err(|e| anyhow!(e))?;
        let model = ModelDims {
            vocab: req_usize(m, "vocab")?,
            d_model: req_usize(m, "d_model")?,
            n_layers: req_usize(m, "n_layers")?,
            n_q_heads: req_usize(m, "n_q_heads")?,
            n_kv_heads: req_usize(m, "n_kv_heads")?,
            d_head: req_usize(m, "d_head")?,
            d_int: req_usize(m, "d_int")?,
            d_surrogate: req_usize(m, "d_surrogate")?,
            t_max: req_usize(m, "t_max")?,
        };
        let s = j.req("special_tokens").map_err(|e| anyhow!(e))?;
        let special = SpecialTokens {
            pad: req_usize(s, "pad")? as u8,
            bos: req_usize(s, "bos")? as u8,
            eos: req_usize(s, "eos")? as u8,
            sep: req_usize(s, "sep")? as u8,
        };
        let b = j.req("buckets").map_err(|e| anyhow!(e))?;
        let buckets = Buckets {
            prefill_t: b.req("prefill_t").map_err(|e| anyhow!(e))?.usize_vec().unwrap(),
            prefill_b: b.req("prefill_b").map_err(|e| anyhow!(e))?.usize_vec().unwrap(),
            decode_b: b.req("decode_b").map_err(|e| anyhow!(e))?.usize_vec().unwrap(),
            kvzip_t: b.req("kvzip_t").map_err(|e| anyhow!(e))?.usize_vec().unwrap(),
        };

        let mut artifacts = HashMap::new();
        for (name, a) in j.req("artifacts").map_err(|e| anyhow!(e))?.as_obj().unwrap() {
            artifacts.insert(
                name.clone(),
                ArtifactMeta {
                    name: name.clone(),
                    file: a.req("file").map_err(|e| anyhow!(e))?.as_str().unwrap().to_string(),
                    kind: a.req("kind").map_err(|e| anyhow!(e))?.as_str().unwrap().to_string(),
                    batch: req_usize(a, "batch")?,
                    t: req_usize(a, "t")?,
                    inputs: io_specs(a.req("inputs").map_err(|e| anyhow!(e))?)?,
                    outputs: io_specs(a.req("outputs").map_err(|e| anyhow!(e))?)?,
                },
            );
        }

        let mut weights = vec![];
        for w in j.req("weights").map_err(|e| anyhow!(e))?.as_arr().unwrap() {
            weights.push(WeightEntry {
                name: w.req("name").map_err(|e| anyhow!(e))?.as_str().unwrap().to_string(),
                shape: w.req("shape").map_err(|e| anyhow!(e))?.usize_vec().unwrap(),
                offset: req_usize(w, "offset")?,
                bytes: req_usize(w, "bytes")?,
            });
        }

        let mut threshold_quantiles = BTreeMap::new();
        if let Some(q) = j.get("threshold_quantiles").and_then(|q| q.as_obj()) {
            for (k, v) in q {
                if let Some(x) = v.as_f64() {
                    threshold_quantiles.insert(k.clone(), x);
                }
            }
        }

        Ok(Manifest {
            model,
            special,
            window: req_usize(&j, "window")?,
            obs_window: req_usize(&j, "obs_window")?,
            buckets,
            artifacts,
            weights,
            threshold_quantiles,
        })
    }

    /// Smallest prefill T bucket that fits `len` tokens (for `batch`).
    pub fn prefill_bucket(&self, len: usize, batch: usize) -> Option<String> {
        let t = self.buckets.prefill_t.iter().copied().find(|&t| t >= len)?;
        let b = self.buckets.prefill_b.iter().copied().find(|&b| b >= batch)?;
        Some(format!("prefill_b{b}_t{t}"))
    }

    pub fn decode_bucket(&self, batch: usize) -> Option<String> {
        let b = self.buckets.decode_b.iter().copied().find(|&b| b >= batch)?;
        Some(format!("decode_b{b}"))
    }

    pub fn kvzip_bucket(&self, len: usize) -> Option<String> {
        let t = self.buckets.kvzip_t.iter().copied().find(|&t| t >= len)?;
        Some(format!("kvzip_score_t{t}"))
    }
}
