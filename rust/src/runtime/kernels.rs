//! CPU compute kernels for the reference backend.
//!
//! Two flavors of every primitive:
//!
//! * **naive** — the untuned scalar loops the backend shipped with
//!   ([`matmul`], [`dot`]). These remain the semantic oracle: the blocked
//!   kernels are required (and property-tested) to be **bitwise identical**
//!   to them, which pins every accumulation to the same operand order.
//! * **blocked** — cache-blocked / transposed-layout variants with small
//!   hand-vectorizable microkernels ([`matmul_blocked`],
//!   [`scores_from_kt`]): per output element the reduction still runs over
//!   `k` (resp. the head dim) in ascending order with a single `f32`
//!   accumulator, so results match the naive loops bit-for-bit while the
//!   independent output lanes vectorize.
//!
//! Both paths share [`fast_exp`], a Cephes-style polynomial `expf` whose
//! body is straight-line arithmetic (no table, no libm call) — the
//! compiler vectorizes it across softmax rows, and using one definition on
//! the scalar *and* parallel paths keeps them bitwise comparable.
//!
//! Bitwise-safety notes the tests rely on:
//! * splitting rows/columns into tiles never touches reduction order;
//! * skipping a `+= 0.0 * w` term is exact for finite `w` (adding `±0.0`
//!   to an accumulator that is never `-0.0` is the identity), so the
//!   naive zero-skip and the branch-free microkernel agree.

#![allow(clippy::needless_range_loop)]

/// Column-lane width of the matmul microkernel (one vector register of
/// f32s on SSE/NEON; two unrolled on AVX2).
pub const MM_LANES: usize = 8;

/// Naive row-major matmul: `out[n,b] = x[n,a] @ w[a,b]` with f32
/// accumulation, skipping zero activations (exact — see module docs).
pub fn matmul(x: &[f32], w: &[f32], n: usize, a: usize, b: usize, out: &mut [f32]) {
    out[..n * b].fill(0.0);
    for i in 0..n {
        for k in 0..a {
            let xv = x[i * a + k];
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[k * b..k * b + b];
            let orow = &mut out[i * b..i * b + b];
            for j in 0..b {
                orow[j] += xv * wrow[j];
            }
        }
    }
}

/// Blocked matmul over an explicit row range: `out[i, :] = x[i, :] @ w`
/// for `i in rows`, tiled over [`MM_LANES`]-wide column panels held in a
/// register accumulator. Bitwise identical to [`matmul`] on the same rows
/// (per output element the `k` reduction order is unchanged); row-range
/// form so a parallel driver can shard rows across threads.
pub fn matmul_block_rows(
    x: &[f32],
    w: &[f32],
    rows: std::ops::Range<usize>,
    a: usize,
    b: usize,
    out: &mut [f32],
) {
    for i in rows {
        let xrow = &x[i * a..i * a + a];
        let orow = &mut out[i * b..i * b + b];
        let mut j0 = 0;
        while j0 < b {
            let jn = MM_LANES.min(b - j0);
            let mut acc = [0.0f32; MM_LANES];
            for (k, &xv) in xrow.iter().enumerate() {
                let wrow = &w[k * b + j0..k * b + j0 + jn];
                for c in 0..jn {
                    acc[c] += xv * wrow[c];
                }
            }
            orow[j0..j0 + jn].copy_from_slice(&acc[..jn]);
            j0 += jn;
        }
    }
}

/// Blocked matmul over all rows (see [`matmul_block_rows`]).
pub fn matmul_blocked(x: &[f32], w: &[f32], n: usize, a: usize, b: usize, out: &mut [f32]) {
    matmul_block_rows(x, w, 0..n, a, b, out);
}

/// Naive dot product over `d` elements, ascending index order.
pub fn dot(a: &[f32], b: &[f32], d: usize) -> f32 {
    let mut s = 0.0;
    for i in 0..d {
        s += a[i] * b[i];
    }
    s
}

/// Transposed-layout attention score microkernel.
///
/// `kt` is one kv head's keys stored `[d, n_ctx]` (position-major lanes);
/// computes `row[s] = q · k_s` for `s < len` by accumulating one `q[dd]`
/// broadcast against the contiguous `kt[dd, :]` panel per step — the inner
/// loop vectorizes over `s` while each `row[s]` still sums the head dim in
/// ascending order, keeping it bitwise identical to [`dot`] against the
/// untransposed keys.
pub fn scores_from_kt(q: &[f32], kt: &[f32], n_ctx: usize, d: usize, len: usize, row: &mut [f32]) {
    row[..len].fill(0.0);
    for dd in 0..d {
        let qv = q[dd];
        let panel = &kt[dd * n_ctx..dd * n_ctx + len];
        let r = &mut row[..len];
        for s in 0..len {
            r[s] += qv * panel[s];
        }
    }
}

/// Pack one kv head's keys `[n, stride]` (rows at `base + s*stride`) into
/// the transposed `[d, n_ctx]` panel layout [`scores_from_kt`] consumes.
pub fn pack_kt(k: &[f32], base: usize, stride: usize, n: usize, d: usize, kt: &mut [f32]) {
    for s in 0..n {
        let krow = &k[base + s * stride..base + s * stride + d];
        for (dd, &kv) in krow.iter().enumerate() {
            kt[dd * n + s] = kv;
        }
    }
}

/// Cephes-style polynomial `expf`: max observed relative error ≈ 2e-7 vs
/// libm over `[-87, 0]` (the softmax input range — scores are shifted by
/// their max before exponentiation). Straight-line arithmetic only, so the
/// compiler can vectorize softmax rows; **both** the scalar and blocked
/// reference paths use it, which keeps them bitwise comparable.
#[inline]
#[allow(clippy::excessive_precision)]
pub fn fast_exp(x: f32) -> f32 {
    const LOG2E: f32 = std::f32::consts::LOG2_E;
    const LN2_HI: f32 = 0.693_359_4;
    const LN2_LO: f32 = -2.121_944_4e-4;
    let x = x.clamp(-87.0, 88.0);
    let n = (x * LOG2E + 0.5).floor();
    let xr = x - n * LN2_HI - n * LN2_LO;
    let mut p = 1.987_569_1e-4f32;
    p = p * xr + 1.398_199_9e-3;
    p = p * xr + 8.333_452e-3;
    p = p * xr + 4.166_579_6e-2;
    p = p * xr + 1.666_666_5e-1;
    p = p * xr + 5.000_000_1e-1;
    let y = p * xr * xr + xr + 1.0;
    // scale by 2^n through the exponent bits (n ∈ [-126, 127] after clamp)
    y * f32::from_bits(((n as i32 + 127) << 23) as u32)
}

// ------------------------------------------------------------ SIMD dispatch
//
// Explicit-vector variants of the hot microkernels (AVX2 on x86_64, NEON
// on aarch64) behind runtime feature detection. Every vector kernel here
// is **bitwise identical** to its scalar blocked counterpart: the panel
// matmul and the kt score kernel keep one independent accumulator per
// output lane with the same ascending-k reduction order (separate mul
// then add — never FMA, which would contract the rounding), and the
// vector `fast_exp` is a lane-for-lane transcription of the scalar
// polynomial. That makes `KVZAP_SIMD=scalar` vs `=auto` a bitwise no-op
// on every prefill output, which the parity property tests and the
// engine-level generation-invariance test pin down.

/// Requested SIMD mode (the `KVZAP_SIMD` override, threaded through
/// `ParallelConfig`). Resolution to an executable [`SimdLevel`] happens
/// at backend construction via [`SimdMode::resolve`]; asking for an ISA
/// the host lacks degrades to scalar rather than erroring, so one config
/// works across machines.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdMode {
    /// Pick the best supported level (AVX2 → NEON → scalar).
    Auto,
    /// Force AVX2 (scalar if the host lacks it).
    Avx2,
    /// Force NEON (scalar on non-aarch64 hosts).
    Neon,
    /// Force the scalar blocked path (the SIMD oracle).
    Scalar,
}

impl SimdMode {
    /// Parse the `KVZAP_SIMD` value (`auto|avx2|neon|scalar`).
    pub fn parse(s: &str) -> Option<SimdMode> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" | "" => Some(SimdMode::Auto),
            "avx2" => Some(SimdMode::Avx2),
            "neon" => Some(SimdMode::Neon),
            "scalar" => Some(SimdMode::Scalar),
            _ => None,
        }
    }

    /// Wire/debug name of the requested mode.
    pub fn name(self) -> &'static str {
        match self {
            SimdMode::Auto => "auto",
            SimdMode::Avx2 => "avx2",
            SimdMode::Neon => "neon",
            SimdMode::Scalar => "scalar",
        }
    }

    /// Resolve the request against what the host actually supports.
    pub fn resolve(self) -> SimdLevel {
        match self {
            SimdMode::Scalar => SimdLevel::Scalar,
            SimdMode::Avx2 => {
                if avx2_available() {
                    SimdLevel::Avx2
                } else {
                    SimdLevel::Scalar
                }
            }
            SimdMode::Neon => {
                if neon_available() {
                    SimdLevel::Neon
                } else {
                    SimdLevel::Scalar
                }
            }
            SimdMode::Auto => {
                if avx2_available() {
                    SimdLevel::Avx2
                } else if neon_available() {
                    SimdLevel::Neon
                } else {
                    SimdLevel::Scalar
                }
            }
        }
    }
}

/// A resolved, executable SIMD level (host-verified — dispatch on this is
/// branch-only, no feature re-detection on the hot path).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdLevel {
    /// Scalar blocked kernels (the oracle).
    Scalar,
    /// 8-lane AVX2 (x86_64, runtime-detected).
    Avx2,
    /// 4-lane NEON, 2x unrolled (aarch64).
    Neon,
}

impl SimdLevel {
    /// Tag for `Backend::describe()` / bench JSON (`scalar|avx2|neon`).
    pub fn tag(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Neon => "neon",
        }
    }

    /// Whether this level actually vectorizes (i.e. is not the scalar
    /// fallback). The bench `--assert-speedup` gate degrades to a no-op
    /// when `Auto` resolves to scalar — no red builds on plain hosts.
    pub fn is_vector(self) -> bool {
        self != SimdLevel::Scalar
    }
}

/// Runtime AVX2 support (false off x86_64).
pub fn avx2_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("avx2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Runtime NEON support (false off aarch64).
pub fn neon_available() -> bool {
    #[cfg(target_arch = "aarch64")]
    {
        std::arch::is_aarch64_feature_detected!("neon")
    }
    #[cfg(not(target_arch = "aarch64"))]
    {
        false
    }
}

/// Level-dispatched blocked matmul over a row range (see
/// [`matmul_block_rows`]). Bitwise identical across every level.
pub fn matmul_block_rows_level(
    x: &[f32],
    w: &[f32],
    rows: std::ops::Range<usize>,
    a: usize,
    b: usize,
    out: &mut [f32],
    level: SimdLevel,
) {
    match level {
        SimdLevel::Scalar => matmul_block_rows(x, w, rows, a, b, out),
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => {
            for i in rows {
                let xrow = &x[i * a..i * a + a];
                let orow = &mut out[i * b..i * b + b];
                let mut j0 = 0;
                while j0 + MM_LANES <= b {
                    // SAFETY: level Avx2 only resolves when the host
                    // reports avx2 (see SimdMode::resolve).
                    unsafe { matmul_panel8_avx2(xrow, w, b, j0, orow) };
                    j0 += MM_LANES;
                }
                matmul_panel_tail(xrow, w, b, j0, orow);
            }
        }
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => {
            for i in rows {
                let xrow = &x[i * a..i * a + a];
                let orow = &mut out[i * b..i * b + b];
                let mut j0 = 0;
                while j0 + MM_LANES <= b {
                    // SAFETY: level Neon only resolves on aarch64.
                    unsafe { matmul_panel8_neon(xrow, w, b, j0, orow) };
                    j0 += MM_LANES;
                }
                matmul_panel_tail(xrow, w, b, j0, orow);
            }
        }
        #[allow(unreachable_patterns)]
        _ => matmul_block_rows(x, w, rows, a, b, out),
    }
}

/// Scalar tail for the last (< [`MM_LANES`]-wide) column panel of a row —
/// the same accumulator loop [`matmul_block_rows`] runs.
fn matmul_panel_tail(xrow: &[f32], w: &[f32], b: usize, j0: usize, orow: &mut [f32]) {
    if j0 >= b {
        return;
    }
    let jn = b - j0;
    let mut acc = [0.0f32; MM_LANES];
    for (k, &xv) in xrow.iter().enumerate() {
        let wrow = &w[k * b + j0..k * b + j0 + jn];
        for c in 0..jn {
            acc[c] += xv * wrow[c];
        }
    }
    orow[j0..j0 + jn].copy_from_slice(&acc[..jn]);
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn matmul_panel8_avx2(xrow: &[f32], w: &[f32], b: usize, j0: usize, orow: &mut [f32]) {
    use std::arch::x86_64::*;
    let mut acc = _mm256_setzero_ps();
    for (k, &xv) in xrow.iter().enumerate() {
        let xvv = _mm256_set1_ps(xv);
        let wv = _mm256_loadu_ps(w.as_ptr().add(k * b + j0));
        // mul then add (not FMA): each lane runs the exact scalar op
        // sequence acc[c] += xv * w[k*b+j0+c]
        acc = _mm256_add_ps(acc, _mm256_mul_ps(xvv, wv));
    }
    _mm256_storeu_ps(orow.as_mut_ptr().add(j0), acc);
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn matmul_panel8_neon(xrow: &[f32], w: &[f32], b: usize, j0: usize, orow: &mut [f32]) {
    use std::arch::aarch64::*;
    let mut acc0 = vdupq_n_f32(0.0);
    let mut acc1 = vdupq_n_f32(0.0);
    for (k, &xv) in xrow.iter().enumerate() {
        let xvv = vdupq_n_f32(xv);
        let p = w.as_ptr().add(k * b + j0);
        acc0 = vaddq_f32(acc0, vmulq_f32(xvv, vld1q_f32(p)));
        acc1 = vaddq_f32(acc1, vmulq_f32(xvv, vld1q_f32(p.add(4))));
    }
    vst1q_f32(orow.as_mut_ptr().add(j0), acc0);
    vst1q_f32(orow.as_mut_ptr().add(j0 + 4), acc1);
}

/// Level-dispatched transposed score kernel (see [`scores_from_kt`]).
/// The vector paths run the identical `row[s] += q[dd] * panel[s]`
/// update per lane in the same `dd` order — bitwise identical to scalar.
pub fn scores_from_kt_level(
    q: &[f32],
    kt: &[f32],
    n_ctx: usize,
    d: usize,
    len: usize,
    row: &mut [f32],
    level: SimdLevel,
) {
    if level == SimdLevel::Scalar {
        return scores_from_kt(q, kt, n_ctx, d, len, row);
    }
    row[..len].fill(0.0);
    for dd in 0..d {
        let qv = q[dd];
        let panel = &kt[dd * n_ctx..dd * n_ctx + len];
        let r = &mut row[..len];
        axpy_level(qv, panel, r, level);
    }
}

/// `r[i] += qv * x[i]` with the level's vector width (exact per element).
fn axpy_level(qv: f32, x: &[f32], r: &mut [f32], level: SimdLevel) {
    let n = x.len().min(r.len());
    let mut i = 0;
    match level {
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => {
            while i + 8 <= n {
                // SAFETY: Avx2 level implies host support; bounds checked.
                unsafe { axpy8_avx2(qv, x.as_ptr().add(i), r.as_mut_ptr().add(i)) };
                i += 8;
            }
        }
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => {
            while i + 8 <= n {
                // SAFETY: Neon level implies aarch64; bounds checked.
                unsafe { axpy8_neon(qv, x.as_ptr().add(i), r.as_mut_ptr().add(i)) };
                i += 8;
            }
        }
        _ => {}
    }
    for j in i..n {
        r[j] += qv * x[j];
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn axpy8_avx2(qv: f32, x: *const f32, r: *mut f32) {
    use std::arch::x86_64::*;
    let acc = _mm256_add_ps(_mm256_loadu_ps(r), _mm256_mul_ps(_mm256_set1_ps(qv), _mm256_loadu_ps(x)));
    _mm256_storeu_ps(r, acc);
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn axpy8_neon(qv: f32, x: *const f32, r: *mut f32) {
    use std::arch::aarch64::*;
    let qvv = vdupq_n_f32(qv);
    vst1q_f32(r, vaddq_f32(vld1q_f32(r), vmulq_f32(qvv, vld1q_f32(x))));
    vst1q_f32(r.add(4), vaddq_f32(vld1q_f32(r.add(4)), vmulq_f32(qvv, vld1q_f32(x.add(4)))));
}

/// Vectorized softmax numerator: `row[i] = fast_exp(row[i] - m)` for a
/// whole row. The vector lanes run the exact scalar [`fast_exp`] op
/// sequence (clamp, floor-based range reduction, Horner polynomial,
/// exponent-bit scaling) — elementwise, so bitwise identical per lane.
pub fn fast_exp_sub_rows(row: &mut [f32], m: f32, level: SimdLevel) {
    let n = row.len();
    let mut i = 0;
    match level {
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => {
            while i + 8 <= n {
                // SAFETY: Avx2 level implies host support; bounds checked.
                unsafe { fast_exp_sub8_avx2(row.as_mut_ptr().add(i), m) };
                i += 8;
            }
        }
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => {
            while i + 4 <= n {
                // SAFETY: Neon level implies aarch64; bounds checked.
                unsafe { fast_exp_sub4_neon(row.as_mut_ptr().add(i), m) };
                i += 4;
            }
        }
        _ => {}
    }
    for r in &mut row[i..n] {
        *r = fast_exp(*r - m);
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[allow(clippy::excessive_precision)]
unsafe fn fast_exp_sub8_avx2(p: *mut f32, m: f32) {
    use std::arch::x86_64::*;
    const LOG2E: f32 = std::f32::consts::LOG2_E;
    const LN2_HI: f32 = 0.693_359_4;
    const LN2_LO: f32 = -2.121_944_4e-4;
    let x = _mm256_sub_ps(_mm256_loadu_ps(p), _mm256_set1_ps(m));
    let x = _mm256_max_ps(_mm256_min_ps(x, _mm256_set1_ps(88.0)), _mm256_set1_ps(-87.0));
    let n = _mm256_floor_ps(_mm256_add_ps(
        _mm256_mul_ps(x, _mm256_set1_ps(LOG2E)),
        _mm256_set1_ps(0.5),
    ));
    let xr = _mm256_sub_ps(
        _mm256_sub_ps(x, _mm256_mul_ps(n, _mm256_set1_ps(LN2_HI))),
        _mm256_mul_ps(n, _mm256_set1_ps(LN2_LO)),
    );
    let mut pl = _mm256_set1_ps(1.987_569_1e-4);
    for c in [1.398_199_9e-3f32, 8.333_452e-3, 4.166_579_6e-2, 1.666_666_5e-1, 5.000_000_1e-1] {
        pl = _mm256_add_ps(_mm256_mul_ps(pl, xr), _mm256_set1_ps(c));
    }
    let y = _mm256_add_ps(
        _mm256_add_ps(_mm256_mul_ps(_mm256_mul_ps(pl, xr), xr), xr),
        _mm256_set1_ps(1.0),
    );
    // 2^n through the exponent bits, like the scalar path
    let two_n = _mm256_castsi256_ps(_mm256_slli_epi32(
        _mm256_add_epi32(_mm256_cvtps_epi32(n), _mm256_set1_epi32(127)),
        23,
    ));
    _mm256_storeu_ps(p, _mm256_mul_ps(y, two_n));
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
#[allow(clippy::excessive_precision)]
unsafe fn fast_exp_sub4_neon(p: *mut f32, m: f32) {
    use std::arch::aarch64::*;
    const LOG2E: f32 = std::f32::consts::LOG2_E;
    const LN2_HI: f32 = 0.693_359_4;
    const LN2_LO: f32 = -2.121_944_4e-4;
    let x = vsubq_f32(vld1q_f32(p), vdupq_n_f32(m));
    let x = vmaxq_f32(vminq_f32(x, vdupq_n_f32(88.0)), vdupq_n_f32(-87.0));
    let n = vrndmq_f32(vaddq_f32(vmulq_f32(x, vdupq_n_f32(LOG2E)), vdupq_n_f32(0.5)));
    let xr = vsubq_f32(
        vsubq_f32(x, vmulq_f32(n, vdupq_n_f32(LN2_HI))),
        vmulq_f32(n, vdupq_n_f32(LN2_LO)),
    );
    let mut pl = vdupq_n_f32(1.987_569_1e-4);
    for c in [1.398_199_9e-3f32, 8.333_452e-3, 4.166_579_6e-2, 1.666_666_5e-1, 5.000_000_1e-1] {
        pl = vaddq_f32(vmulq_f32(pl, xr), vdupq_n_f32(c));
    }
    let y = vaddq_f32(vaddq_f32(vmulq_f32(vmulq_f32(pl, xr), xr), xr), vdupq_n_f32(1.0));
    let two_n = vreinterpretq_f32_s32(vshlq_n_s32::<23>(vaddq_s32(
        vcvtq_s32_f32(n),
        vdupq_n_s32(127),
    )));
    vst1q_f32(p, vmulq_f32(y, two_n));
}

// ------------------------------------------------------------ quantization
//
// Lossy per-group affine quantization for the demoted KV tier (the
// ROADMAP "demote, don't just drop" item). A demoted position's K and V
// rows are stored as unsigned codes plus one (scale, zero) pair per
// `group` contiguous channels: `x ≈ zero + scale * code`. The scalar
// encoder below is the oracle; the backend op and the engine's host-
// snapshot round-trip both call it, so a demote → rehydrate cycle is
// bitwise reproducible everywhere the row is materialized.

/// Code width for the demoted-tier payload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantBits {
    /// 8-bit codes, one byte per channel.
    Int8,
    /// 4-bit codes, two channels per byte (per-group byte-aligned).
    Int4,
    /// 2-bit codes, four channels per byte (per-group byte-aligned).
    Int2,
}

impl QuantBits {
    /// Largest representable code (number of levels minus one).
    pub fn max_code(self) -> u32 {
        match self {
            QuantBits::Int8 => 255,
            QuantBits::Int4 => 15,
            QuantBits::Int2 => 3,
        }
    }

    /// Packed bytes needed for `n` codes. Int4 packs two codes per byte
    /// (Int2 four) and pads the last byte, so groups stay byte-aligned.
    pub fn code_bytes(self, n: usize) -> usize {
        match self {
            QuantBits::Int8 => n,
            QuantBits::Int4 => n.div_ceil(2),
            QuantBits::Int2 => n.div_ceil(4),
        }
    }

    /// Wire/debug name (`int8` / `int4` / `int2`).
    pub fn name(self) -> &'static str {
        match self {
            QuantBits::Int8 => "int8",
            QuantBits::Int4 => "int4",
            QuantBits::Int2 => "int2",
        }
    }

    /// Code width in bits (the `:bits=` wire value).
    pub fn width(self) -> u64 {
        match self {
            QuantBits::Int8 => 8,
            QuantBits::Int4 => 4,
            QuantBits::Int2 => 2,
        }
    }

    /// Parse a `:bits=` wire value (`8|4|2`).
    pub fn from_width(w: u64) -> Option<QuantBits> {
        match w {
            8 => Some(QuantBits::Int8),
            4 => Some(QuantBits::Int4),
            2 => Some(QuantBits::Int2),
            _ => None,
        }
    }

    /// Unpack code `i` of a group packed by [`quantize_group`].
    /// Sub-byte widths store earlier channels in the low bits.
    pub fn code_at(self, packed: &[u8], i: usize) -> u8 {
        match self {
            QuantBits::Int8 => packed[i],
            QuantBits::Int4 => {
                let byte = packed[i / 2];
                if i % 2 == 0 {
                    byte & 0x0F
                } else {
                    byte >> 4
                }
            }
            QuantBits::Int2 => (packed[i / 4] >> (2 * (i % 4))) & 0x3,
        }
    }
}

/// One quantized channel row (K or V of a single position in one head):
/// packed codes plus per-group affine parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantRow {
    /// Packed codes, groups byte-aligned in order.
    pub codes: Vec<u8>,
    /// Per-group scale (0.0 for constant groups).
    pub scales: Vec<f32>,
    /// Per-group zero point (the group minimum).
    pub zeros: Vec<f32>,
}

/// Side-pool bytes one quantized row occupies: packed codes plus
/// 8 bytes (f32 scale + f32 zero) per group. This is the unit the
/// demoted-tier byte accounting charges (see `kvcache::TierConfig`).
pub fn quant_row_bytes(d: usize, group: usize, bits: QuantBits) -> usize {
    let g = group.max(1);
    let mut bytes = 0;
    let mut i = 0;
    while i < d {
        let n = g.min(d - i);
        bytes += bits.code_bytes(n) + 8;
        i += n;
    }
    bytes
}

/// Quantize one group of channels, appending packed codes to `codes`.
/// Returns `(scale, zero)`. Constant (or empty) groups encode with
/// scale 0 and reproduce exactly on dequantization.
pub fn quantize_group(xs: &[f32], bits: QuantBits, codes: &mut Vec<u8>) -> (f32, f32) {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &x in xs {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let levels = bits.max_code() as f32;
    let mut scale = if hi > lo { (hi - lo) / levels } else { 0.0 };
    if !scale.is_finite() {
        scale = 0.0; // degenerate range: encode everything at the zero point
    }
    let inv = if scale > 0.0 { 1.0 / scale } else { 0.0 };
    match bits {
        QuantBits::Int8 => {
            for &x in xs {
                codes.push(((x - lo) * inv).round().clamp(0.0, levels) as u8);
            }
        }
        QuantBits::Int4 => {
            let mut pending: Option<u8> = None;
            for &x in xs {
                let q = ((x - lo) * inv).round().clamp(0.0, levels) as u8;
                match pending.take() {
                    None => pending = Some(q),
                    Some(lo_nib) => codes.push(lo_nib | (q << 4)),
                }
            }
            if let Some(lo_nib) = pending {
                codes.push(lo_nib);
            }
        }
        QuantBits::Int2 => {
            let mut cur = 0u8;
            let mut cnt = 0u32;
            for &x in xs {
                let q = ((x - lo) * inv).round().clamp(0.0, levels) as u8;
                cur |= q << (2 * cnt);
                cnt += 1;
                if cnt == 4 {
                    codes.push(cur);
                    cur = 0;
                    cnt = 0;
                }
            }
            if cnt > 0 {
                codes.push(cur);
            }
        }
    }
    (scale, lo)
}

/// Decode one group previously packed by [`quantize_group`] into `out`.
pub fn dequantize_group(packed: &[u8], bits: QuantBits, scale: f32, zero: f32, out: &mut [f32]) {
    match bits {
        QuantBits::Int8 => {
            for (o, &c) in out.iter_mut().zip(packed.iter()) {
                *o = zero + scale * c as f32;
            }
        }
        QuantBits::Int4 | QuantBits::Int2 => {
            for (i, o) in out.iter_mut().enumerate() {
                *o = zero + scale * bits.code_at(packed, i) as f32;
            }
        }
    }
}

/// Quantize a full channel row groupwise (the demoted-tier encoder).
pub fn quantize_row(row: &[f32], group: usize, bits: QuantBits) -> QuantRow {
    let g = group.max(1);
    let n_groups = row.len().div_ceil(g).max(1);
    let mut qr = QuantRow {
        codes: Vec::with_capacity(bits.code_bytes(row.len()) + n_groups),
        scales: Vec::with_capacity(n_groups),
        zeros: Vec::with_capacity(n_groups),
    };
    for chunk in row.chunks(g) {
        let (s, z) = quantize_group(chunk, bits, &mut qr.codes);
        qr.scales.push(s);
        qr.zeros.push(z);
    }
    qr
}

/// Decode a [`QuantRow`] into `out` (`out.len()` must match the encoded
/// row length for the same `group`/`bits`).
pub fn dequantize_row(qr: &QuantRow, group: usize, bits: QuantBits, out: &mut [f32]) {
    let g = group.max(1);
    let mut byte = 0;
    for (gi, chunk) in out.chunks_mut(g).enumerate() {
        let nb = bits.code_bytes(chunk.len());
        dequantize_group(&qr.codes[byte..byte + nb], bits, qr.scales[gi], qr.zeros[gi], chunk);
        byte += nb;
    }
}

/// In-place lossy round-trip `x ← dequant(quant(x))`. The engine applies
/// this to its host KV snapshot when a position is demoted so a later
/// rejoin-scatter uploads exactly what a backend rehydrate would produce.
pub fn quant_roundtrip(row: &mut [f32], group: usize, bits: QuantBits) {
    let qr = quantize_row(row, group, bits);
    dequantize_row(&qr, group, bits, row);
}

// ------------------------------------------------------------ quant compute
//
// Attend directly over demoted-tier rows without rehydrating: the codes
// are decoded in-register inside the dot/accumulate loops, so a quant-
// attended position never touches the resident fp32 cache or the
// transfer path. Accumulation is ordered (ascending channel), matching
// what the scalar decode kernel would do over the dequantized row — so
// `score_from_quant(q, quantize_row(k)) == dot(q, dequant(k))` bitwise,
// which the property tests pin down.

/// Fused score kernel over a quantized K row: `Σ_i q[i] · (zero_g +
/// scale_g · code_i)`, dequantize-in-register, ordered accumulation.
pub fn score_from_quant(q: &[f32], kq: &QuantRow, group: usize, bits: QuantBits, d: usize) -> f32 {
    let g = group.max(1);
    let mut s = 0.0f32;
    let mut byte = 0;
    let mut gi = 0;
    let mut i = 0;
    while i < d {
        let n = g.min(d - i);
        let (scale, zero) = (kq.scales[gi], kq.zeros[gi]);
        let packed = &kq.codes[byte..byte + bits.code_bytes(n)];
        for j in 0..n {
            s += q[i + j] * (zero + scale * bits.code_at(packed, j) as f32);
        }
        byte += bits.code_bytes(n);
        gi += 1;
        i += n;
    }
    s
}

/// Fused value accumulate over a quantized V row: `out[i] += w ·
/// (zero_g + scale_g · code_i)` — the attention-weighted sum a quant-
/// attended position contributes without materializing the fp32 row.
pub fn axpy_from_quant(
    w: f32,
    vq: &QuantRow,
    group: usize,
    bits: QuantBits,
    d: usize,
    out: &mut [f32],
) {
    let g = group.max(1);
    let mut byte = 0;
    let mut gi = 0;
    let mut i = 0;
    while i < d {
        let n = g.min(d - i);
        let (scale, zero) = (vq.scales[gi], vq.zeros[gi]);
        let packed = &vq.codes[byte..byte + bits.code_bytes(n)];
        for j in 0..n {
            out[i + j] += w * (zero + scale * bits.code_at(packed, j) as f32);
        }
        byte += bits.code_bytes(n);
        gi += 1;
        i += n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| (rng.below(2000) as f32 - 1000.0) / 317.0).collect()
    }

    /// Property: the blocked matmul is bitwise identical to the naive one
    /// over random shapes, including edge dims that are not multiples of
    /// the microkernel lane width (and including zero activations, which
    /// the naive path skips and the microkernel does not).
    #[test]
    fn blocked_matmul_matches_naive_bitwise() {
        let mut rng = Rng::new(0xB10C);
        for case in 0..200 {
            let n = 1 + rng.below(33) as usize;
            let a = 1 + rng.below(70) as usize;
            let b = 1 + rng.below(90) as usize; // frequently not 8-aligned
            let mut x = rand_vec(&mut rng, n * a);
            // sprinkle exact zeros so the naive zero-skip is exercised
            for i in 0..x.len() {
                if rng.below(5) == 0 {
                    x[i] = 0.0;
                }
            }
            let w = rand_vec(&mut rng, a * b);
            let mut naive = vec![0.0f32; n * b];
            let mut blocked = vec![7.0f32; n * b]; // overwritten, not accumulated
            matmul(&x, &w, n, a, b, &mut naive);
            matmul_blocked(&x, &w, n, a, b, &mut blocked);
            for i in 0..n * b {
                assert_eq!(
                    naive[i].to_bits(),
                    blocked[i].to_bits(),
                    "case {case} ({n}x{a}x{b}) elem {i}: {} vs {}",
                    naive[i],
                    blocked[i]
                );
            }
        }
    }

    /// Sharding rows across ranges does not change a single bit.
    #[test]
    fn row_sharded_matmul_matches_whole() {
        let mut rng = Rng::new(0x5EED);
        let (n, a, b) = (23, 48, 37);
        let x = rand_vec(&mut rng, n * a);
        let w = rand_vec(&mut rng, a * b);
        let mut whole = vec![0.0f32; n * b];
        matmul_blocked(&x, &w, n, a, b, &mut whole);
        let mut sharded = vec![0.0f32; n * b];
        for r0 in (0..n).step_by(5) {
            matmul_block_rows(&x, &w, r0..(r0 + 5).min(n), a, b, &mut sharded);
        }
        assert_eq!(
            whole.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            sharded.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    /// The transposed score kernel reproduces the naive dot bit-for-bit.
    #[test]
    fn kt_scores_match_dot_bitwise() {
        let mut rng = Rng::new(0xD07);
        for _ in 0..50 {
            let d = 8;
            let n = 1 + rng.below(200) as usize;
            let heads = 2;
            let stride = heads * d;
            let k = rand_vec(&mut rng, n * stride);
            let q = rand_vec(&mut rng, d);
            for h in 0..heads {
                let mut kt = vec![0.0f32; d * n];
                pack_kt(&k, h * d, stride, n, d, &mut kt);
                let len = 1 + rng.below(n);
                let mut row = vec![0.0f32; len];
                scores_from_kt(&q, &kt, n, d, len, &mut row);
                for s in 0..len {
                    let want = dot(&q, &k[h * d + s * stride..h * d + s * stride + d], d);
                    assert_eq!(want.to_bits(), row[s].to_bits(), "head {h} pos {s}");
                }
            }
        }
    }

    /// fast_exp tracks libm expf tightly over the softmax input range and
    /// hits the exact anchor values the attention math depends on.
    #[test]
    fn fast_exp_accuracy() {
        assert_eq!(fast_exp(0.0), 1.0, "softmax max position must stay exactly 1");
        let mut worst = 0.0f32;
        let mut x = -87.0f32;
        while x <= 8.0 {
            let got = fast_exp(x);
            let want = x.exp();
            let rel = ((got - want) / want).abs();
            if rel > worst {
                worst = rel;
            }
            x += 0.000_37;
        }
        assert!(worst < 5e-7, "max relative error {worst}");
        assert!(fast_exp(-200.0) >= 0.0 && fast_exp(-200.0) < 1e-37);
    }

    /// Property: groupwise quantization round-trips within half a step per
    /// element (`|x - x̂| ≤ scale/2` plus float slack), for both widths,
    /// over random rows / group sizes including non-divisible tails.
    #[test]
    fn quant_roundtrip_error_bounded() {
        let mut rng = Rng::new(0x0_11A7);
        for bits in [QuantBits::Int8, QuantBits::Int4, QuantBits::Int2] {
            for case in 0..200 {
                let d = 1 + rng.below(65) as usize;
                let group = 1 + rng.below(17) as usize;
                let row = rand_vec(&mut rng, d);
                let qr = quantize_row(&row, group, bits);
                assert_eq!(qr.codes.len(), {
                    let mut n = 0;
                    for c in row.chunks(group) {
                        n += bits.code_bytes(c.len());
                    }
                    n
                });
                assert_eq!(quant_row_bytes(d, group, bits), qr.codes.len() + 8 * qr.scales.len());
                let mut out = vec![0.0f32; d];
                dequantize_row(&qr, group, bits, &mut out);
                for (gi, chunk) in row.chunks(group).enumerate() {
                    let bound = qr.scales[gi] * 0.5 + qr.scales[gi].abs() * 1e-5 + 1e-6;
                    for (j, &x) in chunk.iter().enumerate() {
                        let got = out[gi * group + j];
                        assert!(
                            (x - got).abs() <= bound,
                            "{} case {case} d={d} g={group}: |{x} - {got}| > {bound}",
                            bits.name()
                        );
                    }
                }
            }
        }
    }

    /// Constant groups (scale 0) reproduce exactly, and a wider code is
    /// never a worse approximation than a narrower one on the same group.
    #[test]
    fn quant_constant_exact_and_width_monotone() {
        let row = vec![-3.25f32; 12];
        for bits in [QuantBits::Int8, QuantBits::Int4, QuantBits::Int2] {
            let mut out = row.clone();
            quant_roundtrip(&mut out, 8, bits);
            assert_eq!(out, row, "{}: constant group must be exact", bits.name());
        }
        let mut rng = Rng::new(0x0_11A8);
        for _ in 0..100 {
            let row = rand_vec(&mut rng, 16);
            let err = |bits: QuantBits| {
                let mut out = row.clone();
                quant_roundtrip(&mut out, 16, bits);
                row.iter().zip(&out).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max)
            };
            assert!(err(QuantBits::Int8) <= err(QuantBits::Int4) + 1e-6);
            assert!(err(QuantBits::Int4) <= err(QuantBits::Int2) + 1e-6);
        }
    }

    /// `width`/`from_width`/`name` round-trip for every code width, and
    /// `code_at` inverts the packer for sub-byte widths over awkward
    /// (non-multiple-of-pack) group lengths.
    #[test]
    fn quant_bits_wire_round_trip_and_code_at() {
        for bits in [QuantBits::Int8, QuantBits::Int4, QuantBits::Int2] {
            assert_eq!(QuantBits::from_width(bits.width()), Some(bits));
        }
        assert_eq!(QuantBits::from_width(3), None);
        let mut rng = Rng::new(0x0_11AA);
        for bits in [QuantBits::Int8, QuantBits::Int4, QuantBits::Int2] {
            for _ in 0..50 {
                let n = 1 + rng.below(19) as usize;
                let xs = rand_vec(&mut rng, n);
                let mut codes = vec![];
                let (scale, zero) = quantize_group(&xs, bits, &mut codes);
                let mut out = vec![0.0f32; n];
                dequantize_group(&codes, bits, scale, zero, &mut out);
                for (i, &o) in out.iter().enumerate() {
                    let c = bits.code_at(&codes, i);
                    assert!(c as u32 <= bits.max_code());
                    assert_eq!(o.to_bits(), (zero + scale * c as f32).to_bits());
                }
            }
        }
    }

    /// Fused quant-compute parity: attending over a quantized row is
    /// bitwise what the scalar decode path computes over the dequantized
    /// row (same ascending-channel accumulation order) — for score and
    /// value-accumulate, every width, non-aligned d/group shapes.
    #[test]
    fn quant_compute_matches_dequantized_bitwise() {
        let mut rng = Rng::new(0x0_11AB);
        for bits in [QuantBits::Int8, QuantBits::Int4, QuantBits::Int2] {
            for _ in 0..100 {
                let d = 1 + rng.below(33) as usize;
                let group = 1 + rng.below(13) as usize;
                let k = rand_vec(&mut rng, d);
                let v = rand_vec(&mut rng, d);
                let q = rand_vec(&mut rng, d);
                let kq = quantize_row(&k, group, bits);
                let vq = quantize_row(&v, group, bits);
                let mut kd = vec![0.0f32; d];
                let mut vd = vec![0.0f32; d];
                dequantize_row(&kq, group, bits, &mut kd);
                dequantize_row(&vq, group, bits, &mut vd);

                let got = score_from_quant(&q, &kq, group, bits, d);
                let want = dot(&q, &kd, d);
                assert_eq!(got.to_bits(), want.to_bits(), "{} score d={d} g={group}", bits.name());

                let w = 0.371f32;
                let mut got_v = rand_vec(&mut rng, d);
                let mut want_v = got_v.clone();
                axpy_from_quant(w, &vq, group, bits, d, &mut got_v);
                for (o, &x) in want_v.iter_mut().zip(&vd) {
                    *o += w * x;
                }
                for i in 0..d {
                    assert_eq!(got_v[i].to_bits(), want_v[i].to_bits(), "{} axpy", bits.name());
                }
            }
        }
    }

    /// SIMD-vs-scalar parity propcheck: for whatever level the host
    /// resolves under `auto`, the vector panel matmul, score kernel, and
    /// softmax-row `fast_exp` are bitwise identical to the scalar blocked
    /// oracle over random non-aligned shapes (tails included). On hosts
    /// where auto resolves to scalar this degenerates to a self-check.
    #[test]
    fn simd_kernels_match_scalar_bitwise() {
        let level = SimdMode::Auto.resolve();
        let mut rng = Rng::new(0x51_3D);
        for case in 0..120 {
            let n = 1 + rng.below(24) as usize;
            let a = 1 + rng.below(40) as usize;
            let b = 1 + rng.below(40) as usize;
            let x = rand_vec(&mut rng, n * a);
            let w = rand_vec(&mut rng, a * b);
            let mut scalar = vec![0.0f32; n * b];
            let mut vector = vec![3.0f32; n * b];
            matmul_block_rows(&x, &w, 0..n, a, b, &mut scalar);
            matmul_block_rows_level(&x, &w, 0..n, a, b, &mut vector, level);
            for i in 0..n * b {
                assert_eq!(
                    scalar[i].to_bits(),
                    vector[i].to_bits(),
                    "{} case {case} ({n}x{a}x{b}) elem {i}",
                    level.tag()
                );
            }
        }
        for case in 0..120 {
            let d = 1 + rng.below(12) as usize;
            let n_ctx = 1 + rng.below(150) as usize;
            let len = 1 + rng.below(n_ctx);
            let q = rand_vec(&mut rng, d);
            let kt = rand_vec(&mut rng, d * n_ctx);
            let mut scalar = vec![0.0f32; len];
            let mut vector = vec![5.0f32; len];
            scores_from_kt(&q, &kt, n_ctx, d, len, &mut scalar);
            scores_from_kt_level(&q, &kt, n_ctx, d, len, &mut vector, level);
            for s in 0..len {
                assert_eq!(scalar[s].to_bits(), vector[s].to_bits(), "case {case} pos {s}");
            }
        }
        for case in 0..120 {
            let len = 1 + rng.below(90) as usize;
            let row = rand_vec(&mut rng, len);
            let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut scalar = row.clone();
            for r in &mut scalar {
                *r = fast_exp(*r - m);
            }
            let mut vector = row.clone();
            fast_exp_sub_rows(&mut vector, m, level);
            for i in 0..len {
                assert_eq!(scalar[i].to_bits(), vector[i].to_bits(), "case {case} elem {i}");
            }
        }
    }

    /// Dispatch resolution: scalar is always honored, forced ISA modes
    /// degrade to scalar (never panic) off-host, and `auto` picks a
    /// vector level exactly when one is available.
    #[test]
    fn simd_mode_resolution_and_parsing() {
        assert_eq!(SimdMode::parse("auto"), Some(SimdMode::Auto));
        assert_eq!(SimdMode::parse("AVX2"), Some(SimdMode::Avx2));
        assert_eq!(SimdMode::parse(" neon "), Some(SimdMode::Neon));
        assert_eq!(SimdMode::parse("scalar"), Some(SimdMode::Scalar));
        assert_eq!(SimdMode::parse("sse9"), None);
        assert_eq!(SimdMode::Scalar.resolve(), SimdLevel::Scalar);
        let auto = SimdMode::Auto.resolve();
        assert_eq!(auto.is_vector(), avx2_available() || neon_available());
        if !avx2_available() {
            assert_eq!(SimdMode::Avx2.resolve(), SimdLevel::Scalar);
        }
        if !neon_available() {
            assert_eq!(SimdMode::Neon.resolve(), SimdLevel::Scalar);
        }
    }

    /// The engine/backend contract: re-encoding an already round-tripped
    /// row is (near-)stable — a second round-trip moves nothing by more
    /// than float slack, so demote → rehydrate → demote cycles do not
    /// drift the cache contents.
    #[test]
    fn quant_roundtrip_stable_under_reencoding() {
        let mut rng = Rng::new(0x0_11A9);
        for _ in 0..100 {
            let mut row = rand_vec(&mut rng, 24);
            quant_roundtrip(&mut row, 8, QuantBits::Int8);
            let once = row.clone();
            quant_roundtrip(&mut row, 8, QuantBits::Int8);
            for (a, b) in once.iter().zip(&row) {
                assert!((a - b).abs() <= (a.abs() + 1.0) * 1e-4, "{a} vs {b}");
            }
        }
    }
}
