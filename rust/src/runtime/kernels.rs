//! CPU compute kernels for the reference backend.
//!
//! Two flavors of every primitive:
//!
//! * **naive** — the untuned scalar loops the backend shipped with
//!   ([`matmul`], [`dot`]). These remain the semantic oracle: the blocked
//!   kernels are required (and property-tested) to be **bitwise identical**
//!   to them, which pins every accumulation to the same operand order.
//! * **blocked** — cache-blocked / transposed-layout variants with small
//!   hand-vectorizable microkernels ([`matmul_blocked`],
//!   [`scores_from_kt`]): per output element the reduction still runs over
//!   `k` (resp. the head dim) in ascending order with a single `f32`
//!   accumulator, so results match the naive loops bit-for-bit while the
//!   independent output lanes vectorize.
//!
//! Both paths share [`fast_exp`], a Cephes-style polynomial `expf` whose
//! body is straight-line arithmetic (no table, no libm call) — the
//! compiler vectorizes it across softmax rows, and using one definition on
//! the scalar *and* parallel paths keeps them bitwise comparable.
//!
//! Bitwise-safety notes the tests rely on:
//! * splitting rows/columns into tiles never touches reduction order;
//! * skipping a `+= 0.0 * w` term is exact for finite `w` (adding `±0.0`
//!   to an accumulator that is never `-0.0` is the identity), so the
//!   naive zero-skip and the branch-free microkernel agree.

#![allow(clippy::needless_range_loop)]

/// Column-lane width of the matmul microkernel (one vector register of
/// f32s on SSE/NEON; two unrolled on AVX2).
pub const MM_LANES: usize = 8;

/// Naive row-major matmul: `out[n,b] = x[n,a] @ w[a,b]` with f32
/// accumulation, skipping zero activations (exact — see module docs).
pub fn matmul(x: &[f32], w: &[f32], n: usize, a: usize, b: usize, out: &mut [f32]) {
    out[..n * b].fill(0.0);
    for i in 0..n {
        for k in 0..a {
            let xv = x[i * a + k];
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[k * b..k * b + b];
            let orow = &mut out[i * b..i * b + b];
            for j in 0..b {
                orow[j] += xv * wrow[j];
            }
        }
    }
}

/// Blocked matmul over an explicit row range: `out[i, :] = x[i, :] @ w`
/// for `i in rows`, tiled over [`MM_LANES`]-wide column panels held in a
/// register accumulator. Bitwise identical to [`matmul`] on the same rows
/// (per output element the `k` reduction order is unchanged); row-range
/// form so a parallel driver can shard rows across threads.
pub fn matmul_block_rows(
    x: &[f32],
    w: &[f32],
    rows: std::ops::Range<usize>,
    a: usize,
    b: usize,
    out: &mut [f32],
) {
    for i in rows {
        let xrow = &x[i * a..i * a + a];
        let orow = &mut out[i * b..i * b + b];
        let mut j0 = 0;
        while j0 < b {
            let jn = MM_LANES.min(b - j0);
            let mut acc = [0.0f32; MM_LANES];
            for (k, &xv) in xrow.iter().enumerate() {
                let wrow = &w[k * b + j0..k * b + j0 + jn];
                for c in 0..jn {
                    acc[c] += xv * wrow[c];
                }
            }
            orow[j0..j0 + jn].copy_from_slice(&acc[..jn]);
            j0 += jn;
        }
    }
}

/// Blocked matmul over all rows (see [`matmul_block_rows`]).
pub fn matmul_blocked(x: &[f32], w: &[f32], n: usize, a: usize, b: usize, out: &mut [f32]) {
    matmul_block_rows(x, w, 0..n, a, b, out);
}

/// Naive dot product over `d` elements, ascending index order.
pub fn dot(a: &[f32], b: &[f32], d: usize) -> f32 {
    let mut s = 0.0;
    for i in 0..d {
        s += a[i] * b[i];
    }
    s
}

/// Transposed-layout attention score microkernel.
///
/// `kt` is one kv head's keys stored `[d, n_ctx]` (position-major lanes);
/// computes `row[s] = q · k_s` for `s < len` by accumulating one `q[dd]`
/// broadcast against the contiguous `kt[dd, :]` panel per step — the inner
/// loop vectorizes over `s` while each `row[s]` still sums the head dim in
/// ascending order, keeping it bitwise identical to [`dot`] against the
/// untransposed keys.
pub fn scores_from_kt(q: &[f32], kt: &[f32], n_ctx: usize, d: usize, len: usize, row: &mut [f32]) {
    row[..len].fill(0.0);
    for dd in 0..d {
        let qv = q[dd];
        let panel = &kt[dd * n_ctx..dd * n_ctx + len];
        let r = &mut row[..len];
        for s in 0..len {
            r[s] += qv * panel[s];
        }
    }
}

/// Pack one kv head's keys `[n, stride]` (rows at `base + s*stride`) into
/// the transposed `[d, n_ctx]` panel layout [`scores_from_kt`] consumes.
pub fn pack_kt(k: &[f32], base: usize, stride: usize, n: usize, d: usize, kt: &mut [f32]) {
    for s in 0..n {
        let krow = &k[base + s * stride..base + s * stride + d];
        for (dd, &kv) in krow.iter().enumerate() {
            kt[dd * n + s] = kv;
        }
    }
}

/// Cephes-style polynomial `expf`: max observed relative error ≈ 2e-7 vs
/// libm over `[-87, 0]` (the softmax input range — scores are shifted by
/// their max before exponentiation). Straight-line arithmetic only, so the
/// compiler can vectorize softmax rows; **both** the scalar and blocked
/// reference paths use it, which keeps them bitwise comparable.
#[inline]
#[allow(clippy::excessive_precision)]
pub fn fast_exp(x: f32) -> f32 {
    const LOG2E: f32 = std::f32::consts::LOG2_E;
    const LN2_HI: f32 = 0.693_359_4;
    const LN2_LO: f32 = -2.121_944_4e-4;
    let x = x.clamp(-87.0, 88.0);
    let n = (x * LOG2E + 0.5).floor();
    let xr = x - n * LN2_HI - n * LN2_LO;
    let mut p = 1.987_569_1e-4f32;
    p = p * xr + 1.398_199_9e-3;
    p = p * xr + 8.333_452e-3;
    p = p * xr + 4.166_579_6e-2;
    p = p * xr + 1.666_666_5e-1;
    p = p * xr + 5.000_000_1e-1;
    let y = p * xr * xr + xr + 1.0;
    // scale by 2^n through the exponent bits (n ∈ [-126, 127] after clamp)
    y * f32::from_bits(((n as i32 + 127) << 23) as u32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| (rng.below(2000) as f32 - 1000.0) / 317.0).collect()
    }

    /// Property: the blocked matmul is bitwise identical to the naive one
    /// over random shapes, including edge dims that are not multiples of
    /// the microkernel lane width (and including zero activations, which
    /// the naive path skips and the microkernel does not).
    #[test]
    fn blocked_matmul_matches_naive_bitwise() {
        let mut rng = Rng::new(0xB10C);
        for case in 0..200 {
            let n = 1 + rng.below(33) as usize;
            let a = 1 + rng.below(70) as usize;
            let b = 1 + rng.below(90) as usize; // frequently not 8-aligned
            let mut x = rand_vec(&mut rng, n * a);
            // sprinkle exact zeros so the naive zero-skip is exercised
            for i in 0..x.len() {
                if rng.below(5) == 0 {
                    x[i] = 0.0;
                }
            }
            let w = rand_vec(&mut rng, a * b);
            let mut naive = vec![0.0f32; n * b];
            let mut blocked = vec![7.0f32; n * b]; // overwritten, not accumulated
            matmul(&x, &w, n, a, b, &mut naive);
            matmul_blocked(&x, &w, n, a, b, &mut blocked);
            for i in 0..n * b {
                assert_eq!(
                    naive[i].to_bits(),
                    blocked[i].to_bits(),
                    "case {case} ({n}x{a}x{b}) elem {i}: {} vs {}",
                    naive[i],
                    blocked[i]
                );
            }
        }
    }

    /// Sharding rows across ranges does not change a single bit.
    #[test]
    fn row_sharded_matmul_matches_whole() {
        let mut rng = Rng::new(0x5EED);
        let (n, a, b) = (23, 48, 37);
        let x = rand_vec(&mut rng, n * a);
        let w = rand_vec(&mut rng, a * b);
        let mut whole = vec![0.0f32; n * b];
        matmul_blocked(&x, &w, n, a, b, &mut whole);
        let mut sharded = vec![0.0f32; n * b];
        for r0 in (0..n).step_by(5) {
            matmul_block_rows(&x, &w, r0..(r0 + 5).min(n), a, b, &mut sharded);
        }
        assert_eq!(
            whole.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            sharded.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    /// The transposed score kernel reproduces the naive dot bit-for-bit.
    #[test]
    fn kt_scores_match_dot_bitwise() {
        let mut rng = Rng::new(0xD07);
        for _ in 0..50 {
            let d = 8;
            let n = 1 + rng.below(200) as usize;
            let heads = 2;
            let stride = heads * d;
            let k = rand_vec(&mut rng, n * stride);
            let q = rand_vec(&mut rng, d);
            for h in 0..heads {
                let mut kt = vec![0.0f32; d * n];
                pack_kt(&k, h * d, stride, n, d, &mut kt);
                let len = 1 + rng.below(n);
                let mut row = vec![0.0f32; len];
                scores_from_kt(&q, &kt, n, d, len, &mut row);
                for s in 0..len {
                    let want = dot(&q, &k[h * d + s * stride..h * d + s * stride + d], d);
                    assert_eq!(want.to_bits(), row[s].to_bits(), "head {h} pos {s}");
                }
            }
        }
    }

    /// fast_exp tracks libm expf tightly over the softmax input range and
    /// hits the exact anchor values the attention math depends on.
    #[test]
    fn fast_exp_accuracy() {
        assert_eq!(fast_exp(0.0), 1.0, "softmax max position must stay exactly 1");
        let mut worst = 0.0f32;
        let mut x = -87.0f32;
        while x <= 8.0 {
            let got = fast_exp(x);
            let want = x.exp();
            let rel = ((got - want) / want).abs();
            if rel > worst {
                worst = rel;
            }
            x += 0.000_37;
        }
        assert!(worst < 5e-7, "max relative error {worst}");
        assert!(fast_exp(-200.0) >= 0.0 && fast_exp(-200.0) < 1e-37);
    }
}
