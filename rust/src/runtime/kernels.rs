//! CPU compute kernels for the reference backend.
//!
//! Two flavors of every primitive:
//!
//! * **naive** — the untuned scalar loops the backend shipped with
//!   ([`matmul`], [`dot`]). These remain the semantic oracle: the blocked
//!   kernels are required (and property-tested) to be **bitwise identical**
//!   to them, which pins every accumulation to the same operand order.
//! * **blocked** — cache-blocked / transposed-layout variants with small
//!   hand-vectorizable microkernels ([`matmul_blocked`],
//!   [`scores_from_kt`]): per output element the reduction still runs over
//!   `k` (resp. the head dim) in ascending order with a single `f32`
//!   accumulator, so results match the naive loops bit-for-bit while the
//!   independent output lanes vectorize.
//!
//! Both paths share [`fast_exp`], a Cephes-style polynomial `expf` whose
//! body is straight-line arithmetic (no table, no libm call) — the
//! compiler vectorizes it across softmax rows, and using one definition on
//! the scalar *and* parallel paths keeps them bitwise comparable.
//!
//! Bitwise-safety notes the tests rely on:
//! * splitting rows/columns into tiles never touches reduction order;
//! * skipping a `+= 0.0 * w` term is exact for finite `w` (adding `±0.0`
//!   to an accumulator that is never `-0.0` is the identity), so the
//!   naive zero-skip and the branch-free microkernel agree.

#![allow(clippy::needless_range_loop)]

/// Column-lane width of the matmul microkernel (one vector register of
/// f32s on SSE/NEON; two unrolled on AVX2).
pub const MM_LANES: usize = 8;

/// Naive row-major matmul: `out[n,b] = x[n,a] @ w[a,b]` with f32
/// accumulation, skipping zero activations (exact — see module docs).
pub fn matmul(x: &[f32], w: &[f32], n: usize, a: usize, b: usize, out: &mut [f32]) {
    out[..n * b].fill(0.0);
    for i in 0..n {
        for k in 0..a {
            let xv = x[i * a + k];
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[k * b..k * b + b];
            let orow = &mut out[i * b..i * b + b];
            for j in 0..b {
                orow[j] += xv * wrow[j];
            }
        }
    }
}

/// Blocked matmul over an explicit row range: `out[i, :] = x[i, :] @ w`
/// for `i in rows`, tiled over [`MM_LANES`]-wide column panels held in a
/// register accumulator. Bitwise identical to [`matmul`] on the same rows
/// (per output element the `k` reduction order is unchanged); row-range
/// form so a parallel driver can shard rows across threads.
pub fn matmul_block_rows(
    x: &[f32],
    w: &[f32],
    rows: std::ops::Range<usize>,
    a: usize,
    b: usize,
    out: &mut [f32],
) {
    for i in rows {
        let xrow = &x[i * a..i * a + a];
        let orow = &mut out[i * b..i * b + b];
        let mut j0 = 0;
        while j0 < b {
            let jn = MM_LANES.min(b - j0);
            let mut acc = [0.0f32; MM_LANES];
            for (k, &xv) in xrow.iter().enumerate() {
                let wrow = &w[k * b + j0..k * b + j0 + jn];
                for c in 0..jn {
                    acc[c] += xv * wrow[c];
                }
            }
            orow[j0..j0 + jn].copy_from_slice(&acc[..jn]);
            j0 += jn;
        }
    }
}

/// Blocked matmul over all rows (see [`matmul_block_rows`]).
pub fn matmul_blocked(x: &[f32], w: &[f32], n: usize, a: usize, b: usize, out: &mut [f32]) {
    matmul_block_rows(x, w, 0..n, a, b, out);
}

/// Naive dot product over `d` elements, ascending index order.
pub fn dot(a: &[f32], b: &[f32], d: usize) -> f32 {
    let mut s = 0.0;
    for i in 0..d {
        s += a[i] * b[i];
    }
    s
}

/// Transposed-layout attention score microkernel.
///
/// `kt` is one kv head's keys stored `[d, n_ctx]` (position-major lanes);
/// computes `row[s] = q · k_s` for `s < len` by accumulating one `q[dd]`
/// broadcast against the contiguous `kt[dd, :]` panel per step — the inner
/// loop vectorizes over `s` while each `row[s]` still sums the head dim in
/// ascending order, keeping it bitwise identical to [`dot`] against the
/// untransposed keys.
pub fn scores_from_kt(q: &[f32], kt: &[f32], n_ctx: usize, d: usize, len: usize, row: &mut [f32]) {
    row[..len].fill(0.0);
    for dd in 0..d {
        let qv = q[dd];
        let panel = &kt[dd * n_ctx..dd * n_ctx + len];
        let r = &mut row[..len];
        for s in 0..len {
            r[s] += qv * panel[s];
        }
    }
}

/// Pack one kv head's keys `[n, stride]` (rows at `base + s*stride`) into
/// the transposed `[d, n_ctx]` panel layout [`scores_from_kt`] consumes.
pub fn pack_kt(k: &[f32], base: usize, stride: usize, n: usize, d: usize, kt: &mut [f32]) {
    for s in 0..n {
        let krow = &k[base + s * stride..base + s * stride + d];
        for (dd, &kv) in krow.iter().enumerate() {
            kt[dd * n + s] = kv;
        }
    }
}

/// Cephes-style polynomial `expf`: max observed relative error ≈ 2e-7 vs
/// libm over `[-87, 0]` (the softmax input range — scores are shifted by
/// their max before exponentiation). Straight-line arithmetic only, so the
/// compiler can vectorize softmax rows; **both** the scalar and blocked
/// reference paths use it, which keeps them bitwise comparable.
#[inline]
#[allow(clippy::excessive_precision)]
pub fn fast_exp(x: f32) -> f32 {
    const LOG2E: f32 = std::f32::consts::LOG2_E;
    const LN2_HI: f32 = 0.693_359_4;
    const LN2_LO: f32 = -2.121_944_4e-4;
    let x = x.clamp(-87.0, 88.0);
    let n = (x * LOG2E + 0.5).floor();
    let xr = x - n * LN2_HI - n * LN2_LO;
    let mut p = 1.987_569_1e-4f32;
    p = p * xr + 1.398_199_9e-3;
    p = p * xr + 8.333_452e-3;
    p = p * xr + 4.166_579_6e-2;
    p = p * xr + 1.666_666_5e-1;
    p = p * xr + 5.000_000_1e-1;
    let y = p * xr * xr + xr + 1.0;
    // scale by 2^n through the exponent bits (n ∈ [-126, 127] after clamp)
    y * f32::from_bits(((n as i32 + 127) << 23) as u32)
}

// ------------------------------------------------------------ quantization
//
// Lossy per-group affine quantization for the demoted KV tier (the
// ROADMAP "demote, don't just drop" item). A demoted position's K and V
// rows are stored as unsigned codes plus one (scale, zero) pair per
// `group` contiguous channels: `x ≈ zero + scale * code`. The scalar
// encoder below is the oracle; the backend op and the engine's host-
// snapshot round-trip both call it, so a demote → rehydrate cycle is
// bitwise reproducible everywhere the row is materialized.

/// Code width for the demoted-tier payload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantBits {
    /// 8-bit codes, one byte per channel.
    Int8,
    /// 4-bit codes, two channels per byte (per-group byte-aligned).
    Int4,
}

impl QuantBits {
    /// Largest representable code (number of levels minus one).
    pub fn max_code(self) -> u32 {
        match self {
            QuantBits::Int8 => 255,
            QuantBits::Int4 => 15,
        }
    }

    /// Packed bytes needed for `n` codes. Int4 packs two codes per byte
    /// and pads the last byte, so groups stay byte-aligned.
    pub fn code_bytes(self, n: usize) -> usize {
        match self {
            QuantBits::Int8 => n,
            QuantBits::Int4 => n.div_ceil(2),
        }
    }

    /// Wire/debug name (`int8` / `int4`).
    pub fn name(self) -> &'static str {
        match self {
            QuantBits::Int8 => "int8",
            QuantBits::Int4 => "int4",
        }
    }
}

/// One quantized channel row (K or V of a single position in one head):
/// packed codes plus per-group affine parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantRow {
    /// Packed codes, groups byte-aligned in order.
    pub codes: Vec<u8>,
    /// Per-group scale (0.0 for constant groups).
    pub scales: Vec<f32>,
    /// Per-group zero point (the group minimum).
    pub zeros: Vec<f32>,
}

/// Side-pool bytes one quantized row occupies: packed codes plus
/// 8 bytes (f32 scale + f32 zero) per group. This is the unit the
/// demoted-tier byte accounting charges (see `kvcache::TierConfig`).
pub fn quant_row_bytes(d: usize, group: usize, bits: QuantBits) -> usize {
    let g = group.max(1);
    let mut bytes = 0;
    let mut i = 0;
    while i < d {
        let n = g.min(d - i);
        bytes += bits.code_bytes(n) + 8;
        i += n;
    }
    bytes
}

/// Quantize one group of channels, appending packed codes to `codes`.
/// Returns `(scale, zero)`. Constant (or empty) groups encode with
/// scale 0 and reproduce exactly on dequantization.
pub fn quantize_group(xs: &[f32], bits: QuantBits, codes: &mut Vec<u8>) -> (f32, f32) {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &x in xs {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let levels = bits.max_code() as f32;
    let mut scale = if hi > lo { (hi - lo) / levels } else { 0.0 };
    if !scale.is_finite() {
        scale = 0.0; // degenerate range: encode everything at the zero point
    }
    let inv = if scale > 0.0 { 1.0 / scale } else { 0.0 };
    match bits {
        QuantBits::Int8 => {
            for &x in xs {
                codes.push(((x - lo) * inv).round().clamp(0.0, levels) as u8);
            }
        }
        QuantBits::Int4 => {
            let mut pending: Option<u8> = None;
            for &x in xs {
                let q = ((x - lo) * inv).round().clamp(0.0, levels) as u8;
                match pending.take() {
                    None => pending = Some(q),
                    Some(lo_nib) => codes.push(lo_nib | (q << 4)),
                }
            }
            if let Some(lo_nib) = pending {
                codes.push(lo_nib);
            }
        }
    }
    (scale, lo)
}

/// Decode one group previously packed by [`quantize_group`] into `out`.
pub fn dequantize_group(packed: &[u8], bits: QuantBits, scale: f32, zero: f32, out: &mut [f32]) {
    match bits {
        QuantBits::Int8 => {
            for (o, &c) in out.iter_mut().zip(packed.iter()) {
                *o = zero + scale * c as f32;
            }
        }
        QuantBits::Int4 => {
            for (i, o) in out.iter_mut().enumerate() {
                let byte = packed[i / 2];
                let c = if i % 2 == 0 { byte & 0x0F } else { byte >> 4 };
                *o = zero + scale * c as f32;
            }
        }
    }
}

/// Quantize a full channel row groupwise (the demoted-tier encoder).
pub fn quantize_row(row: &[f32], group: usize, bits: QuantBits) -> QuantRow {
    let g = group.max(1);
    let n_groups = row.len().div_ceil(g).max(1);
    let mut qr = QuantRow {
        codes: Vec::with_capacity(bits.code_bytes(row.len()) + n_groups),
        scales: Vec::with_capacity(n_groups),
        zeros: Vec::with_capacity(n_groups),
    };
    for chunk in row.chunks(g) {
        let (s, z) = quantize_group(chunk, bits, &mut qr.codes);
        qr.scales.push(s);
        qr.zeros.push(z);
    }
    qr
}

/// Decode a [`QuantRow`] into `out` (`out.len()` must match the encoded
/// row length for the same `group`/`bits`).
pub fn dequantize_row(qr: &QuantRow, group: usize, bits: QuantBits, out: &mut [f32]) {
    let g = group.max(1);
    let mut byte = 0;
    for (gi, chunk) in out.chunks_mut(g).enumerate() {
        let nb = bits.code_bytes(chunk.len());
        dequantize_group(&qr.codes[byte..byte + nb], bits, qr.scales[gi], qr.zeros[gi], chunk);
        byte += nb;
    }
}

/// In-place lossy round-trip `x ← dequant(quant(x))`. The engine applies
/// this to its host KV snapshot when a position is demoted so a later
/// rejoin-scatter uploads exactly what a backend rehydrate would produce.
pub fn quant_roundtrip(row: &mut [f32], group: usize, bits: QuantBits) {
    let qr = quantize_row(row, group, bits);
    dequantize_row(&qr, group, bits, row);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| (rng.below(2000) as f32 - 1000.0) / 317.0).collect()
    }

    /// Property: the blocked matmul is bitwise identical to the naive one
    /// over random shapes, including edge dims that are not multiples of
    /// the microkernel lane width (and including zero activations, which
    /// the naive path skips and the microkernel does not).
    #[test]
    fn blocked_matmul_matches_naive_bitwise() {
        let mut rng = Rng::new(0xB10C);
        for case in 0..200 {
            let n = 1 + rng.below(33) as usize;
            let a = 1 + rng.below(70) as usize;
            let b = 1 + rng.below(90) as usize; // frequently not 8-aligned
            let mut x = rand_vec(&mut rng, n * a);
            // sprinkle exact zeros so the naive zero-skip is exercised
            for i in 0..x.len() {
                if rng.below(5) == 0 {
                    x[i] = 0.0;
                }
            }
            let w = rand_vec(&mut rng, a * b);
            let mut naive = vec![0.0f32; n * b];
            let mut blocked = vec![7.0f32; n * b]; // overwritten, not accumulated
            matmul(&x, &w, n, a, b, &mut naive);
            matmul_blocked(&x, &w, n, a, b, &mut blocked);
            for i in 0..n * b {
                assert_eq!(
                    naive[i].to_bits(),
                    blocked[i].to_bits(),
                    "case {case} ({n}x{a}x{b}) elem {i}: {} vs {}",
                    naive[i],
                    blocked[i]
                );
            }
        }
    }

    /// Sharding rows across ranges does not change a single bit.
    #[test]
    fn row_sharded_matmul_matches_whole() {
        let mut rng = Rng::new(0x5EED);
        let (n, a, b) = (23, 48, 37);
        let x = rand_vec(&mut rng, n * a);
        let w = rand_vec(&mut rng, a * b);
        let mut whole = vec![0.0f32; n * b];
        matmul_blocked(&x, &w, n, a, b, &mut whole);
        let mut sharded = vec![0.0f32; n * b];
        for r0 in (0..n).step_by(5) {
            matmul_block_rows(&x, &w, r0..(r0 + 5).min(n), a, b, &mut sharded);
        }
        assert_eq!(
            whole.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            sharded.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    /// The transposed score kernel reproduces the naive dot bit-for-bit.
    #[test]
    fn kt_scores_match_dot_bitwise() {
        let mut rng = Rng::new(0xD07);
        for _ in 0..50 {
            let d = 8;
            let n = 1 + rng.below(200) as usize;
            let heads = 2;
            let stride = heads * d;
            let k = rand_vec(&mut rng, n * stride);
            let q = rand_vec(&mut rng, d);
            for h in 0..heads {
                let mut kt = vec![0.0f32; d * n];
                pack_kt(&k, h * d, stride, n, d, &mut kt);
                let len = 1 + rng.below(n);
                let mut row = vec![0.0f32; len];
                scores_from_kt(&q, &kt, n, d, len, &mut row);
                for s in 0..len {
                    let want = dot(&q, &k[h * d + s * stride..h * d + s * stride + d], d);
                    assert_eq!(want.to_bits(), row[s].to_bits(), "head {h} pos {s}");
                }
            }
        }
    }

    /// fast_exp tracks libm expf tightly over the softmax input range and
    /// hits the exact anchor values the attention math depends on.
    #[test]
    fn fast_exp_accuracy() {
        assert_eq!(fast_exp(0.0), 1.0, "softmax max position must stay exactly 1");
        let mut worst = 0.0f32;
        let mut x = -87.0f32;
        while x <= 8.0 {
            let got = fast_exp(x);
            let want = x.exp();
            let rel = ((got - want) / want).abs();
            if rel > worst {
                worst = rel;
            }
            x += 0.000_37;
        }
        assert!(worst < 5e-7, "max relative error {worst}");
        assert!(fast_exp(-200.0) >= 0.0 && fast_exp(-200.0) < 1e-37);
    }

    /// Property: groupwise quantization round-trips within half a step per
    /// element (`|x - x̂| ≤ scale/2` plus float slack), for both widths,
    /// over random rows / group sizes including non-divisible tails.
    #[test]
    fn quant_roundtrip_error_bounded() {
        let mut rng = Rng::new(0x0_11A7);
        for bits in [QuantBits::Int8, QuantBits::Int4] {
            for case in 0..200 {
                let d = 1 + rng.below(65) as usize;
                let group = 1 + rng.below(17) as usize;
                let row = rand_vec(&mut rng, d);
                let qr = quantize_row(&row, group, bits);
                assert_eq!(qr.codes.len(), {
                    let mut n = 0;
                    for c in row.chunks(group) {
                        n += bits.code_bytes(c.len());
                    }
                    n
                });
                assert_eq!(quant_row_bytes(d, group, bits), qr.codes.len() + 8 * qr.scales.len());
                let mut out = vec![0.0f32; d];
                dequantize_row(&qr, group, bits, &mut out);
                for (gi, chunk) in row.chunks(group).enumerate() {
                    let bound = qr.scales[gi] * 0.5 + qr.scales[gi].abs() * 1e-5 + 1e-6;
                    for (j, &x) in chunk.iter().enumerate() {
                        let got = out[gi * group + j];
                        assert!(
                            (x - got).abs() <= bound,
                            "{} case {case} d={d} g={group}: |{x} - {got}| > {bound}",
                            bits.name()
                        );
                    }
                }
            }
        }
    }

    /// Constant groups (scale 0) reproduce exactly, and int8 is never a
    /// worse approximation than int4 on the same group.
    #[test]
    fn quant_constant_exact_and_width_monotone() {
        let row = vec![-3.25f32; 12];
        for bits in [QuantBits::Int8, QuantBits::Int4] {
            let mut out = row.clone();
            quant_roundtrip(&mut out, 8, bits);
            assert_eq!(out, row, "{}: constant group must be exact", bits.name());
        }
        let mut rng = Rng::new(0x0_11A8);
        for _ in 0..100 {
            let row = rand_vec(&mut rng, 16);
            let err = |bits: QuantBits| {
                let mut out = row.clone();
                quant_roundtrip(&mut out, 16, bits);
                row.iter().zip(&out).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max)
            };
            assert!(err(QuantBits::Int8) <= err(QuantBits::Int4) + 1e-6);
        }
    }

    /// The engine/backend contract: re-encoding an already round-tripped
    /// row is (near-)stable — a second round-trip moves nothing by more
    /// than float slack, so demote → rehydrate → demote cycles do not
    /// drift the cache contents.
    #[test]
    fn quant_roundtrip_stable_under_reencoding() {
        let mut rng = Rng::new(0x0_11A9);
        for _ in 0..100 {
            let mut row = rand_vec(&mut rng, 24);
            quant_roundtrip(&mut row, 8, QuantBits::Int8);
            let once = row.clone();
            quant_roundtrip(&mut row, 8, QuantBits::Int8);
            for (a, b) in once.iter().zip(&row) {
                assert!((a - b).abs() <= (a.abs() + 1.0) * 1e-4, "{a} vs {b}");
            }
        }
    }
}
