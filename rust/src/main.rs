//! kvzap CLI — leader entrypoint for the serving stack.
//!
//! Subcommands:
//!   info                         manifest + artifact summary
//!   generate --prompt ... [--policy kvzap_mlp:-4] [--max-new 32]
//!   eval --suite ruler|longbench|aime [--policy ...] [--samples N] [--ctx T]
//!   leaderboard [--quick] [--samples N] [--ctx T] [--seed S]
//!                                full policy-catalog sweep over every suite;
//!                                writes BENCH_leaderboard.json and prints
//!                                per-suite accuracy/compression frontiers
//!   serve [--addr host:port] [--policy ...] [--shards N] [--prefix-reuse]
//!   policies                     pruning-policy catalog (params + defaults)
//!   flops                        Appendix-B overhead table (Table 3)
//!   metrics-demo                 quick built-in load test printing metrics
//!   simulate [--seed S|A..B] [--steps K] [--clients N] [--max-batch B]
//!            [--quick] [--no-solo] [--check-threads] [--threads T]
//!            [--spec-file PATH] [--fault-step K] [--fault-quant-step K]
//!            [--fault-prefix-step K] [--fault-route-step K]
//!            [--tiered] [--shards N] [--prefix-reuse] [--no-prefix-reuse]
//!            [--prefix-budget BYTES] [--kv-budget BYTES]
//!            [--side-budget BYTES]
//!                                deterministic multi-client scenario fuzzer
//!                                with invariant checking (docs/TESTING.md);
//!                                --tiered scripts demotion-heavy episodes
//!                                (two-threshold policies only); --shards N
//!                                routes through the shard pool, adds the
//!                                router invariants, and (with --quick or
//!                                --check-shards) runs the shard-invariance
//!                                metamorphic family on a shared-prefix
//!                                episode; the budget flags bound the
//!                                prefix cache / per-engine KV pools and
//!                                add the pool-budget invariant (0 =
//!                                unbounded; KV budgets imply --no-solo);
//!                                exits non-zero when an invariant fires

use std::sync::Arc;

use anyhow::{anyhow, Result};
use kvzap::coordinator::{Engine, SamplingParams};
use kvzap::policies::spec::PolicySpec;
use kvzap::runtime::Runtime;
use kvzap::server::{Server, ServerConfig};
use kvzap::util::rng::Rng;
use kvzap::workload;

/// Tiny --key value argument parser (clap is unavailable offline).
struct Args {
    cmd: String,
    kv: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse() -> Args {
        let mut it = std::env::args().skip(1);
        let cmd = it.next().unwrap_or_else(|| "help".into());
        let mut kv = std::collections::HashMap::new();
        let rest: Vec<String> = it.collect();
        let mut i = 0;
        while i < rest.len() {
            if let Some(key) = rest[i].strip_prefix("--") {
                let val = if i + 1 < rest.len() && !rest[i + 1].starts_with("--") {
                    i += 1;
                    rest[i].clone()
                } else {
                    "true".into()
                };
                kv.insert(key.to_string(), val);
            }
            i += 1;
        }
        Args { cmd, kv }
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.kv.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn usize(&self, key: &str, default: usize) -> usize {
        self.kv.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

fn main() -> Result<()> {
    let args = Args::parse();
    match args.cmd.as_str() {
        "info" => info(),
        "generate" => generate(&args),
        "eval" => eval(&args),
        "leaderboard" => leaderboard(&args),
        "serve" => serve(&args),
        "policies" => policies_catalog(&args),
        "flops" => flops(),
        "metrics-demo" => metrics_demo(&args),
        "simulate" => simulate(&args),
        _ => {
            eprintln!(
                "usage: kvzap <info|generate|eval|leaderboard|serve|policies|flops|metrics-demo|\
                 simulate> [--key value ...]\n\
                 run `kvzap policies` for the pruning-policy catalog"
            );
            Ok(())
        }
    }
}

/// The simulation harness front-end: run seeded scenarios (or a replayed
/// spec file) against the invariant registry; on a violation print the
/// replay line, write the minimized scenario to SIM_FAILURE.json, and exit
/// non-zero (the CI lane fails on any fired invariant).
fn simulate(args: &Args) -> Result<()> {
    use kvzap::simharness::{
        replay_line, reuse_traces_match, shard_traces_match, simulate as run_one,
        thread_traces_match, Fault, ScenarioSpec, SimOptions,
    };
    let quick = args.kv.contains_key("quick");
    let threads = match args.kv.get("threads") {
        None => None,
        Some(v) => {
            Some(v.parse().map_err(|_| anyhow!("bad --threads '{v}' (want a count)"))?)
        }
    };
    let fault_flags = [
        ("fault-step", "PhantomRowFetch"),
        ("fault-quant-step", "PhantomQuantAttend"),
        ("fault-prefix-step", "PhantomPrefixHit"),
        ("fault-route-step", "PhantomMisroute"),
    ];
    let set: Vec<&str> = fault_flags
        .iter()
        .map(|(f, _)| *f)
        .filter(|f| args.kv.contains_key(*f))
        .collect();
    if set.len() > 1 {
        return Err(anyhow!(
            "--{} are mutually exclusive (one injected bug per mutation run)",
            set.join(" and --")
        ));
    }
    let fault = match set.first() {
        None => None,
        Some(flag) => {
            let v = &args.kv[*flag];
            let step =
                v.parse().map_err(|_| anyhow!("bad --{flag} '{v}' (want a step)"))?;
            Some(match *flag {
                "fault-step" => Fault::PhantomRowFetch { step },
                "fault-quant-step" => Fault::PhantomQuantAttend { step },
                "fault-prefix-step" => Fault::PhantomPrefixHit { step },
                _ => Fault::PhantomMisroute { step },
            })
        }
    };
    let shards = args.usize("shards", 1);
    let prefix_reuse = args.kv.contains_key("prefix-reuse")
        || (shards > 1 && !args.kv.contains_key("no-prefix-reuse"));
    let budget = |key: &str| -> Result<Option<usize>> {
        match args.kv.get(key) {
            None => Ok(None),
            Some(v) => {
                let b: usize =
                    v.parse().map_err(|_| anyhow!("bad --{key} '{v}' (want bytes)"))?;
                Ok((b > 0).then_some(b))
            }
        }
    };
    let prefix_budget = budget("prefix-budget")?;
    let kv_budget = budget("kv-budget")?;
    let side_budget = budget("side-budget")?;
    let opts = SimOptions {
        threads,
        // KV budgets disable the solo replays: they run on the scripted
        // engines, whose pools are still charged by live sequences, so a
        // replay would see (and cause) spurious admission pressure.
        check_solo: !args.kv.contains_key("no-solo")
            && kv_budget.is_none()
            && side_budget.is_none(),
        fault,
        shards,
        prefix_reuse,
        prefix_budget,
        kv_budget,
        side_budget,
        ..SimOptions::default()
    };
    let tiered = args.kv.contains_key("tiered");
    let fail = move |f: Box<kvzap::simharness::SimFailure>| -> Result<()> {
        eprintln!("[kvzap simulate] INVARIANT VIOLATION: {}", f.violation);
        eprintln!(
            "[kvzap simulate] replay: {}{}",
            f.replay,
            if tiered { " --tiered" } else { "" }
        );
        let path = "SIM_FAILURE.json";
        std::fs::write(path, format!("{}\n", f.minimized_json))?;
        eprintln!(
            "[kvzap simulate] minimized scenario ({} clients, {} steps) written to {path}; \
             replay it with: kvzap simulate --spec-file {path}",
            f.minimized.clients.len(),
            f.minimized.steps
        );
        std::process::exit(1);
    };
    if let Some(path) = args.kv.get("spec-file") {
        let body = std::fs::read_to_string(path)?;
        let j = kvzap::util::json::Json::parse(body.trim())
            .map_err(|e| anyhow!("bad spec file {path}: {e}"))?;
        let spec = ScenarioSpec::from_json(&j)?;
        return match run_one(&spec, &opts) {
            Ok(s) => {
                if opts.fault.is_some() && !s.fault_injected {
                    return Err(anyhow!(
                        "the injected fault never fired (nothing to corrupt at that \
                         step): the clean result is not a passed mutation check"
                    ));
                }
                println!(
                    "spec {path}: ok ({} clients, {} completed, {} tokens)",
                    s.clients, s.completed, s.tokens_out
                );
                Ok(())
            }
            Err(f) => fail(f),
        };
    }
    let steps = args.usize("steps", if quick { 48 } else { 200 });
    let clients = args.usize("clients", if quick { 5 } else { 6 });
    let max_batch = args.usize("max-batch", 4);
    let seed_arg = args.get("seed", if quick { "0..4" } else { "0..8" });
    let seeds: Vec<u64> = match seed_arg.split_once("..") {
        Some((a, b)) => {
            let a: u64 = a.parse().map_err(|_| anyhow!("bad seed range '{seed_arg}'"))?;
            let b: u64 = b.parse().map_err(|_| anyhow!("bad seed range '{seed_arg}'"))?;
            (a..b).collect()
        }
        None => vec![seed_arg.parse().map_err(|_| anyhow!("bad seed '{seed_arg}'"))?],
    };
    if seeds.is_empty() {
        return Err(anyhow!("empty seed range '{seed_arg}' — nothing would be tested"));
    }
    let check_threads = quick || args.kv.contains_key("check-threads");
    let check_shards =
        shards > 1 && fault.is_none() && (quick || args.kv.contains_key("check-shards"));
    for &seed in &seeds {
        let spec = if tiered {
            ScenarioSpec::generate_tiered(seed, steps, clients, max_batch)
        } else {
            ScenarioSpec::generate(seed, steps, clients, max_batch)
        };
        match run_one(&spec, &opts) {
            Ok(s) => {
                if opts.fault.is_some() && !s.fault_injected {
                    return Err(anyhow!(
                        "seed {seed}: the injected fault never fired (nothing to \
                         corrupt at that step): the clean result is not a passed \
                         mutation check"
                    ));
                }
                println!(
                    "seed {seed}: ok ({} clients, {} completed, {} cancelled, {} tokens, \
                     {} steps)",
                    s.clients, s.completed, s.cancelled, s.tokens_out, s.steps
                );
            }
            Err(f) => return fail(f),
        }
        if check_threads {
            if let Err(e) = thread_traces_match(&spec, 1, 2) {
                eprintln!("[kvzap simulate] THREAD-INVARIANCE VIOLATION: {e}");
                eprintln!("[kvzap simulate] replay: {} --check-threads", replay_line(&spec));
                std::process::exit(1);
            }
            println!("seed {seed}: threads 1 vs 2 bitwise identical");
        }
        if check_shards {
            // metamorphic shard-invariance family on a cancel-free
            // shared-prefix episode (cancelled streams are schedule-
            // dependent, so the fuzzed spec above is not comparable)
            let shared = ScenarioSpec::generate_shared_prefix(seed, 96, 4, max_batch);
            if let Err(e) = shard_traces_match(&shared, 1, shards.max(2)) {
                eprintln!("[kvzap simulate] SHARD-INVARIANCE VIOLATION: {e}");
                eprintln!(
                    "[kvzap simulate] replay: {} --shards {}",
                    replay_line(&shared),
                    shards.max(2)
                );
                std::process::exit(1);
            }
            if let Err(e) = reuse_traces_match(&shared, shards.max(2)) {
                eprintln!("[kvzap simulate] PREFIX-REUSE-INVARIANCE VIOLATION: {e}");
                eprintln!(
                    "[kvzap simulate] replay: {} --shards {} --prefix-reuse",
                    replay_line(&shared),
                    shards.max(2)
                );
                std::process::exit(1);
            }
            println!(
                "seed {seed}: outputs identical at 1 vs {} shard(s), reuse on vs off",
                shards.max(2)
            );
        }
    }
    println!("simulate: {} seed(s) clean", seeds.len());
    Ok(())
}

/// The policy catalog: every PolicySpec kind with its string forms,
/// parameters and defaults (same data the server's {"cmd":"policies"}
/// returns; `--json` prints that wire form).
fn policies_catalog(args: &Args) -> Result<()> {
    if args.kv.contains_key("json") {
        println!("{}", kvzap::policies::spec::catalog_json().dump());
        return Ok(());
    }
    println!("{:<14} {:<52} {}", "kind", "string forms", "parameters (default)");
    for info in kvzap::policies::spec::CATALOG {
        let params: Vec<String> =
            info.params.iter().map(|p| format!("{}={}", p.name, p.default)).collect();
        println!(
            "{:<14} {:<52} {}",
            info.kind,
            info.string_forms.join(", "),
            if params.is_empty() { "-".to_string() } else { params.join(", ") }
        );
        println!("{:<14} {}", "", info.doc);
    }
    println!(
        "\nstring form: <name>[:<param>[:<param2>]], e.g. kvzap_mlp:-4, \
         streaming_llm:0.3:8\nstructured form (server): {}",
        PolicySpec::parse("kvzap_mlp:-4").unwrap().to_json().dump()
    );
    Ok(())
}

fn load_engine() -> Result<Arc<Engine>> {
    kvzap::bench_support::load_engine()
}

fn info() -> Result<()> {
    let rt = Runtime::auto()?;
    println!("backend: {}", rt.backend_desc());
    let m = &rt.manifest;
    println!("zap-lm: L={} Dh={} Hq={} Hkv={} D={} Dint={} t_max={}",
        m.model.n_layers, m.model.d_model, m.model.n_q_heads, m.model.n_kv_heads,
        m.model.d_head, m.model.d_int, m.model.t_max);
    println!("window w={} obs_window={}", m.window, m.obs_window);
    println!("prefill buckets t={:?} b={:?}", m.buckets.prefill_t, m.buckets.prefill_b);
    println!("decode buckets b={:?}; kvzip oracle t={:?}", m.buckets.decode_b, m.buckets.kvzip_t);
    println!("weights: {} tensors", m.weights.len());
    println!("threshold quantiles (oracle log s+): {:?}", m.threshold_quantiles);
    let mut names: Vec<&String> = m.artifacts.keys().collect();
    names.sort();
    println!("artifacts ({}):", names.len());
    for n in names {
        println!("  {n}");
    }
    Ok(())
}

fn generate(args: &Args) -> Result<()> {
    let engine = load_engine()?;
    let prompt = args.get("prompt", "AAQX = 90210. the sky was clear. Q AAQX\nA ");
    let spec = args.get("policy", "kvzap_mlp:-4");
    let policy = PolicySpec::parse(&spec)?.build(engine.window());
    let sp = SamplingParams::greedy(args.usize("max-new", 32));
    let r = engine.generate(&prompt, policy.as_ref(), &sp)?;
    println!("text: {:?}", r.text);
    println!(
        "compression: {:.3} ({:.2}x) | prefill {}us oracle {}us decode {}us policy {}us",
        r.compression,
        1.0 / (1.0 - r.compression).max(1e-9),
        r.prefill_us,
        r.oracle_us,
        r.decode_us,
        r.policy_us
    );
    Ok(())
}

fn eval(args: &Args) -> Result<()> {
    let engine = load_engine()?;
    let suite = args.get("suite", "ruler");
    let spec = args.get("policy", "kvzap_mlp:-4");
    let samples = args.usize("samples", 5);
    let ctx = args.usize("ctx", 248);
    let policy = PolicySpec::parse(&spec)?.build(engine.window());
    let mut rng = Rng::new(args.usize("seed", 42) as u64);

    let mut total = 0;
    let mut correct = 0;
    let mut comp_sum = 0.0;
    let subsets: Vec<String> = match suite.as_str() {
        "ruler" => workload::RULER_SUBSETS.iter().map(|s| s.to_string()).collect(),
        "longbench" => workload::LONGBENCH_SUBSETS.iter().map(|s| s.to_string()).collect(),
        "aime" => vec!["aime".to_string()],
        _ => return Err(anyhow!("unknown suite {suite}")),
    };
    for subset in &subsets {
        let mut sub_ok = 0;
        for i in 0..samples {
            let mut r = rng.fork(i as u64);
            let (task, max_new) = match suite.as_str() {
                "ruler" => {
                    let t = workload::ruler_instance(subset, ctx, &mut r);
                    let m = t.max_new;
                    (t, m)
                }
                "longbench" => {
                    let t = workload::longbench_instance(subset, ctx, &mut r);
                    let m = t.max_new;
                    (t, m)
                }
                _ => {
                    let a = workload::aime_instance(&mut r);
                    let m = a.task.max_new;
                    (a.task, m)
                }
            };
            let sp = SamplingParams::greedy(max_new);
            let res = engine.generate(&task.prompt, policy.as_ref(), &sp)?;
            let ok = if suite == "aime" {
                workload::generators::parse_aime_answer(&res.text).as_deref()
                    == Some(task.answer.as_str())
            } else {
                task.score(&res.text)
            };
            sub_ok += ok as usize;
            correct += ok as usize;
            total += 1;
            comp_sum += res.compression;
        }
        println!("{subset:<18} acc {:>5.1}%", 100.0 * sub_ok as f64 / samples as f64);
    }
    println!(
        "== {suite} | policy {spec} | acc {:.1}% | mean compression {:.3} ({:.2}x)",
        100.0 * correct as f64 / total as f64,
        comp_sum / total as f64,
        1.0 / (1.0 - comp_sum / total as f64).max(1e-9)
    );
    println!("{}", engine.metrics.report());
    Ok(())
}

/// The full-sweep leaderboard bench: every cataloged policy × suite ×
/// compression target, one BENCH_leaderboard.json + per-suite frontier
/// tables. `--quick` is the hermetic CI smoke lane (one subset per suite,
/// one target per kind) which still must cover every catalog kind.
fn leaderboard(args: &Args) -> Result<()> {
    use kvzap::leaderboard::{run, LeaderboardConfig};
    let engine = load_engine()?;
    let mut cfg = LeaderboardConfig::new(args.kv.contains_key("quick"));
    cfg.samples = args.usize("samples", cfg.samples);
    cfg.ctx = args.usize("ctx", cfg.ctx);
    cfg.seed = args.usize("seed", cfg.seed as usize) as u64;
    let rows = run(&engine, &cfg)?;
    println!("leaderboard: {} rows across {} policies", rows.len(), {
        let mut p: Vec<&str> = rows.iter().map(|r| r.policy.as_str()).collect();
        p.sort_unstable();
        p.dedup();
        p.len()
    });
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    let shards = args.usize("shards", 1).max(1);
    let cfg = ServerConfig {
        addr: args.get("addr", "127.0.0.1:7712"),
        default_policy: args.get("policy", "kvzap_mlp:-4"),
        max_batch: args.usize("max-batch", 4),
        max_wait_us: args.usize("max-wait-us", 2000) as u64,
        shards,
        prefix_reuse: args.kv.contains_key("prefix-reuse")
            || (shards > 1 && !args.kv.contains_key("no-prefix-reuse")),
        prefix_budget: match args.usize("prefix-budget", 0) {
            0 => None, // 0 = unbounded
            b => Some(b),
        },
        tenant_inflight: args.usize("tenant-inflight", 8),
    };
    // one engine (own runtime + resident cache) per shard
    let engines: Result<Vec<_>> = (0..shards).map(|_| load_engine()).collect();
    Server::new_sharded(engines?, cfg).serve()
}

fn flops() -> Result<()> {
    // Include zap-lm from whichever backend is available; the paper rows
    // never need one.
    let extra = Runtime::auto().ok().map(|rt| {
        let m = &rt.manifest.model;
        kvzap::analysis::LayerDims {
            name: "zap-lm (this repo)".into(),
            h_q: m.n_q_heads,
            h_kv: m.n_kv_heads,
            d_head: m.d_head,
            d_model: m.d_model,
            d_int: m.d_int,
            d_surrogate: m.d_surrogate,
        }
    });
    println!("Table 3 | relative compute overhead of KVzap (linear projections only)");
    println!("{:<24} {:>5} {:>3} {:>5} {:>6} {:>7} {:>10} {:>12}",
        "model", "H_Q", "H", "D", "D_h", "D_int", "MLP %", "Linear %");
    for r in kvzap::analysis::overhead_table(extra) {
        println!(
            "{:<24} {:>5} {:>3} {:>5} {:>6} {:>7} {:>9.2}% {:>11.2}%",
            r.dims.name, r.dims.h_q, r.dims.h_kv, r.dims.d_head, r.dims.d_model,
            r.dims.d_int, r.mlp_pct, r.linear_pct
        );
    }
    Ok(())
}

fn metrics_demo(args: &Args) -> Result<()> {
    let engine = load_engine()?;
    let n = args.usize("requests", 8);
    let spec = args.get("policy", "kvzap_mlp:-4");
    let policy = PolicySpec::parse(&spec)?.build(engine.window());
    let mut rng = Rng::new(7);
    for i in 0..n {
        let t = workload::ruler_instance("niah_single_1", 200, &mut rng.fork(i as u64));
        let _ = engine.generate(&t.prompt, policy.as_ref(), &SamplingParams::greedy(t.max_new))?;
    }
    println!("{}", engine.metrics.report());
    Ok(())
}
