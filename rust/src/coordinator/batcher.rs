//! Continuous batcher: the request-level scheduler in front of the engine.
//!
//! Requests enter a queue; the scheduler keeps a set of slots (up to
//! `max_batch`) and advances all resident sequences one token per
//! iteration via [`Engine::decode_step`]. Between steps it admits queued
//! requests into free slots — a sequence joins a *running* decode group
//! the moment a slot opens, each with its own [`SamplingParams`] and
//! [`PolicySpec`] (vLLM-v1-style continuous batching; the old group-static
//! scheduler could only start identical requests together). Cancellation
//! frees a slot mid-decode. tokio is unavailable offline — the runtime is
//! std threads + mpsc channels (DESIGN.md §7).
//!
//! The scheduling logic itself lives in [`SchedCore`], a synchronous
//! deterministic state machine (submit/cancel intake, slot admission,
//! one shared decode step, reaping). Two drivers exist:
//!
//! * [`Batcher`] — the production driver: a thread that blocks on an mpsc
//!   queue, applies the batch-forming grace window, and calls
//!   [`SchedCore::step`] in a loop.
//! * the simulation harness ([`crate::simharness`]) — drives the same
//!   core one discrete step at a time with no threads or timing, and uses
//!   the step-level hooks ([`SchedCore::admit_waiting`],
//!   [`SchedCore::decode_once`], [`SchedCore::live`],
//!   [`SchedCore::group`]) to observe scheduler state between phases and
//!   check invariants.
//!
//! Per-request progress flows over the request's `events` channel:
//! [`SeqEvent::Token`] per accepted token (streaming requests only), then
//! exactly one [`SeqEvent::Done`] with the final [`Response`].

use std::collections::{HashSet, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::engine::{DecodeGroup, Engine, Sequence, StepEvent};
use super::router::PrefixCache;
use super::sampler::SamplingParams;
use crate::policies::PolicySpec;

#[derive(Debug, Clone)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_wait_us: u64,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 4, max_wait_us: 2_000 }
    }
}

pub struct Request {
    pub prompt: String,
    pub policy: PolicySpec,
    pub sp: SamplingParams,
    /// When set, every accepted token is forwarded as [`SeqEvent::Token`];
    /// otherwise only the final [`SeqEvent::Done`] is sent.
    pub stream: bool,
    pub events: Sender<SeqEvent>,
}

/// Per-request progress event (see module docs).
#[derive(Debug, Clone)]
pub enum SeqEvent {
    Token { token: i32, text: String },
    Done(Response),
}

#[derive(Debug, Clone)]
pub struct Response {
    pub text: String,
    pub compression: f64,
    pub tokens_out: usize,
    pub e2e_us: u64,
    pub error: Option<String>,
    /// Engine done reason ("stop" | "max_tokens" | "cache_full" |
    /// "cancelled"); None on transport/build errors.
    pub reason: Option<String>,
}

fn error_response(e2e_us: u64, error: String) -> Response {
    Response {
        text: String::new(),
        compression: 0.0,
        tokens_out: 0,
        e2e_us,
        error: Some(error),
        reason: None,
    }
}

struct Pending {
    id: u64,
    req: Request,
    arrived: Instant,
}

enum Msg {
    Submit(Pending),
    Cancel(u64),
}

struct Slot {
    id: u64,
    req: Request,
    arrived: Instant,
    seq: Sequence,
}

/// The deterministic scheduling core shared by the threaded [`Batcher`]
/// and the simulation harness: request intake, slot admission (prefill),
/// one shared decode step over a persistent [`DecodeGroup`], and reaping
/// of finished sequences. All methods are synchronous; determinism is the
/// caller's to keep (same submit/cancel sequence at the same step
/// boundaries → same token streams, bit for bit).
pub struct SchedCore {
    engine: Arc<Engine>,
    max_batch: usize,
    /// The scheduler's persistent decode session: the backend-resident
    /// group KV cache lives here across steps, so sequences only pay a
    /// scatter when they join and the steady-state step moves one KV row
    /// per sequence.
    group: DecodeGroup,
    slots: Vec<Slot>,
    waiting: VecDeque<Pending>,
    /// Ids cancelled before their Submit was processed.
    cancelled: HashSet<u64>,
    /// Optional shared prefix cache ([`PrefixCache`]): when attached,
    /// admission looks up (prompt, policy) and installs a cached prefill
    /// snapshot on a hit instead of executing the prefill bucket.
    prefix: Option<Arc<PrefixCache>>,
    /// (id, was_hit) per admission since the last drain — the simulation
    /// harness replays the cache protocol and checks these against it.
    prefix_flags: Vec<(u64, bool)>,
}

impl SchedCore {
    /// A fresh scheduler over `engine`. `cfg.max_batch` is clamped so the
    /// scheduler never forms groups larger than the largest decode bucket.
    pub fn new(engine: Arc<Engine>, cfg: BatcherConfig) -> SchedCore {
        let max_bucket =
            engine.rt.manifest.buckets.decode_b.iter().copied().max().unwrap_or(1);
        let group = engine.decode_group();
        SchedCore {
            engine,
            max_batch: cfg.max_batch.clamp(1, max_bucket),
            group,
            slots: vec![],
            waiting: VecDeque::new(),
            cancelled: HashSet::new(),
            prefix: None,
            prefix_flags: vec![],
        }
    }

    /// Attach (or detach) a shared prefix cache; subsequent admissions
    /// consult it before running prefill.
    pub fn set_prefix_cache(&mut self, cache: Option<Arc<PrefixCache>>) {
        self.prefix = cache;
    }

    /// The engine this scheduler drives.
    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }

    /// Drain the per-admission `(id, was_hit)` flags recorded since the
    /// last call. Empty unless a prefix cache is attached.
    pub fn take_prefix_flags(&mut self) -> Vec<(u64, bool)> {
        std::mem::take(&mut self.prefix_flags)
    }

    /// Effective batch cap (after decode-bucket clamping).
    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    /// Enqueue a request under caller-chosen id `id`; progress arrives on
    /// `req.events`. Ids must be unique among in-flight requests.
    pub fn submit(&mut self, id: u64, req: Request) {
        self.enqueue(Pending { id, req, arrived: Instant::now() });
    }

    fn enqueue(&mut self, p: Pending) {
        if self.cancelled.remove(&p.id) {
            respond_cancelled(&p);
        } else {
            self.waiting.push_back(p);
        }
    }

    /// Cancel a request: a resident sequence is freed between decode steps
    /// and its stream receives a final `Done` with reason "cancelled"
    /// (carrying any partial text); a queued request is answered
    /// immediately.
    pub fn cancel(&mut self, id: u64) {
        if let Some(slot) = self.slots.iter_mut().find(|s| s.id == id) {
            slot.seq.cancel(); // freed by the next reap pass
        } else if let Some(i) = self.waiting.iter().position(|p| p.id == id) {
            let p = self.waiting.remove(i).unwrap();
            respond_cancelled(&p);
        } else {
            // The Submit may still be queued behind us; remember the id so
            // it is matched on arrival. Ids of already-finished or bogus
            // requests would linger, so bound the set — dropping ancient
            // entries only un-cancels requests that no longer exist.
            if self.cancelled.len() >= 1024 {
                self.cancelled.clear();
            }
            self.cancelled.insert(id);
        }
    }

    /// No resident and no queued work.
    pub fn is_idle(&self) -> bool {
        self.slots.is_empty() && self.waiting.is_empty()
    }

    /// Resident plus queued request count (the batch-forming driver stops
    /// waiting for companions once this reaches [`SchedCore::max_batch`]).
    pub fn backlog(&self) -> usize {
        self.slots.len() + self.waiting.len()
    }

    /// Slot-resident sequences in slot order, with their request ids.
    /// Includes sequences that finished but have not been reaped yet.
    pub fn live(&self) -> impl Iterator<Item = (u64, &Sequence)> + '_ {
        self.slots.iter().map(|s| (s.id, &s.seq))
    }

    /// The persistent decode-group session (slot residency, capacity).
    pub fn group(&self) -> &DecodeGroup {
        &self.group
    }

    /// Move queued requests into free slots: build the policy, prefill,
    /// and stream the first token. A sequence admitted here decodes
    /// together with whatever is already mid-flight. Returns the ids
    /// admitted (prefill failures are answered with an error response and
    /// not included).
    pub fn admit_waiting(&mut self) -> Vec<u64> {
        let engine = self.engine.clone();
        let mut admitted = vec![];
        while self.slots.len() < self.max_batch && !self.waiting.is_empty() {
            let p = self.waiting.pop_front().unwrap();
            let policy = p.req.policy.build(engine.window());
            let mut seq = engine.sequence(p.id, &p.req.prompt, p.req.sp.clone());
            let prefilled = match &self.prefix {
                None => engine.prefill(&mut seq, policy.as_ref()),
                Some(pc) => {
                    let pkey = p.req.policy.to_string();
                    if let Some(snap) = pc.lookup(&p.req.prompt, &pkey) {
                        // Hit: install the cached post-KVzap prefill state;
                        // the per-request sampler still draws the first
                        // token from the stored logits row, so outputs are
                        // bitwise identical to a fresh prefill.
                        engine.metrics.note_prefix_hit();
                        self.prefix_flags.push((p.id, true));
                        engine.prefill_from_snapshot(&mut seq, &snap)
                    } else {
                        match engine.prefill_with_snapshot(&mut seq, policy.as_ref()) {
                            Ok((events, snap)) => {
                                engine.metrics.note_prefix_miss();
                                self.prefix_flags.push((p.id, false));
                                let out = pc.insert(&p.req.prompt, &pkey, snap);
                                engine.metrics.note_prefix_insert(
                                    out.evicted as u64,
                                    out.raced,
                                    out.rejected,
                                );
                                Ok(events)
                            }
                            Err(e) => Err(e),
                        }
                    }
                }
            };
            match prefilled {
                Ok(events) => {
                    let mut slot = Slot { id: p.id, req: p.req, arrived: p.arrived, seq };
                    dispatch(std::slice::from_mut(&mut slot), &events);
                    admitted.push(slot.id);
                    self.slots.push(slot);
                }
                Err(e) => {
                    let _ = p.req.events.send(SeqEvent::Done(error_response(
                        p.arrived.elapsed().as_micros() as u64,
                        format!("{e:#}"),
                    )));
                }
            }
        }
        admitted
    }

    /// Send final responses for finished sequences and free their slots.
    /// Returns the ids reaped.
    pub fn reap_finished(&mut self) -> Vec<u64> {
        let mut finished = vec![];
        let mut i = 0;
        while i < self.slots.len() {
            if self.slots[i].seq.is_done() {
                let slot = self.slots.remove(i);
                let r = self.engine.finish(&slot.seq);
                let e2e = slot.arrived.elapsed().as_micros() as u64;
                self.engine.metrics.e2e.lock().unwrap().record(e2e);
                let _ = slot.req.events.send(SeqEvent::Done(Response {
                    text: r.text,
                    compression: r.compression,
                    tokens_out: r.tokens_out,
                    e2e_us: e2e,
                    error: None,
                    reason: slot.seq.done_reason().map(|d| d.as_str().to_string()),
                }));
                finished.push(slot.id);
            } else {
                i += 1;
            }
        }
        finished
    }

    /// Advance every resident sequence by one shared decode step and
    /// forward token events to streaming requests. Returns the step's
    /// [`StepEvent`]s. On an engine error every resident request is
    /// answered with an error response, the slots are drained, and the
    /// error is returned.
    pub fn decode_once(&mut self) -> Result<Vec<StepEvent>> {
        if self.slots.is_empty() {
            return Ok(vec![]);
        }
        let engine = self.engine.clone();
        let step = {
            let mut live: Vec<&mut Sequence> =
                self.slots.iter_mut().map(|s| &mut s.seq).collect();
            engine.decode_step(&mut self.group, &mut live)
        };
        match step {
            Ok(events) => {
                dispatch(&mut self.slots, &events);
                Ok(events)
            }
            Err(e) => {
                for slot in self.slots.drain(..) {
                    let _ = slot.req.events.send(SeqEvent::Done(error_response(
                        slot.arrived.elapsed().as_micros() as u64,
                        format!("{e:#}"),
                    )));
                }
                Err(e)
            }
        }
    }

    /// One full scheduler iteration: admit, reap, decode, reap. Engine
    /// errors were already answered to the affected requests and are
    /// swallowed here (the production driver keeps serving).
    pub fn step(&mut self) {
        self.admit_waiting();
        self.reap_finished();
        if self.slots.is_empty() {
            return;
        }
        let _ = self.decode_once();
        self.reap_finished();
    }
}

pub struct Batcher {
    tx: Sender<Msg>,
    next_id: AtomicU64,
    handle: Option<JoinHandle<()>>,
}

impl Batcher {
    pub fn start(engine: Arc<Engine>, cfg: BatcherConfig) -> Batcher {
        Self::start_with_prefix(engine, cfg, None)
    }

    /// [`Batcher::start`] with a (possibly shared) cross-request prefix
    /// cache attached to the scheduler — the sharded server hands every
    /// shard's batcher the same cache.
    pub fn start_with_prefix(
        engine: Arc<Engine>,
        cfg: BatcherConfig,
        prefix: Option<Arc<PrefixCache>>,
    ) -> Batcher {
        let (tx, rx) = mpsc::channel::<Msg>();
        let handle = std::thread::spawn(move || Self::run(engine, cfg, prefix, rx));
        Batcher { tx, next_id: AtomicU64::new(1), handle: Some(handle) }
    }

    /// Enqueue a request; progress arrives on `req.events`. Returns the
    /// batcher-assigned request id (usable with [`Batcher::cancel`]).
    pub fn submit(&self, req: Request) -> Result<u64> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.tx
            .send(Msg::Submit(Pending { id, req, arrived: Instant::now() }))
            .map_err(|_| anyhow::anyhow!("batcher stopped"))?;
        Ok(id)
    }

    /// Cancel a submitted request: its slot is freed between decode steps
    /// and its stream receives a final `Done` with reason "cancelled"
    /// (carrying any partial text).
    pub fn cancel(&self, id: u64) -> Result<()> {
        self.tx.send(Msg::Cancel(id)).map_err(|_| anyhow::anyhow!("batcher stopped"))
    }

    fn run(
        engine: Arc<Engine>,
        cfg: BatcherConfig,
        prefix: Option<Arc<PrefixCache>>,
        rx: Receiver<Msg>,
    ) {
        let mut core = SchedCore::new(engine, cfg.clone());
        core.set_prefix_cache(prefix);
        let mut disconnected = false;
        loop {
            // ---- message intake -------------------------------------------
            if core.is_idle() {
                if disconnected {
                    return;
                }
                match rx.recv() {
                    Ok(msg) => apply(&mut core, msg),
                    Err(_) => return,
                }
                // batch-forming grace: give companions up to max_wait_us to
                // arrive before the first decode step
                let deadline = Instant::now() + Duration::from_micros(cfg.max_wait_us);
                while core.backlog() < core.max_batch() {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(msg) => apply(&mut core, msg),
                        Err(RecvTimeoutError::Timeout) => break,
                        Err(RecvTimeoutError::Disconnected) => {
                            disconnected = true;
                            break;
                        }
                    }
                }
            } else {
                // drain whatever arrived between steps (the slot-join point)
                loop {
                    match rx.try_recv() {
                        Ok(msg) => apply(&mut core, msg),
                        Err(TryRecvError::Empty) => break,
                        Err(TryRecvError::Disconnected) => {
                            disconnected = true;
                            break;
                        }
                    }
                }
            }
            core.step();
        }
    }
}

fn apply(core: &mut SchedCore, msg: Msg) {
    match msg {
        Msg::Submit(p) => core.enqueue(p),
        Msg::Cancel(id) => core.cancel(id),
    }
}

fn respond_cancelled(p: &Pending) {
    let _ = p.req.events.send(SeqEvent::Done(Response {
        text: String::new(),
        compression: 0.0,
        tokens_out: 0,
        e2e_us: p.arrived.elapsed().as_micros() as u64,
        error: None,
        reason: Some("cancelled".into()),
    }));
}

fn dispatch(slots: &mut [Slot], events: &[StepEvent]) {
    for ev in events {
        if let StepEvent::Token { id, token, text, .. } = ev {
            if let Some(slot) = slots.iter_mut().find(|s| s.id == *id) {
                if slot.req.stream
                    && slot
                        .req
                        .events
                        .send(SeqEvent::Token { token: *token, text: text.clone() })
                        .is_err()
                {
                    // client went away: free the slot at the next reap
                    slot.seq.cancel();
                }
            }
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        // Closing `tx` ends the worker loop once resident sequences drain.
        let (dummy_tx, _) = mpsc::channel::<Msg>();
        let tx = std::mem::replace(&mut self.tx, dummy_tx);
        drop(tx);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}
