//! Continuous batcher: the request-level scheduler in front of the engine.
//!
//! Requests enter a queue; a scheduler thread forms decode groups of up to
//! `max_batch` *compatible* requests (same policy spec — they share pruning
//! decisions' configuration, not state) that arrive within `max_wait_us`
//! of the group leader, then runs them through `Engine::generate_batch`.
//! This is vLLM-v0-style group batching; slots of finished sequences stay
//! masked until the group drains (see engine.rs). tokio is unavailable
//! offline — the runtime is std threads + mpsc channels (DESIGN.md §7).

use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::engine::Engine;
use super::sampler::SamplingParams;
use crate::policies;

#[derive(Debug, Clone)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_wait_us: u64,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 4, max_wait_us: 2_000 }
    }
}

pub struct Request {
    pub prompt: String,
    pub policy: String,
    pub sp: SamplingParams,
    pub resp: Sender<Response>,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub text: String,
    pub compression: f64,
    pub tokens_out: usize,
    pub e2e_us: u64,
    pub error: Option<String>,
}

struct Pending {
    req: Request,
    arrived: Instant,
}

pub struct Batcher {
    tx: Sender<Pending>,
    handle: Option<JoinHandle<()>>,
}

impl Batcher {
    pub fn start(engine: Arc<Engine>, cfg: BatcherConfig) -> Batcher {
        let (tx, rx) = mpsc::channel::<Pending>();
        let handle = std::thread::spawn(move || Self::run(engine, cfg, rx));
        Batcher { tx, handle: Some(handle) }
    }

    /// Enqueue a request; the response arrives on `req.resp`.
    pub fn submit(&self, req: Request) -> Result<()> {
        self.tx
            .send(Pending { req, arrived: Instant::now() })
            .map_err(|_| anyhow::anyhow!("batcher stopped"))
    }

    fn run(engine: Arc<Engine>, cfg: BatcherConfig, rx: Receiver<Pending>) {
        loop {
            // Block for the group leader.
            let leader = match rx.recv() {
                Ok(p) => p,
                Err(_) => return, // all senders dropped: shut down
            };
            let mut group = vec![leader];
            let deadline = Instant::now() + Duration::from_micros(cfg.max_wait_us);
            // Fill the group with compatible requests until deadline/full.
            let mut stash: Option<Pending> = None;
            while group.len() < cfg.max_batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(p) => {
                        if p.req.policy == group[0].req.policy
                            && p.req.sp.greedy == group[0].req.sp.greedy
                        {
                            group.push(p);
                        } else {
                            // incompatible: run it as the next group leader
                            stash = Some(p);
                            break;
                        }
                    }
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }
            Self::run_group(&engine, group);
            if let Some(p) = stash {
                Self::run_group(&engine, vec![p]);
            }
        }
    }

    fn run_group(engine: &Engine, group: Vec<Pending>) {
        let policy = match policies::by_name(&group[0].req.policy, engine.window()) {
            Some(p) => p,
            None => {
                for p in &group {
                    let _ = p.req.resp.send(Response {
                        text: String::new(),
                        compression: 0.0,
                        tokens_out: 0,
                        e2e_us: 0,
                        error: Some(format!("unknown policy '{}'", p.req.policy)),
                    });
                }
                return;
            }
        };
        let prompts: Vec<&str> = group.iter().map(|p| p.req.prompt.as_str()).collect();
        let sp = group[0].req.sp.clone();
        match engine.generate_batch(&prompts, policy.as_ref(), &sp) {
            Ok(results) => {
                for (p, r) in group.iter().zip(results) {
                    let e2e = p.arrived.elapsed().as_micros() as u64;
                    engine.metrics.e2e.lock().unwrap().record(e2e);
                    let _ = p.req.resp.send(Response {
                        text: r.text,
                        compression: r.compression,
                        tokens_out: r.tokens_out,
                        e2e_us: e2e,
                        error: None,
                    });
                }
            }
            Err(e) => {
                for p in &group {
                    let _ = p.req.resp.send(Response {
                        text: String::new(),
                        compression: 0.0,
                        tokens_out: 0,
                        e2e_us: p.arrived.elapsed().as_micros() as u64,
                        error: Some(format!("{e:#}")),
                    });
                }
            }
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        // Closing `tx` ends the worker loop once the queue drains.
        // (tx is dropped as part of self; join the worker.)
        let (dummy_tx, _) = mpsc::channel::<Pending>();
        let tx = std::mem::replace(&mut self.tx, dummy_tx);
        drop(tx);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}
