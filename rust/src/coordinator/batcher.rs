//! Continuous batcher: the request-level scheduler in front of the engine.
//!
//! Requests enter a queue; the scheduler thread keeps a set of slots (up
//! to `max_batch`) and advances all resident sequences one token per
//! iteration via [`Engine::decode_step`]. Between steps it admits queued
//! requests into free slots — a sequence joins a *running* decode group
//! the moment a slot opens, each with its own [`SamplingParams`] and
//! [`PolicySpec`] (vLLM-v1-style continuous batching; the old group-static
//! scheduler could only start identical requests together). Cancellation
//! frees a slot mid-decode. tokio is unavailable offline — the runtime is
//! std threads + mpsc channels (DESIGN.md §7).
//!
//! Per-request progress flows over the request's `events` channel:
//! [`SeqEvent::Token`] per accepted token (streaming requests only), then
//! exactly one [`SeqEvent::Done`] with the final [`Response`].

use std::collections::{HashSet, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::engine::{Engine, Sequence, StepEvent};
use super::sampler::SamplingParams;
use crate::policies::PolicySpec;

#[derive(Debug, Clone)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_wait_us: u64,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 4, max_wait_us: 2_000 }
    }
}

pub struct Request {
    pub prompt: String,
    pub policy: PolicySpec,
    pub sp: SamplingParams,
    /// When set, every accepted token is forwarded as [`SeqEvent::Token`];
    /// otherwise only the final [`SeqEvent::Done`] is sent.
    pub stream: bool,
    pub events: Sender<SeqEvent>,
}

/// Per-request progress event (see module docs).
#[derive(Debug, Clone)]
pub enum SeqEvent {
    Token { token: i32, text: String },
    Done(Response),
}

#[derive(Debug, Clone)]
pub struct Response {
    pub text: String,
    pub compression: f64,
    pub tokens_out: usize,
    pub e2e_us: u64,
    pub error: Option<String>,
    /// Engine done reason ("stop" | "max_tokens" | "cache_full" |
    /// "cancelled"); None on transport/build errors.
    pub reason: Option<String>,
}

fn error_response(e2e_us: u64, error: String) -> Response {
    Response {
        text: String::new(),
        compression: 0.0,
        tokens_out: 0,
        e2e_us,
        error: Some(error),
        reason: None,
    }
}

struct Pending {
    id: u64,
    req: Request,
    arrived: Instant,
}

enum Msg {
    Submit(Pending),
    Cancel(u64),
}

struct Slot {
    id: u64,
    req: Request,
    arrived: Instant,
    seq: Sequence,
}

pub struct Batcher {
    tx: Sender<Msg>,
    next_id: AtomicU64,
    handle: Option<JoinHandle<()>>,
}

impl Batcher {
    pub fn start(engine: Arc<Engine>, cfg: BatcherConfig) -> Batcher {
        let (tx, rx) = mpsc::channel::<Msg>();
        // never form groups larger than the largest decode bucket
        let max_bucket =
            engine.rt.manifest.buckets.decode_b.iter().copied().max().unwrap_or(1);
        let cfg = BatcherConfig { max_batch: cfg.max_batch.clamp(1, max_bucket), ..cfg };
        let handle = std::thread::spawn(move || Self::run(engine, cfg, rx));
        Batcher { tx, next_id: AtomicU64::new(1), handle: Some(handle) }
    }

    /// Enqueue a request; progress arrives on `req.events`. Returns the
    /// batcher-assigned request id (usable with [`Batcher::cancel`]).
    pub fn submit(&self, req: Request) -> Result<u64> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.tx
            .send(Msg::Submit(Pending { id, req, arrived: Instant::now() }))
            .map_err(|_| anyhow::anyhow!("batcher stopped"))?;
        Ok(id)
    }

    /// Cancel a submitted request: its slot is freed between decode steps
    /// and its stream receives a final `Done` with reason "cancelled"
    /// (carrying any partial text).
    pub fn cancel(&self, id: u64) -> Result<()> {
        self.tx.send(Msg::Cancel(id)).map_err(|_| anyhow::anyhow!("batcher stopped"))
    }

    fn run(engine: Arc<Engine>, cfg: BatcherConfig, rx: Receiver<Msg>) {
        let mut slots: Vec<Slot> = vec![];
        // the scheduler's persistent decode session: the backend-resident
        // group KV cache lives here across steps, so sequences only pay a
        // scatter when they join and the steady-state step moves one KV
        // row per sequence
        let mut group = engine.decode_group();
        let mut waiting: VecDeque<Pending> = VecDeque::new();
        // ids cancelled before their Submit was processed
        let mut cancelled: HashSet<u64> = HashSet::new();
        let mut disconnected = false;
        loop {
            // ---- message intake -------------------------------------------
            if slots.is_empty() && waiting.is_empty() {
                if disconnected {
                    return;
                }
                match rx.recv() {
                    Ok(msg) => process(msg, &mut slots, &mut waiting, &mut cancelled),
                    Err(_) => return,
                }
                // batch-forming grace: give companions up to max_wait_us to
                // arrive before the first decode step
                let deadline = Instant::now() + Duration::from_micros(cfg.max_wait_us);
                while slots.len() + waiting.len() < cfg.max_batch {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(msg) => process(msg, &mut slots, &mut waiting, &mut cancelled),
                        Err(RecvTimeoutError::Timeout) => break,
                        Err(RecvTimeoutError::Disconnected) => {
                            disconnected = true;
                            break;
                        }
                    }
                }
            } else {
                // drain whatever arrived between steps (the slot-join point)
                loop {
                    match rx.try_recv() {
                        Ok(msg) => process(msg, &mut slots, &mut waiting, &mut cancelled),
                        Err(TryRecvError::Empty) => break,
                        Err(TryRecvError::Disconnected) => {
                            disconnected = true;
                            break;
                        }
                    }
                }
            }
            // ---- admit into free slots, then advance the group ------------
            admit(&engine, &cfg, &mut slots, &mut waiting);
            reap(&engine, &mut slots);
            if slots.is_empty() {
                continue;
            }
            let step = {
                let mut live: Vec<&mut Sequence> =
                    slots.iter_mut().map(|s| &mut s.seq).collect();
                engine.decode_step(&mut group, &mut live)
            };
            match step {
                Ok(events) => dispatch(&mut slots, events),
                Err(e) => {
                    for slot in slots.drain(..) {
                        let _ = slot.req.events.send(SeqEvent::Done(error_response(
                            slot.arrived.elapsed().as_micros() as u64,
                            format!("{e:#}"),
                        )));
                    }
                }
            }
            reap(&engine, &mut slots);
        }
    }
}

fn process(
    msg: Msg,
    slots: &mut [Slot],
    waiting: &mut VecDeque<Pending>,
    cancelled: &mut HashSet<u64>,
) {
    match msg {
        Msg::Submit(p) => {
            if cancelled.remove(&p.id) {
                respond_cancelled(&p);
            } else {
                waiting.push_back(p);
            }
        }
        Msg::Cancel(id) => {
            if let Some(slot) = slots.iter_mut().find(|s| s.id == id) {
                slot.seq.cancel(); // freed by the next reap pass
            } else if let Some(i) = waiting.iter().position(|p| p.id == id) {
                let p = waiting.remove(i).unwrap();
                respond_cancelled(&p);
            } else {
                // The Submit may still be queued behind us; remember the id
                // so it is matched on arrival. Ids of already-finished or
                // bogus requests would linger, so bound the set — dropping
                // ancient entries only un-cancels requests that no longer
                // exist.
                if cancelled.len() >= 1024 {
                    cancelled.clear();
                }
                cancelled.insert(id);
            }
        }
    }
}

fn respond_cancelled(p: &Pending) {
    let _ = p.req.events.send(SeqEvent::Done(Response {
        text: String::new(),
        compression: 0.0,
        tokens_out: 0,
        e2e_us: p.arrived.elapsed().as_micros() as u64,
        error: None,
        reason: Some("cancelled".into()),
    }));
}

/// Move queued requests into free slots: build the policy, prefill, and
/// stream the first token. A sequence admitted here decodes together with
/// whatever is already mid-flight.
fn admit(
    engine: &Engine,
    cfg: &BatcherConfig,
    slots: &mut Vec<Slot>,
    waiting: &mut VecDeque<Pending>,
) {
    while slots.len() < cfg.max_batch && !waiting.is_empty() {
        let p = waiting.pop_front().unwrap();
        let policy = p.req.policy.build(engine.window());
        let mut seq = engine.sequence(p.id, &p.req.prompt, p.req.sp.clone());
        match engine.prefill(&mut seq, policy.as_ref()) {
            Ok(events) => {
                let mut slot = Slot { id: p.id, req: p.req, arrived: p.arrived, seq };
                forward_tokens(&mut slot, events);
                slots.push(slot);
            }
            Err(e) => {
                let _ = p.req.events.send(SeqEvent::Done(error_response(
                    p.arrived.elapsed().as_micros() as u64,
                    format!("{e:#}"),
                )));
            }
        }
    }
}

fn forward_tokens(slot: &mut Slot, events: Vec<StepEvent>) {
    dispatch(std::slice::from_mut(slot), events);
}

fn dispatch(slots: &mut [Slot], events: Vec<StepEvent>) {
    for ev in events {
        if let StepEvent::Token { id, token, text, .. } = ev {
            if let Some(slot) = slots.iter_mut().find(|s| s.id == id) {
                if slot.req.stream
                    && slot.req.events.send(SeqEvent::Token { token, text }).is_err()
                {
                    // client went away: free the slot at the next reap
                    slot.seq.cancel();
                }
            }
        }
    }
}

/// Send final responses for finished sequences and free their slots.
fn reap(engine: &Engine, slots: &mut Vec<Slot>) {
    let mut i = 0;
    while i < slots.len() {
        if slots[i].seq.is_done() {
            let slot = slots.remove(i);
            let r = engine.finish(&slot.seq);
            let e2e = slot.arrived.elapsed().as_micros() as u64;
            engine.metrics.e2e.lock().unwrap().record(e2e);
            let _ = slot.req.events.send(SeqEvent::Done(Response {
                text: r.text,
                compression: r.compression,
                tokens_out: r.tokens_out,
                e2e_us: e2e,
                error: None,
                reason: slot.seq.done_reason().map(|d| d.as_str().to_string()),
            }));
        } else {
            i += 1;
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        // Closing `tx` ends the worker loop once resident sequences drain.
        let (dummy_tx, _) = mpsc::channel::<Msg>();
        let tx = std::mem::replace(&mut self.tx, dummy_tx);
        drop(tx);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}
