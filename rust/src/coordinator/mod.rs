//! L3 coordinator: the serving-system contribution (vLLM-router-shaped).
//!
//! * [`engine`] — prefill → prune → masked-decode generation over the PJRT
//!   artifacts, single or slot-batched.
//! * [`batcher`] — request queue + continuous batcher: groups compatible
//!   requests (same policy) into decode groups within a latency deadline.
//! * [`sampler`] — greedy / temperature / top-k / top-p sampling.
//!
//! KV cache pruning is a first-class feature of the serving path: the
//! engine applies a [`crate::policies::PrunePolicy`] after prefill
//! attention and, for threshold policies (KVzap), keeps pruning during
//! decoding through the sliding-window score buffer.

pub mod batcher;
pub mod engine;
pub mod sampler;

pub use batcher::{Batcher, BatcherConfig, Request, Response};
pub use engine::{Engine, GenResult};
pub use sampler::{Sampler, SamplingParams};
