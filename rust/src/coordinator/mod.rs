//! L3 coordinator: the serving-system contribution (vLLM-router-shaped).
//!
//! * [`engine`] — prefill → prune → masked-decode generation over the
//!   execution backend, exposed as step-level sessions: a [`Sequence`]
//!   state object plus [`Engine::prefill`] / [`Engine::decode_step`]
//!   primitives emitting [`StepEvent`]s, stepping a [`DecodeGroup`] whose
//!   KV cache stays backend-resident across steps.
//!   `generate`/`generate_batch` are thin loops over the same primitives.
//! * [`batcher`] — request queue + continuous batcher: sequences join a
//!   running decode group whenever a slot frees (per-request sampling
//!   params and [`crate::policies::PolicySpec`]), stream token events, and
//!   can be cancelled mid-decode.
//! * [`router`] — the multi-shard coordinator: consistent-hash placement
//!   with load-based spill over N engine workers ([`ShardPool`]), per-
//!   tenant fair-share admission queues, and cross-request prefix reuse
//!   through a shared [`PrefixCache`] of pruned prefill snapshots.
//! * [`sampler`] — greedy / temperature / top-k / top-p sampling.
//!
//! KV cache pruning is a first-class feature of the serving path: the
//! engine applies a [`crate::policies::PrunePolicy`] after prefill
//! attention and, for threshold policies (KVzap), keeps pruning during
//! decoding through the sliding-window score buffer.

pub mod batcher;
pub mod engine;
pub mod router;
pub mod sampler;

pub use batcher::{Batcher, BatcherConfig, Request, Response, SchedCore, SeqEvent};
pub use engine::{
    DecodeGroup, DoneReason, Engine, GenResult, PrefillSnapshot, RescoreMode, Sequence, StepEvent,
};
pub use router::{
    PrefixCache, PrefixCacheStats, PrefixInsertOutcome, Rebalance, Router, RouterConfig,
    ShardPool,
};
pub use sampler::{Sampler, SamplingParams};
