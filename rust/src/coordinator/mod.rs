//! L3 coordinator: the serving-system contribution (vLLM-router-shaped).
//!
//! * [`engine`] — prefill → prune → masked-decode generation over the
//!   execution backend, exposed as step-level sessions: a [`Sequence`]
//!   state object plus [`Engine::prefill`] / [`Engine::decode_step`]
//!   primitives emitting [`StepEvent`]s, stepping a [`DecodeGroup`] whose
//!   KV cache stays backend-resident across steps.
//!   `generate`/`generate_batch` are thin loops over the same primitives.
//! * [`batcher`] — request queue + continuous batcher: sequences join a
//!   running decode group whenever a slot frees (per-request sampling
//!   params and [`crate::policies::PolicySpec`]), stream token events, and
//!   can be cancelled mid-decode.
//! * [`sampler`] — greedy / temperature / top-k / top-p sampling.
//!
//! KV cache pruning is a first-class feature of the serving path: the
//! engine applies a [`crate::policies::PrunePolicy`] after prefill
//! attention and, for threshold policies (KVzap), keeps pruning during
//! decoding through the sliding-window score buffer.

pub mod batcher;
pub mod engine;
pub mod sampler;

pub use batcher::{Batcher, BatcherConfig, Request, Response, SchedCore, SeqEvent};
pub use engine::{DecodeGroup, DoneReason, Engine, GenResult, Sequence, StepEvent};
pub use sampler::{Sampler, SamplingParams};
