//! The generation engine: prefill → prune → masked decode, per sequence or
//! slot-batched. This is the request hot path — python never runs here.
//!
//! The engine is backend-generic: it only sees the [`Runtime`] facade and
//! opaque [`Buffer`]s, so the same code path drives the hermetic reference
//! backend and the PJRT artifacts. Data movement per decode step (see
//! DESIGN.md §Perf): the KV cache lives in backend buffers produced by the
//! previous step (untupled outputs); the host only uploads the new token
//! ids + positions and, when a pruning decision changed it, the keep-mask;
//! it downloads logits `[B, V]` and, for threshold policies, the per-step
//! surrogate scores `[L, B, H]`.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use super::sampler::{Sampler, SamplingParams};
use crate::kvcache::PagedKvCache;
use crate::metrics::EngineMetrics;
use crate::policies::{PrefillView, PrunePolicy, ScoreBuffer, Stat};
use crate::runtime::{Arg, Buffer, Runtime, Tensor};
use crate::workload::ByteTokenizer;

pub struct Engine {
    pub rt: Arc<Runtime>,
    pub tok: ByteTokenizer,
    pub metrics: EngineMetrics,
}

/// -log softmax(logits)[target] in nats.
fn nll_of(logits: &[f32], target: i32) -> f64 {
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let lse: f64 = logits.iter().map(|&x| ((x as f64) - m).exp()).sum::<f64>().ln() + m;
    lse - logits[target as usize] as f64
}

#[derive(Debug, Clone)]
pub struct GenResult {
    pub text: String,
    pub prompt_len: usize,
    pub tokens_out: usize,
    /// Removed fraction of the KV cache at end of generation (the paper's
    /// "compression ratio (removed fraction)", Table 2).
    pub compression: f64,
    pub prefill_us: u64,
    pub oracle_us: u64,
    pub decode_us: u64,
    pub policy_us: u64,
    pub decode_evictions: usize,
}

struct PrefillStats {
    score_lin: Tensor,
    score_mlp: Tensor,
    max_attn: Tensor,
    plus_attn: Tensor,
    cum_attn: Tensor,
    win_attn: Tensor,
    vnorm: Tensor,
    knorm: Tensor,
}

impl PrefillStats {
    fn view<'a>(
        &'a self,
        b: usize,
        oracle: Option<&'a (Tensor, Tensor)>,
    ) -> PrefillView<'a> {
        PrefillView {
            b,
            score_lin: &self.score_lin,
            score_mlp: &self.score_mlp,
            max_attn: &self.max_attn,
            plus_attn: &self.plus_attn,
            cum_attn: &self.cum_attn,
            win_attn: &self.win_attn,
            vnorm: &self.vnorm,
            knorm: &self.knorm,
            oracle_s: oracle.map(|o| &o.0),
            oracle_s_plus: oracle.map(|o| &o.1),
        }
    }
}

impl Engine {
    pub fn new(rt: Arc<Runtime>) -> Engine {
        Engine { rt, tok: ByteTokenizer::default(), metrics: EngineMetrics::default() }
    }

    pub fn window(&self) -> usize {
        self.rt.manifest.window
    }

    /// Largest prompt (in tokens incl. BOS) the artifacts can prefill.
    pub fn max_prompt(&self) -> usize {
        *self.rt.manifest.buckets.prefill_t.iter().max().unwrap()
    }

    /// Generate for a single prompt (B=1 decode path).
    pub fn generate(
        &self,
        prompt: &str,
        policy: &dyn PrunePolicy,
        sp: &SamplingParams,
    ) -> Result<GenResult> {
        let mut rs = self.generate_batch(&[prompt], policy, sp)?;
        Ok(rs.pop().unwrap())
    }

    /// KVzip oracle double pass for one prompt: returns (s, s+) `[L,1,H,T]`.
    fn oracle_scores(&self, tokens: &[i32]) -> Result<(Tensor, Tensor)> {
        let man = &self.rt.manifest;
        let bucket = man
            .kvzip_bucket(tokens.len())
            .ok_or_else(|| anyhow!("no kvzip bucket for len {}", tokens.len()))?;
        let art = self.rt.artifact(&bucket)?;
        let t = art.meta.t;
        let mut padded = vec![self.tok.pad as i32; t];
        padded[..tokens.len()].copy_from_slice(tokens);
        let lens = [tokens.len() as i32];
        let outs = self.rt.exec(&art, &[Arg::I32(&padded, &[1, t]), Arg::I32(&lens, &[1])])?;
        let si = art.meta.output_index("s")?;
        let pi = art.meta.output_index("s_plus")?;
        Ok((
            self.rt.fetch_f32(&outs[si], &art.meta.outputs[si].shape)?,
            self.rt.fetch_f32(&outs[pi], &art.meta.outputs[pi].shape)?,
        ))
    }

    /// Teacher-forced answer scoring: mean NLL (nats/byte) of `answer`
    /// given `prompt` under the pruned cache. This is the smooth quality
    /// metric the benches report alongside exact-match accuracy — it
    /// degrades gracefully as pruning removes needed KV pairs, so the
    /// policy ranking is measurable at any model quality.
    pub fn score_answer(
        &self,
        prompt: &str,
        answer: &str,
        policy: &dyn PrunePolicy,
    ) -> Result<(f64, f64)> {
        let man = &self.rt.manifest;
        let (layers, heads, t_max) =
            (man.model.n_layers, man.model.n_kv_heads, man.model.t_max);
        let toks = self.tok.encode(prompt, self.max_prompt());
        let n = toks.len();
        let ans: Vec<i32> = answer.bytes().map(|b| b as i32).collect();
        let bucket = man
            .prefill_bucket(n, 1)
            .ok_or_else(|| anyhow!("no prefill bucket for {n}"))?;
        let pf = self.rt.artifact(&bucket)?;
        let pt = pf.meta.t;
        let mut tok_flat = vec![self.tok.pad as i32; pt];
        tok_flat[..n].copy_from_slice(&toks);
        let lens = [n as i32];
        let outs =
            self.rt.exec(&pf, &[Arg::I32(&tok_flat, &[1, pt]), Arg::I32(&lens, &[1])])?;
        let fetch = |name: &str| -> Result<Tensor> {
            let i = pf.meta.output_index(name)?;
            self.rt.fetch_f32(&outs[i], &pf.meta.outputs[i].shape)
        };
        let logits0 = fetch("logits")?;
        let stats = PrefillStats {
            score_lin: fetch("score_lin")?,
            score_mlp: fetch("score_mlp")?,
            max_attn: fetch("max_attn")?,
            plus_attn: fetch("plus_attn")?,
            cum_attn: fetch("cum_attn")?,
            win_attn: fetch("win_attn")?,
            vnorm: fetch("vnorm")?,
            knorm: fetch("knorm")?,
        };
        let oracle = if policy.needs_oracle() {
            Some(self.oracle_scores(&toks)?)
        } else {
            None
        };
        let mut cache = PagedKvCache::new(layers, heads, t_max);
        cache.fill(n);
        policy.prefill_prune(&stats.view(0, oracle.as_ref()), n, &mut cache);
        let compression = cache.stats().compression();

        let ki = pf.meta.output_index("kcache")?;
        let vi = pf.meta.output_index("vcache")?;
        let mut outs_opt: Vec<Option<Buffer>> = outs.into_iter().map(Some).collect();
        let mut kc = outs_opt[ki].take().unwrap();
        let mut vc = outs_opt[vi].take().unwrap();
        drop(outs_opt);

        let dec = self.rt.artifact(&man.decode_bucket(1).unwrap())?;
        let mut mask = cache.mask_f32();

        // NLL of answer byte i under logits from step i-1 (teacher forcing).
        let mut nll = 0.0f64;
        let mut count = 0usize;
        let mut logits = logits0;
        for (i, &a) in ans.iter().enumerate() {
            nll += nll_of(logits.row(&[0]), a);
            count += 1;
            let pos = n + i;
            if pos >= t_max || i == ans.len() - 1 {
                break;
            }
            // previously fed answer tokens become attendable
            if i > 0 {
                for l in 0..layers {
                    for h in 0..heads {
                        mask[(l * heads + h) * t_max + pos - 1] = 1.0;
                    }
                }
            }
            let mask_buf = self.rt.upload_f32(&mask, &[layers, 1, heads, t_max])?;
            let outs = self.rt.exec(
                &dec,
                &[
                    Arg::I32(&[a], &[1]),
                    Arg::I32(&[pos as i32], &[1]),
                    Arg::Buf(&kc),
                    Arg::Buf(&vc),
                    Arg::Buf(&mask_buf),
                ],
            )?;
            let li = dec.meta.output_index("logits")?;
            logits = self.rt.fetch_f32(&outs[li], &dec.meta.outputs[li].shape)?;
            let ki = dec.meta.output_index("kcache")?;
            let vi = dec.meta.output_index("vcache")?;
            let mut o: Vec<Option<Buffer>> = outs.into_iter().map(Some).collect();
            kc = o[ki].take().unwrap();
            vc = o[vi].take().unwrap();
        }
        Ok((nll / count.max(1) as f64, compression))
    }

    /// Slot-batched generation: prompts share a prefill bucket and decode
    /// together; sequences that finish keep their slot masked until the
    /// group drains (group-static continuous batching — the batcher forms
    /// the groups, see batcher.rs).
    pub fn generate_batch(
        &self,
        prompts: &[&str],
        policy: &dyn PrunePolicy,
        sp: &SamplingParams,
    ) -> Result<Vec<GenResult>> {
        let man = &self.rt.manifest;
        let (layers, heads, t_max) =
            (man.model.n_layers, man.model.n_kv_heads, man.model.t_max);
        let nb = prompts.len();
        assert!(nb > 0);

        // ---- tokenize + bucket -------------------------------------------
        let toks: Vec<Vec<i32>> =
            prompts.iter().map(|p| self.tok.encode(p, self.max_prompt())).collect();
        let maxlen = toks.iter().map(|t| t.len()).max().unwrap();
        let bucket = man
            .prefill_bucket(maxlen, nb)
            .ok_or_else(|| anyhow!("no prefill bucket for len {maxlen} batch {nb}"))?;
        let pf = self.rt.artifact(&bucket)?;
        let (pb, pt) = (pf.meta.batch, pf.meta.t);
        let dec = self.rt.artifact(
            &man.decode_bucket(nb).ok_or_else(|| anyhow!("no decode bucket for {nb}"))?,
        )?;
        let db = dec.meta.batch;
        if db != pb {
            return Err(anyhow!("bucket mismatch: prefill b{pb} vs decode b{db}"));
        }

        let mut tok_flat = vec![self.tok.pad as i32; pb * pt];
        let mut lens = vec![1i32; pb];
        for (i, t) in toks.iter().enumerate() {
            tok_flat[i * pt..i * pt + t.len()].copy_from_slice(t);
            lens[i] = t.len() as i32;
        }

        // ---- prefill ------------------------------------------------------
        let t0 = crate::util::now_micros();
        let outs =
            self.rt.exec(&pf, &[Arg::I32(&tok_flat, &[pb, pt]), Arg::I32(&lens, &[pb])])?;
        let prefill_us = crate::util::now_micros() - t0;
        self.metrics.prefill.lock().unwrap().record(prefill_us);

        let fetch = |name: &str| -> Result<Tensor> {
            let i = pf.meta.output_index(name)?;
            self.rt.fetch_f32(&outs[i], &pf.meta.outputs[i].shape)
        };
        let logits0 = fetch("logits")?;
        let stats = PrefillStats {
            score_lin: fetch("score_lin")?,
            score_mlp: fetch("score_mlp")?,
            max_attn: fetch("max_attn")?,
            plus_attn: fetch("plus_attn")?,
            cum_attn: fetch("cum_attn")?,
            win_attn: fetch("win_attn")?,
            vnorm: fetch("vnorm")?,
            knorm: fetch("knorm")?,
        };
        let ki = pf.meta.output_index("kcache")?;
        let vi = pf.meta.output_index("vcache")?;
        let mut outs_opt: Vec<Option<Buffer>> = outs.into_iter().map(Some).collect();
        let mut kc = outs_opt[ki].take().unwrap();
        let mut vc = outs_opt[vi].take().unwrap();
        drop(outs_opt);

        // ---- oracle pass (KVzip / KVzip+ baselines only) -------------------
        let mut oracle: Vec<Option<(Tensor, Tensor)>> = (0..nb).map(|_| None).collect();
        let mut oracle_us = 0;
        if policy.needs_oracle() {
            let t0 = crate::util::now_micros();
            for (b, t) in toks.iter().enumerate() {
                oracle[b] = Some(self.oracle_scores(t)?);
            }
            oracle_us = crate::util::now_micros() - t0;
            self.metrics.oracle.lock().unwrap().record(oracle_us);
        }

        // ---- prune after prefill -------------------------------------------
        let t0 = crate::util::now_micros();
        let mut caches: Vec<PagedKvCache> =
            (0..nb).map(|_| PagedKvCache::new(layers, heads, t_max)).collect();
        for b in 0..nb {
            caches[b].fill(lens[b] as usize);
            let view = stats.view(b, oracle[b].as_ref());
            policy.prefill_prune(&view, lens[b] as usize, &mut caches[b]);
        }
        let mut policy_us = crate::util::now_micros() - t0;

        // ---- score buffers (threshold policies prune during decode) --------
        let tau = policy.decode_threshold();
        let dstat = policy.decode_stat();
        let window = self.window();
        let mut sbufs: Vec<ScoreBuffer> = (0..nb)
            .map(|b| {
                let mut sb = ScoreBuffer::new(window, layers, heads);
                if tau.is_some() {
                    let view = stats.view(b, None);
                    sb.seed_from_prefill(lens[b] as usize, |l, h, pos| {
                        view.row(dstat, l, h)[pos]
                    });
                }
                sb
            })
            .collect();

        // ---- decode loop -----------------------------------------------------
        let mut samplers: Vec<Sampler> =
            (0..nb).map(|b| Sampler::new(sp.seed.wrapping_add(b as u64 * 7919))).collect();
        let mut generated: Vec<Vec<i32>> = vec![vec![]; nb];
        let mut done = vec![false; nb];
        let mut evictions = vec![0usize; nb];
        let mut cur = vec![self.tok.pad as i32; db];
        let mut pos: Vec<usize> = (0..db).map(|b| {
            if b < nb { lens[b] as usize } else { t_max - 1 }
        }).collect();

        // first token comes from the prefill logits
        for b in 0..nb {
            let t = samplers[b].sample(logits0.row(&[b]), sp);
            if self.tok.is_stop(t, sp.stop_at_newline) {
                done[b] = true;
            } else {
                generated[b].push(t);
                cur[b] = t;
            }
        }

        let mask_dims = [layers, db, heads, t_max];
        let mut mask = vec![0.0f32; layers * db * heads * t_max];
        let rebuild_mask =
            |mask: &mut Vec<f32>, caches: &[PagedKvCache]| {
                for (b, cache) in caches.iter().enumerate() {
                    let m = cache.mask_f32(); // [L, H, t_max]
                    for l in 0..layers {
                        for h in 0..heads {
                            let src = &m[(l * heads + h) * t_max..][..t_max];
                            let off = ((l * db + b) * heads + h) * t_max;
                            mask[off..off + t_max].copy_from_slice(src);
                        }
                    }
                }
            };
        rebuild_mask(&mut mask, &caches);
        let mut mask_dirty = true;

        let t_dec = crate::util::now_micros();
        let mut steps = 0usize;
        let mut mask_buf: Option<Buffer> = None;
        while steps < sp.max_new.saturating_sub(1) && done.iter().any(|d| !d) {
            // stop sequences that would overflow the cache
            for b in 0..nb {
                if !done[b] && pos[b] >= t_max {
                    done[b] = true;
                }
            }
            if done.iter().all(|d| *d) {
                break;
            }
            let pos_i32: Vec<i32> =
                pos.iter().map(|&p| (p.min(t_max - 1)) as i32).collect();
            if mask_dirty {
                mask_buf = Some(self.rt.upload_f32(&mask, &mask_dims)?);
                mask_dirty = false;
            }
            let outs = self.rt.exec(
                &dec,
                &[
                    Arg::I32(&cur, &[db]),
                    Arg::I32(&pos_i32, &[db]),
                    Arg::Buf(&kc),
                    Arg::Buf(&vc),
                    Arg::Buf(mask_buf.as_ref().unwrap()),
                ],
            )?;
            let li = dec.meta.output_index("logits")?;
            let logits = self.rt.fetch_f32(&outs[li], &dec.meta.outputs[li].shape)?;
            let scores = if tau.is_some() {
                let name = match dstat {
                    Stat::ScoreLin => "score_lin",
                    _ => "score_mlp",
                };
                let i = dec.meta.output_index(name)?;
                Some(self.rt.fetch_f32(&outs[i], &dec.meta.outputs[i].shape)?)
            } else {
                None
            };
            let ki = dec.meta.output_index("kcache")?;
            let vi = dec.meta.output_index("vcache")?;
            let mut outs_opt: Vec<Option<Buffer>> = outs.into_iter().map(Some).collect();
            kc = outs_opt[ki].take().unwrap();
            vc = outs_opt[vi].take().unwrap();
            drop(outs_opt);

            for b in 0..nb {
                if done[b] {
                    continue;
                }
                // the token we just fed occupies pos[b]
                caches[b].fill((pos[b] + 1).min(t_max));
                if let (Some(tau), Some(sc)) = (tau, scores.as_ref()) {
                    // sc is [L, B, H]: collect this sequence's row
                    let mut v = Vec::with_capacity(layers * heads);
                    for l in 0..layers {
                        for h in 0..heads {
                            v.push(sc.at(&[l, b, h]));
                        }
                    }
                    let t0 = crate::util::now_micros();
                    evictions[b] += sbufs[b].push_and_evict(pos[b], v, tau, &mut caches[b]);
                    policy_us += crate::util::now_micros() - t0;
                }
                if caches[b].take_dirty() {
                    mask_dirty = true;
                }
                let t = samplers[b].sample(logits.row(&[b]), sp);
                pos[b] += 1;
                if self.tok.is_stop(t, sp.stop_at_newline)
                    || generated[b].len() + 1 >= sp.max_new
                {
                    done[b] = true;
                } else {
                    generated[b].push(t);
                    cur[b] = t;
                }
            }
            if mask_dirty {
                rebuild_mask(&mut mask, &caches);
            }
            steps += 1;
        }
        let decode_us = crate::util::now_micros() - t_dec;
        if steps > 0 {
            self.metrics.decode_step.lock().unwrap().record(decode_us / steps as u64);
        }

        // ---- results ----------------------------------------------------------
        let mut results = vec![];
        for b in 0..nb {
            let st = caches[b].stats();
            self.metrics.note_request(generated[b].len(), st.compression());
            results.push(GenResult {
                text: self.tok.decode(&generated[b]),
                prompt_len: lens[b] as usize,
                tokens_out: generated[b].len(),
                compression: st.compression(),
                prefill_us,
                oracle_us,
                decode_us,
                policy_us,
                decode_evictions: evictions[b],
            });
        }
        Ok(results)
    }
}
